"""DST (discrete state transition) semantics — eqs. (13)-(20), Fig. 3.

The Pallas kernel must match the oracle bit-for-bit; the oracle itself is
checked against the paper's transition table (six ternary cases of Fig. 3),
the grid-closure invariant, and the tau transition statistics of eq. (20).
The same vectors are exported for the Rust twin (see
rust/src/ternary/dst.rs tests, which hard-code the identical cases).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import dst as dk, ref

jax.config.update("jax_platform_name", "cpu")


def uniforms(shape, seed):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape)


def on_grid(w, dz):
    w = np.asarray(w)
    return np.allclose(w / dz, np.round(w / dz), atol=1e-5) and np.abs(w).max() <= 1 + 1e-6


class TestOracleDST:
    def test_fig3_six_ternary_cases(self):
        """Fig. 3: from state 0 and the boundaries, with dw of either sign."""
        dz, m = 1.0, 3.0
        # u = 0 forces the hop whenever tau > 0; u = 1 forbids it.
        cases = [
            # (w, dw, u, expected)
            (0.0, 0.4, 0.0, 1.0),    # 0 --tau--> +1
            (0.0, 0.4, 1.0, 0.0),    # 0 stays
            (0.0, -0.4, 0.0, -1.0),  # 0 --tau--> -1
            (0.0, -0.4, 1.0, 0.0),
            (-1.0, -0.7, 0.0, -1.0),  # boundary: rho = 0, stays w.p. 1
            (-1.0, 0.4, 0.0, 0.0),    # kappa=0: -1 -> 0 w.p. tau
            (-1.0, 1.2, 0.0, 1.0),    # kappa=1: -1 -> 1 w.p. tau
            (-1.0, 1.2, 1.0, 0.0),    # kappa=1, no hop: -1 -> 0
            (1.0, 0.5, 0.0, 1.0),     # boundary: rho = 0
            (1.0, -0.4, 0.0, 0.0),    # 1 -> 0 w.p. tau
        ]
        for w, dw, u, want in cases:
            got = float(
                ref.dst_update(
                    jnp.array([w]), jnp.array([dw]), jnp.array([u]), dz, m
                )[0]
            )
            assert got == want, f"w={w} dw={dw} u={u}: got {got}, want {want}"

    def test_zero_increment_is_identity(self):
        w = jnp.array([-1.0, 0.0, 1.0])
        got = ref.dst_update(w, jnp.zeros(3), jnp.zeros(3), 1.0, 3.0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(w))

    @settings(max_examples=30, deadline=None)
    @given(
        n=st.integers(1, 6),
        scale=st.floats(0.01, 5.0),
        seed=st.integers(0, 2**30),
    )
    def test_grid_closure(self, n, scale, seed):
        """W(k) on Z_N and any dw => W(k+1) on Z_N, inside [-1, 1]."""
        dz = ref.delta_z(n)
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        states = jax.random.randint(k1, (512,), 0, 2 ** n + 1)
        w = states.astype(jnp.float32) * dz - 1.0
        dw = jax.random.normal(k2, (512,)) * scale
        u = jax.random.uniform(k3, (512,))
        w2 = ref.dst_update(w, dw, u, dz, 3.0)
        assert on_grid(w2, dz)

    def test_transition_probability_matches_tau(self):
        """Empirical hop frequency ~= tanh(m|nu|/dz) (eq. 20)."""
        dz, m, nu = 1.0, 3.0, 0.37
        n = 200_000
        w = jnp.zeros(n)
        dw = jnp.full((n,), nu)
        u = uniforms((n,), 9)
        w2 = np.asarray(ref.dst_update(w, dw, u, dz, m))
        freq = (w2 == 1.0).mean()
        tau = float(np.tanh(m * nu / dz))
        assert abs(freq - tau) < 5e-3, (freq, tau)

    def test_kappa_hops_deterministic(self):
        """|rho| >= dz hops floor(|rho|/dz) states deterministically."""
        dz = 0.25  # N = 3
        w = jnp.array([-1.0])
        dw = jnp.array([0.5])  # kappa = 2, nu = 0
        got = float(ref.dst_update(w, dw, jnp.array([0.999]), dz, 3.0)[0])
        assert got == -0.5

    def test_boundary_clamp_rho(self):
        """eq. 13: increments never push past +-1."""
        w = jnp.array([1.0, -1.0, 0.5])
        dw = jnp.array([10.0, -10.0, 10.0])
        u = jnp.zeros(3)
        got = np.asarray(ref.dst_update(w, dw, u, 0.5, 3.0))
        np.testing.assert_array_equal(got, [1.0, -1.0, 1.0])

    def test_rho_decomposition_signs(self):
        """rem keeps the sign of rho (eq. 16) => hops follow sign(rho)."""
        dz = 1.0
        got = float(ref.dst_update(jnp.array([1.0]), jnp.array([-0.6]), jnp.array([0.0]), dz, 3.0)[0])
        assert got == 0.0  # negative nu hops downward


class TestPallasDST:
    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 6),
        size=st.integers(1, 5000),
        scale=st.floats(0.01, 3.0),
        seed=st.integers(0, 2**30),
    )
    def test_matches_oracle(self, n, size, scale, seed):
        dz = ref.delta_z(n)
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        states = jax.random.randint(k1, (size,), 0, 2 ** n + 1)
        w = states.astype(jnp.float32) * dz - 1.0
        dw = jax.random.normal(k2, (size,)) * scale
        u = jax.random.uniform(k3, (size,))
        got = dk.dst_update(w, dw, u, dz, 3.0)
        want = ref.dst_update(w, dw, u, dz, 3.0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_2d_shape_preserved(self):
        w = jnp.zeros((37, 53))
        dw = jnp.full((37, 53), 0.3)
        u = uniforms((37, 53), 2)
        got = dk.dst_update(w, dw, u, 1.0, 3.0)
        assert got.shape == (37, 53)
