"""L2 graph correctness: shapes, gradients, BN, loss, end-to-end learning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def blobs(key, batch, dim, n_classes=10, noise=0.3):
    kc, ky, kn = jax.random.split(key, 3)
    cents = jax.random.normal(kc, (n_classes, dim)) * 0.5
    y = jax.random.randint(ky, (batch,), 0, n_classes)
    x = cents[y] + jax.random.normal(kn, (batch, dim)) * noise
    return jnp.clip(x, -1, 1), y


class TestArch:
    def test_mlp_shapes(self):
        arch = model.build_arch("mlp")
        pds, sds = model.param_descs(arch)
        assert [p.name for p in pds] == [
            "W0", "gamma0", "beta0", "W1", "gamma1", "beta1", "W2",
        ]
        assert pds[0].shape == (784, 512)
        assert [s.name for s in sds] == ["rmean0", "rvar0", "rmean1", "rvar1"]

    def test_cnn_mnist_paper_topology(self):
        """32C5-MP2-64C5-MP2-512FC-SVM with VALID conv: 28->24->12->8->4."""
        arch = model.build_arch("cnn_mnist")
        pds, _ = model.param_descs(arch)
        w = {p.name: p.shape for p in pds if p.kind == "weight"}
        assert w["W0"] == (5, 5, 1, 32)
        assert w["W1"] == (5, 5, 32, 64)
        assert w["W2"] == (64 * 4 * 4, 512)
        assert w["W3"] == (512, 10)

    def test_cnn_cifar_paper_topology_full_width(self):
        arch = model.build_arch("cnn_cifar", width=1.0)
        pds, _ = model.param_descs(arch)
        w = [p.shape for p in pds if p.kind == "weight"]
        assert w[0] == (3, 3, 3, 128)
        assert w[5] == (3, 3, 512, 512)
        assert w[6] == (512 * 4 * 4, 1024)

    def test_width_scaling(self):
        arch = model.build_arch("cnn_cifar", width=0.25)
        pds, _ = model.param_descs(arch)
        assert pds[0].shape == (3, 3, 3, 32)

    def test_init_on_grid(self):
        arch = model.build_arch("mlp")
        for n1 in (0, 1, 3):
            params, state = model.init_params(arch, jax.random.PRNGKey(0), n1=n1)
            dz = ref.delta_z(n1)
            w0 = np.asarray(params[0])
            # Z_N states are n*dz - 1: offset-grid membership (N=0 states
            # {-1,1} are not multiples of dz=2, but (w+1)/dz is integral).
            k = (w0 + 1.0) / dz
            assert np.allclose(k, np.round(k), atol=1e-6)
            assert np.abs(w0).max() <= 1.0
            # not degenerate: at least two distinct states present
            assert len(np.unique(w0)) >= 2

    def test_init_binary_has_no_zero(self):
        arch = model.build_arch("mlp")
        params, _ = model.init_params(arch, jax.random.PRNGKey(1), n1=0)
        assert set(np.unique(np.asarray(params[0]))) == {-1.0, 1.0}


class TestLoss:
    def test_hinge_zero_when_confident(self):
        logits = jnp.array([[5.0, -5.0], [-5.0, 5.0]])
        labels = jnp.array([0, 1])
        assert float(model.svm_hinge_loss(logits, labels, 2)) == 0.0

    def test_hinge_value(self):
        logits = jnp.zeros((1, 10))
        labels = jnp.array([3])
        # every margin is max(0, 1-0)^2 = 1, summed over 10 classes
        assert float(model.svm_hinge_loss(logits, labels, 10)) == 10.0

    def test_hinge_grad_direction(self):
        labels = jnp.array([0])
        g = jax.grad(lambda o: model.svm_hinge_loss(o, labels, 3))(jnp.zeros((1, 3)))
        g = np.asarray(g)[0]
        assert g[0] < 0 and g[1] > 0 and g[2] > 0


class TestTrainStep:
    @pytest.mark.parametrize("mode", ["fp", "bin", "multi"])
    def test_output_arity_and_shapes(self, mode):
        arch = model.build_arch("mlp")
        params, state = model.init_params(arch, jax.random.PRNGKey(0))
        pds, sds = model.param_descs(arch)
        x, y = blobs(jax.random.PRNGKey(1), 16, 784)
        out = jax.jit(model.make_train_step(arch, mode, use_pallas=False))(
            x, y, 0.5, 0.5, 1.0, *params, *state
        )
        assert len(out) == 3 + len(pds) + len(sds)
        loss, nc, spars = out[0], out[1], out[2]
        assert loss.shape == () and nc.shape == () and spars.shape == (2,)
        for pd, g in zip(pds, out[3 : 3 + len(pds)]):
            assert g.shape == pd.shape, pd.name

    def test_fp_gradients_match_finite_differences(self):
        arch = model.Arch("tiny", (6,), (model.Dense(6, 4), model.Dense(4, 3)), 3)
        params, state = model.init_params(arch, jax.random.PRNGKey(0), n1=4)
        x, y = blobs(jax.random.PRNGKey(2), 8, 6, n_classes=3)
        step = model.make_train_step(arch, "fp", use_pallas=False)
        out = step(x, y, 0.5, 0.5, 1.0, *params, *state)
        g_w0 = np.asarray(out[3])

        def loss_at(w0):
            ps = [w0] + list(params[1:])
            o = step(x, y, 0.5, 0.5, 1.0, *ps, *state)
            return float(o[0])

        eps = 1e-3
        for idx in [(0, 0), (3, 2), (5, 3)]:
            w0p = params[0].at[idx].add(eps)
            w0m = params[0].at[idx].add(-eps)
            fd = (loss_at(w0p) - loss_at(w0m)) / (2 * eps)
            assert abs(fd - g_w0[idx]) < 5e-3, (idx, fd, g_w0[idx])

    def test_ternary_weight_grad_uses_ste_window(self):
        """With r=a and rect window, grads vanish iff preacts far from jumps."""
        arch = model.build_arch("mlp")
        params, state = model.init_params(arch, jax.random.PRNGKey(0))
        x, y = blobs(jax.random.PRNGKey(3), 16, 784)
        step = jax.jit(model.make_train_step(arch, "multi", use_pallas=False))
        out = step(x, y, 0.5, 0.5, 1.0, *params, *state)
        g_w0 = np.asarray(out[3])
        assert np.isfinite(g_w0).all()
        assert np.abs(g_w0).sum() > 0

    def test_bn_state_update_moves_toward_batch(self):
        arch = model.build_arch("mlp")
        params, state = model.init_params(arch, jax.random.PRNGKey(0))
        pds, sds = model.param_descs(arch)
        x, y = blobs(jax.random.PRNGKey(4), 32, 784)
        out = model.make_train_step(arch, "multi", use_pallas=False)(
            x, y, 0.5, 0.5, 1.0, *params, *state
        )
        new_state = out[3 + len(pds) :]
        # rmean0 starts at 0; any signal moves it
        assert np.abs(np.asarray(new_state[0])).sum() > 0
        # rvar stays positive
        assert np.asarray(new_state[1]).min() > 0

    def test_sparsity_in_unit_interval_and_responds_to_r(self):
        arch = model.build_arch("mlp")
        params, state = model.init_params(arch, jax.random.PRNGKey(0))
        x, y = blobs(jax.random.PRNGKey(5), 32, 784)
        step = jax.jit(model.make_train_step(arch, "multi", use_pallas=False))
        s_small = np.asarray(step(x, y, 0.1, 0.5, 1.0, *params, *state)[2])
        s_large = np.asarray(step(x, y, 0.9, 0.5, 1.0, *params, *state)[2])
        assert (0 <= s_small).all() and (s_small <= 1).all()
        assert (s_large >= s_small - 1e-6).all()
        assert s_large.mean() > s_small.mean()


class TestInfer:
    def test_infer_uses_running_stats(self):
        arch = model.build_arch("mlp")
        params, state = model.init_params(arch, jax.random.PRNGKey(0))
        x, _ = blobs(jax.random.PRNGKey(6), 16, 784)
        infer = jax.jit(model.make_infer(arch, "multi", use_pallas=False))
        logits1, spars = infer(x, 0.5, 1.0, *params, *state)
        assert logits1.shape == (16, 10)
        # different running stats -> different logits
        state2 = [s + 0.5 for s in state]
        logits2, _ = infer(x, 0.5, 1.0, *params, *state2)
        assert not np.allclose(np.asarray(logits1), np.asarray(logits2))

    def test_batch_independence(self):
        """Inference is per-sample: row i doesn't depend on other rows."""
        arch = model.build_arch("mlp")
        params, state = model.init_params(arch, jax.random.PRNGKey(0))
        infer = jax.jit(model.make_infer(arch, "multi", use_pallas=False))
        x, _ = blobs(jax.random.PRNGKey(7), 16, 784)
        full, _ = infer(x, 0.5, 1.0, *params, *state)
        x2 = jnp.concatenate([x[:8], jnp.zeros_like(x[8:])])
        half, _ = infer(x2, 0.5, 1.0, *params, *state)
        np.testing.assert_allclose(
            np.asarray(full)[:8], np.asarray(half)[:8], rtol=1e-5, atol=1e-5
        )


class TestEndToEndLearning:
    @pytest.mark.parametrize("mode", ["multi", "bin", "fp"])
    def test_dst_training_learns_blobs(self, mode):
        """Full paper loop: fwd/bwd graph + DST projection; accuracy >> chance."""
        arch = model.Arch(
            "small", (32,), (model.Dense(32, 64), model.Dense(64, 64), model.Dense(64, 10)), 10
        )
        n1 = 0 if mode == "bin" else 1
        params, state = model.init_params(arch, jax.random.PRNGKey(0), n1=n1)
        pds, _ = model.param_descs(arch)
        dz = ref.delta_z(n1)
        step = jax.jit(model.make_train_step(arch, mode, use_pallas=False))
        key = jax.random.PRNGKey(42)
        kc = jax.random.PRNGKey(77)
        cents = jax.random.normal(kc, (10, 32)) * 0.6
        acc = 0.0
        for it in range(80):
            key, kb, kn, ku = jax.random.split(key, 4)
            y = jax.random.randint(kb, (64,), 0, 10)
            x = jnp.clip(cents[y] + jax.random.normal(kn, (64, 32)) * 0.25, -1, 1)
            out = step(x, y, 0.5, 0.5, 1.0, *params, *state)
            acc = float(out[1]) / 64
            grads = out[3 : 3 + len(pds)]
            newp = []
            for pd, p, g in zip(pds, params, grads):
                if pd.kind == "weight" and mode != "fp":
                    ku, kk = jax.random.split(ku)
                    u = jax.random.uniform(kk, p.shape)
                    newp.append(ref.dst_update(p, -0.02 * g, u, dz, 3.0))
                else:
                    newp.append(p - 0.01 * g)
            params = newp
            state = list(out[3 + len(pds) :])
        assert acc > 0.6, f"{mode}: final train acc {acc}"
        if mode != "fp":
            w0 = np.asarray(params[0])
            # offset-grid membership: states are n*dz - 1 (N=0: {-1,1})
            k = (w0 + 1.0) / dz
            assert np.allclose(k, np.round(k), atol=1e-5)
