"""Fused BN->quantize kernel vs the composition of its parts."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import bn_quant, ref

jax.config.update("jax_platform_name", "cpu")


def oracle(z, gamma, beta, rmean, rvar, r, hl, eps=1e-4):
    y = (z - rmean) * jax.lax.rsqrt(rvar + eps) * gamma + beta
    return ref.quantize_fwd(y, r, hl)


class TestFoldBn:
    def test_fold_matches_unfolded(self):
        k = jax.random.PRNGKey(0)
        gamma = jax.random.uniform(k, (8,), minval=0.5, maxval=2.0)
        beta = jax.random.normal(jax.random.PRNGKey(1), (8,))
        rmean = jax.random.normal(jax.random.PRNGKey(2), (8,))
        rvar = jax.random.uniform(jax.random.PRNGKey(3), (8,), minval=0.1, maxval=2.0)
        z = jax.random.normal(jax.random.PRNGKey(4), (16, 8))
        scale, shift = bn_quant.fold_bn(gamma, beta, rmean, rvar)
        got = z * scale + shift
        want = (z - rmean) * jax.lax.rsqrt(rvar + 1e-4) * gamma + beta
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


class TestFusedKernel:
    @settings(max_examples=15, deadline=None)
    @given(
        rows=st.integers(1, 300),
        c=st.integers(1, 600),
        n=st.integers(1, 4),
        r=st.floats(0.0, 0.8),
        seed=st.integers(0, 2**30),
    )
    def test_matches_oracle_composition(self, rows, c, n, r, seed):
        hl = ref.half_levels(n)
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        z = jax.random.normal(ks[0], (rows, c)) * 2
        gamma = jax.random.uniform(ks[1], (c,), minval=0.5, maxval=2.0)
        beta = jax.random.normal(ks[2], (c,))
        rmean = jax.random.normal(ks[3], (c,)) * 0.5
        rvar = jax.random.uniform(ks[4], (c,), minval=0.1, maxval=2.0)
        scale, shift = bn_quant.fold_bn(gamma, beta, rmean, rvar)
        got = bn_quant.bn_quantize(z, scale, shift, r, hl)
        want = oracle(z, gamma, beta, rmean, rvar, r, hl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    def test_nhwc_4d_shape(self):
        z = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 5, 7))
        scale = jnp.ones(7)
        shift = jnp.zeros(7)
        got = bn_quant.bn_quantize(z, scale, shift, 0.5, 1.0)
        assert got.shape == z.shape
        want = ref.quantize_fwd(z, 0.5, 1.0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_outputs_on_grid(self):
        z = jax.random.normal(jax.random.PRNGKey(1), (64, 33)) * 3
        scale = jnp.full((33,), 1.7)
        shift = jnp.full((33,), -0.2)
        for n in (1, 3):
            hl = ref.half_levels(n)
            q = np.asarray(bn_quant.bn_quantize(z, scale, shift, 0.3, hl))
            dz = ref.delta_z(n)
            np.testing.assert_allclose(q / dz, np.round(q / dz), atol=1e-5)
