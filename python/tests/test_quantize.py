"""L1 correctness: Pallas quantizer kernels vs the pure-jnp oracle.

Covers eq. (5) (ternary), eq. (22) (multi-step), eq. (7) (rect window),
eq. (8) (triangular window) and the Z_N grid semantics of eq. (1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quantize as qk, ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0, scale=2.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


# ---------------------------------------------------------------------------
# Oracle semantics (paper equations)
# ---------------------------------------------------------------------------


class TestOracleSemantics:
    def test_ternary_matches_eq5(self):
        """phi_r for N=1 is exactly eq. (5): sign outside the window, 0 inside."""
        x = jnp.array([-2.0, -0.51, -0.5, -0.1, 0.0, 0.1, 0.5, 0.51, 2.0])
        q = ref.quantize_fwd(x, 0.5, 1.0)
        np.testing.assert_array_equal(
            np.asarray(q), [-1, -1, 0, 0, 0, 0, 0, 1, 1]
        )

    def test_zero_window_half_width(self):
        """|x| <= r quantizes to exactly 0 for every level count."""
        for n in range(1, 6):
            hl = ref.half_levels(n)
            x = jnp.linspace(-0.3, 0.3, 41)
            q = ref.quantize_fwd(x, 0.3, hl)
            assert np.all(np.asarray(q) == 0.0), f"N={n}"

    @pytest.mark.parametrize("n", [1, 2, 3, 4, 6])
    def test_outputs_on_grid(self, n):
        hl = ref.half_levels(n)
        dz = ref.delta_z(n)
        x = rand((512,), seed=n)
        q = np.asarray(ref.quantize_fwd(x, 0.4, hl))
        scaled = q / dz
        np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-6)
        assert np.abs(q).max() <= 1.0 + 1e-6

    def test_saturation_at_one(self):
        """Values beyond H=1 clamp to the extreme state."""
        q = ref.quantize_fwd(jnp.array([5.0, -5.0]), 0.5, 4.0)
        np.testing.assert_array_equal(np.asarray(q), [1.0, -1.0])

    def test_monotone_nondecreasing(self):
        x = jnp.linspace(-2, 2, 1001)
        for n in (1, 3):
            q = np.asarray(ref.quantize_fwd(x, 0.25, ref.half_levels(n)))
            assert np.all(np.diff(q) >= -1e-7)

    def test_odd_symmetry(self):
        x = rand((256,), seed=3)
        q1 = np.asarray(ref.quantize_fwd(x, 0.3, 4.0))
        q2 = np.asarray(ref.quantize_fwd(-x, 0.3, 4.0))
        np.testing.assert_allclose(q1, -q2, atol=1e-7)

    def test_binary_mode_is_sign(self):
        x = jnp.array([-0.5, 0.0, 0.5])
        q = np.asarray(ref.quantize_fwd(x, 0.5, 0.5, mode="bin"))
        np.testing.assert_array_equal(q, [-1.0, 1.0, 1.0])  # sign(0) := +1

    def test_fp_mode_is_identity(self):
        x = rand((64,), seed=5)
        np.testing.assert_array_equal(
            np.asarray(ref.quantize_fwd(x, 0.5, 1.0, mode="fp")), np.asarray(x)
        )


class TestOracleDerivative:
    def test_rect_pulse_height_and_support_ternary(self):
        """eq. (7): 1/(2a) within +-a of |x| = r, else 0."""
        r, a = 0.5, 0.25
        x = jnp.array([0.0, 0.24, 0.26, 0.5, 0.74, 0.76, -0.5, -0.76, 2.0])
        d = np.asarray(ref.quantize_bwd(x, r, a, 1.0, window="rect"))
        expect = np.array([0, 0, 2.0, 2.0, 2.0, 0, 2.0, 0, 0])
        np.testing.assert_allclose(d, expect, atol=1e-6)

    def test_tri_peak_and_zero(self):
        """eq. (8): peak 1/a at the jump, 0 at distance >= a."""
        r, a = 0.5, 0.5
        d_at_jump = float(ref.quantize_bwd(jnp.array([r]), r, a, 1.0, window="tri")[0])
        assert abs(d_at_jump - 1.0 / a) < 1e-6
        d_far = float(ref.quantize_bwd(jnp.array([r + a + 0.01]), r, a, 1.0, window="tri")[0])
        assert d_far == 0.0

    def test_pulse_unit_area(self):
        """Each pulse integrates to ~1 (the impulse it approximates)."""
        r, a, n = 0.4, 0.1, 1
        xs = jnp.linspace(0.0, 1.2, 24001)
        dx = float(xs[1] - xs[0])
        for window in ("rect", "tri"):
            d = np.asarray(ref.quantize_bwd(xs, r, a, ref.half_levels(n), window=window))
            area = d.sum() * dx  # single jump at x = r on the positive axis
            assert abs(area - 1.0) < 2e-2, window

    @pytest.mark.parametrize("n", [2, 3])
    def test_multistep_pulse_count(self, n):
        """hl pulses on the positive axis (jumps at r + k*step, k<hl)."""
        r, a = 0.2, 0.02
        hl = ref.half_levels(n)
        xs = jnp.linspace(0.0, 1.5, 60001)
        d = np.asarray(ref.quantize_bwd(xs, r, a, hl, window="rect"))
        # count connected support components
        on = d > 0
        starts = np.sum(on[1:] & ~on[:-1]) + int(on[0])
        assert starts == int(hl)

    def test_bin_mode_hardtanh_window(self):
        x = jnp.array([-1.5, -1.0, 0.0, 1.0, 1.5])
        d = np.asarray(ref.quantize_bwd(x, 0.0, 0.5, 0.5, mode="bin"))
        np.testing.assert_array_equal(d, [0, 1, 1, 1, 0])


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle (the repo's core L1 signal)
# ---------------------------------------------------------------------------


class TestPallasMatchesOracle:
    @settings(max_examples=25, deadline=None)
    @given(
        rows=st.integers(1, 7),
        cols=st.integers(1, 2100),
        r=st.floats(0.0, 0.9),
        n=st.integers(1, 6),
        seed=st.integers(0, 2**30),
    )
    def test_fwd(self, rows, cols, r, n, seed):
        hl = ref.half_levels(n)
        x = rand((rows, cols), seed=seed)
        got = qk.quantize_fwd(x, r, hl)
        want = ref.quantize_fwd(x, r, hl)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=25, deadline=None)
    @given(
        cols=st.integers(1, 2100),
        r=st.floats(0.0, 0.9),
        a=st.floats(0.05, 1.0),
        n=st.integers(1, 6),
        window=st.sampled_from(["rect", "tri"]),
        seed=st.integers(0, 2**30),
    )
    def test_bwd(self, cols, r, a, n, window, seed):
        hl = ref.half_levels(n)
        x = rand((cols,), seed=seed)
        got = qk.quantize_bwd(x, r, a, hl, window=window)
        want = ref.quantize_bwd(x, r, a, hl, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)

    def test_fwd_3d_shape(self):
        x = rand((2, 9, 130), seed=11)
        got = qk.quantize_fwd(x, 0.5, 1.0)
        want = ref.quantize_fwd(x, 0.5, 1.0)
        assert got.shape == x.shape
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_traced_scalars_jit(self):
        """r/hl as traced runtime scalars (the sweep-without-recompile path)."""
        f = jax.jit(lambda x, r, hl: qk.quantize_fwd(x, r, hl))
        x = rand((64,), seed=1)
        for r, n in [(0.3, 1), (0.7, 3)]:
            hl = ref.half_levels(n)
            np.testing.assert_array_equal(
                np.asarray(f(x, r, hl)), np.asarray(ref.quantize_fwd(x, r, hl))
            )
