"""AOT artifact integrity: catalogue, manifest consistency, HLO validity."""

import json
import os

import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestCatalogue:
    def test_names_unique(self):
        gs = aot.graph_catalogue(full=True)
        names = [aot.graph_name(g) for g in gs]
        assert len(names) == len(set(names))

    def test_covers_table1_baselines(self):
        names = {aot.graph_name(g) for g in aot.graph_catalogue(full=False)}
        for want in [
            "mlp_fp_b100_train",
            "mlp_bin_b100_train",
            "mlp_multi_b100_train",
            "cnn_mnist_multi_b100_train",
            "cnn_cifar_multi_b50_train",
        ]:
            assert want in names

    def test_lower_tiny_graph_produces_hlo(self):
        g = dict(arch="mlp", mode="multi", batch=2, width=0.05, kind="train")
        hlo, meta = aot.lower_graph(g, use_pallas=False)
        assert hlo.startswith("HloModule")
        assert len(meta["inputs"]) == 5 + len(meta["params"]) + len(meta["bn_state"])


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_every_graph_file_exists(self, manifest):
        for name, meta in manifest["graphs"].items():
            path = os.path.join(ART, meta["file"])
            assert os.path.exists(path), name
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), name

    def test_io_counts_match_model(self, manifest):
        for name, meta in manifest["graphs"].items():
            arch = model.build_arch(meta["arch"], width=meta["width"])
            pds, sds = model.param_descs(arch)
            assert len(meta["params"]) == len(pds), name
            assert len(meta["bn_state"]) == len(sds), name
            fixed = 5 if meta["kind"] == "train" else 3
            assert len(meta["inputs"]) == fixed + len(pds) + len(sds), name

    def test_param_shapes_match_model(self, manifest):
        for name, meta in manifest["graphs"].items():
            arch = model.build_arch(meta["arch"], width=meta["width"])
            pds, _ = model.param_descs(arch)
            for pd, mp in zip(pds, meta["params"]):
                assert list(pd.shape) == mp["shape"], (name, pd.name)

    def test_train_outputs_contract(self, manifest):
        for name, meta in manifest["graphs"].items():
            outs = [o["name"] for o in meta["outputs"]]
            if meta["kind"] == "train":
                assert outs[:3] == ["loss", "ncorrect", "sparsity"], name
                assert sum(o.startswith("g_") for o in outs) == len(meta["params"])
            else:
                assert outs[0] == "logits", name
