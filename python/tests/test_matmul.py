"""L1 correctness: tiled gated-XNOR Pallas matmul vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import gxnor_matmul as gm, ref

jax.config.update("jax_platform_name", "cpu")


def ternary(shape, seed):
    k = jax.random.PRNGKey(seed)
    return jax.random.randint(k, shape, -1, 2).astype(jnp.float32)


class TestMatmul:
    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 150),
        k=st.integers(1, 300),
        n=st.integers(1, 150),
        seed=st.integers(0, 2**30),
    )
    def test_matches_oracle_float(self, m, k, n, seed):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (m, k))
        w = jax.random.normal(kw, (k, n))
        got = gm.matmul(x, w)
        want = ref.matmul(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        m=st.integers(1, 64),
        k=st.integers(1, 400),
        n=st.integers(1, 64),
        seed=st.integers(0, 2**30),
    )
    def test_ternary_operands_exact(self, m, k, n, seed):
        """Ternary x ternary accumulates small integers -> exact in f32."""
        x = ternary((m, k), seed)
        w = ternary((k, n), seed + 1)
        got = np.asarray(gm.matmul(x, w))
        want = np.asarray(x) @ np.asarray(w)
        np.testing.assert_array_equal(got, want)

    def test_mxu_native_tiles(self):
        """Shapes that are exact 128-multiples (no padding path)."""
        x = ternary((128, 256), 7)
        w = ternary((256, 128), 8)
        np.testing.assert_array_equal(
            np.asarray(gm.matmul(x, w)), np.asarray(x) @ np.asarray(w)
        )

    def test_vjp_matches_jnp(self):
        """custom_vjp backward = (g @ w^T, x^T @ g), via the same kernel."""
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 50))
        w = jax.random.normal(jax.random.PRNGKey(1), (50, 20))

        def loss_pallas(x, w):
            return jnp.sum(gm.matmul_vjp(x, w) ** 2)

        def loss_ref(x, w):
            return jnp.sum(ref.matmul(x, w) ** 2)

        gx1, gw1 = jax.grad(loss_pallas, argnums=(0, 1))(x, w)
        gx2, gw2 = jax.grad(loss_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2), rtol=1e-4, atol=1e-4)

    def test_zero_padding_inert(self):
        """Padded region contributes exactly nothing."""
        x = ternary((100, 784), 3)  # pads to 128 x 896
        w = ternary((784, 512), 4)
        np.testing.assert_array_equal(
            np.asarray(gm.matmul(x, w)), np.asarray(x) @ np.asarray(w)
        )
