"""Layer-1 Pallas kernels (build-time) + pure-jnp oracle (`ref`)."""

from . import bn_quant, dst, gxnor_matmul, quantize, ref  # noqa: F401
