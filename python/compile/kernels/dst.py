"""Pallas kernel for the Discrete State Transition update (eqs. 13-20).

This is the *build-time twin* of the Rust runtime implementation
(`rust/src/ternary/dst.rs`): the production hot path applies DST in Rust
(it owns the RNG and the packed weight store), and pytest cross-checks the
two against the pure-jnp oracle so the semantics cannot drift.

Uniform random numbers are an explicit operand — the kernel is pure, which
is what makes the Rust/JAX equivalence testable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK = 65536


def _dst_kernel(w_ref, dw_ref, u_ref, dz_ref, m_ref, o_ref):
    w = w_ref[...]
    dw = dw_ref[...]
    u = u_ref[...]
    dz = dz_ref[0, 0]
    m = m_ref[0, 0]
    # eq. 13: boundary restriction rho keeps w + rho inside [-1, 1]
    rho = jnp.where(dw >= 0, jnp.minimum(1.0 - w, dw), jnp.maximum(-1.0 - w, dw))
    kappa = jnp.trunc(rho / dz)                 # eq. 15
    nu = rho - kappa * dz                       # eq. 16
    tau = jnp.tanh(m * jnp.abs(nu) / dz)        # eq. 20
    sgn = jnp.where(rho >= 0, 1.0, -1.0)        # eq. 19
    hop = jnp.where(u < tau, sgn, 0.0)          # eq. 18
    o_ref[...] = jnp.clip(w + (kappa + hop) * dz, -1.0, 1.0)


def dst_update(w, dw, u, dz, m):
    """Vectorized DST over arbitrary-shaped weight tensors."""
    shape = w.shape
    flat = w.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    def prep(t):
        t = t.reshape(-1).astype(jnp.float32)
        if pad:
            t = jnp.pad(t, (0, pad))
        return t.reshape(-1, _BLOCK)
    scalar = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    rows = (n + pad) // _BLOCK
    out = pl.pallas_call(
        _dst_kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, _BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, _BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, _BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, _BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _BLOCK), jnp.float32),
        interpret=True,
    )(prep(w), prep(dw), prep(u), scalar(dz), scalar(m))
    return out.reshape(-1)[:n].reshape(shape)
