"""Fused BatchNorm(inference) -> phi_r Pallas kernel.

Every hidden layer of the paper's networks ends in `quantize(BN(z))`. On a
TPU these are two VPU-bound streaming passes over the same feature map —
fusing them halves the HBM traffic of the layer epilogue. The kernel takes
the *folded* BN form:

    y = phi_r(z * scale_c + shift_c)
    scale_c = gamma_c / sqrt(rvar_c + eps),  shift_c = beta_c - rmean_c * scale_c

with per-channel scale/shift broadcast across rows (NHWC: channels are the
minor axis, so tiles stay VPU-lane aligned).

The unfused path in `model.py` remains the default (XLA fuses adequately
under jit); this kernel is the hand-fused variant, validated against the
same oracle composition, and is what a Mosaic (non-interpret) build would
ship. Used by `aot.py --fused-epilogue` graphs if desired.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# rows x channel-block tiling; channels minor (lane axis)
_BLOCK_C = 512
_BLOCK_R = 256


def _kernel(z_ref, scale_ref, shift_ref, r_ref, hl_ref, o_ref):
    z = z_ref[...]
    scale = scale_ref[...]  # (1, BLOCK_C) broadcast over rows
    shift = shift_ref[...]
    r = r_ref[0, 0]
    hl = hl_ref[0, 0]
    y = z * scale + shift
    step = (1.0 - r) / hl
    mag = jnp.clip(jnp.ceil((jnp.abs(y) - r) / step), 0.0, hl) / hl
    o_ref[...] = jnp.sign(y) * mag


def fold_bn(gamma, beta, rmean, rvar, eps: float = 1e-4):
    """Fold BN statistics into per-channel (scale, shift)."""
    scale = gamma * jax.lax.rsqrt(rvar + eps)
    return scale, beta - rmean * scale


def bn_quantize(z, scale, shift, r, hl):
    """Fused y = phi_r(z * scale + shift); z: (..., C), scale/shift: (C,)."""
    orig_shape = z.shape
    c = z.shape[-1]
    rows = 1
    for d in z.shape[:-1]:
        rows *= d
    z2 = z.reshape(rows, c).astype(jnp.float32)
    pad_r = (-rows) % _BLOCK_R
    pad_c = (-c) % _BLOCK_C
    if pad_r or pad_c:
        z2 = jnp.pad(z2, ((0, pad_r), (0, pad_c)))
    sc = jnp.pad(scale.astype(jnp.float32), (0, pad_c)).reshape(1, -1)
    sh = jnp.pad(shift.astype(jnp.float32), (0, pad_c)).reshape(1, -1)
    gr, gc = z2.shape[0] // _BLOCK_R, z2.shape[1] // _BLOCK_C
    scalar = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _kernel,
        grid=(gr, gc),
        in_specs=[
            pl.BlockSpec((_BLOCK_R, _BLOCK_C), lambda i, j: (i, j)),
            pl.BlockSpec((1, _BLOCK_C), lambda i, j: (0, j)),
            pl.BlockSpec((1, _BLOCK_C), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((_BLOCK_R, _BLOCK_C), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(z2.shape, jnp.float32),
        interpret=True,
    )(z2, sc, sh, scalar(r), scalar(hl))
    return out[:rows, :c].reshape(orig_shape)
