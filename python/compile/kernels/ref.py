"""Pure-jnp oracle for every Pallas kernel (L1 correctness ground truth).

Implements the paper's discretization framework exactly:

* ``Z_N`` space (eq. 1): states ``n/2^{N-1} - 1``, ``n = 0..2^N``,
  spacing ``dz = 1/2^{N-1}``.
* Multi-step activation quantization ``phi_r`` (eqs. 5, 22).
* Rectangular / triangular derivative approximations (eqs. 7, 8, Figs. 2/5).
* DST probabilistic projection (eqs. 13-20, 23-26).

All functions are shape-polymorphic and used by pytest/hypothesis as the
reference the Pallas kernels must match bit-for-bit (quantizers) or to
float tolerance (matmul).
"""

from __future__ import annotations

import jax.numpy as jnp


def half_levels(n: int) -> float:
    """``2^{N-1}`` as a float (0.5 for the binary space N=0)."""
    return float(2 ** (n - 1)) if n >= 1 else 0.5


def delta_z(n: int) -> float:
    """State spacing ``dz_N = 1/2^{N-1}`` of Z_N (eq. 1). N=0 -> 2."""
    return 1.0 / half_levels(n)


def quantize_fwd(x, r, hl, mode: str = "multi"):
    """Multi-step quantizer ``phi_r`` (eq. 22; eq. 5 when ``hl == 1``).

    Args:
      x:    pre-activations (already batch-normalized), any shape.
      r:    zero-window half width, ``0 <= r < 1`` (scalar, traced).
      hl:   ``2^{N-1}`` — number of positive levels (scalar, traced).
      mode: ``multi``/``ter`` -> phi_r; ``bin`` -> sign; ``fp`` -> identity.

    Returns values on the Z_N grid in ``[-1, 1]`` (H = 1).
    """
    if mode == "fp":
        return x
    if mode == "bin":
        # Binary space Z_0 = {-1, 1}: sign with sign(0) := +1 (paper eq. 19).
        return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)
    step = (1.0 - r) / hl
    mag = jnp.clip(jnp.ceil((jnp.abs(x) - r) / step), 0.0, hl) / hl
    return jnp.sign(x) * mag


def quantize_bwd(x, r, a, hl, window: str = "rect", mode: str = "multi"):
    """Approximate derivative of ``phi_r`` at ``x`` (eqs. 7/8, Figs. 2/5).

    A pulse of half-width ``a`` is centred on every discontinuity of
    ``phi_r``: ``|x| = r + k*step`` for ``k = 0..hl-1``.

    ``rect``:     1/(2a) inside the pulse (eq. 7).
    ``tri``:      peak 1/a at the jump, linear to 0 at distance a (eq. 8).
    ``bin`` mode: straight-through hardtanh window ``1_{|x|<=1}`` (BNN [19]).
    ``fp`` mode:  identity derivative (1 everywhere).
    """
    if mode == "fp":
        return jnp.ones_like(x)
    if mode == "bin":
        return (jnp.abs(x) <= 1.0).astype(x.dtype)
    step = (1.0 - r) / hl
    u = jnp.abs(x) - r
    k = jnp.clip(jnp.round(u / step), 0.0, hl - 1.0)
    dist = jnp.abs(u - k * step)
    if window == "rect":
        return (dist <= a).astype(x.dtype) / (2.0 * a)
    # triangular
    return jnp.maximum(0.0, a - dist) / (a * a)


def matmul(x, w):
    """f32 reference for the gated-XNOR matmul kernel: plain ``x @ w``."""
    return jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def dst_rho(w, dw):
    """Boundary restriction ``rho`` (eq. 13): keep ``w + rho`` in [-1, 1]."""
    return jnp.where(dw >= 0, jnp.minimum(1.0 - w, dw), jnp.maximum(-1.0 - w, dw))


def dst_update(w, dw, u, dz, m):
    """Discrete State Transition update (eqs. 13-20 / 23-26).

    Args:
      w:  current weights, exactly on the Z_N grid, in [-1, 1].
      dw: real-valued gradient increments (already -lr * grad, possibly
          Adam-preconditioned).
      u:  iid uniforms in [0, 1), same shape as ``w``.
      dz: grid spacing ``Delta z_N``.
      m:  nonlinear transition factor (paper uses m = 3).

    Returns the next weights, exactly on the grid, in [-1, 1].
    """
    rho = dst_rho(w, dw)
    kappa = jnp.trunc(rho / dz)                      # eq. 15 (fix = trunc)
    nu = rho - kappa * dz                            # eq. 16 (rem, sign of rho)
    tau = jnp.tanh(m * jnp.abs(nu) / dz)             # eq. 20
    sgn = jnp.where(rho >= 0, 1.0, -1.0)             # eq. 19
    hop = jnp.where(u < tau, sgn, 0.0)               # eq. 18
    w_next = w + (kappa + hop) * dz
    # Probability-0 overshoot can appear at float precision; clamp to H = 1.
    return jnp.clip(w_next, -1.0, 1.0)


def project_to_grid(x, dz):
    """Deterministic nearest-state projection onto Z_N (used for init)."""
    return jnp.clip(jnp.round(x / dz) * dz, -1.0, 1.0)
