"""Pallas kernels for the multi-step quantizer and its derivative window.

These are the elementwise hot spots of the paper's forward/backward passes
(eqs. 5/22 and 7/8). On a real TPU they run on the VPU over VMEM-resident
tiles; here they are lowered with ``interpret=True`` so the emitted HLO runs
on the CPU PJRT client (the repo-wide rule — Mosaic custom-calls cannot
execute on CPU).

Scalars (``r``, ``a``, ``hl``) are passed as ``(1, 1)`` f32 operands so the
same compiled artifact serves every point of the parameter sweeps (Figs.
8/9/10/13) without recompilation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row tile for elementwise kernels: one VPU-friendly (8, 128) multiple.
_BLOCK = 65536


def _fwd_kernel(x_ref, r_ref, hl_ref, o_ref):
    """phi_r over one tile (eq. 22)."""
    x = x_ref[...]
    r = r_ref[0, 0]
    hl = hl_ref[0, 0]
    step = (1.0 - r) / hl
    mag = jnp.clip(jnp.ceil((jnp.abs(x) - r) / step), 0.0, hl) / hl
    o_ref[...] = jnp.sign(x) * mag


def _bwd_kernel(x_ref, r_ref, a_ref, hl_ref, o_ref, *, window: str):
    """Derivative pulse of phi_r over one tile (eq. 7 rect / eq. 8 tri)."""
    x = x_ref[...]
    r = r_ref[0, 0]
    a = a_ref[0, 0]
    hl = hl_ref[0, 0]
    step = (1.0 - r) / hl
    u = jnp.abs(x) - r
    k = jnp.clip(jnp.round(u / step), 0.0, hl - 1.0)
    dist = jnp.abs(u - k * step)
    if window == "rect":
        o_ref[...] = (dist <= a).astype(x.dtype) / (2.0 * a)
    else:
        o_ref[...] = jnp.maximum(0.0, a - dist) / (a * a)


def _pad_flat(x):
    """Flatten to 1D and zero-pad to a _BLOCK multiple; return (flat, n)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, n


def _scalar(v):
    return jnp.asarray(v, jnp.float32).reshape(1, 1)


def quantize_fwd(x, r, hl):
    """Pallas phi_r (eq. 22). ``r``/``hl`` may be traced scalars."""
    flat, n = _pad_flat(x.astype(jnp.float32))
    rows = flat.shape[0] // _BLOCK
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, _BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, _BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _BLOCK), jnp.float32),
        interpret=True,
    )(flat.reshape(rows, _BLOCK), _scalar(r), _scalar(hl))
    return out.reshape(-1)[:n].reshape(x.shape)


def quantize_bwd(x, r, a, hl, window: str = "rect"):
    """Pallas derivative window (eqs. 7/8). ``window`` is static."""
    flat, n = _pad_flat(x.astype(jnp.float32))
    rows = flat.shape[0] // _BLOCK
    out = pl.pallas_call(
        functools.partial(_bwd_kernel, window=window),
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, _BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, _BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _BLOCK), jnp.float32),
        interpret=True,
    )(flat.reshape(rows, _BLOCK), _scalar(r), _scalar(a), _scalar(hl))
    return out.reshape(-1)[:n].reshape(x.shape)
