"""Tiled gated-XNOR matmul Pallas kernel.

The paper's inner product of ternary activations and ternary weights
(Fig. 1 / Fig. 11f) is, on a TPU, best realized as a *dense* MXU matmul of
exact {-1, 0, 1} values: the systolic array has no per-MAC gating, so the
event-driven win is quantified by the hardware simulator (rust `hwsim`)
instead of being faked in the kernel (DESIGN.md §4).

Tiling: (bm, bk) x (bk, bn) blocks with the K dimension innermost in the
grid so each output tile is revisited and accumulated in place — the
classic HBM->VMEM schedule. Block sizes default to the 128x128 MXU-native
tile and shrink to the (padded) problem when smaller.

interpret=True everywhere (CPU PJRT execution path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: accumulate x_tile @ w_tile into o_tile."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def matmul(x, w, bm: int = None, bk: int = None, bn: int = None):
    """``x @ w`` with f32 accumulation; x: (M, K), w: (K, N).

    Inputs hold exact discrete values; zero-padding to tile multiples is
    numerically inert for a matmul.

    Tile selection: on a real TPU the MXU-native choice is (128, 128, 128)
    — pass it explicitly to pin the HBM<->VMEM schedule. Under
    ``interpret=True`` (this repo's execution mode) each grid step costs a
    dynamic-slice round trip, so the default heuristic grows tiles until
    the grid is small: K/N resident in one or two steps. §Perf iteration 6
    measured 11.8 ms -> 1.1 ms on the 784x512 layer from this change.
    """
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    if bm is None:
        bm = min(128, _ceil_mult(m, 8))
    if bk is None:
        bk = _ceil_mult(k, 128) if k <= 2048 else 512
    if bn is None:
        bn = _ceil_mult(n, 128) if n <= 1024 else 512
    bm = min(bm, _ceil_mult(m, 8))
    bn = min(bn, _ceil_mult(n, 128))
    bk = min(bk, _ceil_mult(k, 128))
    xp = _pad_to(x, bm, bk)
    wp = _pad_to(w, bk, bn)
    gm, gk, gn = xp.shape[0] // bm, xp.shape[1] // bk, wp.shape[1] // bn
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def _ceil_mult(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Differentiable wrapper: the pallas_call itself must not be transformed by
# autodiff (program_id has no JVP rule); the VJP of a matmul is two more
# matmuls, so the backward pass reuses the same tiled kernel.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def matmul_vjp(x, w):
    """Differentiable ``x @ w`` backed by the tiled Pallas kernel."""
    return matmul(x, w)


def _mm_fwd(x, w):
    return matmul(x, w), (x, w)


def _mm_bwd(res, g):
    x, w = res
    return (matmul(g, w.T), matmul(x.T, g))


matmul_vjp.defvjp(_mm_fwd, _mm_bwd)
