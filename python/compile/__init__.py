"""Build-time compile package: L1 kernels, L2 model, AOT lowering."""
