"""AOT lowering: JAX graphs -> HLO *text* artifacts + manifest.json.

Run once at build time (``make artifacts``); the Rust coordinator then loads
and executes the artifacts on the PJRT CPU client, and Python never appears
on the training path again.

HLO text (NOT ``lowered.compiler_ir().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Graph catalogue (``--list`` to print):

* ``mlp_{fp,bin,multi}_b100_{train,infer}``   — Table 1 baselines + sweeps
* ``mlp_multi_b16_{train,infer}``             — fast graphs for cargo tests
* ``cnn_mnist_{fp,multi}_b100_{train,infer}`` — paper MNIST net (Fig. 7, T1)
* ``cnn_cifar_multi_b50_{train,infer}``       — width-reduced CIFAR/SVHN net
* ``cnn_cifar_full_multi_b50_train``          — paper-width CIFAR net,
  emitted only with ``--full`` (compile-scale validation; not used by the
  default training flow)

``multi`` graphs take r, a and the positive-level count hl = 2^{N2-1} as
*runtime scalars*: every point of the Fig. 8/9/10/13 sweeps reuses one
artifact. GXNOR-Net is hl = 1 (ternary); N2 > 1 is the multilevel space.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-clean interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def graph_catalogue(full: bool) -> List[Dict]:
    gs: List[Dict] = []

    def add(arch, mode, batch, width=1.0, kinds=("train", "infer")):
        for kind in kinds:
            gs.append(
                dict(arch=arch, mode=mode, batch=batch, width=width, kind=kind)
            )

    for mode in ("fp", "bin", "multi"):
        add("mlp", mode, 100)
    add("mlp", "multi", 16)
    add("cnn_mnist", "multi", 100)
    add("cnn_mnist", "fp", 100)
    add("cnn_cifar", "multi", 50, width=0.25)
    if full:
        add("cnn_cifar_full", "multi", 50, width=1.0, kinds=("train",))
    return gs


def graph_name(g: Dict) -> str:
    return f"{g['arch']}_{g['mode']}_b{g['batch']}_{g['kind']}"


def lower_graph(g: Dict, use_pallas: bool):
    arch_name = g["arch"].replace("_full", "")
    arch = model.build_arch(arch_name, width=g["width"])
    pds, sds_ = model.param_descs(arch)
    b = g["batch"]
    x_sds = _sds((b, *arch.input_shape))
    scalar = _sds(())
    param_sds = [_sds(pd.shape) for pd in pds]
    state_sds = [_sds(sd.shape) for sd in sds_]

    if g["kind"] == "train":
        fn = model.make_train_step(arch, g["mode"], use_pallas=use_pallas)
        args = (
            x_sds,
            _sds((b,), jnp.int32),
            scalar,
            scalar,
            scalar,
            *param_sds,
            *state_sds,
        )
        inputs = (
            [
                {"name": "x", "shape": [b, *arch.input_shape], "dtype": "f32"},
                {"name": "labels", "shape": [b], "dtype": "i32"},
                {"name": "r", "shape": [], "dtype": "f32"},
                {"name": "a", "shape": [], "dtype": "f32"},
                {"name": "hl", "shape": [], "dtype": "f32"},
            ]
            + [
                {"name": pd.name, "shape": list(pd.shape), "dtype": "f32"}
                for pd in pds
            ]
            + [
                {"name": sd.name, "shape": list(sd.shape), "dtype": "f32"}
                for sd in sds_
            ]
        )
        n_hidden = len(sds_) // 2
        outputs = (
            [
                {"name": "loss", "shape": [], "dtype": "f32"},
                {"name": "ncorrect", "shape": [], "dtype": "f32"},
                {"name": "sparsity", "shape": [n_hidden], "dtype": "f32"},
            ]
            + [
                {"name": f"g_{pd.name}", "shape": list(pd.shape), "dtype": "f32"}
                for pd in pds
            ]
            + [
                {"name": f"new_{sd.name}", "shape": list(sd.shape), "dtype": "f32"}
                for sd in sds_
            ]
        )
    else:
        fn = model.make_infer(arch, g["mode"], use_pallas=use_pallas)
        args = (x_sds, scalar, scalar, *param_sds, *state_sds)
        n_hidden = len(sds_) // 2
        inputs = (
            [
                {"name": "x", "shape": [b, *arch.input_shape], "dtype": "f32"},
                {"name": "r", "shape": [], "dtype": "f32"},
                {"name": "hl", "shape": [], "dtype": "f32"},
            ]
            + [
                {"name": pd.name, "shape": list(pd.shape), "dtype": "f32"}
                for pd in pds
            ]
            + [
                {"name": sd.name, "shape": list(sd.shape), "dtype": "f32"}
                for sd in sds_
            ]
        )
        outputs = [
            {"name": "logits", "shape": [b, arch.n_classes], "dtype": "f32"},
            {"name": "sparsity", "shape": [n_hidden], "dtype": "f32"},
        ]

    # keep_unused=True: fp/bin graphs ignore r/a/hl, but the manifest's
    # calling convention must stay uniform across modes (the Rust side
    # always passes them).
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    meta = {
        "arch": arch_name,
        "mode": g["mode"],
        "batch": b,
        "width": g["width"],
        "kind": g["kind"],
        "input_shape": list(arch.input_shape),
        "n_classes": arch.n_classes,
        "params": [
            {
                "name": pd.name,
                "shape": list(pd.shape),
                "kind": pd.kind,
                "layer": pd.layer,
            }
            for pd in pds
        ],
        "bn_state": [
            {
                "name": sd.name,
                "shape": list(sd.shape),
                "kind": sd.kind,
                "layer": sd.layer,
            }
            for sd in sds_
        ],
        "inputs": inputs,
        "outputs": outputs,
    }
    return to_hlo_text(lowered), meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="", help="comma-separated name filter")
    ap.add_argument("--full", action="store_true", help="also emit paper-width CIFAR graph")
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="swap pallas kernels for the jnp oracle (debug only)",
    )
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    gs = graph_catalogue(args.full)
    if args.list:
        for g in gs:
            print(graph_name(g))
        return
    only = {s for s in args.only.split(",") if s}
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": 1, "graphs": {}}
    for g in gs:
        name = graph_name(g)
        if only and name not in only:
            continue
        hlo, meta = lower_graph(g, use_pallas=not args.no_pallas)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(hlo)
        meta["file"] = fname
        manifest["graphs"][name] = meta
        print(f"lowered {name}: {len(hlo)/1e6:.2f} MB")
    mpath = os.path.join(args.out_dir, "manifest.json")
    # merge with an existing manifest so --only refreshes incrementally
    if only and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old["graphs"].update(manifest["graphs"])
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(manifest['graphs'])} graphs)")


if __name__ == "__main__":
    main()
