"""Layer-2: the paper's networks as JAX forward/backward graphs.

Everything here is *build-time only*: `aot.py` lowers the functions built by
:func:`make_train_step` / :func:`make_infer` to HLO text once; the Rust
coordinator executes the artifacts on the PJRT CPU client at run time.

Paper topologies (Section 3):

* MNIST CNN ......... ``32C5-MP2-64C5-MP2-512FC-SVM``
* CIFAR10/SVHN CNN .. ``2x(128C3)-MP2-2x(256C3)-MP2-2x(512C3)-MP2-1024FC-SVM``
* MLP ............... ``784-512-512-10`` (the Table-1 MLP family of
  BWNs [16] / BNNs [19]; our fast vehicle for the parameter sweeps)

The quantizer is tied to its approximate derivative (eqs. 7/8) with a
``jax.custom_vjp`` — the straight-through machinery of Section 2.C. Hidden
layers are BatchNorm-ed before quantization (BNN [19] lineage; see
DESIGN.md §6). The output layer feeds an L2-SVM squared hinge loss [23].

Activation modes (static per artifact):
  ``fp``    full-precision activations (baseline "full-precision NNs")
  ``bin``   sign(x), straight-through hardtanh derivative (BNN/BWN family)
  ``ter``   phi_r with runtime scalars r, a  (GXNOR: N2 = 1, hl = 1)
  ``multi`` phi_r with runtime scalars r, a, hl (Fig. 13 sweeps, N2 >= 1)

Weight discreteness is entirely the Rust side's business: weights arrive as
f32 tensors already holding exact Z_N grid values, and gradients leave the
graph for the Rust DST update. That is precisely the paper's point — there
is no full-precision weight copy anywhere in the training loop.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import gxnor_matmul, quantize as qk, ref

# ---------------------------------------------------------------------------
# Architecture description
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Conv:
    """2D convolution, NHWC x HWIO -> NHWC."""

    cin: int
    cout: int
    k: int
    padding: str  # "SAME" | "VALID"


@dataclasses.dataclass(frozen=True)
class Pool:
    """Max-pool size x size, stride = size."""

    size: int


@dataclasses.dataclass(frozen=True)
class Flatten:
    pass


@dataclasses.dataclass(frozen=True)
class Dense:
    din: int
    dout: int


Layer = object  # Conv | Pool | Flatten | Dense


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    input_shape: Tuple[int, ...]  # per-sample, NHWC or (features,)
    layers: Tuple[Layer, ...]
    n_classes: int = 10

    def weighted(self) -> List[Layer]:
        return [l for l in self.layers if isinstance(l, (Conv, Dense))]


def build_arch(name: str, width: float = 1.0) -> Arch:
    """Construct a named architecture; ``width`` scales channel counts.

    ``width=1.0`` is the paper's exact topology; the CIFAR net is emitted at
    reduced width for CPU-PJRT training (DESIGN.md §6) and at full width for
    compile-validation.
    """
    c = lambda v: max(8, int(round(v * width)))
    if name == "mlp":
        h = c(512)
        return Arch(
            "mlp",
            (784,),
            (Dense(784, h), Dense(h, h), Dense(h, 10)),
        )
    if name == "cnn_mnist":
        c1, c2, fc = c(32), c(64), c(512)
        return Arch(
            "cnn_mnist",
            (28, 28, 1),
            (
                Conv(1, c1, 5, "VALID"),   # 28 -> 24
                Pool(2),                   # -> 12
                Conv(c1, c2, 5, "VALID"),  # -> 8
                Pool(2),                   # -> 4
                Flatten(),
                Dense(c2 * 4 * 4, fc),
                Dense(fc, 10),
            ),
        )
    if name == "cnn_cifar":
        c1, c2, c3, fc = c(128), c(256), c(512), c(1024)
        return Arch(
            "cnn_cifar",
            (32, 32, 3),
            (
                Conv(3, c1, 3, "SAME"),
                Conv(c1, c1, 3, "SAME"),
                Pool(2),                   # -> 16
                Conv(c1, c2, 3, "SAME"),
                Conv(c2, c2, 3, "SAME"),
                Pool(2),                   # -> 8
                Conv(c2, c3, 3, "SAME"),
                Conv(c3, c3, 3, "SAME"),
                Pool(2),                   # -> 4
                Flatten(),
                Dense(c3 * 4 * 4, fc),
                Dense(fc, 10),
            ),
        )
    raise ValueError(f"unknown arch {name!r}")


# ---------------------------------------------------------------------------
# Parameter bookkeeping — flat, ordered, manifest-friendly
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDesc:
    name: str
    shape: Tuple[int, ...]
    kind: str  # "weight" | "gamma" | "beta" | "rmean" | "rvar"
    layer: int  # index among weighted layers


def weight_shape(l: Layer) -> Tuple[int, ...]:
    if isinstance(l, Conv):
        return (l.k, l.k, l.cin, l.cout)
    assert isinstance(l, Dense)
    return (l.din, l.dout)


def param_descs(arch: Arch) -> Tuple[List[ParamDesc], List[ParamDesc]]:
    """Returns (trainable param descriptors, BN running-state descriptors).

    Trainable order per hidden weighted layer: W_i, gamma_i, beta_i; the
    final layer has only W. BN state order: rmean_i, rvar_i.
    """
    ws = [l for l in arch.layers if isinstance(l, (Conv, Dense))]
    params, state = [], []
    for i, l in enumerate(ws):
        params.append(ParamDesc(f"W{i}", weight_shape(l), "weight", i))
        if i < len(ws) - 1:  # hidden layers carry BN
            ch = l.cout if isinstance(l, Conv) else l.dout
            params.append(ParamDesc(f"gamma{i}", (ch,), "gamma", i))
            params.append(ParamDesc(f"beta{i}", (ch,), "beta", i))
            state.append(ParamDesc(f"rmean{i}", (ch,), "rmean", i))
            state.append(ParamDesc(f"rvar{i}", (ch,), "rvar", i))
    return params, state


def init_params(arch: Arch, key, n1: int = 1):
    """Discrete weight init: uniform over the states of Z_N1.

    A nearest-grid projection of a Glorot init collapses to all-zeros for
    coarse grids (|w| << dz), so discrete nets start from uniformly random
    states instead — BatchNorm absorbs the resulting scale. Mirrors the
    Rust-side initializer (`nn::init`); used by the python tests and by
    `aot.py` to produce example arguments for lowering.
    """
    pds, sds = param_descs(arch)
    dz = ref.delta_z(n1)
    n_states = 2 ** max(n1, 1) + (1 if n1 >= 1 else 0)  # 2^N + 1 (N>=1); 2 (N=0)
    out_p, out_s = [], []
    for pd in pds:
        key, sub = jax.random.split(key)
        if pd.kind == "weight":
            n = jax.random.randint(sub, pd.shape, 0, n_states)
            out_p.append((n.astype(jnp.float32) * dz - 1.0))
        elif pd.kind == "gamma":
            out_p.append(jnp.ones(pd.shape, jnp.float32))
        else:
            out_p.append(jnp.zeros(pd.shape, jnp.float32))
    for sd in sds:
        out_s.append(
            jnp.zeros(sd.shape, jnp.float32)
            if sd.kind == "rmean"
            else jnp.ones(sd.shape, jnp.float32)
        )
    return out_p, out_s


# ---------------------------------------------------------------------------
# Quantizer with approximate derivative (custom_vjp; Section 2.B/2.C)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def make_quantizer(mode: str, window: str, use_pallas: bool):
    """phi_r tied to its derivative pulse via custom_vjp.

    fwd: eq. (22) (Pallas kernel or oracle); bwd: multiply the cotangent by
    the rectangular (eq. 7) or triangular (eq. 8) window evaluated at the
    saved pre-activation.
    """

    if mode == "fp":
        return lambda x, r, a, hl: x

    @jax.custom_vjp
    def quant(x, r, a, hl):
        if mode == "bin":
            return ref.quantize_fwd(x, r, hl, mode="bin")
        if use_pallas:
            return qk.quantize_fwd(x, r, hl)
        return ref.quantize_fwd(x, r, hl)

    def fwd(x, r, a, hl):
        return quant(x, r, a, hl), (x, r, a, hl)

    def bwd(res, g):
        x, r, a, hl = res
        if mode == "bin":
            d = ref.quantize_bwd(x, r, a, hl, mode="bin")
        elif use_pallas:
            d = qk.quantize_bwd(x, r, a, hl, window=window)
        else:
            d = ref.quantize_bwd(x, r, a, hl, window=window)
        return (g * d, None, None, None)

    quant.defvjp(fwd, bwd)
    return quant


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

BN_MOMENTUM = 0.9
BN_EPS = 1e-4


def _batch_norm(z, gamma, beta, rmean, rvar, train: bool):
    """Standard BN over batch (+spatial) axes; returns (y, stats-or-None)."""
    axes = tuple(range(z.ndim - 1))
    if train:
        mean = jnp.mean(z, axes)
        var = jnp.var(z, axes)
        stats = (jax.lax.stop_gradient(mean), jax.lax.stop_gradient(var))
    else:
        mean, var = rmean, rvar
        stats = None
    y = (z - mean) * jax.lax.rsqrt(var + BN_EPS) * gamma + beta
    return y, stats


def _apply_linear(l: Layer, h, w, use_pallas: bool):
    if isinstance(l, Conv):
        return jax.lax.conv_general_dilated(
            h, w, window_strides=(1, 1), padding=l.padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
    assert isinstance(l, Dense)
    if use_pallas:
        return gxnor_matmul.matmul_vjp(h, w)
    return ref.matmul(h, w)


def _max_pool(h, size: int):
    return jax.lax.reduce_window(
        h, -jnp.inf, jax.lax.max,
        window_dimensions=(1, size, size, 1),
        window_strides=(1, size, size, 1),
        padding="VALID",
    )


def forward(
    arch: Arch,
    params: Sequence,
    bn_state: Sequence,
    x,
    r,
    a,
    hl,
    *,
    mode: str,
    window: str = "rect",
    train: bool = True,
    use_pallas: bool = True,
):
    """Runs the network; returns (logits, new_bn_state, sparsity_per_layer).

    ``sparsity`` is the fraction of exactly-zero quantized activations per
    hidden layer — the quantity Fig. 10 sweeps and the hwsim consumes.
    """
    quant = make_quantizer(mode, window, use_pallas)
    pds, _ = param_descs(arch)
    n_w = len([l for l in arch.layers if isinstance(l, (Conv, Dense))])
    pi = 0  # cursor into params
    si = 0  # cursor into bn_state
    wi = 0  # weighted-layer index
    h = x
    new_state = []
    sparsity = []
    for l in arch.layers:
        if isinstance(l, Pool):
            h = _max_pool(h, l.size)
            continue
        if isinstance(l, Flatten):
            h = h.reshape(h.shape[0], -1)
            continue
        w = params[pi]
        pi += 1
        z = _apply_linear(l, h, w, use_pallas)
        wi += 1
        if wi == n_w:  # output layer: raw logits into the SVM loss
            h = z
            continue
        gamma, beta = params[pi], params[pi + 1]
        pi += 2
        rmean, rvar = bn_state[si], bn_state[si + 1]
        si += 2
        y, stats = _batch_norm(z, gamma, beta, rmean, rvar, train)
        if train:
            bmean, bvar = stats
            new_state.append(BN_MOMENTUM * rmean + (1 - BN_MOMENTUM) * bmean)
            new_state.append(BN_MOMENTUM * rvar + (1 - BN_MOMENTUM) * bvar)
        h = quant(y, r, a, hl)
        sparsity.append(jnp.mean((h == 0.0).astype(jnp.float32)))
    spars = (
        jnp.stack(sparsity) if sparsity else jnp.zeros((0,), jnp.float32)
    )
    return h, new_state, spars


# ---------------------------------------------------------------------------
# Loss / train step / infer
# ---------------------------------------------------------------------------


def svm_hinge_loss(logits, labels, n_classes: int):
    """L2-SVM squared hinge [23]: mean_i sum_c max(0, 1 - t_ic * o_ic)^2."""
    t = 2.0 * jax.nn.one_hot(labels, n_classes, dtype=logits.dtype) - 1.0
    margins = jnp.maximum(0.0, 1.0 - t * logits)
    return jnp.mean(jnp.sum(margins * margins, axis=1))


def make_train_step(
    arch: Arch, mode: str, window: str = "rect", use_pallas: bool = True
):
    """Builds the lowered train-step function.

    Signature (all positional, the manifest records this order):
      ``(x, labels, r, a, hl, *params, *bn_state)``
    Returns (flat tuple, the manifest records this order):
      ``(loss, ncorrect, sparsity, *grads, *new_bn_state)``
    with one grad per trainable param (W / gamma / beta, in param order).
    """
    pds, sds = param_descs(arch)
    n_p, n_s = len(pds), len(sds)

    def step(x, labels, r, a, hl, *rest):
        params = list(rest[:n_p])
        bn_state = list(rest[n_p:])
        assert len(bn_state) == n_s

        def loss_fn(ps):
            logits, new_state, spars = forward(
                arch, ps, bn_state, x, r, a, hl,
                mode=mode, window=window, train=True, use_pallas=use_pallas,
            )
            loss = svm_hinge_loss(logits, labels, arch.n_classes)
            ncorrect = jnp.sum(
                (jnp.argmax(logits, axis=1) == labels).astype(jnp.float32)
            )
            return loss, (ncorrect, new_state, spars)

        (loss, (nc, new_state, spars)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params)
        return (loss, nc, spars, *grads, *new_state)

    return step


def make_infer(arch: Arch, mode: str, use_pallas: bool = True):
    """Builds the inference function: ``(x, r, hl, *params, *bn_state)`` ->
    ``(logits, sparsity)`` using BN running statistics."""
    pds, sds = param_descs(arch)
    n_p = len(pds)

    def infer(x, r, hl, *rest):
        params = list(rest[:n_p])
        bn_state = list(rest[n_p:])
        logits, _, spars = forward(
            arch, params, bn_state, x, r, 0.5, hl,
            mode=mode, window="rect", train=False, use_pallas=use_pallas,
        )
        return (logits, spars)

    return infer
