//! In-tree stub of the xla-rs PJRT surface used by `gxnor::runtime`.
//!
//! The offline container cannot fetch (or link) the real `xla` crate and
//! its PJRT CPU plugin, so this stub provides the exact API the runtime
//! compiles against — `PjRtClient`, `PjRtLoadedExecutable`, `Literal`,
//! `HloModuleProto`, `XlaComputation` — plus the mutable-literal accessors
//! (`copy_raw_from` / `copy_raw_to`) the zero-copy execution pool relies
//! on. Host-side behavior (literal construction, in-place refill, tuple
//! decomposition, typed read-out) is fully functional so the marshalling
//! layer is testable without a device; only `PjRtClient::cpu()` fails,
//! with an error explaining how to link the real backend. Every test that
//! actually executes a graph is gated on `artifacts/manifest.json`, so
//! `cargo test` passes cleanly against the stub.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type, mirroring `xla::Error` closely enough for `anyhow`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes the gxnor graphs use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn byte_size(self) -> usize {
        4
    }
}

/// A host-side literal: dtype + dims + row-major raw bytes, or a tuple.
///
/// Functional in the stub (the execution pool refills these in place every
/// step); with the real xla-rs backend the same calls map onto the C++
/// `xla::Literal` untyped-data accessors.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Scalar f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal {
            ty: ElementType::F32,
            dims: Vec::new(),
            data: v.to_le_bytes().to_vec(),
            tuple: None,
        }
    }

    /// Dense literal from raw bytes (one memcpy, no per-element work).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product();
        if data.len() != numel * ty.byte_size() {
            return Err(Error(format!(
                "untyped data is {} bytes, shape {dims:?} needs {}",
                data.len(),
                numel * ty.byte_size()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec(), tuple: None })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len()
    }

    /// Overwrite the payload in place (the zero-copy refill path).
    pub fn copy_raw_from(&mut self, bytes: &[u8]) -> Result<()> {
        if self.tuple.is_some() {
            return Err(Error("copy_raw_from on a tuple literal".into()));
        }
        if bytes.len() != self.data.len() {
            return Err(Error(format!(
                "refill size {} != literal size {}",
                bytes.len(),
                self.data.len()
            )));
        }
        self.data.copy_from_slice(bytes);
        Ok(())
    }

    /// Read the payload into a caller-owned buffer (no allocation).
    pub fn copy_raw_to(&self, out: &mut [u8]) -> Result<()> {
        if self.tuple.is_some() {
            return Err(Error("copy_raw_to on a tuple literal".into()));
        }
        if out.len() != self.data.len() {
            return Err(Error(format!(
                "read-out size {} != literal size {}",
                out.len(),
                self.data.len()
            )));
        }
        out.copy_from_slice(&self.data);
        Ok(())
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.tuple {
            Some(elems) => Ok(elems),
            None => Err(Error("to_tuple on a non-tuple literal".into())),
        }
    }

    /// Build a tuple literal (used by tests to fabricate results).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { ty: ElementType::F32, dims: Vec::new(), data: Vec::new(), tuple: Some(elems) }
    }

    /// Typed copy-out (allocating); mirrors xla-rs `Literal::to_vec`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::ELEMENT_TYPE {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        Ok(self
            .data
            .chunks_exact(T::ELEMENT_TYPE.byte_size())
            .map(T::from_le_chunk)
            .collect())
    }
}

/// Native element types readable out of a [`Literal`].
pub trait NativeType: Sized {
    const ELEMENT_TYPE: ElementType;
    fn from_le_chunk(chunk: &[u8]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn from_le_chunk(chunk: &[u8]) -> Self {
        f32::from_le_bytes(chunk.try_into().unwrap())
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn from_le_chunk(chunk: &[u8]) -> Self {
        i32::from_le_bytes(chunk.try_into().unwrap())
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// Computation handle (opaque in the stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

const STUB_MSG: &str = "PJRT backend unavailable: gxnor was built against the in-tree \
`xla` stub (rust/vendor/xla). Point the `xla` dependency in rust/Cargo.toml at the real \
xla-rs crate (with its PJRT CPU plugin) to compile and execute graphs";

/// PJRT client. `cpu()` fails in the stub — graph execution needs the real
/// backend; everything gated on `artifacts/` skips cleanly without it.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(STUB_MSG.into()))
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB_MSG.into()))
    }
}

/// Compiled executable handle (never constructed by the stub client).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Mirrors xla-rs: one `Vec<PjRtBuffer>` per replica.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(STUB_MSG.into()))
    }
}

/// Device buffer handle (never constructed by the stub client).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(STUB_MSG.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_refill() {
        let xs = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = xs.iter().flat_map(|v| v.to_le_bytes()).collect();
        let mut lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);

        let ys = [9.0f32, 8.0, 7.0];
        let bytes2: Vec<u8> = ys.iter().flat_map(|v| v.to_le_bytes()).collect();
        lit.copy_raw_from(&bytes2).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), ys);

        let mut out = [0u8; 12];
        lit.copy_raw_to(&mut out).unwrap();
        assert_eq!(&out[..], &bytes2[..]);

        assert!(lit.copy_raw_from(&[0u8; 4]).is_err());
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4])
                .is_err()
        );
    }

    #[test]
    fn tuple_decomposition() {
        let t = Literal::tuple(vec![Literal::scalar(1.0), Literal::scalar(2.0)]);
        let elems = t.to_tuple().unwrap();
        assert_eq!(elems.len(), 2);
        assert_eq!(elems[1].to_vec::<f32>().unwrap(), vec![2.0]);
        assert!(Literal::scalar(0.0).to_tuple().is_err());
    }

    #[test]
    fn client_fails_with_guidance() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
