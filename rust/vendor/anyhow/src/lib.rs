//! Offline-vendored minimal subset of the `anyhow` API.
//!
//! The container image has no crates.io access, so the crate graph must be
//! closed over path dependencies. This implements exactly the surface the
//! gxnor crate uses — `Error`, `Result`, the `Context` extension trait and
//! the `anyhow!` / `bail!` macros — with the same semantics (contextual
//! wrapping, `?` conversion from any `std::error::Error`). Swapping in the
//! real crate is a one-line change in `rust/Cargo.toml`.

use std::fmt;

/// An error with an optional chain of context frames.
///
/// Like the real `anyhow::Error`, this deliberately does **not** implement
/// `std::error::Error`, which is what permits the blanket
/// `From<E: std::error::Error>` impl used by the `?` operator.
pub struct Error(Box<ErrorImpl>);

struct ErrorImpl {
    msg: String,
    source: Option<Error>,
}

impl Error {
    /// Construct an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error(Box::new(ErrorImpl { msg: message.to_string(), source: None }))
    }

    /// Wrap this error with an outer context frame.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error(Box::new(ErrorImpl { msg: context.to_string(), source: Some(self) }))
    }

    /// Iterate the chain outermost-first as strings (diagnostics only).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut frames = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            frames.push(e.0.msg.as_str());
            cur = e.0.source.as_ref();
        }
        frames.into_iter()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0.msg)?;
        let mut cur = self.0.source.as_ref();
        while let Some(e) = cur {
            write!(f, ": {}", e.0.msg)?;
            cur = e.0.source.as_ref();
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // fold the std error chain into context frames
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        let mut err = Error::msg(frames.pop().unwrap());
        while let Some(f) = frames.pop() {
            err = err.context(f);
        }
        err
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// Coherent with the impl above because `Error` (a local type) does not and
// cannot downstream-implement `std::error::Error`.
impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn macro_forms() {
        let a: Error = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 3;
        let b: Error = anyhow!("got {n} and {}", 4);
        assert_eq!(b.to_string(), "got 3 and 4");
        let c: Error = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening artifact").unwrap_err();
        assert_eq!(e.to_string(), "opening artifact: missing");
        let e2 = Err::<(), Error>(e).with_context(|| "loading graph").unwrap_err();
        assert_eq!(e2.to_string(), "loading graph: opening artifact: missing");
        assert_eq!(e2.chain().count(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(e.to_string(), "empty");
    }

    #[test]
    fn bail_returns() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative {x}");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative -1");
    }
}
