//! # GXNOR-Net
//!
//! A production reproduction of *"GXNOR-Net: Training deep neural networks with
//! ternary weights and activations without full-precision memory under a unified
//! discretization framework"* (L. Deng, P. Jiao, J. Pei, Z. Wu, G. Li — Neural
//! Networks 100, 49–58, 2018).
//!
//! The system is a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build time)** — the gated-XNOR compute hot spots
//!   (ternary matmul, multi-step activation quantization, derivative
//!   approximation, DST probabilistic projection) written as Pallas kernels in
//!   `python/compile/kernels/`, checked against a pure-`jnp` oracle.
//! * **Layer 2 (JAX, build time)** — the full forward/backward graphs of the
//!   paper's networks (MLP and CNN over MNIST/CIFAR10/SVHN-class data) lowered
//!   once by `python/compile/aot.py` to HLO text in `artifacts/`.
//! * **Layer 3 (Rust, run time)** — everything in this crate: the PJRT runtime
//!   that loads and executes the artifacts, the training coordinator that owns
//!   the discrete-state-transition (DST) weight update, the dataset substrate,
//!   the event-driven hardware simulator, and the experiment/benchmark harness.
//!
//! Python never runs on the training hot path: the lowered graphs compute
//!   logits and gradients; the DST update — the paper's central contribution,
//!   weights living *permanently* in a discrete space with no full-precision
//!   hidden copy — is implemented in [`ternary::dst`] and applied by the
//!   [`coordinator`].

// Nightly-only std::simd dispatch for the bitplane lane kernels; the
// `portable-simd` cargo feature is off by default (see engine::bitplane).
#![cfg_attr(feature = "portable-simd", feature(portable_simd))]
// Dropped Results hide I/O and poisoning failures; `pub` items invisible
// outside the crate belong in `pub(crate)` so the API surface stays the
// one README documents. Scoped repo invariants (determinism, kernel
// exactness, the Remark-2 mirror ban) are enforced by `gxnor-lint` — see
// the `lint` module and README §"Invariants & static analysis".
#![deny(unused_must_use, unreachable_pub)]

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod hwsim;
pub mod lint;
pub mod metrics;
pub mod nn;
pub mod ptest;
pub mod runtime;
pub mod serve;
pub mod sweep;
pub mod ternary;
pub mod util;
