//! Miniature property-based testing framework (no proptest offline).
//!
//! Usage (`no_run`: doctest binaries don't get the xla rpath):
//! ```no_run
//! use gxnor::ptest::{property, Gen};
//! property("abs is non-negative", 200, |g: &mut Gen| {
//!     let x = g.f32_in(-10.0, 10.0);
//!     if x.abs() < 0.0 { return Err(format!("abs({x}) < 0")); }
//!     Ok(())
//! });
//! ```
//!
//! Each case runs with a deterministic per-case seed derived from the
//! property name; failures report the case index and seed so a regression
//! can be replayed with `replay(name, case)`.

use crate::util::prng::Prng;

/// Random-input generator handed to each property case.
pub struct Gen {
    rng: Prng,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Prng::new(seed) }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.rng.below(hi - lo)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn unit_f32(&mut self) -> f32 {
        self.rng.uniform_f32()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.rng.normal_f32()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.normal_f32() * scale).collect()
    }

    /// Access the raw PRNG (e.g. to feed APIs that take `&mut Prng`).
    pub fn rng(&mut self) -> &mut Prng {
        &mut self.rng
    }
}

fn name_seed(name: &str) -> u64 {
    // FNV-1a
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Run `cases` random cases of the property; panics with a replayable
/// diagnostic on the first failure.
pub fn property(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    let base = name_seed(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay with ptest::replay({name:?}, {case}, ..)"
            );
        }
    }
}

/// Re-run a single failing case by (name, case-index).
pub fn replay(name: &str, case: u64, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    let seed = name_seed(name).wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut g = Gen::new(seed);
    prop(&mut g).expect("replayed case should reproduce the failure");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        property("always-true", 50, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property \"always-false\" failed")]
    fn failing_property_panics_with_case() {
        property("always-false", 10, |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        property("det", 5, |g| {
            first.push(g.u64());
            Ok(())
        });
        let mut second = Vec::new();
        property("det", 5, |g| {
            second.push(g.u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            let x = g.f32_in(2.0, 3.0);
            assert!((2.0..3.0).contains(&x));
            let n = g.usize_in(5, 9);
            assert!((5..9).contains(&n));
        }
        let v = g.vec_f32(17, -1.0, 1.0);
        assert_eq!(v.len(), 17);
    }
}
