//! Artifact manifest: the contract between `aot.py` and the Rust runtime.
//!
//! Nothing about graph shapes or parameter ordering is hard-coded in Rust;
//! it all flows from `artifacts/manifest.json`.

use std::path::{Path, PathBuf};

use crate::nn::params::ParamDesc;
use crate::util::json::Json;

/// One named input or output of a lowered graph.
#[derive(Clone, Debug, PartialEq)]
pub struct IoDesc {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl IoDesc {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<IoDesc, String> {
        Ok(IoDesc {
            name: j.get("name").and_then(Json::as_str).ok_or("io missing name")?.into(),
            shape: j.get("shape").and_then(Json::as_usize_vec).ok_or("io missing shape")?,
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("f32")
                .into(),
        })
    }
}

/// Metadata for one lowered graph.
#[derive(Clone, Debug)]
pub struct GraphMeta {
    pub name: String,
    pub file: PathBuf,
    pub arch: String,
    pub mode: String,
    pub kind: String, // "train" | "infer"
    pub batch: usize,
    pub width: f64,
    pub input_shape: Vec<usize>,
    pub n_classes: usize,
    pub params: Vec<ParamDesc>,
    pub bn_state: Vec<IoDesc>,
    pub inputs: Vec<IoDesc>,
    pub outputs: Vec<IoDesc>,
}

impl GraphMeta {
    /// Per-sample flattened input length.
    pub fn sample_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Index of output named `name`.
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|o| o.name == name)
    }
}

/// Parsed manifest with graph lookup.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub graphs: Vec<GraphMeta>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest, String> {
        let path = Path::new(dir).join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e} (run `make artifacts` first)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &str, text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text)?;
        let graphs_obj = j
            .get("graphs")
            .and_then(Json::as_obj)
            .ok_or("manifest missing graphs object")?;
        let mut graphs = Vec::new();
        for (name, g) in graphs_obj {
            let params = g
                .get("params")
                .and_then(Json::as_arr)
                .ok_or("graph missing params")?
                .iter()
                .map(ParamDesc::from_manifest)
                .collect::<Result<Vec<_>, _>>()?;
            let parse_ios = |key: &str| -> Result<Vec<IoDesc>, String> {
                g.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| format!("graph missing {key}"))?
                    .iter()
                    .map(IoDesc::from_json)
                    .collect()
            };
            graphs.push(GraphMeta {
                name: name.clone(),
                file: Path::new(dir).join(
                    g.get("file").and_then(Json::as_str).ok_or("graph missing file")?,
                ),
                arch: g.get("arch").and_then(Json::as_str).ok_or("graph missing arch")?.into(),
                mode: g.get("mode").and_then(Json::as_str).ok_or("graph missing mode")?.into(),
                kind: g.get("kind").and_then(Json::as_str).ok_or("graph missing kind")?.into(),
                batch: g.get("batch").and_then(Json::as_usize).ok_or("graph missing batch")?,
                width: g.get("width").and_then(Json::as_f64).unwrap_or(1.0),
                input_shape: g
                    .get("input_shape")
                    .and_then(Json::as_usize_vec)
                    .ok_or("graph missing input_shape")?,
                n_classes: g.get("n_classes").and_then(Json::as_usize).unwrap_or(10),
                params,
                bn_state: parse_ios("bn_state")?,
                inputs: parse_ios("inputs")?,
                outputs: parse_ios("outputs")?,
            });
        }
        Ok(Manifest { dir: PathBuf::from(dir), graphs })
    }

    pub fn get(&self, name: &str) -> Result<&GraphMeta, String> {
        self.graphs
            .iter()
            .find(|g| g.name == name)
            .ok_or_else(|| {
                let names: Vec<&str> = self.graphs.iter().map(|g| g.name.as_str()).collect();
                format!("graph {name:?} not in manifest; available: {names:?}")
            })
    }

    /// Find the (train, infer) pair for an arch/mode/batch triple.
    pub fn find_pair(
        &self,
        arch: &str,
        mode: &str,
        batch: usize,
    ) -> Result<(&GraphMeta, &GraphMeta), String> {
        let train = self.get(&format!("{arch}_{mode}_b{batch}_train"))?;
        let infer = self.get(&format!("{arch}_{mode}_b{batch}_infer"))?;
        Ok((train, infer))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "graphs": {
        "mlp_multi_b16_train": {
          "file": "mlp_multi_b16_train.hlo.txt",
          "arch": "mlp", "mode": "multi", "batch": 16, "width": 1.0,
          "kind": "train", "input_shape": [784], "n_classes": 10,
          "params": [
            {"name": "W0", "shape": [784, 512], "kind": "weight", "layer": 0},
            {"name": "gamma0", "shape": [512], "kind": "gamma", "layer": 0}
          ],
          "bn_state": [
            {"name": "rmean0", "shape": [512], "dtype": "f32"}
          ],
          "inputs": [
            {"name": "x", "shape": [16, 784], "dtype": "f32"},
            {"name": "labels", "shape": [16], "dtype": "i32"}
          ],
          "outputs": [
            {"name": "loss", "shape": [], "dtype": "f32"},
            {"name": "ncorrect", "shape": [], "dtype": "f32"}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse("/tmp/art", SAMPLE).unwrap();
        assert_eq!(m.graphs.len(), 1);
        let g = m.get("mlp_multi_b16_train").unwrap();
        assert_eq!(g.batch, 16);
        assert_eq!(g.params.len(), 2);
        assert_eq!(g.params[0].numel(), 784 * 512);
        assert_eq!(g.inputs[1].dtype, "i32");
        assert_eq!(g.output_index("ncorrect"), Some(1));
        assert_eq!(g.sample_len(), 784);
        assert!(g.file.ends_with("mlp_multi_b16_train.hlo.txt"));
    }

    #[test]
    fn unknown_graph_lists_available() {
        let m = Manifest::parse("/tmp/art", SAMPLE).unwrap();
        let err = m.get("nope").unwrap_err();
        assert!(err.contains("mlp_multi_b16_train"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("/tmp", "{}").is_err());
        assert!(Manifest::parse("/tmp", r#"{"graphs": {"g": {}}}"#).is_err());
    }

    #[test]
    fn loads_real_manifest_when_present() {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            let m = Manifest::load("artifacts").unwrap();
            assert!(m.get("mlp_multi_b100_train").is_ok());
            let (tr, inf) = m.find_pair("mlp", "multi", 100).unwrap();
            assert_eq!(tr.kind, "train");
            assert_eq!(inf.kind, "infer");
            // contract: train inputs = x, labels, r, a, hl, params..., bn...
            let tr = m.get("mlp_multi_b100_train").unwrap();
            assert_eq!(tr.inputs[0].name, "x");
            assert_eq!(tr.inputs[2].name, "r");
            assert_eq!(
                tr.inputs.len(),
                5 + tr.params.len() + tr.bn_state.len()
            );
            assert_eq!(tr.outputs[0].name, "loss");
        }
    }
}
