//! The inference-engine abstraction: one trait, two backends.
//!
//! * [`XlaInferEngine`] — the lowered HLO infer graph executed on the PJRT
//!   client through the pooled zero-copy boundary (`runtime/client.rs`).
//! * `engine::NativeEngine` — the packed-domain gated-XNOR CPU backend
//!   (Section 3.C of the paper executed for real, not just analyzed).
//!
//! Everything above this trait — `Trainer::evaluate`, `gxnor eval/sweep`,
//! the bench harness — talks to [`ExecEngine`] only, so the two paths can
//! be selected per run (`--engine xla|native`) and A/B'd on identical
//! checkpoints (`BENCH_infer.json`).
//!
//! [`EngineKind`] also selects the *training* backend: `--engine native`
//! on `gxnor train` routes to `coordinator::trainer::NativeTrainer`
//! (device-free DST step loop, `engine::NativeTrainEngine`), while `xla`
//! keeps the lowered train graph through the pooled boundary as the A/B
//! baseline (`BENCH_step.json` v2 compares the two).

use anyhow::Result;

use crate::runtime::client::{ExecBuffers, Runtime};
use crate::runtime::manifest::GraphMeta;

/// A batched inference backend over one fixed network + weight snapshot.
pub trait ExecEngine {
    /// Backend name ("xla" | "native"), for reports and error messages.
    fn name(&self) -> &'static str;

    /// Samples per `infer_batch` call (fixed at construction).
    fn batch(&self) -> usize;

    fn n_classes(&self) -> usize;

    /// Worker threads `infer_batch` shards the batch across. Backends
    /// without a data-parallel path (the XLA graph executes as one
    /// program) report 1; the native engine reports its `--threads`
    /// setting. Purely informational — callers must not assume anything
    /// beyond "results are independent of this value".
    fn threads(&self) -> usize {
        1
    }

    /// Whether `infer_batch` accepts a *partial* batch: an input holding
    /// any 1..=`batch()` samples, returning exactly that many logit rows.
    /// The XLA backend bakes the batch dimension into the lowered program,
    /// so the default is `false`; the native engine shards whatever it is
    /// given and overrides to `true`. The serving layer requires this —
    /// SLO-coalesced batches fill to at most `max-batch`, rarely exactly.
    fn supports_partial_batch(&self) -> bool {
        false
    }

    /// Forward one batch (`batch × sample_len`, flattened NHWC) and return
    /// logits (`batch × n_classes`, row-major). The slice borrows the
    /// engine's pooled output buffer and is valid until the next call.
    /// Engines reporting [`ExecEngine::supports_partial_batch`] also accept
    /// any positive multiple of `sample_len` up to the full batch, and the
    /// returned slice then covers exactly the samples given.
    fn infer_batch(&mut self, x: &[f32]) -> Result<&[f32]>;
}

/// Which [`ExecEngine`] implementation a run evaluates on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineKind {
    /// The lowered XLA infer graph on the PJRT client.
    #[default]
    Xla,
    /// The native packed-domain gated-XNOR CPU engine.
    Native,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind, String> {
        match s {
            "xla" => Ok(EngineKind::Xla),
            "native" => Ok(EngineKind::Native),
            other => Err(format!("unknown engine {other:?} (xla|native)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Xla => "xla",
            EngineKind::Native => "native",
        }
    }
}

/// The PJRT-graph backend: a view over a loaded infer graph and its pooled
/// boundary buffers. The caller refills params/BN state once (they don't
/// change during evaluation); `infer_batch` refills only the batch input.
pub struct XlaInferEngine<'a> {
    rt: &'a Runtime,
    meta: &'a GraphMeta,
    bufs: &'a mut ExecBuffers,
}

impl<'a> XlaInferEngine<'a> {
    /// `bufs` must belong to `meta` and already hold the static scalars
    /// plus current params/BN state (the trainer guarantees this).
    pub fn new(rt: &'a Runtime, meta: &'a GraphMeta, bufs: &'a mut ExecBuffers) -> Self {
        XlaInferEngine { rt, meta, bufs }
    }
}

impl ExecEngine for XlaInferEngine<'_> {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn batch(&self) -> usize {
        self.meta.batch
    }

    fn n_classes(&self) -> usize {
        self.meta.n_classes
    }

    fn infer_batch(&mut self, x: &[f32]) -> Result<&[f32]> {
        self.bufs.set_f32(self.meta, 0, x)?;
        self.rt.execute_into(self.meta, self.bufs)?;
        Ok(&self.bufs.outputs[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_threads_is_one() {
        struct Dummy;
        impl ExecEngine for Dummy {
            fn name(&self) -> &'static str {
                "dummy"
            }
            fn batch(&self) -> usize {
                1
            }
            fn n_classes(&self) -> usize {
                1
            }
            fn infer_batch(&mut self, _x: &[f32]) -> Result<&[f32]> {
                Ok(&[])
            }
        }
        assert_eq!(Dummy.threads(), 1);
        // partial batches are opt-in: backends that don't override must
        // never be handed a short input by the serving layer
        assert!(!Dummy.supports_partial_batch());
    }

    #[test]
    fn engine_kind_parses() {
        assert_eq!(EngineKind::parse("xla").unwrap(), EngineKind::Xla);
        assert_eq!(EngineKind::parse("native").unwrap(), EngineKind::Native);
        assert!(EngineKind::parse("tpu").is_err());
        assert_eq!(EngineKind::default().name(), "xla");
        assert_eq!(EngineKind::Native.name(), "native");
    }
}
