//! PJRT runtime: loads HLO-text artifacts produced by `python/compile/aot.py`
//! and executes them on the CPU PJRT client. This is the only place the
//! process touches XLA; everything above it works with plain `&[f32]`.

pub mod client;
pub mod exec;
pub mod manifest;

pub use client::Runtime;
pub use exec::{EngineKind, ExecEngine, XlaInferEngine};
pub use manifest::{GraphMeta, IoDesc, Manifest};
