//! PJRT execution: compile-once, execute-many.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO **text** ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Executables are cached by graph name, so
//! a parameter sweep touching one graph compiles exactly once.
//!
//! Input marshalling: callers pass `&[f32]` / `&[i32]` slices in manifest
//! input order; literals are built with `create_from_shape_and_untyped_data`
//! (one memcpy, no per-element conversion). Outputs come back as a flat
//! `Vec<Vec<f32>>` in manifest output order.

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use crate::runtime::manifest::GraphMeta;

/// A caller-supplied graph input.
#[derive(Clone, Copy, Debug)]
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    Scalar(f32),
}

/// PJRT CPU runtime with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) the executable for a graph.
    pub fn load(&mut self, meta: &GraphMeta) -> Result<()> {
        if self.cache.contains_key(&meta.name) {
            return Ok(());
        }
        let path = meta
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling graph {}", meta.name))?;
        self.cache.insert(meta.name.clone(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.cache.contains_key(name)
    }

    /// Execute a loaded graph. `args` must match `meta.inputs` in order,
    /// length and dtype. Returns one flat f32 vector per manifest output.
    pub fn execute(&self, meta: &GraphMeta, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
        let exe = self
            .cache
            .get(&meta.name)
            .ok_or_else(|| anyhow!("graph {} not loaded", meta.name))?;
        if args.len() != meta.inputs.len() {
            return Err(anyhow!(
                "graph {} expects {} inputs, got {}",
                meta.name,
                meta.inputs.len(),
                args.len()
            ));
        }
        let mut literals = Vec::with_capacity(args.len());
        for (io, arg) in meta.inputs.iter().zip(args) {
            literals.push(build_literal(io, arg).with_context(|| {
                format!("building input {:?} for {}", io.name, meta.name)
            })?);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", meta.name))?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let elems = result.to_tuple()?;
        if elems.len() != meta.outputs.len() {
            return Err(anyhow!(
                "graph {} returned {} outputs, manifest says {}",
                meta.name,
                elems.len(),
                meta.outputs.len()
            ));
        }
        let mut out = Vec::with_capacity(elems.len());
        for (io, lit) in meta.outputs.iter().zip(elems) {
            let v: Vec<f32> = lit
                .to_vec()
                .with_context(|| format!("reading output {:?}", io.name))?;
            if v.len() != io.numel() {
                return Err(anyhow!(
                    "output {:?}: got {} elements, expected {}",
                    io.name,
                    v.len(),
                    io.numel()
                ));
            }
            out.push(v);
        }
        Ok(out)
    }
}

fn build_literal(io: &crate::runtime::manifest::IoDesc, arg: &Arg<'_>) -> Result<xla::Literal> {
    // single-copy construction: `vec1(..).reshape(..)` would copy twice
    // (§Perf iteration 5 — weights cross this boundary every step)
    fn as_bytes<T>(data: &[T]) -> &[u8] {
        unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
        }
    }
    match (io.dtype.as_str(), arg) {
        ("f32", Arg::Scalar(v)) => {
            if !io.shape.is_empty() {
                return Err(anyhow!("scalar arg for non-scalar input"));
            }
            Ok(xla::Literal::scalar(*v))
        }
        ("f32", Arg::F32(data)) => {
            if data.len() != io.numel() {
                return Err(anyhow!("length {} != shape numel {}", data.len(), io.numel()));
            }
            if io.shape.is_empty() {
                return Ok(xla::Literal::scalar(data[0]));
            }
            Ok(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &io.shape,
                as_bytes(data),
            )?)
        }
        ("i32", Arg::I32(data)) => {
            if data.len() != io.numel() {
                return Err(anyhow!("length {} != shape numel {}", data.len(), io.numel()));
            }
            Ok(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                &io.shape,
                as_bytes(data),
            )?)
        }
        (dt, a) => Err(anyhow!("dtype mismatch: input is {dt}, arg is {a:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn artifacts_ready() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    /// Full round-trip through a real lowered graph (needs `make artifacts`).
    #[test]
    fn executes_mlp_infer_graph() {
        if !artifacts_ready() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        let g = m.get("mlp_multi_b16_infer").unwrap();
        let mut rt = Runtime::new().unwrap();
        rt.load(g).unwrap();
        // zero weights -> logits all zero, sparsity = 1 (everything rests)
        let x = vec![0.5f32; 16 * 784];
        let mut args: Vec<Arg> = vec![Arg::F32(&x), Arg::Scalar(0.5), Arg::Scalar(1.0)];
        let park: Vec<Vec<f32>> = g
            .params
            .iter()
            .map(|p| vec![0.0f32; p.numel()])
            .collect();
        let bns: Vec<Vec<f32>> = g
            .bn_state
            .iter()
            .map(|s| {
                if s.name.starts_with("rvar") {
                    vec![1.0f32; s.numel()]
                } else {
                    vec![0.0f32; s.numel()]
                }
            })
            .collect();
        for p in &park {
            args.push(Arg::F32(p));
        }
        for s in &bns {
            args.push(Arg::F32(s));
        }
        let out = rt.execute(g, &args).unwrap();
        assert_eq!(out.len(), g.outputs.len());
        let logits = &out[0];
        assert_eq!(logits.len(), 16 * 10);
        assert!(logits.iter().all(|&v| v == 0.0));
        let spars = &out[1];
        assert!(spars.iter().all(|&s| s == 1.0), "{spars:?}");
        assert!(rt.is_loaded("mlp_multi_b16_infer"));
    }

    #[test]
    fn wrong_arity_rejected() {
        if !artifacts_ready() {
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        let g = m.get("mlp_multi_b16_infer").unwrap();
        let mut rt = Runtime::new().unwrap();
        rt.load(g).unwrap();
        let x = vec![0.0f32; 16 * 784];
        let err = rt.execute(g, &[Arg::F32(&x)]).unwrap_err();
        assert!(err.to_string().contains("expects"));
    }

    #[test]
    fn wrong_length_rejected() {
        if !artifacts_ready() {
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        let g = m.get("mlp_multi_b16_infer").unwrap();
        let mut rt = Runtime::new().unwrap();
        rt.load(g).unwrap();
        let x = vec![0.0f32; 3]; // wrong
        let mut args = vec![Arg::F32(&x), Arg::Scalar(0.5), Arg::Scalar(1.0)];
        let park: Vec<Vec<f32>> =
            g.params.iter().map(|p| vec![0.0f32; p.numel()]).collect();
        let bns: Vec<Vec<f32>> =
            g.bn_state.iter().map(|s| vec![0.0f32; s.numel()]).collect();
        for p in &park {
            args.push(Arg::F32(p));
        }
        for s in &bns {
            args.push(Arg::F32(s));
        }
        assert!(rt.execute(g, &args).is_err());
    }

    #[test]
    fn execute_unloaded_graph_errors() {
        if !artifacts_ready() {
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        let g = m.get("mlp_multi_b16_infer").unwrap();
        let rt = Runtime::new().unwrap();
        assert!(rt.execute(g, &[]).is_err());
    }
}
