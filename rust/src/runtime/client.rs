//! PJRT execution: compile-once, execute-many, marshal-nothing.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO **text** ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Executables are cached by graph name, so
//! a parameter sweep touching one graph compiles exactly once.
//!
//! Two execution paths cross the boundary:
//!
//! * [`Runtime::execute`] — one-shot: builds every input literal from the
//!   caller's slices and returns fresh `Vec<Vec<f32>>` outputs. Fine for
//!   sweeps and tests; allocates O(inputs + outputs) per call.
//! * [`Runtime::execute_into`] + [`ExecBuffers`] — the training hot path:
//!   input literals are created **once** per graph and refilled in place
//!   (`Literal::copy_raw_from`, one memcpy, no allocation), outputs are
//!   written into caller-owned reusable buffers. Together with the
//!   trainer's dirty-tracking (discrete tensors are only refilled when DST
//!   actually moved a state) the steady-state step loop performs no heap
//!   allocation in the marshalling layer at all (§Perf iteration 9).

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use crate::runtime::manifest::{GraphMeta, IoDesc};

/// A caller-supplied graph input.
#[derive(Clone, Copy, Debug)]
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    Scalar(f32),
}

fn as_bytes<T>(data: &[T]) -> &[u8] {
    // SAFETY: T is f32/i32 (plain-old-data, no padding, align 4 >= 1)
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    }
}

fn as_bytes_mut<T>(data: &mut [T]) -> &mut [u8] {
    // SAFETY: as above, and any bit pattern is a valid f32/i32
    unsafe {
        std::slice::from_raw_parts_mut(
            data.as_mut_ptr() as *mut u8,
            std::mem::size_of_val(data),
        )
    }
}

/// Per-graph pool of reusable PJRT boundary buffers.
///
/// Input literals are allocated once from the graph's manifest metadata and
/// refilled in place; output vectors are sized once and overwritten by
/// [`Runtime::execute_into`]. Callers decide *which* inputs to refill each
/// step — tensors whose host copy did not change (static scalars, discrete
/// weights with zero DST transitions) keep their previous device payload.
pub struct ExecBuffers {
    graph: String,
    literals: Vec<xla::Literal>,
    /// One flat f32 vector per manifest output, in manifest order.
    pub outputs: Vec<Vec<f32>>,
}

impl ExecBuffers {
    /// Allocate the pool for one graph: zero-filled input literals (exact
    /// shapes/dtypes from the manifest) and zero-filled output vectors.
    pub fn new(meta: &GraphMeta) -> Result<ExecBuffers> {
        let mut literals = Vec::with_capacity(meta.inputs.len());
        for io in &meta.inputs {
            let lit = if io.shape.is_empty() {
                if io.dtype != "f32" {
                    return Err(anyhow!(
                        "scalar input {:?} of {}: unsupported dtype {:?} (only f32 scalars)",
                        io.name,
                        meta.name,
                        io.dtype
                    ));
                }
                xla::Literal::scalar(0.0)
            } else {
                match io.dtype.as_str() {
                    "f32" => xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::F32,
                        &io.shape,
                        &vec![0u8; io.numel() * 4],
                    )?,
                    "i32" => xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::S32,
                        &io.shape,
                        &vec![0u8; io.numel() * 4],
                    )?,
                    other => {
                        return Err(anyhow!(
                            "input {:?} of {}: unsupported dtype {other:?}",
                            io.name,
                            meta.name
                        ))
                    }
                }
            };
            literals.push(lit);
        }
        let outputs = meta.outputs.iter().map(|o| vec![0.0f32; o.numel()]).collect();
        Ok(ExecBuffers { graph: meta.name.clone(), literals, outputs })
    }

    pub fn graph(&self) -> &str {
        &self.graph
    }

    fn check(&self, meta: &GraphMeta, idx: usize, dtype: &str, len: usize) -> Result<()> {
        if meta.name != self.graph {
            return Err(anyhow!(
                "buffer pool belongs to {}, refill targets {}",
                self.graph,
                meta.name
            ));
        }
        let io = meta
            .inputs
            .get(idx)
            .ok_or_else(|| anyhow!("input index {idx} out of range for {}", self.graph))?;
        if io.dtype != dtype {
            return Err(anyhow!(
                "input {:?} of {} is {}, refill is {dtype}",
                io.name,
                self.graph,
                io.dtype
            ));
        }
        if io.numel() != len {
            return Err(anyhow!(
                "input {:?} of {}: refill length {len} != shape numel {}",
                io.name,
                self.graph,
                io.numel()
            ));
        }
        Ok(())
    }

    /// Refill input `idx` with f32 data, in place (one memcpy).
    pub fn set_f32(&mut self, meta: &GraphMeta, idx: usize, data: &[f32]) -> Result<()> {
        self.check(meta, idx, "f32", data.len())?;
        self.literals[idx]
            .copy_raw_from(as_bytes(data))
            .with_context(|| format!("refilling input {idx} of {}", self.graph))?;
        Ok(())
    }

    /// Refill input `idx` with i32 data, in place.
    pub fn set_i32(&mut self, meta: &GraphMeta, idx: usize, data: &[i32]) -> Result<()> {
        self.check(meta, idx, "i32", data.len())?;
        self.literals[idx]
            .copy_raw_from(as_bytes(data))
            .with_context(|| format!("refilling input {idx} of {}", self.graph))?;
        Ok(())
    }

    /// Refill a scalar f32 input (static hyper-parameters: set once).
    pub fn set_scalar(&mut self, meta: &GraphMeta, idx: usize, v: f32) -> Result<()> {
        self.check(meta, idx, "f32", 1)?;
        let io = &meta.inputs[idx];
        if !io.shape.is_empty() {
            return Err(anyhow!(
                "input {:?} of {} is not a scalar",
                io.name,
                self.graph
            ));
        }
        self.literals[idx]
            .copy_raw_from(&v.to_le_bytes())
            .with_context(|| format!("refilling scalar input {idx} of {}", self.graph))?;
        Ok(())
    }

    /// Dispatch on [`Arg`] (convenience for code that already builds args).
    pub fn set_arg(&mut self, meta: &GraphMeta, idx: usize, arg: &Arg<'_>) -> Result<()> {
        match arg {
            Arg::F32(d) => self.set_f32(meta, idx, d),
            Arg::I32(d) => self.set_i32(meta, idx, d),
            Arg::Scalar(v) => self.set_scalar(meta, idx, *v),
        }
    }
}

/// PJRT CPU runtime with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) the executable for a graph.
    pub fn load(&mut self, meta: &GraphMeta) -> Result<()> {
        if self.cache.contains_key(&meta.name) {
            return Ok(());
        }
        let path = meta
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling graph {}", meta.name))?;
        self.cache.insert(meta.name.clone(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.cache.contains_key(name)
    }

    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.cache
            .get(name)
            .ok_or_else(|| anyhow!("graph {name} not loaded"))
    }

    /// Run the executable and unpack the result tuple, with contextual
    /// errors instead of panics on empty replica/device output sets.
    fn run_tuple(
        &self,
        meta: &GraphMeta,
        exe: &xla::PjRtLoadedExecutable,
        literals: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let mut replicas = exe
            .execute::<xla::Literal>(literals)
            .with_context(|| format!("executing {}", meta.name))?;
        if replicas.is_empty() || replicas[0].is_empty() {
            return Err(anyhow!(
                "graph {} produced no device outputs (replicas: {}, first replica empty)",
                meta.name,
                replicas.len()
            ));
        }
        let result = replicas[0].swap_remove(0).to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let elems = result.to_tuple()?;
        if elems.len() != meta.outputs.len() {
            return Err(anyhow!(
                "graph {} returned {} outputs, manifest says {}",
                meta.name,
                elems.len(),
                meta.outputs.len()
            ));
        }
        Ok(elems)
    }

    /// Execute a loaded graph. `args` must match `meta.inputs` in order,
    /// length and dtype. Returns one flat f32 vector per manifest output.
    ///
    /// One-shot path: builds every literal and allocates every output. The
    /// step loop uses [`Runtime::execute_into`] instead.
    pub fn execute(&self, meta: &GraphMeta, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
        let exe = self.exe(&meta.name)?;
        if args.len() != meta.inputs.len() {
            return Err(anyhow!(
                "graph {} expects {} inputs, got {}",
                meta.name,
                meta.inputs.len(),
                args.len()
            ));
        }
        let mut literals = Vec::with_capacity(args.len());
        for (io, arg) in meta.inputs.iter().zip(args) {
            literals.push(build_literal(io, arg).with_context(|| {
                format!("building input {:?} for {}", io.name, meta.name)
            })?);
        }
        let elems = self.run_tuple(meta, exe, &literals)?;
        let mut out = Vec::with_capacity(elems.len());
        for (io, lit) in meta.outputs.iter().zip(elems) {
            let v: Vec<f32> = lit
                .to_vec()
                .with_context(|| format!("reading output {:?}", io.name))?;
            if v.len() != io.numel() {
                return Err(anyhow!(
                    "output {:?}: got {} elements, expected {}",
                    io.name,
                    v.len(),
                    io.numel()
                ));
            }
            out.push(v);
        }
        Ok(out)
    }

    /// Execute a loaded graph against a pre-filled [`ExecBuffers`] pool,
    /// writing outputs into `bufs.outputs` in place. The steady-state
    /// training path: no literal construction, no output allocation.
    pub fn execute_into(&self, meta: &GraphMeta, bufs: &mut ExecBuffers) -> Result<()> {
        if bufs.graph != meta.name {
            return Err(anyhow!(
                "buffer pool belongs to {}, executing {}",
                bufs.graph,
                meta.name
            ));
        }
        if bufs.outputs.len() != meta.outputs.len() {
            return Err(anyhow!(
                "buffer pool for {} holds {} output buffers, manifest says {}",
                meta.name,
                bufs.outputs.len(),
                meta.outputs.len()
            ));
        }
        let exe = self.exe(&meta.name)?;
        let elems = self.run_tuple(meta, exe, &bufs.literals)?;
        for ((io, lit), out) in meta.outputs.iter().zip(elems).zip(bufs.outputs.iter_mut()) {
            if lit.element_count() != io.numel() {
                return Err(anyhow!(
                    "output {:?}: got {} elements, expected {}",
                    io.name,
                    lit.element_count(),
                    io.numel()
                ));
            }
            lit.copy_raw_to(as_bytes_mut(out.as_mut_slice()))
                .with_context(|| format!("reading output {:?}", io.name))?;
        }
        Ok(())
    }
}

fn build_literal(io: &IoDesc, arg: &Arg<'_>) -> Result<xla::Literal> {
    // single-copy construction: `vec1(..).reshape(..)` would copy twice
    // (§Perf iteration 5 — weights cross this boundary every step)
    match (io.dtype.as_str(), arg) {
        ("f32", Arg::Scalar(v)) => {
            if !io.shape.is_empty() {
                return Err(anyhow!("scalar arg for non-scalar input"));
            }
            Ok(xla::Literal::scalar(*v))
        }
        ("f32", Arg::F32(data)) => {
            if data.len() != io.numel() {
                return Err(anyhow!("length {} != shape numel {}", data.len(), io.numel()));
            }
            if io.shape.is_empty() {
                return Ok(xla::Literal::scalar(data[0]));
            }
            Ok(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &io.shape,
                as_bytes(data),
            )?)
        }
        ("i32", Arg::I32(data)) => {
            if data.len() != io.numel() {
                return Err(anyhow!("length {} != shape numel {}", data.len(), io.numel()));
            }
            Ok(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                &io.shape,
                as_bytes(data),
            )?)
        }
        (dt, a) => Err(anyhow!("dtype mismatch: input is {dt}, arg is {a:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn artifacts_ready() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    const POOL_SAMPLE: &str = r#"{
      "format": 1,
      "graphs": {
        "tiny_train": {
          "file": "tiny_train.hlo.txt",
          "arch": "tiny", "mode": "multi", "batch": 2, "width": 1.0,
          "kind": "train", "input_shape": [3], "n_classes": 2,
          "params": [
            {"name": "W0", "shape": [3, 2], "kind": "weight", "layer": 0}
          ],
          "bn_state": [],
          "inputs": [
            {"name": "x", "shape": [2, 3], "dtype": "f32"},
            {"name": "labels", "shape": [2], "dtype": "i32"},
            {"name": "r", "shape": [], "dtype": "f32"},
            {"name": "W0", "shape": [3, 2], "dtype": "f32"}
          ],
          "outputs": [
            {"name": "loss", "shape": [], "dtype": "f32"},
            {"name": "gW0", "shape": [3, 2], "dtype": "f32"}
          ]
        }
      }
    }"#;

    /// The pool is pure host-side marshalling: testable without a device.
    #[test]
    fn exec_buffers_refill_and_validate() {
        let m = Manifest::parse("/tmp/art", POOL_SAMPLE).unwrap();
        let g = m.get("tiny_train").unwrap();
        let mut bufs = ExecBuffers::new(g).unwrap();
        assert_eq!(bufs.graph(), "tiny_train");
        assert_eq!(bufs.outputs.len(), 2);
        assert_eq!(bufs.outputs[1].len(), 6);

        // valid refills
        bufs.set_f32(g, 0, &[0.5; 6]).unwrap();
        bufs.set_i32(g, 1, &[1, 0]).unwrap();
        bufs.set_scalar(g, 2, 0.5).unwrap();
        bufs.set_f32(g, 3, &[1.0; 6]).unwrap();
        bufs.set_arg(g, 3, &Arg::F32(&[0.0; 6])).unwrap();

        // wrong length / dtype / index / scalar-ness all rejected
        assert!(bufs.set_f32(g, 0, &[0.5; 5]).is_err());
        assert!(bufs.set_i32(g, 0, &[1; 6]).is_err());
        assert!(bufs.set_f32(g, 1, &[0.0; 2]).is_err());
        assert!(bufs.set_f32(g, 99, &[0.0; 1]).is_err());
        assert!(bufs.set_scalar(g, 0, 1.0).is_err());

        // refills against a foreign graph's meta are rejected up front
        let mut foreign = g.clone();
        foreign.name = "other".into();
        let err = bufs.set_f32(&foreign, 0, &[0.5; 6]).unwrap_err();
        assert!(err.to_string().contains("belongs to"), "{err}");
    }

    #[test]
    fn pool_rejects_foreign_graph() {
        let m = Manifest::parse("/tmp/art", POOL_SAMPLE).unwrap();
        let g = m.get("tiny_train").unwrap();
        let bufs = ExecBuffers::new(g).unwrap();
        let mut g2 = g.clone();
        g2.name = "other".into();
        if let Ok(rt) = Runtime::new() {
            let mut bufs = bufs;
            let err = rt.execute_into(&g2, &mut bufs).unwrap_err();
            assert!(err.to_string().contains("belongs to"));
        }
    }

    /// Full round-trip through a real lowered graph (needs `make artifacts`).
    #[test]
    fn executes_mlp_infer_graph() {
        if !artifacts_ready() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        let g = m.get("mlp_multi_b16_infer").unwrap();
        let mut rt = Runtime::new().unwrap();
        rt.load(g).unwrap();
        // zero weights -> logits all zero, sparsity = 1 (everything rests)
        let x = vec![0.5f32; 16 * 784];
        let mut args: Vec<Arg> = vec![Arg::F32(&x), Arg::Scalar(0.5), Arg::Scalar(1.0)];
        let park: Vec<Vec<f32>> = g
            .params
            .iter()
            .map(|p| vec![0.0f32; p.numel()])
            .collect();
        let bns: Vec<Vec<f32>> = g
            .bn_state
            .iter()
            .map(|s| {
                if s.name.starts_with("rvar") {
                    vec![1.0f32; s.numel()]
                } else {
                    vec![0.0f32; s.numel()]
                }
            })
            .collect();
        for p in &park {
            args.push(Arg::F32(p));
        }
        for s in &bns {
            args.push(Arg::F32(s));
        }
        let out = rt.execute(g, &args).unwrap();
        assert_eq!(out.len(), g.outputs.len());
        let logits = &out[0];
        assert_eq!(logits.len(), 16 * 10);
        assert!(logits.iter().all(|&v| v == 0.0));
        let spars = &out[1];
        assert!(spars.iter().all(|&s| s == 1.0), "{spars:?}");
        assert!(rt.is_loaded("mlp_multi_b16_infer"));
    }

    /// `execute_into` must agree bit-for-bit with `execute` on the same
    /// inputs — the pooled path changes marshalling, not math.
    #[test]
    fn execute_into_matches_execute() {
        if !artifacts_ready() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        let g = m.get("mlp_multi_b16_infer").unwrap();
        let mut rt = Runtime::new().unwrap();
        rt.load(g).unwrap();
        let x: Vec<f32> = (0..16 * 784).map(|i| ((i % 17) as f32) / 17.0 - 0.5).collect();
        let park: Vec<Vec<f32>> = g
            .params
            .iter()
            .enumerate()
            .map(|(k, p)| {
                (0..p.numel())
                    .map(|i| [-1.0f32, 0.0, 1.0][(i + k) % 3])
                    .collect()
            })
            .collect();
        let bns: Vec<Vec<f32>> = g
            .bn_state
            .iter()
            .map(|s| {
                if s.name.starts_with("rvar") {
                    vec![1.0f32; s.numel()]
                } else {
                    vec![0.1f32; s.numel()]
                }
            })
            .collect();
        let mut args: Vec<Arg> = vec![Arg::F32(&x), Arg::Scalar(0.5), Arg::Scalar(1.0)];
        for p in &park {
            args.push(Arg::F32(p));
        }
        for s in &bns {
            args.push(Arg::F32(s));
        }
        let reference = rt.execute(g, &args).unwrap();

        let mut bufs = ExecBuffers::new(g).unwrap();
        for (i, a) in args.iter().enumerate() {
            bufs.set_arg(g, i, a).unwrap();
        }
        // run twice: the second pass exercises buffer reuse
        rt.execute_into(g, &mut bufs).unwrap();
        rt.execute_into(g, &mut bufs).unwrap();
        assert_eq!(bufs.outputs, reference);
    }

    #[test]
    fn wrong_arity_rejected() {
        if !artifacts_ready() {
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        let g = m.get("mlp_multi_b16_infer").unwrap();
        let mut rt = Runtime::new().unwrap();
        rt.load(g).unwrap();
        let x = vec![0.0f32; 16 * 784];
        let err = rt.execute(g, &[Arg::F32(&x)]).unwrap_err();
        assert!(err.to_string().contains("expects"));
    }

    #[test]
    fn wrong_length_rejected() {
        if !artifacts_ready() {
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        let g = m.get("mlp_multi_b16_infer").unwrap();
        let mut rt = Runtime::new().unwrap();
        rt.load(g).unwrap();
        let x = vec![0.0f32; 3]; // wrong
        let mut args = vec![Arg::F32(&x), Arg::Scalar(0.5), Arg::Scalar(1.0)];
        let park: Vec<Vec<f32>> =
            g.params.iter().map(|p| vec![0.0f32; p.numel()]).collect();
        let bns: Vec<Vec<f32>> =
            g.bn_state.iter().map(|s| vec![0.0f32; s.numel()]).collect();
        for p in &park {
            args.push(Arg::F32(p));
        }
        for s in &bns {
            args.push(Arg::F32(s));
        }
        assert!(rt.execute(g, &args).is_err());
    }

    #[test]
    fn execute_unloaded_graph_errors() {
        if !artifacts_ready() {
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        let g = m.get("mlp_multi_b16_infer").unwrap();
        let rt = Runtime::new().unwrap();
        assert!(rt.execute(g, &[]).is_err());
    }
}
