//! Discrete State Transition (DST) — the paper's central training operator
//! (Section 2.D, eqs. 13–20; multilevel form eqs. 23–26).
//!
//! Given a weight vector living *exactly* on the Z_N grid and a real-valued
//! increment `dw` (already -lr·grad, possibly Adam-preconditioned), DST:
//!
//! 1. clamps the increment so the next state stays inside [-1, 1]
//!    (boundary restriction ϱ, eq. 13),
//! 2. splits ϱ into κ whole state-hops and a remainder ν (eqs. 15/16,
//!    `fix` = truncation toward zero, `rem` keeps the sign of ϱ),
//! 3. commits the κ hops deterministically and resolves the remainder with
//!    one Bernoulli draw of probability τ(ν) = tanh(m·|ν|/dz) (eqs. 18–20).
//!
//! No full-precision weight copy exists anywhere: the input *is* the
//! discrete state and the output is the next discrete state. This function
//! is the hot CPU path of training (one call per weight tensor per step)
//! and is written branch-light for vectorization; `benches/` tracks its
//! throughput and `ptest` checks its invariants against the same vectors
//! as the Python twins (python/tests/test_dst.py).

use crate::ternary::packed::PackedTensor;
use crate::ternary::space::DiscreteSpace;
use crate::util::prng::Prng;

/// Per-call statistics (used by the convergence diagnostics and hwsim).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DstStats {
    /// weights that changed state this step
    pub transitions: u64,
    /// deterministic multi-state hops (|kappa| >= 1)
    pub kappa_hops: u64,
    /// stochastic hops taken (u < tau)
    pub stochastic_hops: u64,
    pub n: u64,
}

impl DstStats {
    pub fn transition_rate(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.transitions as f64 / self.n as f64
        }
    }

    pub fn merge(&mut self, o: &DstStats) {
        self.transitions += o.transitions;
        self.kappa_hops += o.kappa_hops;
        self.stochastic_hops += o.stochastic_hops;
        self.n += o.n;
    }
}

/// Apply DST in place: `w[i] <- next state`. `w` must be on the `space`
/// grid (checked in debug builds). Returns transition statistics.
/// Resolution of the tanh lookup table used on the hot path. τ is smooth
/// and concave on [0, 1); linear interpolation over 2048 knots keeps the
/// absolute error below 2e-7 — far inside the statistical tolerance the
/// equivalence tests check (and the tail is clamped exactly).
const TANH_LUT_SIZE: usize = 2048;

/// Precomputed τ(ν) = tanh(m·|ν|/dz) over |ν|/dz ∈ [0, 1].
struct TauLut {
    table: [f32; TANH_LUT_SIZE + 1],
}

impl TauLut {
    fn new(m: f32) -> Self {
        let mut table = [0.0f32; TANH_LUT_SIZE + 1];
        for (i, t) in table.iter_mut().enumerate() {
            *t = (m * i as f32 / TANH_LUT_SIZE as f32).tanh();
        }
        TauLut { table }
    }

    #[inline]
    fn eval(&self, frac: f32) -> f32 {
        // frac = |nu|/dz in [0, 1)
        let x = frac * TANH_LUT_SIZE as f32;
        let i = (x as usize).min(TANH_LUT_SIZE - 1);
        let t = x - i as f32;
        self.table[i] + t * (self.table[i + 1] - self.table[i])
    }
}

pub fn dst_update(
    w: &mut [f32],
    dw: &[f32],
    space: DiscreteSpace,
    m: f32,
    rng: &mut Prng,
    threads: usize,
) -> DstStats {
    // one uniform per weight, drawn up front: the xoshiro state update is a
    // serial dependency chain; pre-filling (4 interleaved lanes) lets the
    // projection loop below pipeline freely (§Perf iteration 7)
    let mut u = vec![0.0f32; w.len()];
    rng.fill_uniform_x4(&mut u);

    // large tensors: shard across threads — DST is embarrassingly parallel
    // (per-element, disjoint writes) and memory-bandwidth friendly
    // (§Perf iteration 8: 17 ms -> ~5 ms / 1M on 4 cores). The count comes
    // from pool::resolve_threads so --threads/GXNOR_THREADS is honored, and
    // because uniforms are pre-drawn and shards own disjoint ranges, the
    // result is bit-identical for every thread count.
    const PAR_THRESHOLD: usize = 200_000;
    let threads = crate::util::pool::resolve_threads(threads);
    if w.len() >= PAR_THRESHOLD && threads > 1 {
        let chunk = crate::util::pool::shard_chunk(w.len(), threads.min(8));
        let tasks: Vec<_> = w
            .chunks_mut(chunk)
            .zip(dw.chunks(chunk))
            .zip(u.chunks(chunk))
            .map(|((wc, dc), uc)| move || dst_update_with_uniforms(wc, dc, uc, space, m))
            .collect();
        let mut total = DstStats::default();
        for r in crate::util::pool::scope_map(tasks) {
            total.merge(&r);
        }
        return total;
    }
    dst_update_with_uniforms(w, dw, &u, space, m)
}

/// DST with caller-supplied uniforms (also the API the equivalence tests
/// use to pin semantics against the JAX twin, which takes uniforms too).
pub fn dst_update_with_uniforms(
    w: &mut [f32],
    dw: &[f32],
    u: &[f32],
    space: DiscreteSpace,
    m: f32,
) -> DstStats {
    assert_eq!(w.len(), dw.len(), "weight/increment length mismatch");
    assert_eq!(w.len(), u.len(), "weight/uniform length mismatch");
    let dz = space.dz();
    let inv_dz = 1.0 / dz;
    let lut = TauLut::new(m);
    let mut stats = DstStats { n: w.len() as u64, ..Default::default() };
    for ((wi, &di), &ui) in w.iter_mut().zip(dw.iter()).zip(u.iter()) {
        debug_assert!(space.contains(*wi), "off-grid weight {wi}");
        let old = *wi;
        // eq. 13 as a branchless clamp: for di >= 0 the lower bound is
        // inactive (di > -1-old), for di < 0 the upper bound is — so the
        // two-sided clamp equals the paper's piecewise form exactly.
        let rho = di.clamp(-1.0 - old, 1.0 - old);
        // eq. 15/16: kappa = fix(rho/dz), nu = rem(rho, dz)
        let scaled = rho * inv_dz;
        let kappa = scaled.trunc();
        let nu_frac = (scaled - kappa).abs(); // |nu|/dz in [0, 1)
        // eq. 20: transition probability (tanh via LUT)
        let tau = lut.eval(nu_frac);
        // eq. 18/19: stochastic remainder hop along sign(rho)
        let take = (ui < tau) as u32 as f32;
        let sgn = if rho >= 0.0 { 1.0f32 } else { -1.0f32 };
        let hop = take * sgn;
        let next = (old + (kappa + hop) * dz).clamp(-1.0, 1.0);
        stats.kappa_hops += (kappa != 0.0) as u64;
        stats.stochastic_hops += (hop != 0.0) as u64;
        stats.transitions += (next != old) as u64;
        *wi = next;
    }
    stats
}

/// DST applied **directly to the packed state storage** — the native
/// training engine's update path. The weight tensor stays bit-packed
/// (1-bit binary, 2-bit ternary, up to the 7-bit Z_6 layout) end to end:
/// states stream through word-aligned chunks
/// ([`PackedTensor::state_chunks_mut`], which aligns chunk boundaries to
/// 64-state multiples so *every* bit width chunks cleanly, straddling
/// layouts included), each unpacked into a small per-chunk buffer,
/// stepped with [`dst_update_with_uniforms`], and repacked — at no point
/// does a full-tensor f32 weight copy exist (Remark 2, kept literal in
/// the step loop).
///
/// Uniform consumption is identical to [`dst_update`] (one `fill_uniform_x4`
/// over the whole tensor up front), so for the same RNG state the packed
/// and f32 paths produce bit-identical next states and statistics — pinned
/// by `packed_update_matches_f32_update`. Large tensors run their chunks
/// on scoped workers, honoring the caller's `threads` knob (0 = auto, the
/// same contract as `util::pool::resolve_threads`); every state is stepped
/// by exactly one worker with its own pre-drawn uniform and the statistics
/// are integer sums, so the result is bit-identical for any thread count.
pub fn dst_update_packed(
    p: &mut PackedTensor,
    dw: &[f32],
    m: f32,
    rng: &mut Prng,
    threads: usize,
) -> DstStats {
    assert_eq!(p.len(), dw.len(), "weight/increment length mismatch");
    let space = p.space();
    let mut u = vec![0.0f32; dw.len()];
    rng.fill_uniform_x4(&mut u);

    const PAR_THRESHOLD: usize = 200_000;
    let threads = crate::util::pool::resolve_threads(threads);
    let chunk_states = if p.len() >= PAR_THRESHOLD && threads > 1 {
        crate::util::div_ceil(p.len(), threads.min(8))
    } else {
        p.len().max(1)
    };
    let chunks = p.state_chunks_mut(chunk_states);
    let mut tasks = Vec::with_capacity(chunks.len());
    let mut off = 0usize;
    for chunk in chunks {
        let len = chunk.len();
        let dwc = &dw[off..off + len];
        let uc = &u[off..off + len];
        off += len;
        tasks.push(move || {
            let mut chunk = chunk;
            let mut buf = vec![0.0f32; chunk.len()];
            chunk.unpack_into(&mut buf);
            let stats = dst_update_with_uniforms(&mut buf, dwc, uc, space, m);
            chunk.repack_from(&buf);
            stats
        });
    }
    let mut total = DstStats::default();
    for s in crate::util::pool::scope_map(tasks) {
        total.merge(&s);
    }
    total
}

/// Reference (scalar) DST for one weight with an explicit uniform draw —
/// used by the property/equivalence tests to pin semantics independently of
/// RNG consumption order.
pub fn dst_step_scalar(w: f32, dw: f32, u: f32, dz: f32, m: f32) -> f32 {
    let rho = if dw >= 0.0 {
        dw.min(1.0 - w)
    } else {
        dw.max(-1.0 - w)
    };
    let kappa = (rho / dz).trunc();
    let nu = rho - kappa * dz;
    let tau = (m * nu.abs() / dz).tanh();
    let sgn = if rho >= 0.0 { 1.0 } else { -1.0 };
    let hop = if u < tau { sgn } else { 0.0 };
    (w + (kappa + hop) * dz).clamp(-1.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exact vectors of python/tests/test_dst.py::test_fig3_six_ternary_cases —
    /// the Rust and JAX twins must agree on every one.
    #[test]
    fn fig3_transition_table() {
        let dz = 1.0;
        let m = 3.0;
        let cases: &[(f32, f32, f32, f32)] = &[
            (0.0, 0.4, 0.0, 1.0),
            (0.0, 0.4, 1.0, 0.0),
            (0.0, -0.4, 0.0, -1.0),
            (0.0, -0.4, 1.0, 0.0),
            (-1.0, -0.7, 0.0, -1.0),
            (-1.0, 0.4, 0.0, 0.0),
            (-1.0, 1.2, 0.0, 1.0),
            (-1.0, 1.2, 1.0, 0.0),
            (1.0, 0.5, 0.0, 1.0),
            (1.0, -0.4, 0.0, 0.0),
        ];
        for &(w, dw, u, want) in cases {
            let got = dst_step_scalar(w, dw, u, dz, m);
            assert_eq!(got, want, "w={w} dw={dw} u={u}");
        }
    }

    #[test]
    fn zero_increment_is_identity() {
        let space = DiscreteSpace::TERNARY;
        let mut w = vec![-1.0, 0.0, 1.0];
        let dw = vec![0.0; 3];
        let mut rng = Prng::new(0);
        let stats = dst_update(&mut w, &dw, space, 3.0, &mut rng, 1);
        assert_eq!(w, vec![-1.0, 0.0, 1.0]);
        assert_eq!(stats.transitions, 0);
    }

    #[test]
    fn grid_closure_all_spaces() {
        let mut rng = Prng::new(42);
        for n in 0..7 {
            let space = DiscreteSpace::new(n);
            let mut w: Vec<f32> = (0..2048)
                .map(|_| space.state(rng.below(space.n_states())))
                .collect();
            let dw: Vec<f32> = (0..2048).map(|_| rng.normal_f32() * 1.5).collect();
            dst_update(&mut w, &dw, space, 3.0, &mut rng, 1);
            for &v in &w {
                assert!(space.contains(v), "N={n}: {v} off grid");
            }
        }
    }

    #[test]
    fn transition_frequency_matches_tau() {
        // eq. 20: empirical hop rate ~ tanh(m |nu| / dz)
        let space = DiscreteSpace::TERNARY;
        let m = 3.0;
        let nu = 0.37f32;
        let n = 200_000;
        let mut w = vec![0.0f32; n];
        let dw = vec![nu; n];
        let mut rng = Prng::new(7);
        let stats = dst_update(&mut w, &dw, space, m, &mut rng, 1);
        let freq = stats.transitions as f64 / n as f64;
        let tau = (m as f64 * nu as f64).tanh();
        assert!((freq - tau).abs() < 5e-3, "freq={freq} tau={tau}");
        assert_eq!(stats.stochastic_hops, stats.transitions);
        assert_eq!(stats.kappa_hops, 0);
    }

    #[test]
    fn kappa_hops_deterministic() {
        // dz = 0.25 (N=3), dw = 0.5 => kappa = 2, nu = 0
        let space = DiscreteSpace::new(3);
        let mut w = vec![-1.0f32];
        let dw = vec![0.5f32];
        let mut rng = Prng::new(1);
        let stats = dst_update(&mut w, &dw, space, 3.0, &mut rng, 1);
        assert_eq!(w[0], -0.5);
        assert_eq!(stats.kappa_hops, 1);
    }

    #[test]
    fn boundary_saturation() {
        let space = DiscreteSpace::TERNARY;
        let mut w = vec![1.0, -1.0];
        let dw = vec![100.0, -100.0];
        let mut rng = Prng::new(2);
        dst_update(&mut w, &dw, space, 3.0, &mut rng, 1);
        assert_eq!(w, vec![1.0, -1.0]);
    }

    #[test]
    fn binary_space_hops_between_poles() {
        // N=0: dz=2; from -1 an increment of +1.2 gives nu=1.2,
        // tau = tanh(3*0.6) ~ 0.947 -> nearly always flips to +1.
        let space = DiscreteSpace::BINARY;
        let n = 50_000;
        let mut w = vec![-1.0f32; n];
        let dw = vec![1.2f32; n];
        let mut rng = Prng::new(3);
        dst_update(&mut w, &dw, space, 3.0, &mut rng, 1);
        let flipped = w.iter().filter(|&&v| v == 1.0).count() as f64 / n as f64;
        let tau = (3.0f64 * 1.2 / 2.0).tanh();
        assert!((flipped - tau).abs() < 0.01, "flipped={flipped} tau={tau}");
        for &v in &w {
            assert!(v == -1.0 || v == 1.0);
        }
    }

    #[test]
    fn expected_drift_follows_gradient_sign() {
        // Many small positive increments must move the mean weight up.
        let space = DiscreteSpace::TERNARY;
        let n = 10_000;
        let mut w = vec![0.0f32; n];
        let mut rng = Prng::new(4);
        for _ in 0..5 {
            let dw = vec![0.05f32; n];
            dst_update(&mut w, &dw, space, 3.0, &mut rng, 1);
        }
        let mean: f32 = w.iter().sum::<f32>() / n as f32;
        assert!(mean > 0.2, "mean={mean}");
    }

    /// The packed-domain update must be bit-identical to the f32 update
    /// under the same RNG state — same next states, same statistics —
    /// including the parallel chunked path (large tensors, ternary *and*
    /// the straddling 3-bit N=2 layout), the binary layout, and the
    /// wider multi-level layouts (4-bit N=3, 7-bit N=6).
    #[test]
    fn packed_update_matches_f32_update() {
        for (n, len) in [
            (1u32, 250_007usize),
            (0, 10_001),
            (1, 777),
            (2, 501),
            (2, 250_007),
            (3, 2048),
            (6, 777),
        ] {
            let space = DiscreteSpace::new(n);
            let mut rng = Prng::new(100 + n as u64 + len as u64);
            let vals: Vec<f32> =
                (0..len).map(|_| space.state(rng.below(space.n_states()))).collect();
            let dw: Vec<f32> = (0..len).map(|_| rng.normal_f32() * 0.8).collect();

            let mut w = vals.clone();
            let mut rng_a = Prng::new(9);
            let stats_f32 = dst_update(&mut w, &dw, space, 3.0, &mut rng_a, 1);

            let mut p = PackedTensor::pack(&vals, &[len], space);
            let mut rng_b = Prng::new(9);
            let stats_packed = dst_update_packed(&mut p, &dw, 3.0, &mut rng_b, 0);

            assert_eq!(stats_f32, stats_packed, "N={n} len={len}: stats diverge");
            assert_eq!(p.unpack(), w, "N={n} len={len}: states diverge");
        }
    }

    /// Regression for the determinism-contract bug lint rule D1 exists to
    /// catch: `dst_update` once sized its shards from a raw
    /// `available_parallelism` probe, so the f32 path ignored the
    /// `--threads`/`GXNOR_THREADS` contract. The update must be
    /// bit-identical — next states *and* statistics — for every thread
    /// count, on tensors large enough to take the parallel path.
    #[test]
    fn f32_update_is_thread_count_invariant() {
        let space = DiscreteSpace::TERNARY;
        let len = 250_007usize; // above PAR_THRESHOLD, not a multiple of 64
        let mut rng = Prng::new(11);
        let vals: Vec<f32> =
            (0..len).map(|_| space.state(rng.below(space.n_states()))).collect();
        let dw: Vec<f32> = (0..len).map(|_| rng.normal_f32() * 0.8).collect();

        let mut want = vals.clone();
        let mut rng_ref = Prng::new(77);
        let want_stats = dst_update(&mut want, &dw, space, 3.0, &mut rng_ref, 1);

        for threads in [2usize, 3, 5, 8, 13] {
            let mut w = vals.clone();
            let mut rng_t = Prng::new(77);
            let stats = dst_update(&mut w, &dw, space, 3.0, &mut rng_t, threads);
            assert_eq!(stats, want_stats, "threads={threads}: stats diverge");
            assert_eq!(w, want, "threads={threads}: states diverge");
        }
    }

    #[test]
    fn packed_update_zero_increment_is_identity() {
        let space = DiscreteSpace::TERNARY;
        let vals = vec![-1.0f32, 0.0, 1.0, 0.0];
        let mut p = PackedTensor::pack(&vals, &[4], space);
        let mut rng = Prng::new(0);
        let stats = dst_update_packed(&mut p, &[0.0; 4], 3.0, &mut rng, 1);
        assert_eq!(stats.transitions, 0);
        assert_eq!(p.unpack(), vals);
    }

    #[test]
    fn stats_merge() {
        let mut a = DstStats { transitions: 1, kappa_hops: 2, stochastic_hops: 3, n: 4 };
        let b = DstStats { transitions: 10, kappa_hops: 20, stochastic_hops: 30, n: 40 };
        a.merge(&b);
        assert_eq!(a, DstStats { transitions: 11, kappa_hops: 22, stochastic_hops: 33, n: 44 });
        assert!((a.transition_rate() - 0.25).abs() < 1e-12);
    }
}
