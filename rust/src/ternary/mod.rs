//! The paper's discretization core: the Z_N space (eq. 1), discrete-state
//! tensors with bit-packed storage, and the Discrete State Transition
//! operator (eqs. 13–20) — the run-time twin of the Pallas kernel in
//! `python/compile/kernels/dst.py`.

pub mod dst;
pub mod packed;
pub mod space;

pub use dst::{dst_update, dst_update_packed, DstStats};
pub use packed::{PackedTensor, StateChunkMut};
pub use space::DiscreteSpace;
