//! The discrete space Z_N of eq. (1):
//!
//! ```text
//! Z_N = { n / 2^{N-1} - 1 | n = 0, 1, ..., 2^N },   dz_N = 1 / 2^{N-1}
//! ```
//!
//! N = 0 is the binary space {-1, 1} (dz = 2, and the grid is *offset*: its
//! states are not multiples of dz), N = 1 the ternary space {-1, 0, 1} of
//! GXNOR-Net, N >= 2 the multilevel spaces of Fig. 13.

/// A discrete weight/activation space parameterized by N (eq. 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DiscreteSpace {
    n: u32,
}

impl DiscreteSpace {
    pub const BINARY: DiscreteSpace = DiscreteSpace { n: 0 };
    pub const TERNARY: DiscreteSpace = DiscreteSpace { n: 1 };

    pub fn new(n: u32) -> Self {
        assert!(n <= 15, "Z_N with N={n} overflows the state index");
        DiscreteSpace { n }
    }

    pub fn n(&self) -> u32 {
        self.n
    }

    /// Number of states: 2^N + 1, except the binary space which has 2
    /// (eq. 1 with N = 0 gives n = 0, 1, 2 -> {-1, 0, 1}? No: dz_0 = 2, so
    /// n ranges over {0, 1} -> {-1, 1}; the paper's N=0 space is binary).
    pub fn n_states(&self) -> usize {
        if self.n == 0 {
            2
        } else {
            (1usize << self.n) + 1
        }
    }

    /// State spacing dz_N = 1 / 2^{N-1}; dz_0 = 2.
    pub fn dz(&self) -> f32 {
        if self.n == 0 {
            2.0
        } else {
            1.0 / (1u32 << (self.n - 1)) as f32
        }
    }

    /// Half-level count 2^{N-1} (the quantizer's `hl` scalar); 0.5 for N=0.
    pub fn half_levels(&self) -> f32 {
        if self.n == 0 {
            0.5
        } else {
            (1u32 << (self.n - 1)) as f32
        }
    }

    /// The k-th state value, k in [0, n_states).
    pub fn state(&self, k: usize) -> f32 {
        debug_assert!(k < self.n_states());
        (k as f32) * self.dz() - 1.0
    }

    /// All states, ascending.
    pub fn states(&self) -> Vec<f32> {
        (0..self.n_states()).map(|k| self.state(k)).collect()
    }

    /// Index of the nearest state to `v` (clamped).
    pub fn index_of(&self, v: f32) -> usize {
        let k = ((v + 1.0) / self.dz()).round() as isize;
        k.clamp(0, self.n_states() as isize - 1) as usize
    }

    /// Nearest-state projection.
    pub fn project(&self, v: f32) -> f32 {
        self.state(self.index_of(v))
    }

    /// Exact grid membership (within float tolerance).
    pub fn contains(&self, v: f32) -> bool {
        if !(-1.0..=1.0).contains(&v) {
            return false;
        }
        let k = (v + 1.0) / self.dz();
        (k - k.round()).abs() < 1e-5
    }

    /// Bits needed to store one state index.
    pub fn bits_per_state(&self) -> u32 {
        usize::BITS - (self.n_states() - 1).leading_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_space() {
        let s = DiscreteSpace::BINARY;
        assert_eq!(s.n_states(), 2);
        assert_eq!(s.dz(), 2.0);
        assert_eq!(s.states(), vec![-1.0, 1.0]);
        assert_eq!(s.bits_per_state(), 1);
    }

    #[test]
    fn ternary_space_matches_paper() {
        let s = DiscreteSpace::TERNARY;
        assert_eq!(s.n_states(), 3);
        assert_eq!(s.dz(), 1.0);
        assert_eq!(s.states(), vec![-1.0, 0.0, 1.0]);
        assert_eq!(s.bits_per_state(), 2);
    }

    #[test]
    fn eq1_general_form() {
        // N=2: dz = 0.5, states {-1,-0.5,0,0.5,1}
        let s = DiscreteSpace::new(2);
        assert_eq!(s.states(), vec![-1.0, -0.5, 0.0, 0.5, 1.0]);
        // N=6 (paper's best weight direction): 65 states
        assert_eq!(DiscreteSpace::new(6).n_states(), 65);
        assert!((DiscreteSpace::new(6).dz() - 1.0 / 32.0).abs() < 1e-9);
    }

    #[test]
    fn projection_roundtrip() {
        for n in 0..7 {
            let s = DiscreteSpace::new(n);
            for k in 0..s.n_states() {
                let v = s.state(k);
                assert_eq!(s.index_of(v), k);
                assert!(s.contains(v), "N={n} state {v}");
            }
        }
    }

    #[test]
    fn project_clamps_and_snaps() {
        let s = DiscreteSpace::TERNARY;
        assert_eq!(s.project(5.0), 1.0);
        assert_eq!(s.project(-5.0), -1.0);
        assert_eq!(s.project(0.4), 0.0);
        assert_eq!(s.project(0.6), 1.0);
    }

    #[test]
    fn contains_rejects_off_grid() {
        let s = DiscreteSpace::TERNARY;
        assert!(!s.contains(0.5));
        assert!(!s.contains(1.5));
        let b = DiscreteSpace::BINARY;
        assert!(!b.contains(0.0)); // binary grid is offset: 0 is not a state
    }

    #[test]
    fn bits_per_state_tight() {
        assert_eq!(DiscreteSpace::new(1).bits_per_state(), 2); // 3 states
        assert_eq!(DiscreteSpace::new(2).bits_per_state(), 3); // 5 states
        assert_eq!(DiscreteSpace::new(3).bits_per_state(), 4); // 9 states
        assert_eq!(DiscreteSpace::new(6).bits_per_state(), 7); // 65 states
    }
}
