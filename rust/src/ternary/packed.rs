//! Bit-packed storage for discrete tensors.
//!
//! The paper's memory claim (Remark 2) is that training holds *no*
//! full-precision weight copy: a ternary weight needs 2 bits, not 32.
//! `PackedTensor` is the canonical at-rest representation — checkpoints,
//! the weight store between steps, and the hwsim all use it; weights are
//! expanded to f32 grid values only to cross the PJRT boundary.

use crate::ternary::space::DiscreteSpace;
use crate::util::div_ceil;

/// u64 words holding `len` packed states of `bits` bits each — the
/// bit-string counterpart of `engine::bitplane::words_for` (which counts
/// one-bit lanes). Both ride `util::div_ceil` now instead of each module
/// open-coding `(x + 63) / 64` over subtly different operands.
const fn words_for_states(len: usize, bits: u32) -> usize {
    div_ceil(len * bits as usize, 64)
}

/// A discrete tensor stored as bit-packed state indices.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTensor {
    space: DiscreteSpace,
    shape: Vec<usize>,
    bits: u32,
    data: Vec<u64>,
    len: usize,
}

impl PackedTensor {
    /// Pack f32 grid values (each must lie on the space's grid).
    pub fn pack(values: &[f32], shape: &[usize], space: DiscreteSpace) -> Self {
        let len: usize = shape.iter().product();
        assert_eq!(len, values.len(), "shape/product mismatch");
        let bits = space.bits_per_state();
        let mut data = vec![0u64; words_for_states(len, bits)];
        for (i, &v) in values.iter().enumerate() {
            debug_assert!(space.contains(v), "off-grid value {v}");
            let idx = space.index_of(v) as u64;
            set_bits(&mut data, i, bits, idx);
        }
        PackedTensor { space, shape: shape.to_vec(), bits, data, len }
    }

    /// All-zero (or lowest-state for binary) tensor.
    pub fn zeros(shape: &[usize], space: DiscreteSpace) -> Self {
        let len: usize = shape.iter().product();
        let zero_idx = space.index_of(0.0) as u64;
        let bits = space.bits_per_state();
        let mut data = vec![0u64; words_for_states(len, bits)];
        if zero_idx != 0 {
            for i in 0..len {
                set_bits(&mut data, i, bits, zero_idx);
            }
        }
        PackedTensor { space, shape: shape.to_vec(), bits, data, len }
    }

    pub fn space(&self) -> DiscreteSpace {
        self.space
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes used by the packed payload (the paper's memory win).
    pub fn payload_bytes(&self) -> usize {
        self.data.len() * 8
    }

    pub fn get(&self, i: usize) -> f32 {
        assert!(i < self.len);
        let idx = get_bits(&self.data, i, self.bits) as usize;
        self.space.state(idx)
    }

    pub fn set(&mut self, i: usize, v: f32) {
        assert!(i < self.len);
        debug_assert!(self.space.contains(v));
        set_bits(&mut self.data, i, self.bits, self.space.index_of(v) as u64);
    }

    /// Expand to f32 grid values (the PJRT boundary format).
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len);
        for i in 0..self.len {
            out.push(self.get(i));
        }
        out
    }

    /// Expand into a caller-provided buffer (hot-path, no allocation).
    ///
    /// The 2-bit (ternary) layout gets a word-at-a-time fast path: 32
    /// states per u64, no cross-word straddling (64 % 2 == 0).
    pub fn unpack_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        unpack_words(self.space, self.bits, &self.data, out);
    }

    /// Re-pack from updated grid values (after a DST step).
    /// Same 2-bit word-at-a-time fast path as `unpack_into`.
    pub fn repack_from(&mut self, values: &[f32]) {
        assert_eq!(values.len(), self.len);
        repack_words(self.space, self.bits, &mut self.data, values);
    }

    /// Split the tensor into word-aligned mutable state chunks of about
    /// `chunk_states` states each (the last chunk carries the remainder).
    /// Works for **every** bit width, straddling layouts included: chunk
    /// boundaries land on state indices that are multiples of 64, and 64
    /// states of `b` bits occupy exactly `b` whole u64 words, so each
    /// chunk owns its words outright and any in-chunk straddling stays
    /// in-chunk.
    ///
    /// This is the packed-domain DST's streaming surface: each chunk can
    /// be unpacked into a small stack-sized buffer, stepped, and repacked
    /// by an independent worker, so the update never materializes a
    /// full-tensor f32 weight copy (the paper's Remark 2, kept literal in
    /// the training hot loop).
    pub fn state_chunks_mut(&mut self, chunk_states: usize) -> Vec<StateChunkMut<'_>> {
        if self.len == 0 {
            return Vec::new();
        }
        // round the chunk up to a multiple of 64 states = `bits` words
        let block_states = div_ceil(chunk_states.max(1), 64) * 64;
        let chunk_words = (block_states / 64) * self.bits as usize;
        let mut out = Vec::new();
        let mut remaining = self.len;
        for data in self.data.chunks_mut(chunk_words) {
            let len = remaining.min(block_states);
            out.push(StateChunkMut { space: self.space, bits: self.bits, data, len });
            remaining -= len;
        }
        debug_assert_eq!(remaining, 0);
        out
    }

    /// Histogram over state indices (sparsity/distribution diagnostics;
    /// Table 2's resting-probability analysis consumes this).
    ///
    /// The binary (1-bit) and ternary (2-bit) layouts — the paper's hot
    /// cases, where this runs every epoch over every weight tensor — are
    /// word-parallel: popcount over masked u64 words (64 resp. 32 states
    /// per word) instead of a per-element `get_bits` walk. Wider layouts
    /// fall back to the scalar walk.
    pub fn histogram(&self) -> Vec<u64> {
        match self.bits {
            1 => self.histogram_b1(),
            2 => self.histogram_b2(),
            _ => self.histogram_scalar(),
        }
    }

    /// Scalar reference walk (any bit width, including straddling ones);
    /// the word-parallel paths are checked against this in the tests.
    fn histogram_scalar(&self) -> Vec<u64> {
        let mut h = vec![0u64; self.space.n_states()];
        for i in 0..self.len {
            h[get_bits(&self.data, i, self.bits) as usize] += 1;
        }
        h
    }

    /// 1-bit (binary space): one popcount per word; tail fields masked.
    fn histogram_b1(&self) -> Vec<u64> {
        let full = self.len / 64;
        let mut ones: u64 = self.data[..full].iter().map(|w| w.count_ones() as u64).sum();
        let rem = self.len % 64;
        if rem > 0 {
            ones += (self.data[full] & ((1u64 << rem) - 1)).count_ones() as u64;
        }
        vec![self.len as u64 - ones, ones]
    }

    /// 2-bit (ternary space): 32 states per word, no straddling. Split
    /// each word into lo/hi bit planes; states 1 (`01`) and 2 (`10`) are
    /// popcounts of the exclusive planes, state 0 is the remainder. The
    /// encoding never writes `11`, so it contributes to neither count
    /// (asserted in debug builds).
    fn histogram_b2(&self) -> Vec<u64> {
        const LO: u64 = 0x5555_5555_5555_5555;
        let mut c1 = 0u64;
        let mut c2 = 0u64;
        let full = self.len / 32;
        for &w in &self.data[..full] {
            let lo = w & LO;
            let hi = (w >> 1) & LO;
            debug_assert_eq!(lo & hi, 0, "invalid ternary state 0b11");
            c1 += (lo & !hi).count_ones() as u64;
            c2 += (hi & !lo).count_ones() as u64;
        }
        let rem = self.len % 32;
        if rem > 0 {
            let w = self.data[full] & ((1u64 << (2 * rem)) - 1);
            let lo = w & LO;
            let hi = (w >> 1) & LO;
            debug_assert_eq!(lo & hi, 0, "invalid ternary state 0b11");
            c1 += (lo & !hi).count_ones() as u64;
            c2 += (hi & !lo).count_ones() as u64;
        }
        vec![self.len as u64 - c1 - c2, c1, c2]
    }

    /// Fraction of exactly-zero states (0 for the binary space).
    pub fn zero_fraction(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        let zero_state = self.space.index_of(0.0);
        if self.space.state(zero_state) != 0.0 {
            return 0.0;
        }
        self.histogram()[zero_state] as f64 / self.len as f64
    }

    // ---- binary serialization (checkpoints) ------------------------------

    /// Layout: [n: u32][ndim: u32][dims: u64 x ndim][words: u64][data].
    pub fn serialize(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.space.n().to_le_bytes());
        out.extend_from_slice(&(self.shape.len() as u32).to_le_bytes());
        for &d in &self.shape {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        out.extend_from_slice(&(self.data.len() as u64).to_le_bytes());
        for &w in &self.data {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }

    pub fn deserialize(buf: &[u8], pos: &mut usize) -> Result<Self, String> {
        let rd_u32 = |buf: &[u8], pos: &mut usize| -> Result<u32, String> {
            let b = buf
                .get(*pos..*pos + 4)
                .ok_or("truncated checkpoint")?
                .try_into()
                .unwrap();
            *pos += 4;
            Ok(u32::from_le_bytes(b))
        };
        let rd_u64 = |buf: &[u8], pos: &mut usize| -> Result<u64, String> {
            let b = buf
                .get(*pos..*pos + 8)
                .ok_or("truncated checkpoint")?
                .try_into()
                .unwrap();
            *pos += 8;
            Ok(u64::from_le_bytes(b))
        };
        let n = rd_u32(buf, pos)?;
        let ndim = rd_u32(buf, pos)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(rd_u64(buf, pos)? as usize);
        }
        let words = rd_u64(buf, pos)? as usize;
        let mut data = Vec::with_capacity(words);
        for _ in 0..words {
            data.push(rd_u64(buf, pos)?);
        }
        let space = DiscreteSpace::new(n);
        let len: usize = shape.iter().product();
        let bits = space.bits_per_state();
        if data.len() != words_for_states(len, bits) {
            return Err("packed payload size mismatch".into());
        }
        Ok(PackedTensor { space, shape, bits, data, len })
    }
}

/// A word-aligned mutable range of packed states (see
/// [`PackedTensor::state_chunks_mut`]). State indices are local to the
/// chunk; unused tail bits of the final word are don't-care padding.
pub struct StateChunkMut<'a> {
    space: DiscreteSpace,
    bits: u32,
    data: &'a mut [u64],
    len: usize,
}

impl StateChunkMut<'_> {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Expand this chunk's states into `out` (length [`StateChunkMut::len`]).
    pub fn unpack_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        unpack_words(self.space, self.bits, self.data, out);
    }

    /// Re-pack updated grid values over this chunk.
    pub fn repack_from(&mut self, values: &[f32]) {
        assert_eq!(values.len(), self.len);
        repack_words(self.space, self.bits, self.data, values);
    }
}

/// Shared word-walk behind `unpack_into` (tensor and chunk views): `out`
/// determines how many states are read.
fn unpack_words(space: DiscreteSpace, bits: u32, data: &[u64], out: &mut [f32]) {
    if bits == 2 {
        let dz = space.dz();
        for (wi, chunk) in out.chunks_mut(32).enumerate() {
            let mut word = data[wi];
            for o in chunk {
                *o = (word & 3) as f32 * dz - 1.0;
                word >>= 2;
            }
        }
        return;
    }
    for (i, o) in out.iter_mut().enumerate() {
        *o = space.state(get_bits(data, i, bits) as usize);
    }
}

/// Shared word-walk behind `repack_from`. The 2-bit fast path rewrites
/// whole words; tail bits past `values.len()` in the final word are
/// padding in every caller, so zeroing them is harmless.
fn repack_words(space: DiscreteSpace, bits: u32, data: &mut [u64], values: &[f32]) {
    if bits == 2 {
        // ternary states are exactly representable: v + 1.0 ∈ {0, 1, 2}
        for (wi, chunk) in values.chunks(32).enumerate() {
            let mut word = 0u64;
            for (j, &v) in chunk.iter().enumerate() {
                debug_assert!(space.contains(v), "off-grid value {v}");
                word |= ((v + 1.0) as u64) << (2 * j);
            }
            data[wi] = word;
        }
        return;
    }
    for (i, &v) in values.iter().enumerate() {
        debug_assert!(space.contains(v), "off-grid value {v}");
        set_bits(data, i, bits, space.index_of(v) as u64);
    }
}

#[inline]
fn set_bits(data: &mut [u64], i: usize, bits: u32, val: u64) {
    let bit_pos = i * bits as usize;
    let word = bit_pos / 64;
    let off = (bit_pos % 64) as u32;
    let mask = (1u64 << bits) - 1;
    data[word] = (data[word] & !(mask << off)) | ((val & mask) << off);
    if off + bits > 64 {
        let hi_bits = off + bits - 64;
        let lo_mask = (1u64 << hi_bits) - 1;
        data[word + 1] = (data[word + 1] & !lo_mask) | (val >> (bits - hi_bits));
    }
}

#[inline]
fn get_bits(data: &[u64], i: usize, bits: u32) -> u64 {
    let bit_pos = i * bits as usize;
    let word = bit_pos / 64;
    let off = (bit_pos % 64) as u32;
    let mask = (1u64 << bits) - 1;
    let mut v = (data[word] >> off) & mask;
    if off + bits > 64 {
        let hi_bits = off + bits - 64;
        v |= (data[word + 1] & ((1u64 << hi_bits) - 1)) << (bits - hi_bits);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn random_grid(space: DiscreteSpace, n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        (0..n).map(|_| space.state(rng.below(space.n_states()))).collect()
    }

    #[test]
    fn pack_unpack_roundtrip_all_spaces() {
        for n in 0..7 {
            let space = DiscreteSpace::new(n);
            let vals = random_grid(space, 1000, n as u64);
            let p = PackedTensor::pack(&vals, &[10, 100], space);
            assert_eq!(p.unpack(), vals, "N={n}");
        }
    }

    #[test]
    fn ternary_uses_2_bits() {
        let space = DiscreteSpace::TERNARY;
        let vals = random_grid(space, 4096, 1);
        let p = PackedTensor::pack(&vals, &[4096], space);
        // 4096 weights * 2 bits = 1 KiB vs 16 KiB f32: 16x smaller
        assert_eq!(p.payload_bytes(), 4096 * 2 / 8);
    }

    #[test]
    fn get_set() {
        let space = DiscreteSpace::TERNARY;
        let mut p = PackedTensor::zeros(&[64], space);
        assert_eq!(p.get(13), 0.0);
        p.set(13, -1.0);
        p.set(14, 1.0);
        assert_eq!(p.get(13), -1.0);
        assert_eq!(p.get(14), 1.0);
        assert_eq!(p.get(15), 0.0);
    }

    #[test]
    fn crossing_word_boundaries() {
        // 7-bit states (N=6) straddle u64 boundaries: exercise hi/lo paths.
        let space = DiscreteSpace::new(6);
        let vals = random_grid(space, 300, 9);
        let p = PackedTensor::pack(&vals, &[300], space);
        assert_eq!(p.unpack(), vals);
    }

    #[test]
    fn histogram_counts() {
        let space = DiscreteSpace::TERNARY;
        let vals = vec![-1.0, -1.0, 0.0, 1.0, 1.0, 1.0];
        let p = PackedTensor::pack(&vals, &[6], space);
        assert_eq!(p.histogram(), vec![2, 1, 3]);
        assert!((p.zero_fraction() - 1.0 / 6.0).abs() < 1e-12);
    }

    /// Word-parallel histogram/zero_fraction vs the scalar reference and
    /// an independent unpack-based count, across every space (including
    /// the 7-bit N=6 layout whose states straddle u64 boundaries) and
    /// lengths straddling word edges.
    #[test]
    fn histogram_matches_scalar_reference_all_spaces() {
        for n in 0..7u32 {
            let space = DiscreteSpace::new(n);
            for &len in &[1usize, 31, 32, 33, 63, 64, 65, 127, 300, 1000, 4096] {
                let vals = random_grid(space, len, (n as u64) << 8 | len as u64);
                let p = PackedTensor::pack(&vals, &[len], space);
                let fast = p.histogram();
                let scalar = p.histogram_scalar();
                assert_eq!(fast, scalar, "N={n} len={len}");
                // independent reference from the f32 expansion
                let mut want = vec![0u64; space.n_states()];
                for &v in &vals {
                    want[space.index_of(v)] += 1;
                }
                assert_eq!(fast, want, "N={n} len={len}");
                assert_eq!(fast.iter().sum::<u64>(), len as u64, "N={n} len={len}");
                // zero_fraction rides the same kernel
                let zf_want = if space.state(space.index_of(0.0)) == 0.0 {
                    want[space.index_of(0.0)] as f64 / len as f64
                } else {
                    0.0
                };
                assert!((p.zero_fraction() - zf_want).abs() < 1e-12, "N={n} len={len}");
            }
        }
    }

    /// The 2-bit kernel must survive tensors mutated by `set` (field
    /// clears leave no stale bits to miscount).
    #[test]
    fn histogram_after_mutation() {
        let space = DiscreteSpace::TERNARY;
        let mut p = PackedTensor::zeros(&[100], space);
        for i in (0..100).step_by(3) {
            p.set(i, 1.0);
        }
        for i in (1..100).step_by(7) {
            p.set(i, -1.0);
        }
        assert_eq!(p.histogram(), p.histogram_scalar());
        assert_eq!(p.histogram().iter().sum::<u64>(), 100);
    }

    #[test]
    fn binary_zero_fraction_is_zero() {
        let space = DiscreteSpace::BINARY;
        let p = PackedTensor::pack(&[-1.0, 1.0, 1.0], &[3], space);
        assert_eq!(p.zero_fraction(), 0.0);
    }

    #[test]
    fn unpack_into_matches_unpack() {
        let space = DiscreteSpace::new(2);
        let vals = random_grid(space, 513, 3);
        let p = PackedTensor::pack(&vals, &[513], space);
        let mut buf = vec![0.0f32; 513];
        p.unpack_into(&mut buf);
        assert_eq!(buf, p.unpack());
    }

    #[test]
    fn repack_after_dst_step() {
        let space = DiscreteSpace::TERNARY;
        let vals = random_grid(space, 256, 4);
        let mut p = PackedTensor::pack(&vals, &[256], space);
        let mut w = p.unpack();
        let dw: Vec<f32> = (0..256).map(|i| if i % 2 == 0 { 0.9 } else { -0.9 }).collect();
        let mut rng = Prng::new(5);
        crate::ternary::dst::dst_update(&mut w, &dw, space, 3.0, &mut rng, 1);
        p.repack_from(&w);
        assert_eq!(p.unpack(), w);
    }

    /// Chunked streaming access must see exactly the tensor's states, in
    /// order, and chunk-local repacks must land in the right global slots
    /// — for **every** bit width, including the straddling 3-bit (N=2)
    /// and 7-bit (N=6) layouts, which chunk on 64-state boundaries.
    #[test]
    fn state_chunks_roundtrip_and_mutate() {
        for n in [0u32, 1, 2, 3, 6] {
            let space = DiscreteSpace::new(n);
            let len = 300usize; // straddles several words for every width
            let vals = random_grid(space, len, 70 + n as u64);
            let mut p = PackedTensor::pack(&vals, &[len], space);
            let chunks = p.state_chunks_mut(70);
            let mut seen = Vec::new();
            let mut lens = Vec::new();
            for mut c in chunks {
                let mut buf = vec![0.0f32; c.len()];
                c.unpack_into(&mut buf);
                // write back a mutated copy: every state hops to state 0
                let mutated = vec![space.state(0); c.len()];
                c.repack_from(&mutated);
                seen.extend_from_slice(&buf);
                lens.push(c.len());
            }
            // chunk boundaries land on 64-state multiples (word-aligned
            // for any width); only the final chunk may be ragged
            for &l in &lens[..lens.len() - 1] {
                assert_eq!(l % 64, 0, "N={n}: interior chunk of {l} states");
            }
            assert_eq!(lens.iter().sum::<usize>(), len, "N={n}");
            assert_eq!(seen, vals, "N={n}: chunk walk differs from tensor");
            assert_eq!(p.unpack(), vec![space.state(0); len], "N={n}: repack misplaced");
        }
    }

    #[test]
    fn serialize_roundtrip() {
        for n in [0u32, 1, 3, 6] {
            let space = DiscreteSpace::new(n);
            let vals = random_grid(space, 777, 10 + n as u64);
            let p = PackedTensor::pack(&vals, &[7, 111], space);
            let mut buf = Vec::new();
            p.serialize(&mut buf);
            let mut pos = 0;
            let q = PackedTensor::deserialize(&buf, &mut pos).unwrap();
            assert_eq!(pos, buf.len());
            assert_eq!(p, q);
        }
    }

    #[test]
    fn deserialize_rejects_truncation() {
        let space = DiscreteSpace::TERNARY;
        let p = PackedTensor::pack(&[0.0, 1.0], &[2], space);
        let mut buf = Vec::new();
        p.serialize(&mut buf);
        buf.truncate(buf.len() - 1);
        let mut pos = 0;
        assert!(PackedTensor::deserialize(&buf, &mut pos).is_err());
    }
}
