//! xoshiro256++ PRNG with SplitMix64 seeding.
//!
//! The DST update (eq. 18) consumes one uniform per weight per step, so the
//! generator sits on the training hot path; xoshiro256++ is 4 adds/rotates
//! per 64-bit draw and trivially vectorizable by the compiler. Deterministic
//! seeding makes every experiment reproducible from the config seed.

/// xoshiro256++ generator. Not cryptographic; statistical quality is more
/// than sufficient for stochastic rounding.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
    /// cached second Box-Muller output
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s, spare_normal: None }
    }

    /// Derive an independent stream (e.g. one per weight tensor).
    pub fn fork(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Snapshot the full generator state (for checkpointing). Restoring
    /// via [`Prng::from_state`] continues the exact draw sequence,
    /// including the cached Box-Muller half.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare_normal)
    }

    /// Rebuild a generator from a [`Prng::state`] snapshot.
    pub fn from_state(s: [u64; 4], spare_normal: Option<f64>) -> Prng {
        Prng { s, spare_normal }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 24 bits of mantissa (f32-exact).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53 bits.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our sizes).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform_f32()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal_f32(&mut self) -> f32 {
        if let Some(z) = self.spare_normal.take() {
            return z as f32;
        }
        let u1 = self.uniform_f64().max(1e-300);
        let u2 = self.uniform_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        (r * c) as f32
    }

    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for v in out {
            *v = self.uniform_f32();
        }
    }

    /// Fill with uniforms using 4 interleaved streams: breaks the serial
    /// state-update dependency chain so the compiler can overlap the
    /// arithmetic (the DST hot path consumes one uniform per weight).
    /// Deterministic given the generator state, but a *different* sequence
    /// than repeated `uniform_f32` calls.
    pub fn fill_uniform_x4(&mut self, out: &mut [f32]) {
        let mut lanes = [
            self.fork(0x9E37),
            self.fork(0x79B9),
            self.fork(0x7F4A),
            self.fork(0x7C15),
        ];
        for chunk in out.chunks_mut(4) {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = lanes[i].uniform_f32();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_with_correct_mean() {
        let mut p = Prng::new(7);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = p.uniform_f32();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let z = p.normal_f32() as f64;
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut p = Prng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = p.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fill_uniform_x4_statistics() {
        let mut p = Prng::new(21);
        let mut buf = vec![0.0f32; 100_003]; // non-multiple of 4
        p.fill_uniform_x4(&mut buf);
        let mean: f64 = buf.iter().map(|&v| v as f64).sum::<f64>() / buf.len() as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!(buf.iter().all(|&v| (0.0..1.0).contains(&v)));
        // lanes differ
        assert_ne!(buf[0], buf[1]);
    }

    #[test]
    fn state_roundtrip_continues_sequence() {
        let mut a = Prng::new(123);
        for _ in 0..17 {
            a.next_u64();
        }
        a.normal_f32(); // leaves a spare Box-Muller half cached
        let (s, spare) = a.state();
        assert!(spare.is_some());
        let mut b = Prng::from_state(s, spare);
        for _ in 0..5 {
            assert_eq!(a.normal_f32().to_bits(), b.normal_f32().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Prng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
