//! Terminal line/scatter plots for the figure benches (no plotting crate
//! offline). Renders (x, y) series on a character grid with axis labels —
//! enough to *see* the U-shapes of Figs. 8/9/10/13 in `cargo bench` output.

use std::fmt::Write as _;

/// Render one or more named series on a shared grid.
/// Each series is a list of (x, y) points; markers cycle through `*+ox#`.
pub fn line_plot(
    title: &str,
    series: &[(&str, Vec<(f64, f64)>)],
    width: usize,
    height: usize,
) -> String {
    let all: Vec<(f64, f64)> = series.iter().flat_map(|(_, p)| p.iter().copied()).collect();
    if all.is_empty() {
        return format!("{title}: (no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let markers = ['*', '+', 'o', 'x', '#'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        let mk = markers[si % markers.len()];
        for &(x, y) in pts {
            let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = mk;
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{y1:>8.3}")
        } else if r == height - 1 {
            format!("{y0:>8.3}")
        } else {
            " ".repeat(8)
        };
        let _ = writeln!(out, "{label} |{}|", row.iter().collect::<String>());
    }
    let _ = writeln!(
        out,
        "{:>8}  {x0:<12.3}{:>w$.3}",
        "",
        x1,
        w = width.saturating_sub(12)
    );
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {name}", markers[i % markers.len()]))
        .collect();
    let _ = writeln!(out, "{:>10}{}", "", legend.join("   "));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plots_single_series() {
        let pts: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, (i * i) as f64)).collect();
        let p = line_plot("parabola", &[("y=x^2", pts)], 40, 10);
        assert!(p.contains("parabola"));
        assert!(p.contains('*'));
        assert!(p.contains("81.000")); // y max label
        assert!(p.contains("y=x^2"));
    }

    #[test]
    fn plots_multiple_series_with_distinct_markers() {
        let a: Vec<(f64, f64)> = (0..5).map(|i| (i as f64, i as f64)).collect();
        let b: Vec<(f64, f64)> = (0..5).map(|i| (i as f64, 4.0 - i as f64)).collect();
        let p = line_plot("cross", &[("up", a), ("down", b)], 30, 8);
        assert!(p.contains('*') && p.contains('+'));
    }

    #[test]
    fn handles_degenerate_input() {
        assert!(line_plot("empty", &[("none", vec![])], 10, 5).contains("no data"));
        let p = line_plot("point", &[("p", vec![(1.0, 1.0)])], 10, 5);
        assert!(p.contains('*'));
    }
}
