//! Foundation substrates built from scratch (the offline vendor set has no
//! serde/rand/clap/criterion — see DESIGN.md §2): PRNG, JSON, timing.

pub mod json;
pub mod plot;
pub mod prng;
pub mod timer;

pub use json::Json;
pub use prng::Prng;
pub use timer::Stopwatch;
