//! Foundation substrates built from scratch (the offline vendor set has no
//! serde/rand/clap/criterion — see DESIGN.md §2): PRNG, JSON, timing.

pub mod align;
pub mod crc32;
pub mod fault;
pub mod json;
pub mod lock;
pub mod plot;
pub mod pool;
pub mod prng;
pub mod timer;

pub use fault::FaultPlan;
pub use json::Json;
pub use lock::lock_recover;
pub use prng::Prng;
pub use timer::Stopwatch;

/// Ceiling division: the number of `b`-sized chunks covering `a`. The
/// shared home the packing word-count helpers delegate to
/// (`engine::bitplane::words_for`, `ternary::packed::words_for_states`,
/// `util::pool::shard_chunk`, DST chunking) instead of each open-coding
/// `(a + b - 1) / b` over subtly different operands. Plain call sites
/// may equally use std's `usize::div_ceil`, which this wraps (`const`,
/// so array dimensions can use it too).
pub const fn div_ceil(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Index of the first maximal element under `f32::total_cmp` (NaN-safe;
/// first occurrence wins on exact ties, matching `jnp.argmax`). Shared by
/// every engine's evaluation path so XLA and native classify identically.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in xs.iter().enumerate().skip(1) {
        if v.total_cmp(&xs[best]) == std::cmp::Ordering::Greater {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::{argmax, div_ceil};

    #[test]
    fn div_ceil_matches_definition() {
        assert_eq!(div_ceil(0, 64), 0);
        assert_eq!(div_ceil(1, 64), 1);
        assert_eq!(div_ceil(64, 64), 1);
        assert_eq!(div_ceil(65, 64), 2);
        assert_eq!(div_ceil(128, 64), 2);
        for a in 0..200usize {
            for b in 1..10usize {
                assert_eq!(div_ceil(a, b), a.div_ceil(b), "{a}/{b}");
            }
        }
    }

    #[test]
    fn argmax_first_max_wins_and_handles_nan() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9, 0.2]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[-1.0, -0.5, -2.0]), 1);
        // total_cmp orders +NaN above +inf: deterministic, never panics
        assert_eq!(argmax(&[0.0, f32::NAN, 1.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -0.0, 0.0]), 2);
    }
}
