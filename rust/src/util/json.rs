//! Minimal JSON parser/writer (no serde in the offline vendor set).
//!
//! Parses the artifact manifest written by `python/compile/aot.py` and
//! serializes experiment reports. Supports the full JSON grammar except
//! exotic number forms; preserves object key order (the manifest's input
//! ordering is semantically meaningful).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects keep insertion order via a Vec of pairs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// `[1, 2, 3]` -> `vec![1, 2, 3]`.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // ---- parsing ---------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }

    // ---- writing ---------------------------------------------------------

    // inherent by design: no Display impl wanted for a JSON value
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literal: emitting `NaN` or
                    // `inf` produces an unparsable document (TrainReport's
                    // final_train_loss defaults to NaN and flows into the
                    // bench output). Non-finite serializes as null.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Builder helpers for report emission.
    pub fn obj(kvs: Vec<(&str, Json)>) -> Json {
        Json::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected {lit:?} at byte {pos}", pos = *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'n' => expect(b, pos, "null").map(|_| Json::Null),
        b't' => expect(b, pos, "true").map(|_| Json::Bool(true)),
        b'f' => expect(b, pos, "false").map(|_| Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut v = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            loop {
                v.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(v));
                    }
                    _ => return Err(format!("expected , or ] at byte {pos}", pos = *pos)),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut kvs = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(kvs));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, ":")?;
                let val = parse_value(b, pos)?;
                kvs.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(kvs));
                    }
                    _ => return Err(format!("expected , or }} at byte {pos}", pos = *pos)),
                }
            }
        }
        _ => parse_number(b, pos),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos}", pos = *pos));
    }
    *pos += 1;
    let mut s = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(s);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|e| e.to_string())?;
                        let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err("bad escape".into()),
                }
                *pos += 1;
            }
            c => {
                // copy one UTF-8 scalar
                let len = utf8_len(c);
                s.push_str(
                    std::str::from_utf8(&b[*pos..*pos + len]).map_err(|e| e.to_string())?,
                );
                *pos += len;
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    s.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number {s:?} at byte {start}"))
}

/// The shared provenance header every `BENCH_*.json` document embeds
/// under the `"provenance"` key, so benchmark numbers are comparable
/// build-to-build: git revision, rustc version, available hardware
/// threads, the kernel lane width, and the feature flags that change
/// codegen. `lane_words` is passed in (util cannot depend on the engine);
/// callers hand it `engine::bitplane::LANE_WORDS`. Fields that cannot be
/// determined (no git, no rustc on PATH) serialize as `null` rather than
/// failing the bench run.
pub fn provenance(lane_words: usize) -> Json {
    fn cmd_line(prog: &str, args: &[&str]) -> Json {
        std::process::Command::new(prog)
            .args(args)
            .output()
            .ok()
            .filter(|o| o.status.success())
            .and_then(|o| String::from_utf8(o.stdout).ok())
            .map(|s| Json::Str(s.trim().to_string()))
            .unwrap_or(Json::Null)
    }
    // Provenance wants the machine's raw hardware-thread count, not the
    // resolved work-splitting decision — a GXNOR_THREADS override must not
    // masquerade as the host's parallelism in a bench record.
    #[allow(clippy::disallowed_methods)]
    fn hw_threads() -> Json {
        // lint:allow(D1): provenance reports raw hardware parallelism, not a work-split choice
        std::thread::available_parallelism()
            .map(|n| Json::Num(n.get() as f64))
            .unwrap_or(Json::Null)
    }
    let threads = hw_threads();
    Json::obj(vec![
        ("git_rev", cmd_line("git", &["rev-parse", "HEAD"])),
        ("rustc", cmd_line("rustc", &["--version"])),
        ("threads_available", threads),
        ("lane_words", Json::Num(lane_words as f64)),
        (
            "features",
            Json::obj(vec![("portable_simd", Json::Bool(cfg!(feature = "portable-simd")))]),
        ),
        ("debug_assertions", Json::Bool(cfg!(debug_assertions))),
    ])
}

/// Flat "key -> f64" convenience for metrics files.
pub fn to_f64_map(j: &Json) -> BTreeMap<String, f64> {
    let mut m = BTreeMap::new();
    if let Json::Obj(kvs) = j {
        for (k, v) in kvs {
            if let Json::Num(n) = v {
                m.insert(k.clone(), *n);
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn preserves_key_order() {
        let j = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = j.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"mlp","shape":[100,784],"f":0.5,"ok":true,"n":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
        // JSON has no NaN/Infinity literals — and we never emit them
        assert!(Json::parse("NaN").is_err());
        assert!(Json::parse("inf").is_err());
    }

    /// Non-finite numbers serialize as `null` (valid JSON) and round-trip
    /// through the parser; finite neighbours are untouched.
    #[test]
    fn non_finite_numbers_serialize_as_null_and_roundtrip() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let doc = Json::obj(vec![("loss", Json::Num(v)), ("acc", Json::Num(0.5))]);
            let text = doc.to_string();
            assert_eq!(text, r#"{"loss":null,"acc":0.5}"#);
            let back = Json::parse(&text).unwrap();
            assert_eq!(back.get("loss"), Some(&Json::Null));
            assert_eq!(back.get("acc").and_then(Json::as_f64), Some(0.5));
        }
        assert_eq!(Json::Num(1e300).to_string(), "1e300");
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn usize_vec() {
        let j = Json::parse("[100, 784]").unwrap();
        assert_eq!(j.as_usize_vec(), Some(vec![100, 784]));
    }

    /// The provenance header always carries its full key set (missing
    /// tools degrade to null, never to absent keys) and round-trips.
    #[test]
    fn provenance_header_is_structurally_complete() {
        let p = provenance(8);
        for key in
            ["git_rev", "rustc", "threads_available", "lane_words", "features", "debug_assertions"]
        {
            assert!(p.get(key).is_some(), "missing {key}");
        }
        assert_eq!(p.get("lane_words").and_then(Json::as_usize), Some(8));
        assert!(p.get("features").unwrap().get("portable_simd").is_some());
        let back = Json::parse(&p.to_string()).unwrap();
        assert_eq!(back.get("lane_words").and_then(Json::as_usize), Some(8));
    }
}
