//! Poison-recovering mutex acquisition.
//!
//! `Mutex::lock` returns `Err(PoisonError)` if a previous holder
//! panicked. For the serving stack that default is exactly wrong: one
//! replica panic would make every subsequent stats probe, dispatcher
//! tick, and connection handler panic too, cascading a single bad batch
//! into a dead service. All our guarded state (counters, job receivers)
//! stays structurally valid across a panic — counts may be off by the
//! in-flight increment, which we accept — so recovery is always safe.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard if the mutex is poisoned.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::lock_recover;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::Mutex;

    #[test]
    fn recovers_after_panic_poisons_mutex() {
        let m = Mutex::new(7u32);
        let r = catch_unwind(AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison it");
        }));
        assert!(r.is_err());
        assert!(m.is_poisoned());
        let mut g = lock_recover(&m);
        assert_eq!(*g, 7);
        *g += 1;
        drop(g);
        assert_eq!(*lock_recover(&m), 8);
    }
}
