//! Deterministic fault injection.
//!
//! A `FaultPlan` names the exact fault to fire and when — "panic the
//! replica handling the 3rd batch", "tear the 1st checkpoint write" —
//! so recovery paths are *proved* by tests and CI smoke jobs instead of
//! being trusted by inspection. Determinism comes from counting, not
//! randomness: the Nth event fires, every run, every machine.
//!
//! Plans are carried as `Option<Arc<FaultPlan>>` and resolved once at
//! startup from `--faults` / `GXNOR_FAULTS`; the disabled path is a
//! `None` check at each injection point, so production costs nothing.
//!
//! Spec grammar: comma-separated `knob=N` pairs, `N = 0` disables.
//!
//! | knob                | fires                                        |
//! |---------------------|----------------------------------------------|
//! | `replica_panic=N`   | panic inside `infer_batch` on the Nth batch  |
//! | `torn_ckpt=N`       | Nth checkpoint write stops halfway, no rename|
//! | `conn_drop=K`       | server drops each connection after K frames  |
//! | `delay_dispatch_ms=D` | dispatcher sleeps D ms before each batch   |
//! | `train_crash=E`     | training aborts right after epoch E completes|

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// A parsed, armed fault plan. Counters are process-global per plan:
/// "the Nth batch" means the Nth across all replicas, in dispatch order.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// Panic the replica worker on the Nth inference batch (1-based).
    pub replica_panic_batch: Option<u64>,
    /// Truncate the Nth checkpoint write halfway and fail it (1-based).
    pub torn_ckpt_write: Option<u64>,
    /// Server drops each connection after K handled frames.
    pub conn_drop_frames: Option<u64>,
    /// Dispatcher sleeps this long before sending each batch to the pool.
    pub delay_dispatch_ms: Option<u64>,
    /// Abort training with an error right after this epoch completes
    /// (1-based: `train_crash=2` dies after the 2nd epoch's checkpoint).
    pub train_crash_epoch: Option<u64>,
    batches: AtomicU64,
    ckpt_writes: AtomicU64,
}

/// How fault plans travel through config structs: absent = disabled.
pub type Faults = Option<Arc<FaultPlan>>;

impl FaultPlan {
    /// Parse a `knob=N,knob=N` spec. Unknown knobs are an error (a typo
    /// must not silently disarm a fault the CI job depends on).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec `{part}`: expected knob=N"))?;
            let n: u64 = val
                .trim()
                .parse()
                .map_err(|_| format!("fault spec `{part}`: `{val}` is not a count"))?;
            let slot = match key.trim() {
                "replica_panic" => &mut plan.replica_panic_batch,
                "torn_ckpt" => &mut plan.torn_ckpt_write,
                "conn_drop" => &mut plan.conn_drop_frames,
                "delay_dispatch_ms" => &mut plan.delay_dispatch_ms,
                "train_crash" => &mut plan.train_crash_epoch,
                other => {
                    return Err(format!(
                        "unknown fault knob `{other}` (knobs: replica_panic, \
                         torn_ckpt, conn_drop, delay_dispatch_ms, train_crash)"
                    ))
                }
            };
            *slot = (n != 0).then_some(n);
        }
        Ok(plan)
    }

    /// Resolve the effective plan: the CLI flag wins, else `GXNOR_FAULTS`,
    /// else disabled. Empty specs resolve to `None` so `--faults ""` and
    /// an unset env var mean "off", not "armed with nothing".
    pub fn resolve(flag: &str) -> Result<Faults, String> {
        let spec = if !flag.is_empty() {
            flag.to_string()
        } else {
            std::env::var("GXNOR_FAULTS").unwrap_or_default()
        };
        if spec.trim().is_empty() {
            return Ok(None);
        }
        let plan = Self::parse(&spec)?;
        if plan.is_empty() {
            return Ok(None);
        }
        Ok(Some(Arc::new(plan)))
    }

    fn is_empty(&self) -> bool {
        self.replica_panic_batch.is_none()
            && self.torn_ckpt_write.is_none()
            && self.conn_drop_frames.is_none()
            && self.delay_dispatch_ms.is_none()
            && self.train_crash_epoch.is_none()
    }

    /// Advance the batch counter; true exactly once, on the Nth call.
    /// Counts only advance while the knob is armed, so the fire point is
    /// stable regardless of how many plans share a process.
    pub fn fire_replica_panic(&self) -> bool {
        match self.replica_panic_batch {
            Some(n) => self.batches.fetch_add(1, Ordering::Relaxed) + 1 == n,
            None => false,
        }
    }

    /// Advance the checkpoint-write counter; true exactly once, on the
    /// Nth call.
    pub fn fire_torn_write(&self) -> bool {
        match self.torn_ckpt_write {
            Some(n) => self.ckpt_writes.fetch_add(1, Ordering::Relaxed) + 1 == n,
            None => false,
        }
    }

    /// Frames after which the server should drop a connection, if armed.
    pub fn conn_drop_frames(&self) -> Option<u64> {
        self.conn_drop_frames
    }

    /// Artificial dispatch latency, if armed.
    pub fn dispatch_delay(&self) -> Option<Duration> {
        self.delay_dispatch_ms.map(Duration::from_millis)
    }

    /// True when training should abort after completing `epoch_done`
    /// (1-based count of finished epochs).
    pub fn fire_train_crash(&self, epoch_done: u64) -> bool {
        self.train_crash_epoch == Some(epoch_done)
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut knobs = Vec::new();
        if let Some(n) = self.replica_panic_batch {
            knobs.push(format!("replica_panic={n}"));
        }
        if let Some(n) = self.torn_ckpt_write {
            knobs.push(format!("torn_ckpt={n}"));
        }
        if let Some(n) = self.conn_drop_frames {
            knobs.push(format!("conn_drop={n}"));
        }
        if let Some(n) = self.delay_dispatch_ms {
            knobs.push(format!("delay_dispatch_ms={n}"));
        }
        if let Some(n) = self.train_crash_epoch {
            knobs.push(format!("train_crash={n}"));
        }
        write!(f, "{}", knobs.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::FaultPlan;

    #[test]
    fn parses_full_spec_and_roundtrips_display() {
        let p = FaultPlan::parse(
            "replica_panic=3, torn_ckpt=1,conn_drop=5,delay_dispatch_ms=20,train_crash=2",
        )
        .unwrap();
        assert_eq!(p.replica_panic_batch, Some(3));
        assert_eq!(p.torn_ckpt_write, Some(1));
        assert_eq!(p.conn_drop_frames, Some(5));
        assert_eq!(p.delay_dispatch_ms, Some(20));
        assert_eq!(p.train_crash_epoch, Some(2));
        let rt = FaultPlan::parse(&p.to_string()).unwrap();
        assert_eq!(rt.replica_panic_batch, Some(3));
        assert_eq!(rt.train_crash_epoch, Some(2));
    }

    #[test]
    fn zero_disables_and_unknown_knob_errors() {
        let p = FaultPlan::parse("replica_panic=0").unwrap();
        assert!(p.replica_panic_batch.is_none());
        assert!(p.is_empty());
        assert!(FaultPlan::parse("replika_panic=1").is_err());
        assert!(FaultPlan::parse("replica_panic").is_err());
        assert!(FaultPlan::parse("replica_panic=lots").is_err());
    }

    #[test]
    fn counters_fire_exactly_once_on_nth_event() {
        let p = FaultPlan::parse("replica_panic=3,torn_ckpt=1").unwrap();
        assert!(!p.fire_replica_panic());
        assert!(!p.fire_replica_panic());
        assert!(p.fire_replica_panic());
        assert!(!p.fire_replica_panic());
        assert!(p.fire_torn_write());
        assert!(!p.fire_torn_write());
        // disarmed knobs never fire and never advance
        let off = FaultPlan::default();
        for _ in 0..10 {
            assert!(!off.fire_replica_panic());
            assert!(!off.fire_torn_write());
        }
        assert!(!off.fire_train_crash(1));
        assert!(!p.fire_train_crash(0));
    }

    #[test]
    fn train_crash_matches_only_its_epoch() {
        let p = FaultPlan::parse("train_crash=2").unwrap();
        assert!(!p.fire_train_crash(1));
        assert!(p.fire_train_crash(2));
        assert!(!p.fire_train_crash(3));
    }
}
