//! Lightweight timing helpers for the coordinator and the bench harness.

use std::time::Instant;

/// Accumulating stopwatch: measure disjoint spans of the same phase.
#[derive(Debug)]
pub struct Stopwatch {
    start: Option<Instant>,
    total_ns: u128,
    laps: u64,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: None, total_ns: 0, laps: 0 }
    }

    pub fn start(&mut self) {
        self.start = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(s) = self.start.take() {
            self.total_ns += s.elapsed().as_nanos();
            self.laps += 1;
        }
    }

    /// Discard accumulated laps (e.g. after benchmark warmup).
    pub fn reset(&mut self) {
        self.start = None;
        self.total_ns = 0;
        self.laps = 0;
    }

    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }

    pub fn total_secs(&self) -> f64 {
        self.total_ns as f64 / 1e9
    }

    pub fn laps(&self) -> u64 {
        self.laps
    }

    pub fn mean_ms(&self) -> f64 {
        if self.laps == 0 {
            0.0
        } else {
            self.total_ns as f64 / 1e6 / self.laps as f64
        }
    }
}

/// Nearest-rank percentile (`p` in [0, 100]) of unsorted samples; 0.0 on
/// empty input. Thin re-export: the single definition (exact nearest-rank,
/// shared with the serve stats and `BENCH_serve.json`) lives in
/// [`crate::metrics::percentile`]; kept here so bench/timing call sites keep
/// their historical import path.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    crate::metrics::percentile(samples, p)
}

/// Run `f` `iters` times, returning (mean_ms, min_ms, max_ms).
pub fn time_iters(iters: usize, mut f: impl FnMut()) -> (f64, f64, f64) {
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let sum: f64 = times.iter().sum();
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    (sum / iters as f64, min, max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        for _ in 0..3 {
            sw.time(|| std::thread::sleep(std::time::Duration::from_millis(2)));
        }
        assert_eq!(sw.laps(), 3);
        assert!(sw.total_secs() >= 0.006);
        assert!(sw.mean_ms() >= 2.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        let p50 = percentile(&v, 50.0);
        assert!((50.0..=51.0).contains(&p50), "{p50}");
        let p99 = percentile(&v, 99.0);
        assert!((99.0..=100.0).contains(&p99), "{p99}");
        // order-independent
        let mut rev = v.clone();
        rev.reverse();
        assert_eq!(percentile(&rev, 99.0), p99);
    }

    #[test]
    fn time_iters_stats_ordered() {
        let (mean, min, max) = time_iters(5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(min <= mean && mean <= max);
    }
}
