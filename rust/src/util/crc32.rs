//! CRC-32 (IEEE 802.3, polynomial 0xEDB88320), table-driven.
//!
//! Used as the integrity check on v2 run checkpoints: a torn or
//! bit-flipped file must be *detected* as corrupt, never half-restored.
//! The table is built at compile time — no lazy init, no dependencies.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `bytes` (standard init/final XOR with `!0`).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

#[cfg(test)]
mod tests {
    use super::crc32;

    #[test]
    fn known_vectors() {
        // the canonical CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn detects_single_bit_flips() {
        let mut data = b"the quick brown fox jumps over the lazy dog".to_vec();
        let base = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                data[byte] ^= 1 << bit;
                assert_ne!(crc32(&data), base, "flip at {byte}:{bit} undetected");
                data[byte] ^= 1 << bit;
            }
        }
        assert_eq!(crc32(&data), base);
    }
}
