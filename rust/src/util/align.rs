//! Cache-line-aligned `u64` buffers for the bitplane kernels.
//!
//! The gated-XNOR kernels read sign/nonzero/digit planes in multi-word
//! lanes (`engine::bitplane::LANE_WORDS` words per iteration). Lane loads
//! only stay cache-line aligned if (a) every plane buffer *starts* on a
//! 64-byte boundary and (b) every per-row / per-column stride is a whole
//! number of lanes. This module provides (a); `bitplane::words_stride`
//! provides (b). `AlignedWords` is the one aligned-alloc util shared by
//! `PackScratch` and `BitplaneCols`.

use std::ops::{Deref, DerefMut};

/// Alignment of every plane buffer: one cache line.
pub const LINE_BYTES: usize = 64;

/// `u64` words per cache line — the kernel lane width derives from this.
pub const LINE_WORDS: usize = LINE_BYTES / std::mem::size_of::<u64>();

/// One cache line of words. `repr(C, align(64))` makes a `Vec<Line>`
/// allocation 64-byte aligned with no unsafe raw-alloc plumbing; the
/// buffer views it as a flat `[u64]`.
#[repr(C, align(64))]
#[derive(Clone, Copy, Default)]
struct Line([u64; LINE_WORDS]);

/// A contiguous `u64` buffer whose first word sits on a 64-byte boundary
/// and whose length is always a whole number of cache lines. Derefs to
/// `[u64]`, so call sites index and slice it like a `Vec<u64>`.
#[derive(Clone, Default)]
pub struct AlignedWords {
    lines: Vec<Line>,
}

impl AlignedWords {
    pub fn new() -> Self {
        Self::default()
    }

    /// A zero-filled buffer of at least `words` words (rounded up to a
    /// whole cache line).
    pub fn zeroed(words: usize) -> Self {
        let mut buf = Self::default();
        buf.ensure(words);
        buf
    }

    /// Grow to at least `words` words, zero-filling any new lines. Never
    /// shrinks (scratch reuse keeps the high-water allocation, matching
    /// the previous `Vec::resize`-if-shorter behaviour); existing word
    /// contents are preserved, so packers must clear the slices they
    /// write into (see `bitplane::pack_row_into`).
    pub fn ensure(&mut self, words: usize) {
        let lines = crate::util::div_ceil(words, LINE_WORDS);
        if lines > self.lines.len() {
            self.lines.resize(lines, Line([0; LINE_WORDS]));
        }
    }

    /// Zero the whole buffer (all lines, not just a logical prefix).
    pub fn clear(&mut self) {
        self.lines.fill(Line([0; LINE_WORDS]));
    }

    pub fn as_slice(&self) -> &[u64] {
        // SAFETY: `Line` is `repr(C)` over `[u64; LINE_WORDS]`, so a
        // `Vec<Line>` of length L is exactly L*LINE_WORDS contiguous,
        // initialised u64 words.
        unsafe {
            std::slice::from_raw_parts(self.lines.as_ptr().cast(), self.lines.len() * LINE_WORDS)
        }
    }

    pub fn as_mut_slice(&mut self) -> &mut [u64] {
        // SAFETY: as in `as_slice`; exclusive borrow of self.
        unsafe {
            std::slice::from_raw_parts_mut(
                self.lines.as_mut_ptr().cast(),
                self.lines.len() * LINE_WORDS,
            )
        }
    }
}

impl Deref for AlignedWords {
    type Target = [u64];

    #[inline]
    fn deref(&self) -> &[u64] {
        self.as_slice()
    }
}

impl DerefMut for AlignedWords {
    #[inline]
    fn deref_mut(&mut self) -> &mut [u64] {
        self.as_mut_slice()
    }
}

impl std::fmt::Debug for AlignedWords {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AlignedWords").field("words", &self.len()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_are_line_aligned_and_line_granular() {
        for words in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let buf = AlignedWords::zeroed(words);
            assert_eq!(buf.as_slice().as_ptr() as usize % LINE_BYTES, 0, "words={words}");
            assert_eq!(buf.len(), crate::util::div_ceil(words, LINE_WORDS) * LINE_WORDS);
            assert!(buf.iter().all(|&w| w == 0));
        }
    }

    #[test]
    fn ensure_grows_zeroed_and_never_shrinks() {
        let mut buf = AlignedWords::zeroed(3);
        buf[0] = 0xAB;
        buf[2] = 0xCD;
        buf.ensure(20); // grow: old words kept, new lines zero
        assert_eq!(buf.len(), 24);
        assert_eq!((buf[0], buf[2]), (0xAB, 0xCD));
        assert!(buf[8..].iter().all(|&w| w == 0));
        buf.ensure(1); // "shrink": allocation and contents untouched
        assert_eq!(buf.len(), 24);
        assert_eq!(buf[0], 0xAB);
        buf.clear();
        assert!(buf.iter().all(|&w| w == 0));
    }

    #[test]
    fn deref_slicing_works_like_a_vec() {
        let mut buf = AlignedWords::zeroed(16);
        buf[9] = 7;
        assert_eq!(&buf[8..12], &[0, 7, 0, 0]);
        for (i, w) in buf.as_mut_slice()[..4].iter_mut().enumerate() {
            *w = i as u64;
        }
        assert_eq!(buf.iter().take(4).copied().collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }
}
