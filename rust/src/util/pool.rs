//! Dependency-free scoped-thread worker pool (no rayon in the offline
//! vendor set — see DESIGN.md §2).
//!
//! The model is deliberately minimal: a caller splits its work into a
//! `Vec` of closures (one per shard, each owning `&mut` access to a
//! disjoint slice of the output) and [`scope_run`]/[`scope_map`] execute
//! them on `std::thread::scope` workers — the same borrow-friendly
//! scoped-thread pattern `data::prefetch` uses, so shards may freely
//! capture references into the caller's stack. The final closure always
//! runs inline on the calling thread (the caller's core works instead of
//! idling in `join`; `n` shards cost `n − 1` spawns), which also makes
//! the single-shard case exactly the serial code path: determinism
//! arguments only ever need to reason about *how work is split*, never
//! about how it is executed.
//!
//! [`shard_chunk`] is the canonical splitter: contiguous index ranges of
//! `div_ceil(n, parts)` items, so `slice::chunks(shard_chunk(..) * stride)`
//! on two parallel buffers always produces aligned shard pairs. The
//! native engine shards `infer_batch` by sample range this way; per-shard
//! `GateStats` merge back in shard order, and because every tally is an
//! integer sum over disjoint sample sets, the merged totals are identical
//! for any thread count (pinned by the engine parity tests).

use crate::util::div_ceil;

/// Worker threads to use for `requested`. Explicit requests win; `0` means
/// "auto": the `GXNOR_THREADS` environment variable if set to a positive
/// integer, else one thread per available core. Every parallel path in the
/// crate must size itself through this function — it is the single point
/// where the `--threads`/`GXNOR_THREADS` contract is honored (lint rule D1
/// bans raw `available_parallelism` elsewhere).
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var("GXNOR_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    hardware_threads()
}

// The one sanctioned probe of the machine's parallelism (see clippy.toml's
// disallowed-methods mirror of lint rule D1).
#[allow(clippy::disallowed_methods)]
fn hardware_threads() -> usize {
    // lint:allow(D1): resolve_threads is D1's home — the one sanctioned raw parallelism probe
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Spawn a named, detached service thread. Long-lived daemons (the serve
/// dispatcher, accept loop, replica supervisor, …) cannot use the scoped
/// helpers below — they outlive their caller's stack frame — so this is
/// the sanctioned escape hatch: every detached thread in the crate is
/// created here, carries a `gxnor-` name for debuggers, and is auditable
/// by grepping one symbol (lint rule D1 bans raw `thread::spawn`).
pub fn spawn_service<T, F>(name: &str, f: F) -> std::thread::JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    // lint:allow(D1): spawn_service is D1's home for detached threads; all daemons route here
    std::thread::Builder::new()
        .name(format!("gxnor-{name}"))
        .spawn(f)
        .unwrap_or_else(|e| panic!("spawn_service({name}): {e}"))
}

/// Contiguous-shard chunk length: splitting `n` items into chunks of this
/// size yields at most `parts` shards, all but the last of equal size.
/// Always >= 1 so degenerate inputs (n = 0, parts > n) stay well-formed.
pub fn shard_chunk(n: usize, parts: usize) -> usize {
    div_ceil(n.max(1), parts.max(1))
}

/// Run the closures concurrently on scoped threads, returning their
/// results in task order. The final task always runs inline on the
/// calling thread — the caller's core does the last shard instead of
/// idling in `join`, and `n` shards cost only `n - 1` spawns per call. A
/// panicking task propagates its panic to the caller.
pub fn scope_map<T, F>(tasks: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let mut tasks = tasks;
    let Some(last) = tasks.pop() else {
        return Vec::new();
    };
    if tasks.is_empty() {
        return vec![last()];
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = tasks.into_iter().map(|t| s.spawn(t)).collect();
        let last_out = last();
        let mut out: Vec<T> = handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)))
            .collect();
        out.push(last_out);
        out
    })
}

/// [`scope_map`] for side-effecting shards (each closure owns `&mut`
/// access to its disjoint output slice).
pub fn scope_run<F>(tasks: Vec<F>)
where
    F: FnOnce() + Send,
{
    scope_map(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn resolve_threads_zero_is_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(1), 1);
    }

    #[test]
    fn resolve_threads_honors_env_in_auto_mode() {
        // Note: process-global env. Explicit requests must still win, and
        // garbage must fall back to the hardware probe. Results everywhere
        // in the crate are thread-count invariant, so a concurrent test
        // observing the temporary value is harmless.
        std::env::set_var("GXNOR_THREADS", "5");
        assert_eq!(resolve_threads(0), 5);
        assert_eq!(resolve_threads(2), 2);
        std::env::set_var("GXNOR_THREADS", "not-a-number");
        assert!(resolve_threads(0) >= 1);
        std::env::set_var("GXNOR_THREADS", "0");
        assert!(resolve_threads(0) >= 1);
        std::env::remove_var("GXNOR_THREADS");
    }

    #[test]
    fn spawn_service_names_and_detaches() {
        let h = spawn_service("unit-test", || {
            std::thread::current().name().map(|s| s.to_string())
        });
        let name = h.join().expect("service thread panicked");
        assert_eq!(name.as_deref(), Some("gxnor-unit-test"));
    }

    #[test]
    fn shard_chunk_covers_exactly() {
        for n in 0..40usize {
            for parts in 1..9usize {
                let chunk = shard_chunk(n, parts);
                assert!(chunk >= 1, "n={n} parts={parts}");
                let shards = if n == 0 { 0 } else { n.div_ceil(chunk) };
                assert!(shards <= parts, "n={n} parts={parts}: {shards} shards");
                // chunks cover [0, n) exactly, in order, no overlap
                let total: usize = (0..shards).map(|i| chunk.min(n - i * chunk)).sum();
                assert_eq!(total, n, "n={n} parts={parts}");
            }
        }
    }

    #[test]
    fn scope_map_preserves_task_order() {
        let tasks: Vec<_> = (0..8usize).map(|i| move || i * 10).collect();
        assert_eq!(scope_map(tasks), vec![0, 10, 20, 30, 40, 50, 60, 70]);
        let none: Vec<fn() -> usize> = Vec::new();
        assert_eq!(scope_map(none), Vec::<usize>::new());
    }

    #[test]
    fn scope_run_executes_every_shard_with_disjoint_writes() {
        let mut out = vec![0usize; 10];
        let chunk = shard_chunk(out.len(), 3);
        assert_eq!(chunk, 4);
        let tasks: Vec<_> = out
            .chunks_mut(chunk)
            .enumerate()
            .map(|(si, slice)| {
                move || {
                    for (j, v) in slice.iter_mut().enumerate() {
                        *v = si * 100 + j;
                    }
                }
            })
            .collect();
        scope_run(tasks);
        assert_eq!(out, vec![0, 1, 2, 3, 100, 101, 102, 103, 200, 201]);
    }

    #[test]
    fn single_task_runs_inline() {
        // a lone task must execute on the calling thread (no spawn)
        let caller = std::thread::current().id();
        let got = scope_map(vec![move || std::thread::current().id() == caller]);
        assert_eq!(got, vec![true]);
    }

    #[test]
    fn panics_propagate_to_caller() {
        static RAN: AtomicUsize = AtomicUsize::new(0);
        let r = std::panic::catch_unwind(|| {
            scope_run(vec![
                (|| {
                    RAN.fetch_add(1, Ordering::SeqCst);
                }) as fn(),
                (|| panic!("shard failed")) as fn(),
            ]);
        });
        assert!(r.is_err());
        assert_eq!(RAN.load(Ordering::SeqCst), 1);
    }
}
