//! TOML-subset config parser (no serde/toml in the offline vendor set).
//!
//! Supports what the experiment configs need:
//!
//! ```toml
//! # training config
//! [train]
//! dataset = "synth_mnist"     # strings
//! epochs = 10                 # integers
//! lr_start = 0.02             # floats
//! adam = true                 # booleans
//! sparsity_r = 0.5
//! levels = [1, 2, 3]          # homogeneous arrays
//! ```
//!
//! Keys are addressed as `"section.key"`. Typed getters return defaults so
//! configs stay minimal.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed config: flat map from "section.key" (or bare "key") to Value.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            let value = parse_value(val.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            values.insert(full_key, value);
        }
        Ok(Config { values })
    }

    pub fn from_file(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.f64(key, default as f64) as f32
    }

    pub fn i64(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.i64(key, default as i64) as usize
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn f64_array(&self, key: &str) -> Option<Vec<f64>> {
        match self.get(key)? {
            Value::Arr(v) => v.iter().map(Value::as_f64).collect(),
            _ => None,
        }
    }

    /// Override a value (CLI `--set section.key=value`).
    pub fn set(&mut self, key: &str, raw: &str) -> Result<(), String> {
        self.values.insert(key.to_string(), parse_value(raw)?);
        Ok(())
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"').and_then(|t| t.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            inner.split(',').map(|it| parse_value(it.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // bare word -> string (forgiving for enum-ish values)
    if s.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '-') {
        return Ok(Value::Str(s.to_string()));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "gxnor-mnist"
[train]
epochs = 10          # comment after value
lr_start = 2e-2
lr_fin = 1e-4
adam = true
method = gxnor
[model]
levels = [0, 1, 2]
widths = [0.5, 1.0]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("name", ""), "gxnor-mnist");
        assert_eq!(c.usize("train.epochs", 0), 10);
        assert!((c.f64("train.lr_start", 0.0) - 0.02).abs() < 1e-12);
        assert!(c.bool("train.adam", false));
        assert_eq!(c.str("train.method", ""), "gxnor");
    }

    #[test]
    fn arrays() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.f64_array("model.levels").unwrap(), vec![0.0, 1.0, 2.0]);
        assert_eq!(c.f64_array("model.widths").unwrap(), vec![0.5, 1.0]);
    }

    #[test]
    fn defaults_for_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize("train.epochs", 7), 7);
        assert_eq!(c.str("x", "d"), "d");
    }

    #[test]
    fn hash_inside_string_preserved() {
        let c = Config::parse("tag = \"a#b\"").unwrap();
        assert_eq!(c.str("tag", ""), "a#b");
    }

    #[test]
    fn cli_override() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set("train.epochs", "99").unwrap();
        assert_eq!(c.usize("train.epochs", 0), 99);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("just a line").is_err());
        assert!(Config::parse("k = ").is_err());
    }

    #[test]
    fn int_vs_float() {
        let c = Config::parse("a = 3\nb = 3.0").unwrap();
        assert_eq!(c.get("a"), Some(&Value::Int(3)));
        assert_eq!(c.get("b"), Some(&Value::Float(3.0)));
        assert_eq!(c.f64("a", 0.0), 3.0); // ints coerce to f64
    }
}
