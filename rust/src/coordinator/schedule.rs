//! Learning-rate schedule, exactly the paper's Section 3 recipe:
//! "the learning rate decays at each training epoch by LR = alpha * LR,
//! where alpha = (LR_fin / LR_start)^(1/Epochs)".

#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub lr_start: f64,
    pub lr_fin: f64,
    pub epochs: usize,
}

impl LrSchedule {
    pub fn new(lr_start: f64, lr_fin: f64, epochs: usize) -> Self {
        assert!(lr_start > 0.0 && lr_fin > 0.0 && epochs > 0);
        LrSchedule { lr_start, lr_fin, epochs }
    }

    /// The per-epoch decay factor alpha.
    pub fn alpha(&self) -> f64 {
        (self.lr_fin / self.lr_start).powf(1.0 / self.epochs as f64)
    }

    /// LR in effect during `epoch` (0-based).
    pub fn lr_at(&self, epoch: usize) -> f64 {
        self.lr_start * self.alpha().powi(epoch as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints() {
        let s = LrSchedule::new(0.02, 1e-4, 30);
        assert!((s.lr_at(0) - 0.02).abs() < 1e-12);
        // after all epochs the LR has reached lr_fin
        assert!((s.lr_at(30) - 1e-4).abs() < 1e-9);
    }

    #[test]
    fn geometric_decay() {
        let s = LrSchedule::new(0.1, 0.001, 10);
        let a = s.alpha();
        for e in 0..10 {
            let ratio = s.lr_at(e + 1) / s.lr_at(e);
            assert!((ratio - a).abs() < 1e-12);
        }
        assert!(a < 1.0);
    }

    #[test]
    fn constant_when_equal() {
        let s = LrSchedule::new(0.01, 0.01, 5);
        assert!((s.alpha() - 1.0).abs() < 1e-12);
        assert!((s.lr_at(3) - 0.01).abs() < 1e-12);
    }
}
