//! Hidden-weight training — the *baseline* the paper argues against
//! (Fig. 4a): BinaryConnect [16], TWN [17] and BNN [19] all keep a
//! full-precision master copy of every weight, apply gradient updates to
//! it, and re-quantize ("binarization / ternary discretization step") on
//! every forward pass, "switching frequently between the CWS and the
//! BWS/TWS".
//!
//! Implemented here so the Table-1 baselines can be run *faithfully*
//! (their original algorithm) as well as under the paper's DST framework,
//! and so the DST-vs-hidden ablation (bench section `fig4`) can quantify
//! exactly what removing the hidden weights costs or buys.

use crate::coordinator::optimizer::Optimizer;
use crate::ternary::DiscreteSpace;

/// Full-precision master weights for one tensor.
#[derive(Clone, Debug)]
pub struct HiddenWeights {
    pub master: Vec<f32>,
    space: DiscreteSpace,
}

impl HiddenWeights {
    /// Initialize masters from the current discrete states (keeps the two
    /// update rules comparable from identical starting points).
    pub fn from_discrete(states: &[f32], space: DiscreteSpace) -> Self {
        HiddenWeights { master: states.to_vec(), space }
    }

    /// BinaryConnect-style step: optimizer increment into the master,
    /// clip to [-1, 1] (as in [16] — keeps weights near the quantization
    /// range), then write the *quantized* view into `out`.
    ///
    /// Quantization: sign for the binary space (states are not multiples
    /// of dz), nearest-state projection otherwise.
    pub fn step(
        &mut self,
        idx: usize,
        opt: &mut Optimizer,
        grad: &[f32],
        lr: f64,
        dw_buf: &mut [f32],
        out: &mut [f32],
    ) {
        assert_eq!(grad.len(), self.master.len());
        let dw = &mut dw_buf[..grad.len()];
        opt.increment(idx, grad, lr, dw);
        let binary = self.space.n() == 0;
        let space = self.space;
        for ((m, &d), o) in self.master.iter_mut().zip(dw.iter()).zip(out.iter_mut()) {
            *m = (*m + d).clamp(-1.0, 1.0);
            *o = if binary {
                if *m >= 0.0 { 1.0 } else { -1.0 }
            } else {
                space.project(*m)
            };
        }
    }

    #[inline]
    pub fn quantize(&self, v: f32) -> f32 {
        if self.space.n() == 0 {
            if v >= 0.0 {
                1.0
            } else {
                -1.0
            }
        } else {
            self.space.project(v)
        }
    }

    /// Memory the master copy costs (the paper's Remark-2 overhead).
    pub fn fp32_bytes(&self) -> usize {
        self.master.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::optimizer::OptKind;

    #[test]
    fn masters_accumulate_small_gradients() {
        // the whole point of hidden weights: sub-dz increments accumulate
        let space = DiscreteSpace::TERNARY;
        let mut hw = HiddenWeights::from_discrete(&[0.0; 4], space);
        let mut opt = Optimizer::new(OptKind::Sgd, 1);
        let mut dw = vec![0.0; 4];
        let mut out = vec![0.0; 4];
        for _ in 0..30 {
            opt.begin_step();
            hw.step(0, &mut opt, &[-1.0; 4], 0.03, &mut dw, &mut out);
        }
        // master drifted up ~0.9; quantized view flipped to 1 after passing 0.5
        assert!(hw.master[0] > 0.8);
        assert_eq!(out, vec![1.0; 4]);
    }

    #[test]
    fn quantized_view_always_on_grid() {
        for n in [0u32, 1, 3] {
            let space = DiscreteSpace::new(n);
            let mut hw = HiddenWeights::from_discrete(&vec![0.9; 16], space);
            let mut opt = Optimizer::new(OptKind::Adam, 1);
            let mut dw = vec![0.0; 16];
            let mut out = vec![0.0; 16];
            let mut rng = crate::util::prng::Prng::new(n as u64);
            for _ in 0..10 {
                opt.begin_step();
                let g: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
                hw.step(0, &mut opt, &g, 0.05, &mut dw, &mut out);
                for &v in &out {
                    assert!(space.contains(v), "N={n}: {v}");
                }
                for &m in &hw.master {
                    assert!((-1.0..=1.0).contains(&m));
                }
            }
        }
    }

    #[test]
    fn binary_quantize_is_sign() {
        let hw = HiddenWeights::from_discrete(&[-1.0, 1.0], DiscreteSpace::BINARY);
        assert_eq!(hw.quantize(-0.001), -1.0);
        assert_eq!(hw.quantize(0.0), 1.0);
        assert_eq!(hw.quantize(0.7), 1.0);
    }

    #[test]
    fn memory_overhead_reported() {
        let hw = HiddenWeights::from_discrete(&[0.0; 1000], DiscreteSpace::TERNARY);
        assert_eq!(hw.fp32_bytes(), 4000);
    }
}
