//! Gradient preprocessing ahead of the DST projection.
//!
//! The paper's "base algorithm for gradient descent is Adam" (Section 3):
//! gradients are Adam-preconditioned, then the resulting real-valued
//! increment `dw = -lr * adam(g)` is handed to the DST operator, which
//! projects it onto a discrete state transition. The Adam moments are
//! optimizer state (O(2·#weights) f32), not a hidden weight copy — and the
//! pure `Sgd` mode has zero auxiliary state, demonstrating the paper's
//! no-full-precision-memory property end to end (DESIGN.md §6).
//!
//! Dense parameters (BN gamma/beta, and all weights in the `fp` baseline)
//! are updated in place by the same machinery.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptKind {
    Sgd,
    Adam,
}

impl OptKind {
    pub fn parse(s: &str) -> Result<OptKind, String> {
        match s {
            "sgd" => Ok(OptKind::Sgd),
            "adam" => Ok(OptKind::Adam),
            other => Err(format!("unknown optimizer {other:?} (sgd|adam)")),
        }
    }
}

const BETA1: f64 = 0.9;
const BETA2: f64 = 0.999;
const EPS: f64 = 1e-8;

/// Per-tensor optimizer state.
#[derive(Clone, Debug)]
struct Slot {
    m: Vec<f32>,
    v: Vec<f32>,
}

/// Optimizer over an ordered set of tensors (index-addressed; the trainer
/// uses the manifest's param order).
#[derive(Clone, Debug)]
pub struct Optimizer {
    kind: OptKind,
    slots: Vec<Option<Slot>>,
    t: u64,
}

impl Optimizer {
    pub fn new(kind: OptKind, n_tensors: usize) -> Self {
        Optimizer { kind, slots: vec![None; n_tensors], t: 0 }
    }

    pub fn kind(&self) -> OptKind {
        self.kind
    }

    /// Advance the shared timestep (call once per training step, before
    /// the per-tensor updates).
    pub fn begin_step(&mut self) {
        self.t += 1;
    }

    /// Compute the real-valued increment `dw = -lr * direction(grad)` into
    /// `dw_out` for tensor `idx` (input to DST for discrete weights).
    pub fn increment(&mut self, idx: usize, grad: &[f32], lr: f64, dw_out: &mut [f32]) {
        assert_eq!(grad.len(), dw_out.len());
        assert!(self.t > 0, "call begin_step first");
        match self.kind {
            OptKind::Sgd => {
                for (o, &g) in dw_out.iter_mut().zip(grad) {
                    *o = (-lr * g as f64) as f32;
                }
            }
            OptKind::Adam => {
                let slot = self.slots[idx].get_or_insert_with(|| Slot {
                    m: vec![0.0; grad.len()],
                    v: vec![0.0; grad.len()],
                });
                assert_eq!(slot.m.len(), grad.len(), "tensor {idx} changed size");
                // bias corrections in f64 (scalars), per-element math in f32
                // (the moments themselves are stored f32; doing the
                // arithmetic in f32 vectorizes and loses nothing that the
                // storage hadn't already lost — §Perf iteration 4)
                let bc1 = (1.0 - BETA1.powi(self.t as i32)) as f32;
                let bc2 = (1.0 - BETA2.powi(self.t as i32)) as f32;
                let (b1, b2) = (BETA1 as f32, BETA2 as f32);
                let neg_lr_over_bc1 = (-lr) as f32 / bc1;
                let inv_bc2 = 1.0 / bc2;
                let eps = EPS as f32;
                for i in 0..grad.len() {
                    let g = grad[i];
                    let m = b1 * slot.m[i] + (1.0 - b1) * g;
                    let v = b2 * slot.v[i] + (1.0 - b2) * g * g;
                    slot.m[i] = m;
                    slot.v[i] = v;
                    dw_out[i] = neg_lr_over_bc1 * m / ((v * inv_bc2).sqrt() + eps);
                }
            }
        }
    }

    /// Apply the increment directly to a dense tensor (BN params, fp weights).
    pub fn apply_dense(&mut self, idx: usize, param: &mut [f32], grad: &[f32], lr: f64) {
        let mut dw = vec![0.0f32; grad.len()];
        self.increment(idx, grad, lr, &mut dw);
        for (p, d) in param.iter_mut().zip(&dw) {
            *p += d;
        }
    }

    /// Auxiliary f32 state held (bytes) — memory accounting for Remark 2.
    pub fn state_bytes(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .map(|s| (s.m.len() + s.v.len()) * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_is_minus_lr_grad() {
        let mut o = Optimizer::new(OptKind::Sgd, 1);
        o.begin_step();
        let mut dw = vec![0.0; 3];
        o.increment(0, &[1.0, -2.0, 0.0], 0.1, &mut dw);
        assert_eq!(dw, vec![-0.1, 0.2, 0.0]);
        assert_eq!(o.state_bytes(), 0);
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        // bias-corrected first step: |dw| ~ lr regardless of grad scale
        let mut o = Optimizer::new(OptKind::Adam, 1);
        o.begin_step();
        let mut dw = vec![0.0; 2];
        o.increment(0, &[1e-3, -100.0], 0.01, &mut dw);
        assert!((dw[0] + 0.01).abs() < 1e-4, "{dw:?}");
        assert!((dw[1] - 0.01).abs() < 1e-4, "{dw:?}");
    }

    #[test]
    fn adam_damps_oscillation() {
        // alternating gradients: second moment grows, step shrinks
        let mut o = Optimizer::new(OptKind::Adam, 1);
        let mut dws = Vec::new();
        for t in 0..20 {
            o.begin_step();
            let g = if t % 2 == 0 { 1.0 } else { -1.0 };
            let mut dw = vec![0.0];
            o.increment(0, &[g], 0.01, &mut dw);
            dws.push(dw[0].abs());
        }
        assert!(dws[19] < dws[0] * 0.5, "{dws:?}");
    }

    #[test]
    fn adam_converges_quadratic() {
        // minimize (x-3)^2 with dense updates
        let mut o = Optimizer::new(OptKind::Adam, 1);
        let mut x = vec![0.0f32];
        for _ in 0..800 {
            o.begin_step();
            let g = vec![2.0 * (x[0] - 3.0)];
            o.apply_dense(0, &mut x, &g, 0.05);
        }
        assert!((x[0] - 3.0).abs() < 0.05, "x={}", x[0]);
    }

    #[test]
    fn state_accounting() {
        let mut o = Optimizer::new(OptKind::Adam, 2);
        o.begin_step();
        let mut dw = vec![0.0; 10];
        o.increment(0, &[0.0; 10], 0.01, &mut dw);
        assert_eq!(o.state_bytes(), 10 * 2 * 4);
    }

    #[test]
    #[should_panic(expected = "begin_step")]
    fn increment_requires_begin_step() {
        let mut o = Optimizer::new(OptKind::Sgd, 1);
        let mut dw = vec![0.0];
        o.increment(0, &[1.0], 0.1, &mut dw);
    }
}
