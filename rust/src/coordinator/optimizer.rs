//! Gradient preprocessing ahead of the DST projection.
//!
//! The paper's "base algorithm for gradient descent is Adam" (Section 3):
//! gradients are Adam-preconditioned, then the resulting real-valued
//! increment `dw = -lr * adam(g)` is handed to the DST operator, which
//! projects it onto a discrete state transition. The Adam moments are
//! optimizer state (O(2·#weights) f32), not a hidden weight copy — and the
//! pure `Sgd` mode has zero auxiliary state, demonstrating the paper's
//! no-full-precision-memory property end to end (DESIGN.md §6).
//!
//! Dense parameters (BN gamma/beta, and all weights in the `fp` baseline)
//! are updated in place by the same machinery.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptKind {
    Sgd,
    Adam,
}

impl OptKind {
    pub fn parse(s: &str) -> Result<OptKind, String> {
        match s {
            "sgd" => Ok(OptKind::Sgd),
            "adam" => Ok(OptKind::Adam),
            other => Err(format!("unknown optimizer {other:?} (sgd|adam)")),
        }
    }
}

const BETA1: f64 = 0.9;
const BETA2: f64 = 0.999;
const EPS: f64 = 1e-8;

/// Per-tensor optimizer state.
#[derive(Clone, Debug)]
struct Slot {
    m: Vec<f32>,
    v: Vec<f32>,
}

/// Optimizer over an ordered set of tensors (index-addressed; the trainer
/// uses the manifest's param order).
#[derive(Clone, Debug)]
pub struct Optimizer {
    kind: OptKind,
    slots: Vec<Option<Slot>>,
    t: u64,
}

impl Optimizer {
    pub fn new(kind: OptKind, n_tensors: usize) -> Self {
        Optimizer { kind, slots: vec![None; n_tensors], t: 0 }
    }

    pub fn kind(&self) -> OptKind {
        self.kind
    }

    /// Advance the shared timestep (call once per training step, before
    /// the per-tensor updates).
    pub fn begin_step(&mut self) {
        self.t += 1;
    }

    /// Compute the real-valued increment `dw = -lr * direction(grad)` into
    /// `dw_out` for tensor `idx` (input to DST for discrete weights).
    pub fn increment(&mut self, idx: usize, grad: &[f32], lr: f64, dw_out: &mut [f32]) {
        assert_eq!(grad.len(), dw_out.len());
        assert!(self.t > 0, "call begin_step first");
        match self.kind {
            OptKind::Sgd => {
                for (o, &g) in dw_out.iter_mut().zip(grad) {
                    *o = (-lr * g as f64) as f32;
                }
            }
            OptKind::Adam => {
                let slot = self.slots[idx].get_or_insert_with(|| Slot {
                    m: vec![0.0; grad.len()],
                    v: vec![0.0; grad.len()],
                });
                assert_eq!(slot.m.len(), grad.len(), "tensor {idx} changed size");
                // bias corrections in f64 (scalars), per-element math in f32
                // (the moments themselves are stored f32; doing the
                // arithmetic in f32 vectorizes and loses nothing that the
                // storage hadn't already lost — §Perf iteration 4)
                let bc1 = (1.0 - BETA1.powi(self.t as i32)) as f32;
                let bc2 = (1.0 - BETA2.powi(self.t as i32)) as f32;
                let (b1, b2) = (BETA1 as f32, BETA2 as f32);
                let neg_lr_over_bc1 = (-lr) as f32 / bc1;
                let inv_bc2 = 1.0 / bc2;
                let eps = EPS as f32;
                for i in 0..grad.len() {
                    let g = grad[i];
                    let m = b1 * slot.m[i] + (1.0 - b1) * g;
                    let v = b2 * slot.v[i] + (1.0 - b2) * g * g;
                    slot.m[i] = m;
                    slot.v[i] = v;
                    dw_out[i] = neg_lr_over_bc1 * m / ((v * inv_bc2).sqrt() + eps);
                }
            }
        }
    }

    /// Apply the increment directly to a dense tensor (BN params, fp weights).
    pub fn apply_dense(&mut self, idx: usize, param: &mut [f32], grad: &[f32], lr: f64) {
        let mut dw = vec![0.0f32; grad.len()];
        self.increment(idx, grad, lr, &mut dw);
        for (p, d) in param.iter_mut().zip(&dw) {
            *p += d;
        }
    }

    /// Auxiliary f32 state held (bytes) — memory accounting for Remark 2.
    pub fn state_bytes(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .map(|s| (s.m.len() + s.v.len()) * 4)
            .sum()
    }

    /// Shared timestep (number of `begin_step` calls so far).
    pub fn t(&self) -> u64 {
        self.t
    }

    /// Append the full optimizer state (kind, timestep, per-tensor
    /// moments) to `out` — the checkpoint section that makes resumed Adam
    /// bias corrections and moment trajectories bit-identical.
    ///
    /// Layout: `kind u8 | t u64 | n_slots u32 | per slot:
    /// present u8 | [len u64 | m f32s | v f32s]`.
    pub fn serialize_state(&self, out: &mut Vec<u8>) {
        out.push(match self.kind {
            OptKind::Sgd => 0u8,
            OptKind::Adam => 1u8,
        });
        out.extend_from_slice(&self.t.to_le_bytes());
        out.extend_from_slice(&(self.slots.len() as u32).to_le_bytes());
        for slot in &self.slots {
            match slot {
                None => out.push(0u8),
                Some(s) => {
                    out.push(1u8);
                    out.extend_from_slice(&(s.m.len() as u64).to_le_bytes());
                    for &x in &s.m {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                    for &x in &s.v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
    }

    /// Restore state written by [`Optimizer::serialize_state`], advancing
    /// `pos` past the section. The optimizer must already be constructed
    /// with the matching kind and tensor count (both are validated).
    pub fn restore_state(&mut self, bytes: &[u8], pos: &mut usize) -> Result<(), String> {
        fn take<'a>(bytes: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8], String> {
            let end = pos
                .checked_add(n)
                .filter(|&e| e <= bytes.len())
                .ok_or("truncated optimizer state")?;
            let s = &bytes[*pos..end];
            *pos = end;
            Ok(s)
        }
        let kind_tag = take(bytes, pos, 1)?[0];
        let want_tag = match self.kind {
            OptKind::Sgd => 0u8,
            OptKind::Adam => 1u8,
        };
        if kind_tag != want_tag {
            return Err(format!(
                "optimizer kind mismatch: checkpoint has tag {kind_tag}, run uses {:?}",
                self.kind
            ));
        }
        let t = u64::from_le_bytes(take(bytes, pos, 8)?.try_into().unwrap());
        let n = u32::from_le_bytes(take(bytes, pos, 4)?.try_into().unwrap()) as usize;
        if n != self.slots.len() {
            return Err(format!(
                "optimizer slot count mismatch: checkpoint has {n}, run has {}",
                self.slots.len()
            ));
        }
        let mut slots: Vec<Option<Slot>> = Vec::with_capacity(n);
        for _ in 0..n {
            let present = take(bytes, pos, 1)?[0];
            match present {
                0 => slots.push(None),
                1 => {
                    let len = u64::from_le_bytes(take(bytes, pos, 8)?.try_into().unwrap()) as usize;
                    let mut read_f32s = |pos: &mut usize| -> Result<Vec<f32>, String> {
                        let raw = take(bytes, pos, len * 4)?;
                        Ok(raw
                            .chunks_exact(4)
                            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                            .collect())
                    };
                    let m = read_f32s(pos)?;
                    let v = read_f32s(pos)?;
                    slots.push(Some(Slot { m, v }));
                }
                other => return Err(format!("bad optimizer slot tag {other}")),
            }
        }
        self.t = t;
        self.slots = slots;
        Ok(())
    }

    /// Skip over a serialized optimizer section without restoring it
    /// (used when inspecting or when only model weights are wanted).
    pub fn skip_state(bytes: &[u8], pos: &mut usize) -> Result<(), String> {
        let need = |pos: usize, n: usize| -> Result<usize, String> {
            pos.checked_add(n)
                .filter(|&e| e <= bytes.len())
                .ok_or_else(|| "truncated optimizer state".to_string())
        };
        *pos = need(*pos, 1 + 8)?;
        let n = u32::from_le_bytes(
            bytes[*pos..need(*pos, 4)?]
                .try_into()
                .map_err(|_| "truncated optimizer state")?,
        ) as usize;
        *pos = need(*pos, 4)?;
        for _ in 0..n {
            let present = bytes[*pos..need(*pos, 1)?][0];
            *pos = need(*pos, 1)?;
            if present == 1 {
                let end = need(*pos, 8)?;
                let len =
                    u64::from_le_bytes(bytes[*pos..end].try_into().unwrap()) as usize;
                *pos = end;
                *pos = need(*pos, len * 8)?; // m + v, 4 bytes each
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_is_minus_lr_grad() {
        let mut o = Optimizer::new(OptKind::Sgd, 1);
        o.begin_step();
        let mut dw = vec![0.0; 3];
        o.increment(0, &[1.0, -2.0, 0.0], 0.1, &mut dw);
        assert_eq!(dw, vec![-0.1, 0.2, 0.0]);
        assert_eq!(o.state_bytes(), 0);
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        // bias-corrected first step: |dw| ~ lr regardless of grad scale
        let mut o = Optimizer::new(OptKind::Adam, 1);
        o.begin_step();
        let mut dw = vec![0.0; 2];
        o.increment(0, &[1e-3, -100.0], 0.01, &mut dw);
        assert!((dw[0] + 0.01).abs() < 1e-4, "{dw:?}");
        assert!((dw[1] - 0.01).abs() < 1e-4, "{dw:?}");
    }

    #[test]
    fn adam_damps_oscillation() {
        // alternating gradients: second moment grows, step shrinks
        let mut o = Optimizer::new(OptKind::Adam, 1);
        let mut dws = Vec::new();
        for t in 0..20 {
            o.begin_step();
            let g = if t % 2 == 0 { 1.0 } else { -1.0 };
            let mut dw = vec![0.0];
            o.increment(0, &[g], 0.01, &mut dw);
            dws.push(dw[0].abs());
        }
        assert!(dws[19] < dws[0] * 0.5, "{dws:?}");
    }

    #[test]
    fn adam_converges_quadratic() {
        // minimize (x-3)^2 with dense updates
        let mut o = Optimizer::new(OptKind::Adam, 1);
        let mut x = vec![0.0f32];
        for _ in 0..800 {
            o.begin_step();
            let g = vec![2.0 * (x[0] - 3.0)];
            o.apply_dense(0, &mut x, &g, 0.05);
        }
        assert!((x[0] - 3.0).abs() < 0.05, "x={}", x[0]);
    }

    #[test]
    fn state_accounting() {
        let mut o = Optimizer::new(OptKind::Adam, 2);
        o.begin_step();
        let mut dw = vec![0.0; 10];
        o.increment(0, &[0.0; 10], 0.01, &mut dw);
        assert_eq!(o.state_bytes(), 10 * 2 * 4);
    }

    #[test]
    fn state_roundtrip_is_bit_exact() {
        // run a few steps, snapshot, run more; restored copy must match
        let mut o = Optimizer::new(OptKind::Adam, 3);
        let mut dw = vec![0.0f32; 5];
        for t in 0..7 {
            o.begin_step();
            let g: Vec<f32> = (0..5).map(|i| (t * 5 + i) as f32 * 0.01 - 0.1).collect();
            o.increment(0, &g, 0.01, &mut dw);
            o.increment(2, &g, 0.01, &mut dw); // slot 1 never touched
        }
        let mut blob = Vec::new();
        o.serialize_state(&mut blob);

        let mut r = Optimizer::new(OptKind::Adam, 3);
        let mut pos = 0usize;
        r.restore_state(&blob, &mut pos).unwrap();
        assert_eq!(pos, blob.len());
        assert_eq!(r.t(), o.t());

        // identical trajectories after restore
        let g = vec![0.03f32; 5];
        let (mut da, mut db) = (vec![0.0f32; 5], vec![0.0f32; 5]);
        for _ in 0..3 {
            o.begin_step();
            r.begin_step();
            o.increment(0, &g, 0.02, &mut da);
            r.increment(0, &g, 0.02, &mut db);
            assert_eq!(
                da.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                db.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }

        // skip_state walks the exact same extent
        let mut skip_pos = 0usize;
        Optimizer::skip_state(&blob, &mut skip_pos).unwrap();
        assert_eq!(skip_pos, blob.len());

        // mismatched shapes are rejected, not silently accepted
        let mut wrong_n = Optimizer::new(OptKind::Adam, 2);
        let mut p = 0usize;
        assert!(wrong_n.restore_state(&blob, &mut p).is_err());
        let mut wrong_kind = Optimizer::new(OptKind::Sgd, 3);
        let mut p = 0usize;
        assert!(wrong_kind.restore_state(&blob, &mut p).is_err());
        // truncation is an error, never a panic
        let mut p = 0usize;
        let mut r2 = Optimizer::new(OptKind::Adam, 3);
        assert!(r2.restore_state(&blob[..blob.len() - 3], &mut p).is_err());
    }

    #[test]
    #[should_panic(expected = "begin_step")]
    fn increment_requires_begin_step() {
        let mut o = Optimizer::new(OptKind::Sgd, 1);
        let mut dw = vec![0.0];
        o.increment(0, &[1.0], 0.1, &mut dw);
    }
}
