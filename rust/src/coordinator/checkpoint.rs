//! Checkpointing: weights stay bit-packed on disk, exactly as in memory.
//!
//! Two formats share the `GXNR` magic:
//!
//! v1 — model-only (weights + BN state), the publishable artifact:
//! ```text
//! magic "GXNR" | version u32 (=1) | n_params u32
//!   per param: name_len u32 | name bytes | tag u8 (0 packed, 1 dense)
//!              payload (PackedTensor::serialize or len u64 + f32s)
//! n_bn u32
//!   per bn:   name_len u32 | name bytes | len u64 | f32s
//! ```
//! A ternary MNIST-CNN checkpoint is ~16x smaller than its f32 equivalent —
//! the paper's Remark 2 memory claim, made concrete.
//!
//! v2 — full run state, for crash-safe resumable training:
//! ```text
//! magic "GXNR" | version u32 (=2) | payload_len u64 | payload | crc32 u32
//!   payload: run meta | prng state | model body (v1 body) | optimizer state
//! ```
//! The trailing CRC-32 covers everything before it, so a torn or
//! bit-flipped file is *detected* ([`CkptError::Corrupt`]) rather than
//! half-restored. Both formats are written via [`write_atomic`]
//! (temp file + fsync + rename): a kill at any instant leaves either the
//! previous complete file or the new complete file at the target path,
//! never a truncated one.

use crate::coordinator::optimizer::Optimizer;
use crate::nn::params::{ModelState, ParamValue};
use crate::ternary::PackedTensor;
use crate::util::crc32::crc32;
use crate::util::fault::FaultPlan;
use crate::util::Prng;

const MAGIC: &[u8; 4] = b"GXNR";
const VERSION: u32 = 1;
const VERSION_RUN: u32 = 2;

/// Why a checkpoint operation failed — callers branch on this (a corrupt
/// file warrants falling back to an older checkpoint; a shape mismatch
/// means the config is wrong; I/O is environmental).
#[derive(Debug)]
pub enum CkptError {
    /// The file could not be read or written.
    Io(String),
    /// The bytes on disk are damaged: bad magic, failed CRC, truncation.
    Corrupt(String),
    /// The file is intact but does not match this model/run configuration.
    Format(String),
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "checkpoint i/o error: {e}"),
            CkptError::Corrupt(e) => write!(f, "corrupt checkpoint ({e})"),
            CkptError::Format(e) => write!(f, "checkpoint mismatch: {e}"),
        }
    }
}

impl std::error::Error for CkptError {}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    out.extend_from_slice(&(v.len() as u64).to_le_bytes());
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_u32(b: &[u8], pos: &mut usize) -> Result<u32, String> {
    let s = b.get(*pos..*pos + 4).ok_or("truncated checkpoint")?;
    *pos += 4;
    Ok(u32::from_le_bytes(s.try_into().unwrap()))
}

fn get_u64(b: &[u8], pos: &mut usize) -> Result<u64, String> {
    let s = b.get(*pos..*pos + 8).ok_or("truncated checkpoint")?;
    *pos += 8;
    Ok(u64::from_le_bytes(s.try_into().unwrap()))
}

fn get_f32(b: &[u8], pos: &mut usize) -> Result<f32, String> {
    let s = b.get(*pos..*pos + 4).ok_or("truncated checkpoint")?;
    *pos += 4;
    Ok(f32::from_le_bytes(s.try_into().unwrap()))
}

fn get_f64(b: &[u8], pos: &mut usize) -> Result<f64, String> {
    let s = b.get(*pos..*pos + 8).ok_or("truncated checkpoint")?;
    *pos += 8;
    Ok(f64::from_le_bytes(s.try_into().unwrap()))
}

fn get_str(b: &[u8], pos: &mut usize) -> Result<String, String> {
    let len = get_u32(b, pos)? as usize;
    let s = b.get(*pos..*pos + len).ok_or("truncated checkpoint")?;
    *pos += len;
    String::from_utf8(s.to_vec()).map_err(|e| e.to_string())
}

fn get_f32s(b: &[u8], pos: &mut usize) -> Result<Vec<f32>, String> {
    let len = get_u64(b, pos)? as usize;
    let mut v = Vec::with_capacity(len);
    for _ in 0..len {
        let s = b.get(*pos..*pos + 4).ok_or("truncated checkpoint")?;
        *pos += 4;
        v.push(f32::from_le_bytes(s.try_into().unwrap()));
    }
    Ok(v)
}

/// The params + BN section shared verbatim by both formats.
fn put_model_body(out: &mut Vec<u8>, model: &ModelState) {
    out.extend_from_slice(&(model.values.len() as u32).to_le_bytes());
    for (d, v) in model.descs.iter().zip(&model.values) {
        put_str(out, &d.name);
        match v {
            ParamValue::Discrete(p) => {
                out.push(0);
                p.serialize(out);
            }
            ParamValue::Dense(f) => {
                out.push(1);
                put_f32s(out, f);
            }
        }
    }
    out.extend_from_slice(&(model.bn_state.len() as u32).to_le_bytes());
    for (name, s) in model.bn_names.iter().zip(&model.bn_state) {
        put_str(out, name);
        put_f32s(out, s);
    }
}

fn get_model_body(model: &mut ModelState, bytes: &[u8], pos: &mut usize) -> Result<(), CkptError> {
    let n = get_u32(bytes, pos).map_err(CkptError::Corrupt)? as usize;
    if n != model.values.len() {
        return Err(CkptError::Format(format!(
            "param count mismatch: {n} vs {}",
            model.values.len()
        )));
    }
    for i in 0..n {
        let name = get_str(bytes, pos).map_err(CkptError::Corrupt)?;
        if name != model.descs[i].name {
            return Err(CkptError::Format(format!(
                "param {i} name mismatch: {name} vs {}",
                model.descs[i].name
            )));
        }
        let tag = *bytes
            .get(*pos)
            .ok_or_else(|| CkptError::Corrupt("truncated checkpoint".into()))?;
        *pos += 1;
        match tag {
            0 => {
                let p = PackedTensor::deserialize(bytes, pos).map_err(CkptError::Corrupt)?;
                if p.len() != model.descs[i].numel() {
                    return Err(CkptError::Format(format!("param {name} size mismatch")));
                }
                model.values[i] = ParamValue::Discrete(p);
            }
            1 => {
                let f = get_f32s(bytes, pos).map_err(CkptError::Corrupt)?;
                if f.len() != model.descs[i].numel() {
                    return Err(CkptError::Format(format!("param {name} size mismatch")));
                }
                model.values[i] = ParamValue::Dense(f);
            }
            t => return Err(CkptError::Corrupt(format!("bad param tag {t}"))),
        }
    }
    let n_bn = get_u32(bytes, pos).map_err(CkptError::Corrupt)? as usize;
    if n_bn != model.bn_state.len() {
        return Err(CkptError::Format("bn state count mismatch".into()));
    }
    for i in 0..n_bn {
        let name = get_str(bytes, pos).map_err(CkptError::Corrupt)?;
        if name != model.bn_names[i] {
            return Err(CkptError::Format(format!("bn {i} name mismatch")));
        }
        let f = get_f32s(bytes, pos).map_err(CkptError::Corrupt)?;
        if f.len() != model.bn_state[i].len() {
            return Err(CkptError::Format(format!("bn {name} size mismatch")));
        }
        model.bn_state[i] = f;
    }
    Ok(())
}

/// Serialize params + BN state only (v1 — optimizer state is deliberately
/// excluded: a restored model resumes with fresh moments, like the
/// paper's runs; use [`serialize_run`] for exact training resume).
pub fn serialize(model: &ModelState) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    put_model_body(&mut out, model);
    out
}

/// Restore into an existing (shape-compatible) model.
pub fn restore(model: &mut ModelState, bytes: &[u8]) -> Result<(), String> {
    restore_classified(model, bytes).map_err(|e| e.to_string())
}

/// [`restore`] with a classified error. Accepts both formats: a v2 run
/// checkpoint restores just its model section, so `eval` and `serve`
/// work on periodic training checkpoints directly.
pub fn restore_classified(model: &mut ModelState, bytes: &[u8]) -> Result<(), CkptError> {
    let mut pos = 0usize;
    if bytes.get(0..4) != Some(MAGIC.as_slice()) {
        return Err(CkptError::Corrupt("bad magic".into()));
    }
    pos += 4;
    let ver = get_u32(bytes, &mut pos).map_err(CkptError::Corrupt)?;
    match ver {
        VERSION => {
            get_model_body(model, bytes, &mut pos)?;
            if pos != bytes.len() {
                return Err(CkptError::Corrupt("trailing bytes".into()));
            }
            Ok(())
        }
        VERSION_RUN => {
            let payload = v2_payload(bytes, &mut pos)?;
            let mut p = 0usize;
            get_run_meta(payload, &mut p).map_err(CkptError::Corrupt)?;
            get_prng(payload, &mut p).map_err(CkptError::Corrupt)?;
            get_model_body(model, payload, &mut p)?;
            Optimizer::skip_state(payload, &mut p).map_err(CkptError::Corrupt)?;
            if p != payload.len() {
                return Err(CkptError::Corrupt("trailing bytes".into()));
            }
            Ok(())
        }
        v => Err(CkptError::Format(format!("unsupported checkpoint version {v}"))),
    }
}

/// Validate the v2 envelope (length + trailing CRC over everything before
/// it) and return the payload slice. `pos` must sit just after the
/// version field on entry; it is advanced to the end of `bytes`.
fn v2_payload<'a>(bytes: &'a [u8], pos: &mut usize) -> Result<&'a [u8], CkptError> {
    let payload_len = get_u64(bytes, pos).map_err(CkptError::Corrupt)? as usize;
    match pos.checked_add(payload_len).and_then(|e| e.checked_add(4)) {
        Some(total) if total == bytes.len() => {}
        _ => return Err(CkptError::Corrupt("truncated checkpoint".into())),
    }
    let stored = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    let computed = crc32(&bytes[..bytes.len() - 4]);
    if stored != computed {
        return Err(CkptError::Corrupt(format!(
            "bad CRC: stored 0x{stored:08X}, computed 0x{computed:08X}"
        )));
    }
    let payload = &bytes[*pos..*pos + payload_len];
    *pos = bytes.len();
    Ok(payload)
}

/// Run position and identity captured in a v2 checkpoint. Resume
/// validates the identity fields against the live config — continuing a
/// run under a different arch/seed/schedule would silently break the
/// bit-exactness the format exists to guarantee.
#[derive(Clone, Debug, PartialEq)]
pub struct RunMeta {
    /// First epoch the resumed run should execute (epochs completed so far).
    pub epoch_next: u64,
    /// Optimizer steps taken across the whole run.
    pub global_step: u64,
    pub epochs_total: u64,
    pub batch: u64,
    pub seed: u64,
    pub arch: String,
    pub method: String,
    /// DST transition scale (paper's `m`).
    pub m: f32,
    /// Zero-window half width (paper's `r`).
    pub r: f32,
    /// BN/EMA momentum-style coefficient (paper's `a`).
    pub a: f32,
    pub lr_start: f64,
    pub lr_fin: f64,
}

fn put_run_meta(out: &mut Vec<u8>, meta: &RunMeta) {
    out.extend_from_slice(&meta.epoch_next.to_le_bytes());
    out.extend_from_slice(&meta.global_step.to_le_bytes());
    out.extend_from_slice(&meta.epochs_total.to_le_bytes());
    out.extend_from_slice(&meta.batch.to_le_bytes());
    out.extend_from_slice(&meta.seed.to_le_bytes());
    put_str(out, &meta.arch);
    put_str(out, &meta.method);
    out.extend_from_slice(&meta.m.to_le_bytes());
    out.extend_from_slice(&meta.r.to_le_bytes());
    out.extend_from_slice(&meta.a.to_le_bytes());
    out.extend_from_slice(&meta.lr_start.to_le_bytes());
    out.extend_from_slice(&meta.lr_fin.to_le_bytes());
}

fn get_run_meta(b: &[u8], pos: &mut usize) -> Result<RunMeta, String> {
    Ok(RunMeta {
        epoch_next: get_u64(b, pos)?,
        global_step: get_u64(b, pos)?,
        epochs_total: get_u64(b, pos)?,
        batch: get_u64(b, pos)?,
        seed: get_u64(b, pos)?,
        arch: get_str(b, pos)?,
        method: get_str(b, pos)?,
        m: get_f32(b, pos)?,
        r: get_f32(b, pos)?,
        a: get_f32(b, pos)?,
        lr_start: get_f64(b, pos)?,
        lr_fin: get_f64(b, pos)?,
    })
}

fn put_prng(out: &mut Vec<u8>, rng: &Prng) {
    let (s, spare) = rng.state();
    for w in s {
        out.extend_from_slice(&w.to_le_bytes());
    }
    match spare {
        None => out.push(0),
        Some(z) => {
            out.push(1);
            out.extend_from_slice(&z.to_bits().to_le_bytes());
        }
    }
}

fn get_prng(b: &[u8], pos: &mut usize) -> Result<Prng, String> {
    let mut s = [0u64; 4];
    for w in &mut s {
        *w = get_u64(b, pos)?;
    }
    let flag = *b.get(*pos).ok_or("truncated checkpoint")?;
    *pos += 1;
    let spare = match flag {
        0 => None,
        1 => Some(f64::from_bits(get_u64(b, pos)?)),
        t => return Err(format!("bad prng spare flag {t}")),
    };
    Ok(Prng::from_state(s, spare))
}

/// Serialize the complete run state (v2): meta, Prng, model, optimizer —
/// everything needed to continue training bit-identically.
pub fn serialize_run(model: &ModelState, opt: &Optimizer, rng: &Prng, meta: &RunMeta) -> Vec<u8> {
    let mut payload = Vec::new();
    put_run_meta(&mut payload, meta);
    put_prng(&mut payload, rng);
    put_model_body(&mut payload, model);
    opt.serialize_state(&mut payload);
    let mut out = Vec::with_capacity(payload.len() + 20);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION_RUN.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&payload);
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Restore a v2 run checkpoint: model weights, optimizer moments (pass
/// `None` to skip them), and the returned Prng + meta. v1 files are a
/// [`CkptError::Format`] here — they carry no run state to resume from.
pub fn restore_v2(
    model: &mut ModelState,
    opt: Option<&mut Optimizer>,
    bytes: &[u8],
) -> Result<(Prng, RunMeta), CkptError> {
    let mut pos = 0usize;
    if bytes.get(0..4) != Some(MAGIC.as_slice()) {
        return Err(CkptError::Corrupt("bad magic".into()));
    }
    pos += 4;
    let ver = get_u32(bytes, &mut pos).map_err(CkptError::Corrupt)?;
    if ver != VERSION_RUN {
        return Err(CkptError::Format(format!(
            "not a run checkpoint (version {ver}); only v{VERSION_RUN} files written \
             with --checkpoint-every are resumable"
        )));
    }
    let payload = v2_payload(bytes, &mut pos)?;
    let mut p = 0usize;
    let meta = get_run_meta(payload, &mut p).map_err(CkptError::Corrupt)?;
    let rng = get_prng(payload, &mut p).map_err(CkptError::Corrupt)?;
    get_model_body(model, payload, &mut p)?;
    match opt {
        Some(o) => o.restore_state(payload, &mut p).map_err(CkptError::Format)?,
        None => Optimizer::skip_state(payload, &mut p).map_err(CkptError::Corrupt)?,
    }
    if p != payload.len() {
        return Err(CkptError::Corrupt("trailing bytes".into()));
    }
    Ok((rng, meta))
}

/// Standalone checkpoint inspection: parse without a model and describe
/// every tensor (name, kind, space, shape, state histogram). Powers
/// `gxnor inspect`; understands both formats.
pub fn inspect(bytes: &[u8]) -> Result<String, String> {
    use std::fmt::Write as _;
    let mut pos = 0usize;
    if bytes.get(0..4) != Some(MAGIC.as_slice()) {
        return Err("bad checkpoint magic".into());
    }
    pos += 4;
    let ver = get_u32(bytes, &mut pos)?;
    let mut out = String::new();
    match ver {
        VERSION => {
            describe_body(bytes, &mut pos, &mut out, ver)?;
            Ok(out)
        }
        VERSION_RUN => {
            let payload = v2_payload(bytes, &mut pos).map_err(|e| e.to_string())?;
            let mut p = 0usize;
            let meta = get_run_meta(payload, &mut p)?;
            let _ = writeln!(
                out,
                "run state: epoch {}/{}, step {}, arch {}, method {}, seed {}, \
                 batch {}, m {} r {} a {}, lr {}→{}",
                meta.epoch_next,
                meta.epochs_total,
                meta.global_step,
                meta.arch,
                meta.method,
                meta.seed,
                meta.batch,
                meta.m,
                meta.r,
                meta.a,
                meta.lr_start,
                meta.lr_fin,
            );
            get_prng(payload, &mut p)?;
            describe_body(payload, &mut p, &mut out, ver)?;
            let opt_start = p;
            Optimizer::skip_state(payload, &mut p)?;
            let _ = writeln!(out, "optimizer state: {} B", p - opt_start);
            Ok(out)
        }
        v => Err(format!("unsupported checkpoint version {v}")),
    }
}

fn describe_body(
    bytes: &[u8],
    pos: &mut usize,
    out: &mut String,
    ver: u32,
) -> Result<(), String> {
    use std::fmt::Write as _;
    let n = get_u32(bytes, pos)? as usize;
    let _ = writeln!(out, "gxnor checkpoint v{ver}: {n} params");
    let mut packed_bytes = 0usize;
    let mut dense_bytes = 0usize;
    for _ in 0..n {
        let name = get_str(bytes, pos)?;
        let tag = *bytes.get(*pos).ok_or("truncated checkpoint")?;
        *pos += 1;
        match tag {
            0 => {
                let p = PackedTensor::deserialize(bytes, pos)?;
                packed_bytes += p.payload_bytes();
                let h = p.histogram();
                let states: Vec<String> = p
                    .space()
                    .states()
                    .iter()
                    .zip(&h)
                    .map(|(s, c)| format!("{s:+.2}:{c}"))
                    .collect();
                let _ = writeln!(
                    out,
                    "  {name:<10} Z_{} {:?} packed {} B  zero {:.3}  [{}]",
                    p.space().n(),
                    p.shape(),
                    p.payload_bytes(),
                    p.zero_fraction(),
                    states.join(" ")
                );
            }
            1 => {
                let f = get_f32s(bytes, pos)?;
                dense_bytes += f.len() * 4;
                let mean = f.iter().sum::<f32>() / f.len().max(1) as f32;
                let _ = writeln!(
                    out,
                    "  {name:<10} dense f32 [{}]  {} B  mean {mean:.4}",
                    f.len(),
                    f.len() * 4
                );
            }
            t => return Err(format!("bad tag {t}")),
        }
    }
    let n_bn = get_u32(bytes, pos)? as usize;
    for _ in 0..n_bn {
        let name = get_str(bytes, pos)?;
        let f = get_f32s(bytes, pos)?;
        dense_bytes += f.len() * 4;
        let _ = writeln!(out, "  {name:<10} bn state [{}]", f.len());
    }
    let _ = writeln!(
        out,
        "totals: {packed_bytes} B packed weights, {dense_bytes} B dense f32"
    );
    Ok(())
}

/// Write `bytes` to `path` atomically: temp file + fsync + rename. A
/// crash at any instant leaves the target path holding either the old
/// complete file or the new complete file — never a torn one.
pub fn write_atomic(path: &str, bytes: &[u8]) -> Result<(), CkptError> {
    write_atomic_with(path, bytes, None)
}

/// [`write_atomic`] with an optional fault plan: when the plan's
/// `torn_ckpt` knob fires, half the bytes land in the temp file and the
/// write fails *without renaming* — simulating a kill mid-write so tests
/// can assert the target path survives untouched.
pub fn write_atomic_with(
    path: &str,
    bytes: &[u8],
    faults: Option<&FaultPlan>,
) -> Result<(), CkptError> {
    use std::io::Write as _;
    let target = std::path::Path::new(path);
    let parent = target.parent().filter(|d| !d.as_os_str().is_empty());
    if let Some(dir) = parent {
        std::fs::create_dir_all(dir)
            .map_err(|e| CkptError::Io(format!("{}: {e}", dir.display())))?;
    }
    let tmp = format!("{path}.tmp.{}", std::process::id());
    if faults.is_some_and(|f| f.fire_torn_write()) {
        let _ = std::fs::write(&tmp, &bytes[..bytes.len() / 2]);
        return Err(CkptError::Io(format!("injected fault: torn write of {tmp}")));
    }
    let res = (|| -> std::io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, target)?;
        // Durability of the rename itself needs the directory synced; on
        // non-unix we settle for the rename's atomicity.
        #[cfg(unix)]
        if let Some(dir) = parent {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    })();
    if let Err(e) = res {
        let _ = std::fs::remove_file(&tmp);
        return Err(CkptError::Io(format!("{path}: {e}")));
    }
    Ok(())
}

pub fn save(model: &ModelState, path: &str) -> Result<(), String> {
    write_atomic(path, &serialize(model)).map_err(|e| e.to_string())
}

pub fn load(model: &mut ModelState, path: &str) -> Result<(), String> {
    load_classified(model, path).map_err(|e| e.to_string())
}

/// [`load`] with a classified error: I/O vs corrupt vs mismatch.
pub fn load_classified(model: &mut ModelState, path: &str) -> Result<(), CkptError> {
    let bytes = std::fs::read(path).map_err(|e| CkptError::Io(format!("{path}: {e}")))?;
    restore_classified(model, &bytes)
}

/// Atomically write a v2 run checkpoint.
pub fn save_run(
    path: &str,
    model: &ModelState,
    opt: &Optimizer,
    rng: &Prng,
    meta: &RunMeta,
    faults: Option<&FaultPlan>,
) -> Result<(), CkptError> {
    write_atomic_with(path, &serialize_run(model, opt, rng, meta), faults)
}

/// Load a v2 run checkpoint into an existing model + optimizer, returning
/// the saved Prng and run meta.
pub fn load_run(
    model: &mut ModelState,
    opt: &mut Optimizer,
    path: &str,
) -> Result<(Prng, RunMeta), CkptError> {
    let bytes = std::fs::read(path).map_err(|e| CkptError::Io(format!("{path}: {e}")))?;
    restore_v2(model, Some(opt), &bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::optimizer::OptKind;
    use crate::nn::init::init_model;
    use crate::nn::params::{ParamDesc, ParamKind};
    use crate::ternary::DiscreteSpace;

    fn model() -> ModelState {
        init_model(
            vec![
                ParamDesc { name: "W0".into(), shape: vec![8, 16], kind: ParamKind::Weight, layer: 0 },
                ParamDesc { name: "gamma0".into(), shape: vec![16], kind: ParamKind::Gamma, layer: 0 },
                ParamDesc { name: "W1".into(), shape: vec![16, 4], kind: ParamKind::Weight, layer: 1 },
            ],
            vec!["rmean0".into(), "rvar0".into()],
            &[16, 16],
            DiscreteSpace::TERNARY,
            3,
        )
    }

    fn meta() -> RunMeta {
        RunMeta {
            epoch_next: 4,
            global_step: 120,
            epochs_total: 10,
            batch: 32,
            seed: 7,
            arch: "mlp".into(),
            method: "gxnor".into(),
            m: 0.5,
            r: 0.5,
            a: 0.9,
            lr_start: 0.01,
            lr_fin: 0.001,
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let mut src = model();
        src.bn_state[0][3] = 0.77;
        let bytes = serialize(&src);
        let mut dst = model();
        restore(&mut dst, &bytes).unwrap();
        for (a, b) in src.values.iter().zip(&dst.values) {
            assert_eq!(a.to_f32(), b.to_f32());
        }
        assert_eq!(src.bn_state, dst.bn_state);
    }

    #[test]
    fn packed_checkpoint_is_small() {
        let src = model();
        let bytes = serialize(&src);
        let fp32_weights = (8 * 16 + 16 * 4) * 4;
        // weights dominate; packed ternary is ~16x smaller than f32
        assert!(
            bytes.len() < fp32_weights,
            "checkpoint {} >= fp32 {}",
            bytes.len(),
            fp32_weights
        );
    }

    #[test]
    fn rejects_corruption() {
        let src = model();
        let mut bytes = serialize(&src);
        bytes[0] = b'X';
        let mut dst = model();
        assert!(restore(&mut dst, &bytes).is_err());

        let mut bytes2 = serialize(&src);
        bytes2.truncate(bytes2.len() - 3);
        assert!(restore(&mut dst, &bytes2).is_err());

        let mut bytes3 = serialize(&src);
        bytes3.push(0);
        assert!(restore(&mut dst, &bytes3).is_err());
    }

    #[test]
    fn rejects_shape_mismatch() {
        let src = model();
        let bytes = serialize(&src);
        let mut other = init_model(
            vec![ParamDesc {
                name: "W0".into(),
                shape: vec![4, 4],
                kind: ParamKind::Weight,
                layer: 0,
            }],
            vec![],
            &[],
            DiscreteSpace::TERNARY,
            3,
        );
        assert!(restore(&mut other, &bytes).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let src = model();
        let path = std::env::temp_dir().join(format!("gxnor_ckpt_{}.bin", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        save(&src, &path).unwrap();
        let mut dst = model();
        load(&mut dst, &path).unwrap();
        assert_eq!(src.values[0].to_f32(), dst.values[0].to_f32());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn run_checkpoint_roundtrips_model_opt_prng_meta() {
        let mut src = model();
        src.bn_state[1][5] = 3.14;
        let mut opt = Optimizer::new(OptKind::Adam, src.values.len());
        let mut dw = vec![0.0f32; 16];
        for _ in 0..3 {
            opt.begin_step();
            opt.increment(1, &[0.05f32; 16], 0.01, &mut dw);
        }
        let mut rng = Prng::new(99);
        for _ in 0..13 {
            rng.next_u64();
        }
        let bytes = serialize_run(&src, &opt, &rng, &meta());

        let mut dst = model();
        let mut opt2 = Optimizer::new(OptKind::Adam, src.values.len());
        let (mut rng2, meta2) = restore_v2(&mut dst, Some(&mut opt2), &bytes).unwrap();
        assert_eq!(meta2, meta());
        assert_eq!(opt2.t(), opt.t());
        for (a, b) in src.values.iter().zip(&dst.values) {
            assert_eq!(a.to_f32(), b.to_f32());
        }
        assert_eq!(src.bn_state, dst.bn_state);
        let mut rng_ref = rng.clone();
        for _ in 0..8 {
            assert_eq!(rng_ref.next_u64(), rng2.next_u64());
        }
        // identical bytes when re-serialized: full state captured
        assert_eq!(bytes, serialize_run(&dst, &opt2, &rng, &meta()));
    }

    #[test]
    fn v2_bad_crc_is_reported_as_corrupt() {
        let src = model();
        let opt = Optimizer::new(OptKind::Adam, src.values.len());
        let rng = Prng::new(1);
        let mut bytes = serialize_run(&src, &opt, &rng, &meta());
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let mut dst = model();
        let mut opt2 = Optimizer::new(OptKind::Adam, src.values.len());
        match restore_v2(&mut dst, Some(&mut opt2), &bytes) {
            Err(CkptError::Corrupt(msg)) => assert!(msg.contains("bad CRC"), "{msg}"),
            other => panic!("expected Corrupt(bad CRC), got {other:?}"),
        }
        // the String-facing API keeps the distinct wording the CLI shows
        let err = restore(&mut dst, &bytes).unwrap_err();
        assert!(err.contains("corrupt checkpoint (bad CRC"), "{err}");
    }

    #[test]
    fn v2_restores_model_only_via_v1_api() {
        // eval/serve load run checkpoints through plain `restore`
        let mut src = model();
        src.bn_state[0][2] = 0.25;
        let opt = Optimizer::new(OptKind::Adam, src.values.len());
        let rng = Prng::new(5);
        let bytes = serialize_run(&src, &opt, &rng, &meta());
        let mut dst = model();
        restore(&mut dst, &bytes).unwrap();
        for (a, b) in src.values.iter().zip(&dst.values) {
            assert_eq!(a.to_f32(), b.to_f32());
        }
        assert_eq!(src.bn_state, dst.bn_state);
        // and inspect understands it
        let desc = inspect(&bytes).unwrap();
        assert!(desc.contains("run state: epoch 4/10"), "{desc}");
    }

    #[test]
    fn load_errors_are_classified() {
        let mut dst = model();
        match load_classified(&mut dst, "/nonexistent/gxnor/ckpt.bin") {
            Err(CkptError::Io(_)) => {}
            other => panic!("expected Io, got {other:?}"),
        }
        // v1 into wrong shapes → Format
        let bytes = serialize(&model());
        let mut other = init_model(
            vec![ParamDesc {
                name: "W0".into(),
                shape: vec![4, 4],
                kind: ParamKind::Weight,
                layer: 0,
            }],
            vec![],
            &[],
            DiscreteSpace::TERNARY,
            3,
        );
        match restore_classified(&mut other, &bytes) {
            Err(CkptError::Format(_)) => {}
            other => panic!("expected Format, got {other:?}"),
        }
        // v1 file through the resume path → Format (not resumable)
        let mut opt = Optimizer::new(OptKind::Adam, 3);
        match restore_v2(&mut dst, Some(&mut opt), &bytes) {
            Err(CkptError::Format(msg)) => assert!(msg.contains("not a run checkpoint"), "{msg}"),
            other => panic!("expected Format, got {other:?}"),
        }
    }

    #[test]
    fn torn_write_fault_preserves_previous_file() {
        let src = model();
        let dir = std::env::temp_dir().join(format!("gxnor_torn_{}", std::process::id()));
        let path = dir.join("run.ckpt");
        let path = path.to_str().unwrap().to_string();
        // first write succeeds, second is torn
        let plan = FaultPlan::parse("torn_ckpt=2").unwrap();
        let opt = Optimizer::new(OptKind::Adam, src.values.len());
        let rng = Prng::new(3);
        save_run(&path, &src, &opt, &rng, &meta(), Some(&plan)).unwrap();
        let good = std::fs::read(&path).unwrap();
        let mut meta2 = meta();
        meta2.epoch_next = 5;
        let err = save_run(&path, &src, &opt, &rng, &meta2, Some(&plan)).unwrap_err();
        assert!(matches!(err, CkptError::Io(_)), "{err:?}");
        // target path still holds the previous complete checkpoint
        assert_eq!(std::fs::read(&path).unwrap(), good);
        let mut dst = model();
        let mut opt2 = Optimizer::new(OptKind::Adam, src.values.len());
        let (_, m) = load_run(&mut dst, &mut opt2, &path).unwrap();
        assert_eq!(m.epoch_next, 4);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
