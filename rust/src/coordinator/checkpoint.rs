//! Checkpointing: weights stay bit-packed on disk, exactly as in memory.
//!
//! Format (little-endian):
//! ```text
//! magic "GXNR" | version u32 | n_params u32
//!   per param: name_len u32 | name bytes | tag u8 (0 packed, 1 dense)
//!              payload (PackedTensor::serialize or len u64 + f32s)
//! n_bn u32
//!   per bn:   name_len u32 | name bytes | len u64 | f32s
//! ```
//! A ternary MNIST-CNN checkpoint is ~16x smaller than its f32 equivalent —
//! the paper's Remark 2 memory claim, made concrete.

use crate::nn::params::{ModelState, ParamValue};
use crate::ternary::PackedTensor;

const MAGIC: &[u8; 4] = b"GXNR";
const VERSION: u32 = 1;

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    out.extend_from_slice(&(v.len() as u64).to_le_bytes());
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_u32(b: &[u8], pos: &mut usize) -> Result<u32, String> {
    let s = b.get(*pos..*pos + 4).ok_or("truncated checkpoint")?;
    *pos += 4;
    Ok(u32::from_le_bytes(s.try_into().unwrap()))
}

fn get_u64(b: &[u8], pos: &mut usize) -> Result<u64, String> {
    let s = b.get(*pos..*pos + 8).ok_or("truncated checkpoint")?;
    *pos += 8;
    Ok(u64::from_le_bytes(s.try_into().unwrap()))
}

fn get_str(b: &[u8], pos: &mut usize) -> Result<String, String> {
    let len = get_u32(b, pos)? as usize;
    let s = b.get(*pos..*pos + len).ok_or("truncated checkpoint")?;
    *pos += len;
    String::from_utf8(s.to_vec()).map_err(|e| e.to_string())
}

fn get_f32s(b: &[u8], pos: &mut usize) -> Result<Vec<f32>, String> {
    let len = get_u64(b, pos)? as usize;
    let mut v = Vec::with_capacity(len);
    for _ in 0..len {
        let s = b.get(*pos..*pos + 4).ok_or("truncated checkpoint")?;
        *pos += 4;
        v.push(f32::from_le_bytes(s.try_into().unwrap()));
    }
    Ok(v)
}

/// Serialize params + BN state (optimizer state is deliberately excluded:
/// a restored model resumes with fresh moments, like the paper's runs).
pub fn serialize(model: &ModelState) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(model.values.len() as u32).to_le_bytes());
    for (d, v) in model.descs.iter().zip(&model.values) {
        put_str(&mut out, &d.name);
        match v {
            ParamValue::Discrete(p) => {
                out.push(0);
                p.serialize(&mut out);
            }
            ParamValue::Dense(f) => {
                out.push(1);
                put_f32s(&mut out, f);
            }
        }
    }
    out.extend_from_slice(&(model.bn_state.len() as u32).to_le_bytes());
    for (name, s) in model.bn_names.iter().zip(&model.bn_state) {
        put_str(&mut out, name);
        put_f32s(&mut out, s);
    }
    out
}

/// Restore into an existing (shape-compatible) model.
pub fn restore(model: &mut ModelState, bytes: &[u8]) -> Result<(), String> {
    let mut pos = 0usize;
    if bytes.get(0..4) != Some(MAGIC.as_slice()) {
        return Err("bad checkpoint magic".into());
    }
    pos += 4;
    let ver = get_u32(bytes, &mut pos)?;
    if ver != VERSION {
        return Err(format!("unsupported checkpoint version {ver}"));
    }
    let n = get_u32(bytes, &mut pos)? as usize;
    if n != model.values.len() {
        return Err(format!("param count mismatch: {n} vs {}", model.values.len()));
    }
    for i in 0..n {
        let name = get_str(bytes, &mut pos)?;
        if name != model.descs[i].name {
            return Err(format!("param {i} name mismatch: {name} vs {}", model.descs[i].name));
        }
        let tag = *bytes.get(pos).ok_or("truncated checkpoint")?;
        pos += 1;
        match tag {
            0 => {
                let p = PackedTensor::deserialize(bytes, &mut pos)?;
                if p.len() != model.descs[i].numel() {
                    return Err(format!("param {name} size mismatch"));
                }
                model.values[i] = ParamValue::Discrete(p);
            }
            1 => {
                let f = get_f32s(bytes, &mut pos)?;
                if f.len() != model.descs[i].numel() {
                    return Err(format!("param {name} size mismatch"));
                }
                model.values[i] = ParamValue::Dense(f);
            }
            t => return Err(format!("bad param tag {t}")),
        }
    }
    let n_bn = get_u32(bytes, &mut pos)? as usize;
    if n_bn != model.bn_state.len() {
        return Err("bn state count mismatch".into());
    }
    for i in 0..n_bn {
        let name = get_str(bytes, &mut pos)?;
        if name != model.bn_names[i] {
            return Err(format!("bn {i} name mismatch"));
        }
        let f = get_f32s(bytes, &mut pos)?;
        if f.len() != model.bn_state[i].len() {
            return Err(format!("bn {name} size mismatch"));
        }
        model.bn_state[i] = f;
    }
    if pos != bytes.len() {
        return Err("trailing bytes in checkpoint".into());
    }
    Ok(())
}

/// Standalone checkpoint inspection: parse without a model and describe
/// every tensor (name, kind, space, shape, state histogram). Powers
/// `gxnor inspect`.
pub fn inspect(bytes: &[u8]) -> Result<String, String> {
    use std::fmt::Write as _;
    let mut pos = 0usize;
    if bytes.get(0..4) != Some(MAGIC.as_slice()) {
        return Err("bad checkpoint magic".into());
    }
    pos += 4;
    let ver = get_u32(bytes, &mut pos)?;
    let n = get_u32(bytes, &mut pos)? as usize;
    let mut out = String::new();
    let _ = writeln!(out, "gxnor checkpoint v{ver}: {n} params");
    let mut packed_bytes = 0usize;
    let mut dense_bytes = 0usize;
    for _ in 0..n {
        let name = get_str(bytes, &mut pos)?;
        let tag = *bytes.get(pos).ok_or("truncated checkpoint")?;
        pos += 1;
        match tag {
            0 => {
                let p = PackedTensor::deserialize(bytes, &mut pos)?;
                packed_bytes += p.payload_bytes();
                let h = p.histogram();
                let states: Vec<String> = p
                    .space()
                    .states()
                    .iter()
                    .zip(&h)
                    .map(|(s, c)| format!("{s:+.2}:{c}"))
                    .collect();
                let _ = writeln!(
                    out,
                    "  {name:<10} Z_{} {:?} packed {} B  zero {:.3}  [{}]",
                    p.space().n(),
                    p.shape(),
                    p.payload_bytes(),
                    p.zero_fraction(),
                    states.join(" ")
                );
            }
            1 => {
                let f = get_f32s(bytes, &mut pos)?;
                dense_bytes += f.len() * 4;
                let mean = f.iter().sum::<f32>() / f.len().max(1) as f32;
                let _ = writeln!(
                    out,
                    "  {name:<10} dense f32 [{}]  {} B  mean {mean:.4}",
                    f.len(),
                    f.len() * 4
                );
            }
            t => return Err(format!("bad tag {t}")),
        }
    }
    let n_bn = get_u32(bytes, &mut pos)? as usize;
    for _ in 0..n_bn {
        let name = get_str(bytes, &mut pos)?;
        let f = get_f32s(bytes, &mut pos)?;
        dense_bytes += f.len() * 4;
        let _ = writeln!(out, "  {name:<10} bn state [{}]", f.len());
    }
    let _ = writeln!(
        out,
        "totals: {packed_bytes} B packed weights, {dense_bytes} B dense f32"
    );
    Ok(out)
}

pub fn save(model: &ModelState, path: &str) -> Result<(), String> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
    }
    std::fs::write(path, serialize(model)).map_err(|e| e.to_string())
}

pub fn load(model: &mut ModelState, path: &str) -> Result<(), String> {
    let bytes = std::fs::read(path).map_err(|e| format!("{path}: {e}"))?;
    restore(model, &bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::init::init_model;
    use crate::nn::params::{ParamDesc, ParamKind};
    use crate::ternary::DiscreteSpace;

    fn model() -> ModelState {
        init_model(
            vec![
                ParamDesc { name: "W0".into(), shape: vec![8, 16], kind: ParamKind::Weight, layer: 0 },
                ParamDesc { name: "gamma0".into(), shape: vec![16], kind: ParamKind::Gamma, layer: 0 },
                ParamDesc { name: "W1".into(), shape: vec![16, 4], kind: ParamKind::Weight, layer: 1 },
            ],
            vec!["rmean0".into(), "rvar0".into()],
            &[16, 16],
            DiscreteSpace::TERNARY,
            3,
        )
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let mut src = model();
        src.bn_state[0][3] = 0.77;
        let bytes = serialize(&src);
        let mut dst = model();
        restore(&mut dst, &bytes).unwrap();
        for (a, b) in src.values.iter().zip(&dst.values) {
            assert_eq!(a.to_f32(), b.to_f32());
        }
        assert_eq!(src.bn_state, dst.bn_state);
    }

    #[test]
    fn packed_checkpoint_is_small() {
        let src = model();
        let bytes = serialize(&src);
        let fp32_weights = (8 * 16 + 16 * 4) * 4;
        // weights dominate; packed ternary is ~16x smaller than f32
        assert!(
            bytes.len() < fp32_weights,
            "checkpoint {} >= fp32 {}",
            bytes.len(),
            fp32_weights
        );
    }

    #[test]
    fn rejects_corruption() {
        let src = model();
        let mut bytes = serialize(&src);
        bytes[0] = b'X';
        let mut dst = model();
        assert!(restore(&mut dst, &bytes).is_err());

        let mut bytes2 = serialize(&src);
        bytes2.truncate(bytes2.len() - 3);
        assert!(restore(&mut dst, &bytes2).is_err());

        let mut bytes3 = serialize(&src);
        bytes3.push(0);
        assert!(restore(&mut dst, &bytes3).is_err());
    }

    #[test]
    fn rejects_shape_mismatch() {
        let src = model();
        let bytes = serialize(&src);
        let mut other = init_model(
            vec![ParamDesc {
                name: "W0".into(),
                shape: vec![4, 4],
                kind: ParamKind::Weight,
                layer: 0,
            }],
            vec![],
            &[],
            DiscreteSpace::TERNARY,
            3,
        );
        assert!(restore(&mut other, &bytes).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let src = model();
        let path = std::env::temp_dir().join(format!("gxnor_ckpt_{}.bin", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        save(&src, &path).unwrap();
        let mut dst = model();
        load(&mut dst, &path).unwrap();
        assert_eq!(src.values[0].to_f32(), dst.values[0].to_f32());
        std::fs::remove_file(&path).unwrap();
    }
}
