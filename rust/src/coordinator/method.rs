//! The unified framework's method space (Table 1 / Fig. 13).
//!
//! A method fixes (weight space N1, activation mode/space N2):
//!
//! | method | weights        | activations      | graph mode |
//! |--------|----------------|------------------|------------|
//! | fp     | dense f32      | full-precision   | `fp`       |
//! | bwn    | Z_0 = {-1,1}   | full-precision   | `fp`       |
//! | twn    | Z_1 = {-1,0,1} | full-precision   | `fp`       |
//! | bnn    | Z_0            | sign             | `bin`      |
//! | gxnor  | Z_1            | phi_r ternary    | `multi` (hl=1) |
//! | multi  | Z_N1           | phi_r 2^N2+1-ary | `multi`    |

use crate::ternary::DiscreteSpace;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Full-precision baseline ("Full-precision NNs" row of Table 1).
    Fp,
    /// Binary weight network [16][17].
    Bwn,
    /// Ternary weight network [17][18].
    Twn,
    /// Binarized neural network / XNOR-Net [19][20].
    Bnn,
    /// The paper's GXNOR-Net: ternary weights *and* activations.
    Gxnor,
    /// The unified multilevel space of Fig. 13: weights Z_N1, acts Z_N2.
    Multi { n1: u32, n2: u32 },
}

impl Method {
    pub fn parse(s: &str) -> Result<Method, String> {
        match s {
            "fp" => Ok(Method::Fp),
            "bwn" => Ok(Method::Bwn),
            "twn" => Ok(Method::Twn),
            "bnn" => Ok(Method::Bnn),
            "gxnor" => Ok(Method::Gxnor),
            other => {
                // "multi:N1,N2"
                if let Some(rest) = other.strip_prefix("multi:") {
                    let (a, b) = rest
                        .split_once(',')
                        .ok_or("multi method needs N1,N2 (e.g. multi:6,4)")?;
                    let n1: u32 = a.parse().map_err(|_| format!("bad N1 {a:?}"))?;
                    let n2: u32 = b.parse().map_err(|_| format!("bad N2 {b:?}"))?;
                    // DiscreteSpace::new asserts N <= 15 (state-index
                    // width); reject here with a clean error instead of
                    // panicking when the space is first constructed
                    if n1 > 15 || n2 > 15 {
                        return Err(format!(
                            "multi:{n1},{n2}: N1/N2 must be <= 15 (Z_N state index)"
                        ));
                    }
                    return Ok(Method::Multi { n1, n2 });
                }
                Err(format!(
                    "unknown method {other:?} (fp|bwn|twn|bnn|gxnor|multi:N1,N2)"
                ))
            }
        }
    }

    /// Weight space, or None for dense full-precision weights.
    pub fn weight_space(&self) -> Option<DiscreteSpace> {
        match self {
            Method::Fp => None,
            Method::Bwn | Method::Bnn => Some(DiscreteSpace::BINARY),
            Method::Twn | Method::Gxnor => Some(DiscreteSpace::TERNARY),
            Method::Multi { n1, .. } => Some(DiscreteSpace::new(*n1)),
        }
    }

    /// The lowered-graph activation mode this method executes on.
    pub fn graph_mode(&self) -> &'static str {
        match self {
            Method::Fp | Method::Bwn | Method::Twn => "fp",
            Method::Bnn => "bin",
            Method::Gxnor | Method::Multi { .. } => "multi",
        }
    }

    /// The quantizer's half-level scalar `hl = 2^{N2-1}` (1.0 when unused).
    pub fn hl(&self) -> f32 {
        match self {
            Method::Multi { n2, .. } => DiscreteSpace::new(*n2).half_levels(),
            _ => 1.0,
        }
    }

    pub fn name(&self) -> String {
        match self {
            Method::Fp => "fp".into(),
            Method::Bwn => "bwn".into(),
            Method::Twn => "twn".into(),
            Method::Bnn => "bnn".into(),
            Method::Gxnor => "gxnor".into(),
            Method::Multi { n1, n2 } => format!("multi:{n1},{n2}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["fp", "bwn", "twn", "bnn", "gxnor", "multi:6,4"] {
            let m = Method::parse(s).unwrap();
            assert_eq!(m.name(), s);
        }
        assert!(Method::parse("nope").is_err());
        assert!(Method::parse("multi:6").is_err());
        // N > 15 would panic DiscreteSpace::new later — clean error here
        assert!(Method::parse("multi:16,2").is_err());
        assert!(Method::parse("multi:2,16").is_err());
    }

    #[test]
    fn table1_space_assignments() {
        assert_eq!(Method::Fp.weight_space(), None);
        assert_eq!(Method::Bwn.weight_space(), Some(DiscreteSpace::BINARY));
        assert_eq!(Method::Twn.weight_space(), Some(DiscreteSpace::TERNARY));
        assert_eq!(Method::Bnn.weight_space(), Some(DiscreteSpace::BINARY));
        assert_eq!(Method::Gxnor.weight_space(), Some(DiscreteSpace::TERNARY));
    }

    #[test]
    fn graph_modes() {
        assert_eq!(Method::Bwn.graph_mode(), "fp"); // fp activations
        assert_eq!(Method::Bnn.graph_mode(), "bin");
        assert_eq!(Method::Gxnor.graph_mode(), "multi");
        assert_eq!(Method::Gxnor.hl(), 1.0);
        assert_eq!(Method::Multi { n1: 1, n2: 4 }.hl(), 8.0);
    }
}
