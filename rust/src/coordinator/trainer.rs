//! The training loop: the system's end-to-end hot path.
//!
//! Per step: expand packed weights to f32 → execute the lowered train graph
//! (loss, accuracy, per-layer activation sparsity, gradients, BN stats) →
//! Adam/SGD-precondition the gradients → **DST-project** the weight
//! increments back onto the Z_N grid (eqs. 13–20) → store packed. Dense
//! parameters (BN affine; all weights in the `fp` baseline) take ordinary
//! dense updates. Python is never involved.

use anyhow::{anyhow, Result};

use crate::coordinator::hidden::HiddenWeights;
use crate::coordinator::method::Method;
use crate::coordinator::optimizer::{OptKind, Optimizer};
use crate::coordinator::schedule::LrSchedule;
use crate::data::{AugmentCfg, BatchIter, Dataset};
use crate::metrics::Recorder;
use crate::nn::params::{ModelState, ParamKind, ParamValue};
use crate::nn::init::init_model;
use crate::runtime::client::{Arg, Runtime};
use crate::runtime::manifest::{GraphMeta, Manifest};
use crate::ternary::{dst_update, DiscreteSpace, DstStats};
use crate::util::prng::Prng;
use crate::util::timer::Stopwatch;

/// How discrete weights are updated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateRule {
    /// The paper's Discrete State Transition: weights live permanently on
    /// the Z_N grid, no full-precision copy (eqs. 13-20).
    Dst,
    /// The baseline the paper replaces (Fig. 4a): full-precision hidden
    /// weights updated by gradients and re-quantized each step
    /// (BinaryConnect [16] / TWN [17] / BNN [19]).
    Hidden,
}

impl UpdateRule {
    pub fn parse(s: &str) -> Result<UpdateRule, String> {
        match s {
            "dst" => Ok(UpdateRule::Dst),
            "hidden" => Ok(UpdateRule::Hidden),
            other => Err(format!("unknown update rule {other:?} (dst|hidden)")),
        }
    }
}

/// Everything a training run needs.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub arch: String,
    pub method: Method,
    pub dataset: String,
    pub train_len: usize,
    pub test_len: usize,
    pub epochs: usize,
    pub seed: u64,
    /// zero-window half width r (Fig. 10's sparsity knob)
    pub r: f32,
    /// derivative pulse half-width a (Fig. 9)
    pub a: f32,
    /// DST nonlinearity m (Fig. 8)
    pub m: f32,
    pub lr_start: f64,
    pub lr_fin: f64,
    pub opt: OptKind,
    pub update_rule: UpdateRule,
    pub augment: bool,
    /// learning rate multiplier for BN/dense params
    pub dense_lr_scale: f64,
    /// print progress lines
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            arch: "mlp".into(),
            method: Method::Gxnor,
            dataset: "synth_mnist".into(),
            train_len: 2000,
            test_len: 500,
            epochs: 3,
            seed: 42,
            r: 0.5,
            a: 0.5,   // paper: rectangular window, a = 0.5
            m: 3.0,   // paper: m = 3
            lr_start: 0.02,
            lr_fin: 1e-3,
            opt: OptKind::Adam,
            update_rule: UpdateRule::Dst,
            augment: false,
            dense_lr_scale: 0.5,
            verbose: false,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub loss: f64,
    pub acc: f64,
    /// mean zero-activation fraction across hidden layers
    pub sparsity: f64,
    /// per-hidden-layer zero-activation fraction (hwsim input)
    pub sparsity_per_layer: Vec<f64>,
    pub dst: DstStats,
}

/// Result of a full run (feeds the benches and EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub recorder: Recorder,
    pub test_acc: f64,
    pub final_train_loss: f64,
    pub weight_zero_fraction: f64,
    pub mean_act_sparsity: f64,
    pub packed_bytes: usize,
    pub fp32_bytes: usize,
    /// fp32 bytes held by hidden masters (0 under DST — the paper's claim)
    pub hidden_fp32_bytes: usize,
    pub step_time_ms: f64,
    pub exec_time_ms: f64,
    pub dst_time_ms: f64,
}

/// Trainer wiring one model to one (train, infer) graph pair.
pub struct Trainer<'rt> {
    rt: &'rt mut Runtime,
    train_g: GraphMeta,
    infer_g: GraphMeta,
    pub model: ModelState,
    opt: Optimizer,
    cfg: TrainConfig,
    rng: Prng,
    /// cached f32 expansion of every param (PJRT boundary buffers)
    param_f32: Vec<Vec<f32>>,
    /// scratch for DST increments
    dw_buf: Vec<f32>,
    /// full-precision masters, only under UpdateRule::Hidden (Fig. 4a)
    hidden: Vec<Option<HiddenWeights>>,
    pub sw_exec: Stopwatch,
    pub sw_update: Stopwatch,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt mut Runtime, manifest: &Manifest, cfg: TrainConfig) -> Result<Self> {
        let mode = cfg.method.graph_mode();
        // batch size comes from whatever graph the manifest has for this
        // arch/mode (the catalogue fixes it per arch).
        let train_g = manifest
            .graphs
            .iter()
            .find(|g| g.arch == cfg.arch && g.mode == mode && g.kind == "train" && g.batch > 16)
            .or_else(|| {
                manifest
                    .graphs
                    .iter()
                    .find(|g| g.arch == cfg.arch && g.mode == mode && g.kind == "train")
            })
            .ok_or_else(|| {
                anyhow!("no train graph for arch={} mode={mode} in manifest", cfg.arch)
            })?
            .clone();
        let infer_g = manifest
            .get(&train_g.name.replace("_train", "_infer"))
            .map_err(|e| anyhow!(e))?
            .clone();
        rt.load(&train_g)?;
        rt.load(&infer_g)?;

        let descs: Vec<_> = train_g.params.clone();
        let bn_names: Vec<String> = train_g.bn_state.iter().map(|s| s.name.clone()).collect();
        let bn_shapes: Vec<usize> = train_g.bn_state.iter().map(|s| s.numel()).collect();
        let space = cfg
            .method
            .weight_space()
            .unwrap_or(DiscreteSpace::TERNARY); // placeholder for fp; unused
        let mut model = init_model(descs, bn_names, &bn_shapes, space, cfg.seed);
        if cfg.method.weight_space().is_none() {
            // fp baseline: replace packed weights with dense Glorot init
            let mut rng = Prng::new(cfg.seed ^ 0xF9);
            for (d, v) in model.descs.iter().zip(model.values.iter_mut()) {
                if d.kind == ParamKind::Weight {
                    let fan_in: usize =
                        d.shape[..d.shape.len() - 1].iter().product::<usize>().max(1);
                    let std = (2.0 / fan_in as f32).sqrt();
                    *v = ParamValue::Dense(
                        (0..d.numel()).map(|_| rng.normal_f32() * std).collect(),
                    );
                }
            }
        }
        let param_f32: Vec<Vec<f32>> = model.values.iter().map(|v| v.to_f32()).collect();
        // hidden-weight baseline: seed masters from the initial discrete states
        let hidden: Vec<Option<HiddenWeights>> = model
            .values
            .iter()
            .zip(&param_f32)
            .map(|(v, f)| match (cfg.update_rule, v) {
                (UpdateRule::Hidden, ParamValue::Discrete(p)) => {
                    Some(HiddenWeights::from_discrete(f, p.space()))
                }
                _ => None,
            })
            .collect();
        let max_numel = model.descs.iter().map(|d| d.numel()).max().unwrap_or(0);
        let opt = Optimizer::new(cfg.opt, model.values.len());
        let rng = Prng::new(cfg.seed ^ 0xD57);
        Ok(Trainer {
            rt,
            train_g,
            infer_g,
            model,
            opt,
            cfg,
            rng,
            param_f32,
            dw_buf: vec![0.0; max_numel],
            hidden,
            sw_exec: Stopwatch::new(),
            sw_update: Stopwatch::new(),
        })
    }

    pub fn batch_size(&self) -> usize {
        self.train_g.batch
    }

    pub fn graph_name(&self) -> &str {
        &self.train_g.name
    }

    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    fn refresh_param_f32(&mut self) {
        for (v, buf) in self.model.values.iter().zip(self.param_f32.iter_mut()) {
            match v {
                ParamValue::Discrete(p) => p.unpack_into(buf),
                ParamValue::Dense(d) => buf.copy_from_slice(d),
            }
        }
    }

    /// One training step on a prepared batch.
    pub fn step(&mut self, x: &[f32], labels: &[i32], lr: f64) -> Result<StepStats> {
        let b = self.train_g.batch;
        assert_eq!(labels.len(), b);
        // 1. execute the lowered fwd/bwd graph
        let hl = self.cfg.method.hl();
        let mut args: Vec<Arg> = vec![
            Arg::F32(x),
            Arg::I32(labels),
            Arg::Scalar(self.cfg.r),
            Arg::Scalar(self.cfg.a),
            Arg::Scalar(hl),
        ];
        for p in &self.param_f32 {
            args.push(Arg::F32(p));
        }
        for s in &self.model.bn_state {
            args.push(Arg::F32(s));
        }
        self.sw_exec.start();
        let outs = self.rt.execute(&self.train_g, &args)?;
        self.sw_exec.stop();

        let loss = outs[0][0] as f64;
        let acc = outs[1][0] as f64 / b as f64;
        let spars = &outs[2];
        let sparsity = if spars.is_empty() {
            0.0
        } else {
            spars.iter().map(|&v| v as f64).sum::<f64>() / spars.len() as f64
        };

        // 2. updates: DST for discrete weights, dense for the rest
        self.sw_update.start();
        self.opt.begin_step();
        let n_params = self.model.descs.len();
        let mut dst_stats = DstStats::default();
        for i in 0..n_params {
            let grad = &outs[3 + i];
            let desc = &self.model.descs[i];
            match &mut self.model.values[i] {
                ParamValue::Discrete(packed) => {
                    debug_assert_eq!(desc.kind, ParamKind::Weight);
                    let w = &mut self.param_f32[i];
                    if let Some(hw) = &mut self.hidden[i] {
                        // Fig. 4a baseline: update the fp master, requantize
                        hw.step(i, &mut self.opt, grad, lr, &mut self.dw_buf, w);
                    } else {
                        // the paper's DST: no master copy exists
                        let dw = &mut self.dw_buf[..grad.len()];
                        self.opt.increment(i, grad, lr, dw);
                        let stats =
                            dst_update(w, dw, packed.space(), self.cfg.m, &mut self.rng);
                        dst_stats.merge(&stats);
                    }
                    packed.repack_from(w);
                }
                ParamValue::Dense(dense) => {
                    let scale = if desc.kind == ParamKind::Weight {
                        1.0 // fp baseline weights use the full LR
                    } else {
                        self.cfg.dense_lr_scale
                    };
                    self.opt.apply_dense(i, dense, grad, lr * scale);
                    self.param_f32[i].copy_from_slice(dense);
                }
            }
        }
        // 3. BN running stats come straight off the graph
        let bn_off = 3 + n_params;
        for (j, s) in self.model.bn_state.iter_mut().enumerate() {
            s.copy_from_slice(&outs[bn_off + j]);
        }
        self.sw_update.stop();

        Ok(StepStats {
            loss,
            acc,
            sparsity,
            sparsity_per_layer: spars.iter().map(|&v| v as f64).collect(),
            dst: dst_stats,
        })
    }

    /// Accuracy over a dataset using the infer graph (BN running stats).
    pub fn evaluate(&mut self, ds: &dyn Dataset) -> Result<f64> {
        self.refresh_param_f32();
        let b = self.infer_g.batch;
        let sample_len = ds.sample_len();
        let mut x = vec![0.0f32; b * sample_len];
        let mut correct = 0usize;
        let mut total = 0usize;
        let n_batches = ds.len() / b;
        let hl = self.cfg.method.hl();
        for nb in 0..n_batches {
            let mut labels = vec![0i32; b];
            for i in 0..b {
                labels[i] =
                    ds.fill(nb * b + i, &mut x[i * sample_len..(i + 1) * sample_len]) as i32;
            }
            let mut args: Vec<Arg> =
                vec![Arg::F32(&x), Arg::Scalar(self.cfg.r), Arg::Scalar(hl)];
            for p in &self.param_f32 {
                args.push(Arg::F32(p));
            }
            for s in &self.model.bn_state {
                args.push(Arg::F32(s));
            }
            let outs = self.rt.execute(&self.infer_g, &args)?;
            let logits = &outs[0];
            for (i, &lbl) in labels.iter().enumerate() {
                let row = &logits[i * self.infer_g.n_classes..(i + 1) * self.infer_g.n_classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(k, _)| k as i32)
                    .unwrap();
                if pred == lbl {
                    correct += 1;
                }
            }
            total += b;
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Full run: epochs × batches with the paper's LR decay; returns the
    /// report consumed by the benches.
    pub fn run(&mut self, train: &dyn Dataset, test: &dyn Dataset) -> Result<TrainReport> {
        let schedule = LrSchedule::new(self.cfg.lr_start, self.cfg.lr_fin, self.cfg.epochs);
        let aug = if self.cfg.augment {
            AugmentCfg::paper()
        } else {
            AugmentCfg::none()
        };
        let b = self.train_g.batch;
        let sample_len = train.sample_len();
        let mut x = vec![0.0f32; b * sample_len];
        let mut y = vec![0i32; b];
        let mut rec = Recorder::new();
        let mut steps = 0u64;
        let t0 = std::time::Instant::now();
        for epoch in 0..self.cfg.epochs {
            let lr = schedule.lr_at(epoch);
            let mut it = BatchIter::new(train, b, self.cfg.seed.wrapping_add(epoch as u64), aug);
            let mut ep_loss = 0.0;
            let mut ep_acc = 0.0;
            let mut n = 0;
            self.refresh_param_f32();
            while it.next_batch(&mut x, &mut y) {
                let s = self.step(&x, &y, lr)?;
                ep_loss += s.loss;
                ep_acc += s.acc;
                n += 1;
                steps += 1;
                rec.push("loss", s.loss);
                rec.push("train_acc", s.acc);
                rec.push("act_sparsity", s.sparsity);
                for (j, &v) in s.sparsity_per_layer.iter().enumerate() {
                    rec.push(&format!("act_sparsity_l{j}"), v);
                }
                rec.push("dst_rate", s.dst.transition_rate());
            }
            let test_acc = self.evaluate(test)?;
            rec.push("epoch_loss", ep_loss / n.max(1) as f64);
            rec.push("epoch_train_acc", ep_acc / n.max(1) as f64);
            rec.push("test_acc", test_acc);
            rec.push("test_err", 1.0 - test_acc);
            rec.push("lr", lr);
            if self.cfg.verbose {
                println!(
                    "epoch {epoch:>3}  lr {lr:.2e}  loss {:>8.4}  train {:5.1}%  test {:5.1}%  spars {:.2}",
                    ep_loss / n.max(1) as f64,
                    100.0 * ep_acc / n.max(1) as f64,
                    100.0 * test_acc,
                    rec.last("act_sparsity").unwrap_or(0.0),
                );
            }
        }
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let (packed, fp32) = self.model.weight_memory_bytes();
        Ok(TrainReport {
            test_acc: rec.last("test_acc").unwrap_or(0.0),
            final_train_loss: rec.last("epoch_loss").unwrap_or(f64::NAN),
            weight_zero_fraction: self.model.weight_zero_fraction(),
            mean_act_sparsity: rec.tail_mean("act_sparsity", 50),
            packed_bytes: packed,
            fp32_bytes: fp32,
            hidden_fp32_bytes: self.hidden.iter().flatten().map(|h| h.fp32_bytes()).sum(),
            step_time_ms: wall_ms / steps.max(1) as f64,
            exec_time_ms: self.sw_exec.mean_ms(),
            dst_time_ms: self.sw_update.mean_ms(),
            recorder: rec,
        })
    }
}

/// Convenience: open datasets, build a trainer, run, return the report.
pub fn run_training(rt: &mut Runtime, manifest: &Manifest, cfg: TrainConfig) -> Result<TrainReport> {
    let train = crate::data::open(&cfg.dataset, true, cfg.train_len).map_err(|e| anyhow!(e))?;
    let test = crate::data::open(&cfg.dataset, false, cfg.test_len).map_err(|e| anyhow!(e))?;
    let mut tr = Trainer::new(rt, manifest, cfg)?;
    tr.run(train.as_ref(), test.as_ref())
}
