//! The training loop: the system's end-to-end hot path.
//!
//! Per step: execute the lowered train graph (loss, accuracy, per-layer
//! activation sparsity, gradients, BN stats) → Adam/SGD-precondition the
//! gradients → **DST-project** the weight increments back onto the Z_N
//! grid (eqs. 13–20) → store packed. Dense parameters (BN affine; all
//! weights in the `fp` baseline) take ordinary dense updates. Python is
//! never involved.
//!
//! The boundary is pooled and pipelined (§Perf iteration 9):
//!
//! * **Zero-copy marshalling** — every input literal lives in a per-graph
//!   [`ExecBuffers`] pool created at construction and refilled in place.
//!   Batch `x`/`labels` and BN state refill every step; a discrete weight
//!   tensor refills only when DST actually moved a state on it
//!   (`DstStats::transitions > 0` — at high sparsity most tensors most
//!   steps move nothing, echoing the paper's Remark 2 that the discrete
//!   weights *are* the state); static scalars (`r`, `a`, `hl`) are written
//!   once. Outputs land in reusable caller-owned buffers via
//!   [`Runtime::execute_into`]. The steady-state marshalling path performs
//!   no heap allocation.
//! * **Pipelined batches** — a [`Prefetcher`] worker assembles batch *k+1*
//!   (shuffle, procedural fill, augment) while the graph executes batch
//!   *k*, reproducing the serial iterator's per-epoch RNG streams exactly,
//!   so the training trajectory is bit-identical to the serial loop.
//!
//! Since §Perf iteration 10 the step loop also runs **fully native**
//! (`--engine native` → [`NativeTrainer`]): forward-with-cache +
//! ternary-operand backward in `engine::NativeTrainEngine`, DST applied
//! directly to the packed 2-bit states (`ternary::dst_update_packed`) —
//! no PJRT boundary, no f32 weight tensor anywhere in the loop. Both
//! backends drive one shared epoch loop (`drive_epochs` via the
//! private `LoopBackend` trait), so schedules, metrics and evaluation
//! cadence are identical and the XLA path survives as the A/B baseline.

use anyhow::{anyhow, Result};

use crate::coordinator::checkpoint::{self, RunMeta};
use crate::coordinator::hidden::HiddenWeights;
use crate::coordinator::method::Method;
use crate::coordinator::optimizer::{OptKind, Optimizer};
use crate::coordinator::schedule::LrSchedule;
use crate::data::{AugmentCfg, Batch, Dataset, Item, Prefetcher};
use crate::engine::{NativeEngine, NativeTrainEngine};
use crate::metrics::{percentile, Recorder};
use crate::nn::arch::{build_arch, param_descs};
use crate::nn::init::init_model;
use crate::nn::params::{ModelState, ParamDesc, ParamKind, ParamValue};
use crate::runtime::client::{Arg, ExecBuffers, Runtime};
use crate::runtime::exec::{EngineKind, ExecEngine, XlaInferEngine};
use crate::runtime::manifest::{GraphMeta, Manifest};
use crate::ternary::{dst_update, dst_update_packed, DiscreteSpace, DstStats};
use crate::util::argmax;
use crate::util::fault::Faults;
use crate::util::prng::Prng;
use crate::util::timer::Stopwatch;

/// Train-graph input layout: x, labels, r, a, hl, params…, bn….
const TRAIN_FIXED_INPUTS: usize = 5;
/// Infer-graph input layout: x, r, hl, params…, bn….
const INFER_FIXED_INPUTS: usize = 3;
/// Pipeline depth of the batch prefetcher (double buffering).
const PREFETCH_DEPTH: usize = 2;

/// How discrete weights are updated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateRule {
    /// The paper's Discrete State Transition: weights live permanently on
    /// the Z_N grid, no full-precision copy (eqs. 13-20).
    Dst,
    /// The baseline the paper replaces (Fig. 4a): full-precision hidden
    /// weights updated by gradients and re-quantized each step
    /// (BinaryConnect [16] / TWN [17] / BNN [19]).
    Hidden,
}

impl UpdateRule {
    pub fn parse(s: &str) -> Result<UpdateRule, String> {
        match s {
            "dst" => Ok(UpdateRule::Dst),
            "hidden" => Ok(UpdateRule::Hidden),
            other => Err(format!("unknown update rule {other:?} (dst|hidden)")),
        }
    }
}

/// Everything a training run needs.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub arch: String,
    pub method: Method,
    pub dataset: String,
    pub train_len: usize,
    pub test_len: usize,
    pub epochs: usize,
    pub seed: u64,
    /// zero-window half width r (Fig. 10's sparsity knob)
    pub r: f32,
    /// derivative pulse half-width a (Fig. 9)
    pub a: f32,
    /// DST nonlinearity m (Fig. 8)
    pub m: f32,
    pub lr_start: f64,
    pub lr_fin: f64,
    pub opt: OptKind,
    pub update_rule: UpdateRule,
    pub augment: bool,
    /// learning rate multiplier for BN/dense params
    pub dense_lr_scale: f64,
    /// which `ExecEngine` evaluation runs on (`--engine xla|native`)
    pub engine: EngineKind,
    /// native-engine worker threads for `infer_batch` sharding
    /// (`--threads N`; 0 = auto, up to one per core). Logits and merged
    /// `GateStats` are thread-count-invariant, so this is purely a
    /// throughput knob.
    pub threads: usize,
    /// batch size for the native training engine (`--batch N`; 0 = take
    /// the manifest graph's batch, or 100 without a manifest). The XLA
    /// path ignores this: its batch is baked into the lowered graph.
    pub batch: usize,
    /// print progress lines
    pub verbose: bool,
    /// save a v2 run checkpoint to `checkpoint_path` every N completed
    /// epochs (`--checkpoint-every N`; 0 = off)
    pub checkpoint_every: usize,
    /// where periodic run checkpoints land (shares the `--save` path)
    pub checkpoint_path: String,
    /// armed fault-injection plan (`--faults` / `GXNOR_FAULTS`; `None` in
    /// production — every injection point is a no-op then)
    pub faults: Faults,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            arch: "mlp".into(),
            method: Method::Gxnor,
            dataset: "synth_mnist".into(),
            train_len: 2000,
            test_len: 500,
            epochs: 3,
            seed: 42,
            r: 0.5,
            a: 0.5,   // paper: rectangular window, a = 0.5
            m: 3.0,   // paper: m = 3
            lr_start: 0.02,
            lr_fin: 1e-3,
            opt: OptKind::Adam,
            update_rule: UpdateRule::Dst,
            augment: false,
            dense_lr_scale: 0.5,
            engine: EngineKind::Xla,
            threads: 0,
            batch: 0,
            verbose: false,
            checkpoint_every: 0,
            checkpoint_path: String::new(),
            faults: None,
        }
    }
}

/// The v2 checkpoint meta for a run at a given position. `global_step`
/// is the optimizer's shared timestep — it survives resume because
/// `Optimizer::restore_state` carries it.
fn run_meta(cfg: &TrainConfig, batch: usize, epoch_next: u64, global_step: u64) -> RunMeta {
    RunMeta {
        epoch_next,
        global_step,
        epochs_total: cfg.epochs as u64,
        batch: batch as u64,
        seed: cfg.seed,
        arch: cfg.arch.clone(),
        method: cfg.method.name(),
        m: cfg.m,
        r: cfg.r,
        a: cfg.a,
        lr_start: cfg.lr_start,
        lr_fin: cfg.lr_fin,
    }
}

#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub loss: f64,
    pub acc: f64,
    /// mean zero-activation fraction across hidden layers
    pub sparsity: f64,
    /// per-hidden-layer zero-activation fraction (hwsim input)
    pub sparsity_per_layer: Vec<f64>,
    pub dst: DstStats,
}

/// Result of a full run (feeds the benches and EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub recorder: Recorder,
    pub test_acc: f64,
    pub final_train_loss: f64,
    pub weight_zero_fraction: f64,
    pub mean_act_sparsity: f64,
    pub packed_bytes: usize,
    pub fp32_bytes: usize,
    /// fp32 bytes held by hidden masters (0 under DST — the paper's claim)
    pub hidden_fp32_bytes: usize,
    /// fp32 bytes of *expanded weight mirrors* held across the step loop:
    /// the XLA path keeps one f32 expansion per discrete tensor to feed
    /// the PJRT boundary; the native DST path keeps **none** — weights
    /// stay 2-bit packed and DST streams them in place. Asserting this is
    /// exactly 0 under `--engine native` is the memory-accounting
    /// satellite's numerical form of the hidden-weight-free claim.
    pub weight_f32_mirror_bytes: usize,
    pub step_time_ms: f64,
    pub exec_time_ms: f64,
    pub dst_time_ms: f64,
    /// mean time spent refilling input literals (the PJRT boundary cost)
    pub marshal_time_ms: f64,
    /// median / tail step latency over the whole run
    pub step_p50_ms: f64,
    pub step_p99_ms: f64,
    pub steps_per_sec: f64,
}

/// Trainer wiring one model to one (train, infer) graph pair.
pub struct Trainer<'rt> {
    rt: &'rt mut Runtime,
    train_g: GraphMeta,
    infer_g: GraphMeta,
    pub model: ModelState,
    opt: Optimizer,
    cfg: TrainConfig,
    rng: Prng,
    /// cached f32 expansion of every param (PJRT boundary buffers)
    param_f32: Vec<Vec<f32>>,
    /// scratch for DST increments
    dw_buf: Vec<f32>,
    /// full-precision masters, only under UpdateRule::Hidden (Fig. 4a)
    hidden: Vec<Option<HiddenWeights>>,
    /// pooled input literals + reusable output buffers, per graph
    train_bufs: ExecBuffers,
    infer_bufs: ExecBuffers,
    /// param i's device literal is stale and needs a refill next step
    dirty: Vec<bool>,
    pub sw_exec: Stopwatch,
    pub sw_update: Stopwatch,
    pub sw_marshal: Stopwatch,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt mut Runtime, manifest: &Manifest, cfg: TrainConfig) -> Result<Self> {
        let mode = cfg.method.graph_mode();
        // batch size comes from whatever graph the manifest has for this
        // arch/mode (the catalogue fixes it per arch).
        let train_g = manifest
            .graphs
            .iter()
            .find(|g| g.arch == cfg.arch && g.mode == mode && g.kind == "train" && g.batch > 16)
            .or_else(|| {
                manifest
                    .graphs
                    .iter()
                    .find(|g| g.arch == cfg.arch && g.mode == mode && g.kind == "train")
            })
            .ok_or_else(|| {
                anyhow!("no train graph for arch={} mode={mode} in manifest", cfg.arch)
            })?
            .clone();
        let infer_g = manifest
            .get(&train_g.name.replace("_train", "_infer"))
            .map_err(|e| anyhow!(e))?
            .clone();
        rt.load(&train_g)?;
        rt.load(&infer_g)?;

        let descs: Vec<_> = train_g.params.clone();
        let bn_names: Vec<String> = train_g.bn_state.iter().map(|s| s.name.clone()).collect();
        let bn_shapes: Vec<usize> = train_g.bn_state.iter().map(|s| s.numel()).collect();
        let space = cfg
            .method
            .weight_space()
            .unwrap_or(DiscreteSpace::TERNARY); // placeholder for fp; unused
        let mut model = init_model(descs, bn_names, &bn_shapes, space, cfg.seed);
        if cfg.method.weight_space().is_none() {
            densify_fp_weights(&mut model, cfg.seed);
        }
        let param_f32: Vec<Vec<f32>> = model.values.iter().map(|v| v.to_f32()).collect();
        // hidden-weight baseline: seed masters from the initial discrete states
        let hidden: Vec<Option<HiddenWeights>> = model
            .values
            .iter()
            .zip(&param_f32)
            .map(|(v, f)| match (cfg.update_rule, v) {
                (UpdateRule::Hidden, ParamValue::Discrete(p)) => {
                    Some(HiddenWeights::from_discrete(f, p.space()))
                }
                _ => None,
            })
            .collect();
        let max_numel = model.descs.iter().map(|d| d.numel()).max().unwrap_or(0);
        let opt = Optimizer::new(cfg.opt, model.values.len());
        let rng = Prng::new(cfg.seed ^ 0xD57);

        // boundary pools: literals allocated once, static scalars set once
        let hl = cfg.method.hl();
        let mut train_bufs = ExecBuffers::new(&train_g)?;
        train_bufs.set_scalar(&train_g, 2, cfg.r)?;
        train_bufs.set_scalar(&train_g, 3, cfg.a)?;
        train_bufs.set_scalar(&train_g, 4, hl)?;
        let mut infer_bufs = ExecBuffers::new(&infer_g)?;
        infer_bufs.set_scalar(&infer_g, 1, cfg.r)?;
        infer_bufs.set_scalar(&infer_g, 2, hl)?;
        let dirty = vec![true; model.values.len()];

        Ok(Trainer {
            rt,
            train_g,
            infer_g,
            model,
            opt,
            cfg,
            rng,
            param_f32,
            dw_buf: vec![0.0; max_numel],
            hidden,
            train_bufs,
            infer_bufs,
            dirty,
            sw_exec: Stopwatch::new(),
            sw_update: Stopwatch::new(),
            sw_marshal: Stopwatch::new(),
        })
    }

    pub fn batch_size(&self) -> usize {
        self.train_g.batch
    }

    pub fn graph_name(&self) -> &str {
        &self.train_g.name
    }

    pub fn infer_graph_name(&self) -> &str {
        &self.infer_g.name
    }

    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    fn refresh_param_f32(&mut self) {
        for (v, buf) in self.model.values.iter().zip(self.param_f32.iter_mut()) {
            match v {
                ParamValue::Discrete(p) => p.unpack_into(buf),
                ParamValue::Dense(d) => buf.copy_from_slice(d),
            }
        }
    }

    /// Re-expand the packed model and invalidate every pooled literal.
    /// Called at run start so externally mutated state (e.g. a checkpoint
    /// loaded into `self.model`) reaches the device.
    pub fn sync_from_model(&mut self) {
        self.refresh_param_f32();
        self.dirty.fill(true);
    }

    /// One training step on a prepared batch (pooled, allocation-free
    /// marshalling).
    pub fn step(&mut self, x: &[f32], labels: &[i32], lr: f64) -> Result<StepStats> {
        let b = self.train_g.batch;
        assert_eq!(labels.len(), b);
        let n_params = self.model.descs.len();

        // 1. refill only what changed on the host since the last step
        self.sw_marshal.start();
        self.train_bufs.set_f32(&self.train_g, 0, x)?;
        self.train_bufs.set_i32(&self.train_g, 1, labels)?;
        for i in 0..n_params {
            if self.dirty[i] {
                self.train_bufs
                    .set_f32(&self.train_g, TRAIN_FIXED_INPUTS + i, &self.param_f32[i])?;
                self.dirty[i] = false;
            }
        }
        for (j, s) in self.model.bn_state.iter().enumerate() {
            self.train_bufs
                .set_f32(&self.train_g, TRAIN_FIXED_INPUTS + n_params + j, s)?;
        }
        self.sw_marshal.stop();

        // 2. execute the lowered fwd/bwd graph into pooled output buffers
        self.sw_exec.start();
        self.rt.execute_into(&self.train_g, &mut self.train_bufs)?;
        self.sw_exec.stop();

        // 3. updates (take the outputs out of the pool to sidestep the
        //    simultaneous-borrow of self; zero-cost swap, restored below)
        let outs = std::mem::take(&mut self.train_bufs.outputs);
        self.sw_update.start();
        let dst_stats = self.apply_updates(&outs, lr, false);
        self.sw_update.stop();

        let loss = outs[0][0] as f64;
        let acc = outs[1][0] as f64 / b as f64;
        let spars = &outs[2];
        let sparsity = if spars.is_empty() {
            0.0
        } else {
            spars.iter().map(|&v| v as f64).sum::<f64>() / spars.len() as f64
        };
        let stats = StepStats {
            loss,
            acc,
            sparsity,
            sparsity_per_layer: spars.iter().map(|&v| v as f64).collect(),
            dst: dst_stats,
        };
        self.train_bufs.outputs = outs;
        Ok(stats)
    }

    /// One training step through the legacy one-shot boundary: every
    /// literal rebuilt, every output freshly allocated, every discrete
    /// tensor repacked. Kept as the A/B baseline the `perf` bench section
    /// measures the pooled path against (`BENCH_step.json`).
    pub fn step_unpooled(&mut self, x: &[f32], labels: &[i32], lr: f64) -> Result<StepStats> {
        let b = self.train_g.batch;
        assert_eq!(labels.len(), b);
        let hl = self.cfg.method.hl();
        self.sw_marshal.start();
        let mut args: Vec<Arg> = vec![
            Arg::F32(x),
            Arg::I32(labels),
            Arg::Scalar(self.cfg.r),
            Arg::Scalar(self.cfg.a),
            Arg::Scalar(hl),
        ];
        for p in &self.param_f32 {
            args.push(Arg::F32(p));
        }
        for s in &self.model.bn_state {
            args.push(Arg::F32(s));
        }
        self.sw_marshal.stop();
        self.sw_exec.start();
        let outs = self.rt.execute(&self.train_g, &args)?;
        self.sw_exec.stop();

        self.sw_update.start();
        let dst_stats = self.apply_updates(&outs, lr, true);
        self.sw_update.stop();

        let loss = outs[0][0] as f64;
        let acc = outs[1][0] as f64 / b as f64;
        let spars = &outs[2];
        let sparsity = if spars.is_empty() {
            0.0
        } else {
            spars.iter().map(|&v| v as f64).sum::<f64>() / spars.len() as f64
        };
        Ok(StepStats {
            loss,
            acc,
            sparsity,
            sparsity_per_layer: spars.iter().map(|&v| v as f64).collect(),
            dst: dst_stats,
        })
    }

    /// Shared update half of a step: DST for discrete weights, dense for
    /// the rest, BN running stats straight off the graph. With
    /// `force_repack` every discrete tensor is repacked and marked dirty
    /// (legacy semantics); otherwise tensors with zero DST transitions
    /// skip both the repack and the next literal refill.
    fn apply_updates(&mut self, outs: &[Vec<f32>], lr: f64, force_repack: bool) -> DstStats {
        self.opt.begin_step();
        let n_params = self.model.descs.len();
        let mut dst_stats = DstStats::default();
        for i in 0..n_params {
            let grad = &outs[3 + i];
            let desc = &self.model.descs[i];
            match &mut self.model.values[i] {
                ParamValue::Discrete(packed) => {
                    debug_assert_eq!(desc.kind, ParamKind::Weight);
                    let w = &mut self.param_f32[i];
                    if let Some(hw) = &mut self.hidden[i] {
                        // Fig. 4a baseline: update the fp master, requantize
                        hw.step(i, &mut self.opt, grad, lr, &mut self.dw_buf, w);
                        packed.repack_from(w);
                        self.dirty[i] = true;
                    } else {
                        // the paper's DST: no master copy exists
                        let dw = &mut self.dw_buf[..grad.len()];
                        self.opt.increment(i, grad, lr, dw);
                        let stats = dst_update(
                            w,
                            dw,
                            packed.space(),
                            self.cfg.m,
                            &mut self.rng,
                            self.cfg.threads,
                        );
                        if force_repack || stats.transitions > 0 {
                            packed.repack_from(w);
                            self.dirty[i] = true;
                        }
                        dst_stats.merge(&stats);
                    }
                }
                ParamValue::Dense(dense) => {
                    let scale = if desc.kind == ParamKind::Weight {
                        1.0 // fp baseline weights use the full LR
                    } else {
                        self.cfg.dense_lr_scale
                    };
                    self.opt.apply_dense(i, dense, grad, lr * scale);
                    self.param_f32[i].copy_from_slice(dense);
                    self.dirty[i] = true;
                }
            }
        }
        // BN running stats come straight off the graph
        let bn_off = 3 + n_params;
        for (j, s) in self.model.bn_state.iter_mut().enumerate() {
            s.copy_from_slice(&outs[bn_off + j]);
        }
        dst_stats
    }

    /// Build the XLA-backed [`ExecEngine`] view over the infer graph, with
    /// params/BN state refilled from the current model. The view borrows
    /// the trainer's pooled boundary buffers.
    pub fn xla_engine(&mut self) -> Result<XlaInferEngine<'_>> {
        self.refresh_param_f32();
        let n_params = self.model.descs.len();
        for i in 0..n_params {
            self.infer_bufs
                .set_f32(&self.infer_g, INFER_FIXED_INPUTS + i, &self.param_f32[i])?;
        }
        for (j, s) in self.model.bn_state.iter().enumerate() {
            self.infer_bufs
                .set_f32(&self.infer_g, INFER_FIXED_INPUTS + n_params + j, s)?;
        }
        Ok(XlaInferEngine::new(&*self.rt, &self.infer_g, &mut self.infer_bufs))
    }

    /// Build a native gated-XNOR engine snapshot of the current model
    /// (packed weights ternarized into bit planes, BN folded into
    /// per-channel thresholds). Independent of the PJRT device; shards
    /// batches across `TrainConfig::threads` workers.
    pub fn native_engine(&self) -> Result<NativeEngine> {
        NativeEngine::from_model(
            &self.cfg.arch,
            self.cfg.method,
            &self.model,
            self.cfg.r,
            self.infer_g.batch,
            self.infer_g.n_classes,
            self.cfg.threads,
        )
    }

    /// Accuracy over a dataset using the configured inference engine
    /// (`TrainConfig::engine`): the XLA infer graph through the pooled
    /// boundary, or the native packed-domain engine. Both run the shared
    /// [`evaluate_engine`] loop, so batching, final-batch padding and
    /// argmax are identical.
    pub fn evaluate(&mut self, ds: &dyn Dataset) -> Result<f64> {
        match self.cfg.engine {
            EngineKind::Native => {
                let mut eng = self.native_engine()?;
                evaluate_engine(&mut eng, ds)
            }
            EngineKind::Xla => {
                let mut eng = self.xla_engine()?;
                evaluate_engine(&mut eng, ds)
            }
        }
    }

    /// Full run: epochs × batches with the paper's LR decay; returns the
    /// report consumed by the benches. Batch k+1 is assembled on the
    /// prefetch worker while the graph executes batch k; the trajectory is
    /// bit-identical to the serial loop (same per-epoch RNG streams).
    pub fn run(&mut self, train: &dyn Dataset, test: &dyn Dataset) -> Result<TrainReport> {
        let cfg = self.cfg.clone();
        let out = drive_epochs(self, &cfg, train, test)?;
        let (packed, fp32) = self.model.weight_memory_bytes();
        // the PJRT boundary holds one f32 expansion per discrete tensor
        let pjrt_f32_bytes: usize = self
            .model
            .values
            .iter()
            .zip(&self.param_f32)
            .filter(|(v, _)| matches!(v, ParamValue::Discrete(_)))
            .map(|(_, f)| f.len() * 4)
            .sum();
        Ok(TrainReport {
            test_acc: out.rec.last("test_acc").unwrap_or(0.0),
            final_train_loss: out.rec.last("epoch_loss").unwrap_or(f64::NAN),
            weight_zero_fraction: self.model.weight_zero_fraction(),
            mean_act_sparsity: out.rec.tail_mean("act_sparsity", 50),
            packed_bytes: packed,
            fp32_bytes: fp32,
            hidden_fp32_bytes: self.hidden.iter().flatten().map(|h| h.fp32_bytes()).sum(),
            weight_f32_mirror_bytes: pjrt_f32_bytes,
            step_time_ms: out.wall_ms / out.steps.max(1) as f64,
            exec_time_ms: self.sw_exec.mean_ms(),
            dst_time_ms: self.sw_update.mean_ms(),
            marshal_time_ms: self.sw_marshal.mean_ms(),
            step_p50_ms: percentile(&out.step_ms, 50.0),
            step_p99_ms: percentile(&out.step_ms, 99.0),
            steps_per_sec: out.steps as f64 / (out.wall_ms / 1e3).max(1e-9),
            recorder: out.rec,
        })
    }
}

impl LoopBackend for Trainer<'_> {
    fn loop_batch_size(&self) -> usize {
        self.train_g.batch
    }

    fn pad_final_batch(&self) -> bool {
        // the lowered graph has a fixed batch dimension and no masking
        false
    }

    fn prepare_run(&mut self) -> Result<()> {
        self.sync_from_model();
        Ok(())
    }

    fn step_batch(&mut self, b: &Batch, lr: f64) -> Result<StepStats> {
        debug_assert_eq!(b.valid, b.y.len(), "XLA path never sees padded batches");
        self.step(&b.x, &b.y, lr)
    }

    fn eval_split(&mut self, ds: &dyn Dataset) -> Result<f64> {
        self.evaluate(ds)
    }

    fn save_run_checkpoint(&mut self, epoch_next: u64) -> Result<()> {
        if self.cfg.update_rule == UpdateRule::Hidden {
            return Err(anyhow!(
                "--checkpoint-every captures DST run state only; the hidden-weight \
                 baseline (Fig. 4a) keeps f32 masters a v2 checkpoint does not carry"
            ));
        }
        let meta = run_meta(&self.cfg, self.train_g.batch, epoch_next, self.opt.t());
        checkpoint::save_run(
            &self.cfg.checkpoint_path,
            &self.model,
            &self.opt,
            &self.rng,
            &meta,
            self.cfg.faults.as_deref(),
        )
        .map_err(|e| anyhow!(e.to_string()))
    }
}

/// One training backend drivable by [`drive_epochs`]: the XLA-graph
/// [`Trainer`] and the device-free [`NativeTrainer`] share the epoch loop
/// (LR schedule, prefetch, metric recording, per-epoch evaluation) and
/// differ only in how a batch steps and how evaluation runs.
trait LoopBackend {
    fn loop_batch_size(&self) -> usize;
    /// Whether the prefetcher pads the final partial batch (the backend
    /// masks pad rows) or drops it.
    fn pad_final_batch(&self) -> bool;
    fn prepare_run(&mut self) -> Result<()>;
    fn step_batch(&mut self, b: &Batch, lr: f64) -> Result<StepStats>;
    fn eval_split(&mut self, ds: &dyn Dataset) -> Result<f64>;
    /// First epoch to execute — non-zero when resuming from a checkpoint.
    fn start_epoch(&self) -> u64 {
        0
    }
    /// Persist a v2 run checkpoint after an epoch completes. `epoch_next`
    /// is the first epoch a resumed run would execute.
    fn save_run_checkpoint(&mut self, epoch_next: u64) -> Result<()>;
}

/// What [`drive_epochs`] hands back for report assembly.
struct LoopOutcome {
    rec: Recorder,
    steps: u64,
    step_ms: Vec<f64>,
    wall_ms: f64,
}

/// The epoch loop both backends run: prefetched batches, the paper's
/// per-epoch exponential LR decay, per-epoch test evaluation, metric
/// recording. Extracted verbatim from the original `Trainer::run`, so
/// XLA trajectories are unchanged by the refactor.
fn drive_epochs<B: LoopBackend + ?Sized>(
    be: &mut B,
    cfg: &TrainConfig,
    train: &dyn Dataset,
    test: &dyn Dataset,
) -> Result<LoopOutcome> {
    let schedule = LrSchedule::new(cfg.lr_start, cfg.lr_fin, cfg.epochs);
    let aug = if cfg.augment {
        AugmentCfg::paper()
    } else {
        AugmentCfg::none()
    };
    let b = be.loop_batch_size();
    let epochs = cfg.epochs;
    let seed = cfg.seed;
    let verbose = cfg.verbose;
    let start_epoch = be.start_epoch();
    be.prepare_run()?;
    let mut rec = Recorder::new();
    let mut steps = 0u64;
    let mut step_ms: Vec<f64> = Vec::with_capacity(epochs * (train.len() / b.max(1)));
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let mut pf = if be.pad_final_batch() {
            Prefetcher::spawn_train_padded_from(
                scope, train, b, seed, aug, start_epoch, epochs, PREFETCH_DEPTH,
            )
        } else {
            Prefetcher::spawn_train_from(
                scope, train, b, seed, aug, start_epoch, epochs, PREFETCH_DEPTH,
            )
        };
        let mut lr = schedule.lr_at(start_epoch as usize);
        let mut ep_loss = 0.0;
        let mut ep_acc = 0.0;
        let mut n = 0usize;
        while let Some(item) = pf.next() {
            match item {
                Item::Batch(batch) => {
                    let ts = std::time::Instant::now();
                    let s = be.step_batch(&batch, lr)?;
                    step_ms.push(ts.elapsed().as_secs_f64() * 1e3);
                    pf.recycle(batch);
                    ep_loss += s.loss;
                    ep_acc += s.acc;
                    n += 1;
                    steps += 1;
                    rec.push("loss", s.loss);
                    rec.push("train_acc", s.acc);
                    rec.push("act_sparsity", s.sparsity);
                    for (j, &v) in s.sparsity_per_layer.iter().enumerate() {
                        rec.push(&format!("act_sparsity_l{j}"), v);
                    }
                    rec.push("dst_rate", s.dst.transition_rate());
                }
                Item::EpochEnd { epoch } => {
                    let test_acc = be.eval_split(test)?;
                    rec.push("epoch_loss", ep_loss / n.max(1) as f64);
                    rec.push("epoch_train_acc", ep_acc / n.max(1) as f64);
                    rec.push("test_acc", test_acc);
                    rec.push("test_err", 1.0 - test_acc);
                    rec.push("lr", lr);
                    if verbose {
                        println!(
                            "epoch {epoch:>3}  lr {lr:.2e}  loss {:>8.4}  train {:5.1}%  test {:5.1}%  spars {:.2}",
                            ep_loss / n.max(1) as f64,
                            100.0 * ep_acc / n.max(1) as f64,
                            100.0 * test_acc,
                            rec.last("act_sparsity").unwrap_or(0.0),
                        );
                    }
                    ep_loss = 0.0;
                    ep_acc = 0.0;
                    n = 0;
                    lr = schedule.lr_at(epoch as usize + 1);
                    let done = epoch + 1;
                    if cfg.checkpoint_every > 0
                        && !cfg.checkpoint_path.is_empty()
                        && done % cfg.checkpoint_every as u64 == 0
                    {
                        be.save_run_checkpoint(done)?;
                    }
                    if let Some(f) = cfg.faults.as_deref() {
                        if f.fire_train_crash(done) {
                            return Err(anyhow!(
                                "injected fault: training aborted after epoch {done} (train_crash)"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    })?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    Ok(LoopOutcome { rec, steps, step_ms, wall_ms })
}

/// fp baseline: replace packed weights with a dense Glorot init (the
/// discrete-space uniform init makes no sense for continuous weights).
/// Shared by the XLA and native trainers so the fp starting points are
/// identical.
fn densify_fp_weights(model: &mut ModelState, seed: u64) {
    let mut rng = Prng::new(seed ^ 0xF9);
    for (d, v) in model.descs.iter().zip(model.values.iter_mut()) {
        if d.kind == ParamKind::Weight {
            let fan_in: usize = d.shape[..d.shape.len() - 1].iter().product::<usize>().max(1);
            let std = (2.0 / fan_in as f32).sqrt();
            *v = ParamValue::Dense((0..d.numel()).map(|_| rng.normal_f32() * std).collect());
        }
    }
}

/// Accuracy of any [`ExecEngine`] over a dataset: batch assembly is
/// prefetched and double-buffered, the final partial batch is padded (not
/// dropped — only its `valid` rows are scored, so the denominator is the
/// true dataset length), and class prediction uses the shared NaN-safe
/// [`argmax`]. Both the XLA and native backends evaluate through this one
/// loop, which is what makes their accuracies directly comparable.
pub fn evaluate_engine(engine: &mut dyn ExecEngine, ds: &dyn Dataset) -> Result<f64> {
    let b = engine.batch();
    let n_classes = engine.n_classes();
    let mut correct = 0usize;
    let mut total = 0usize;
    std::thread::scope(|scope| -> Result<()> {
        let mut pf = Prefetcher::spawn_eval(scope, ds, b, PREFETCH_DEPTH);
        while let Some(item) = pf.next() {
            let Item::Batch(batch) = item else { continue };
            let logits = engine.infer_batch(&batch.x)?;
            for (i, &lbl) in batch.y[..batch.valid].iter().enumerate() {
                if argmax(&logits[i * n_classes..(i + 1) * n_classes]) as i32 == lbl {
                    correct += 1;
                }
            }
            total += batch.valid;
            pf.recycle(batch);
        }
        Ok(())
    })?;
    debug_assert_eq!(total, ds.len(), "evaluation must cover the whole split");
    Ok(correct as f64 / total.max(1) as f64)
}

// ===========================================================================
// Native DST trainer: the step loop with no PJRT boundary at all
// ===========================================================================

/// Batch size when no manifest pins one (mirrors the b100 graphs).
const DEFAULT_NATIVE_BATCH: usize = 100;

/// The fully native training coordinator: forward, backward and the DST
/// update all run in-process (`engine::NativeTrainEngine` +
/// `ternary::dst_update_packed`) — no PJRT device, no lowered graphs,
/// and **no f32 weight tensor anywhere in the step loop**. Discrete
/// weights live packed (1-bit binary, 2-bit ternary, up to 7-bit for the
/// multi-level `Z_N` spaces of Fig. 13 — every `multi:N1,N2` method runs
/// here); the engine's
/// bitplanes derive from those states directly and are rebuilt only when
/// a DST update actually moved a state (`DstStats::transitions > 0`),
/// mirroring the XLA path's refill-skip.
///
/// Gradients, DST transitions, logits and BN statistics are bit-identical
/// for any `TrainConfig::threads` value — see `NativeTrainEngine`'s
/// determinism notes and `tests/train_native.rs`.
pub struct NativeTrainer {
    pub model: ModelState,
    engine: NativeTrainEngine,
    opt: Optimizer,
    cfg: TrainConfig,
    rng: Prng,
    /// scratch for optimizer increments (gradient-side state, not weights)
    dw_buf: Vec<f32>,
    /// param i's engine bitplanes are stale (DST moved a state)
    dirty: Vec<bool>,
    batch: usize,
    n_classes: usize,
    /// first epoch `run` executes (non-zero after [`NativeTrainer::resume_from`])
    start_epoch: u64,
    /// discrete-tensor DST update events (steps × tensors)
    dst_updates: u64,
    /// update events that moved ≥ 1 state — the upper bound on repacks
    transitioned_updates: u64,
    pub sw_exec: Stopwatch,
    pub sw_update: Stopwatch,
}

impl NativeTrainer {
    /// Build a native trainer. With a manifest, parameter shapes, batch
    /// and class count come from the matching train graph (so runs are
    /// comparable with the XLA path); without one, shapes come from the
    /// catalogue architecture ([`param_descs`]) — fully device- and
    /// artifact-free. `cfg.batch > 0` overrides the batch either way.
    pub fn new(manifest: Option<&Manifest>, cfg: TrainConfig) -> Result<Self> {
        let mode = cfg.method.graph_mode();
        let g = manifest.and_then(|m| {
            m.graphs
                .iter()
                .find(|g| g.arch == cfg.arch && g.mode == mode && g.kind == "train" && g.batch > 16)
                .or_else(|| {
                    m.graphs
                        .iter()
                        .find(|g| g.arch == cfg.arch && g.mode == mode && g.kind == "train")
                })
        });
        let (descs, bn_names, bn_lens, g_batch, n_classes) = match g {
            Some(g) => (
                g.params.clone(),
                g.bn_state.iter().map(|s| s.name.clone()).collect::<Vec<_>>(),
                g.bn_state.iter().map(|s| s.numel()).collect::<Vec<_>>(),
                g.batch,
                g.n_classes,
            ),
            None => {
                let arch = build_arch(&cfg.arch).map_err(|e| anyhow!(e))?;
                let (d, names, lens) = param_descs(&arch);
                (d, names, lens, DEFAULT_NATIVE_BATCH, 10)
            }
        };
        let batch = if cfg.batch > 0 { cfg.batch } else { g_batch };
        Self::from_descs(cfg, descs, bn_names, &bn_lens, batch, n_classes)
    }

    /// Build from explicit parameter descriptors — the entry the tests,
    /// benches and parity harnesses use for full control over shapes.
    pub fn from_descs(
        cfg: TrainConfig,
        descs: Vec<ParamDesc>,
        bn_names: Vec<String>,
        bn_lens: &[usize],
        batch: usize,
        n_classes: usize,
    ) -> Result<Self> {
        if cfg.update_rule == UpdateRule::Hidden {
            return Err(anyhow!(
                "--engine native trains with the paper's DST only; the hidden-weight \
                 baseline (Fig. 4a) keeps f32 masters — use --engine xla"
            ));
        }
        let space = cfg.method.weight_space().unwrap_or(DiscreteSpace::TERNARY);
        let mut model = init_model(descs, bn_names, bn_lens, space, cfg.seed);
        if cfg.method.weight_space().is_none() {
            densify_fp_weights(&mut model, cfg.seed);
        }
        let engine = NativeTrainEngine::new(
            &cfg.arch,
            cfg.method,
            &model.descs,
            batch,
            n_classes,
            cfg.r,
            cfg.a,
            cfg.threads,
        )?;
        let max_numel = model.descs.iter().map(|d| d.numel()).max().unwrap_or(0);
        let opt = Optimizer::new(cfg.opt, model.values.len());
        // same stream derivation as the XLA trainer: under a shared seed
        // the DST draws line up step for step and tensor for tensor
        let rng = Prng::new(cfg.seed ^ 0xD57);
        let dirty = vec![true; model.values.len()];
        Ok(NativeTrainer {
            engine,
            opt,
            rng,
            dw_buf: vec![0.0; max_numel],
            dirty,
            batch,
            n_classes,
            start_epoch: 0,
            dst_updates: 0,
            transitioned_updates: 0,
            sw_exec: Stopwatch::new(),
            sw_update: Stopwatch::new(),
            cfg,
            model,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Weight-bitplane rebuilds the engine performed after the initial
    /// packs. Invariant (asserted in tests): within one run this never
    /// exceeds [`NativeTrainer::transitioned_update_count`] — tensors
    /// with zero DST transitions are never repacked.
    pub fn repack_count(&self) -> u64 {
        self.engine.repack_count()
    }

    /// Discrete-tensor DST update events so far (steps × tensors).
    pub fn dst_update_count(&self) -> u64 {
        self.dst_updates
    }

    /// DST update events that moved at least one state.
    pub fn transitioned_update_count(&self) -> u64 {
        self.transitioned_updates
    }

    /// Bytes of derived weight bitplanes the engine holds (the only
    /// weight-side memory beyond the packed states themselves).
    pub fn engine_bitplane_bytes(&self) -> usize {
        self.engine.bitplane_bytes()
    }

    pub fn set_threads(&mut self, threads: usize) {
        self.cfg.threads = threads;
        self.engine.set_threads(threads);
    }

    /// Mark every weight tensor stale (e.g. after loading a checkpoint
    /// into `self.model`) so the engine rebuilds its bitplanes on the
    /// next step. Note: the resulting repacks are externally caused, so
    /// calling this mid-life loosens the repack ≤ transitioned-updates
    /// invariant by one repack per discrete tensor.
    pub fn sync_from_model(&mut self) {
        self.dirty.fill(true);
    }

    /// Load a v2 run checkpoint and position this trainer to continue it:
    /// model weights, BN/EMA state, optimizer moments + timestep, and the
    /// Prng are all restored, and [`NativeTrainer::run`] will start at the
    /// saved epoch. Because batch streams, LR and DST draws depend only on
    /// (config, epoch, restored state), the continuation is bit-identical
    /// to the uninterrupted run — the identity fields are validated here
    /// precisely because a mismatch would silently break that.
    ///
    /// Returns the first epoch the resumed run will execute.
    pub fn resume_from(&mut self, path: &str) -> Result<u64> {
        let (rng, meta) = checkpoint::load_run(&mut self.model, &mut self.opt, path)
            .map_err(|e| anyhow!(e.to_string()))?;
        let cfg = &self.cfg;
        if meta.arch != cfg.arch {
            return Err(anyhow!("resume: checkpoint arch {} != run arch {}", meta.arch, cfg.arch));
        }
        if meta.method != cfg.method.name() {
            return Err(anyhow!(
                "resume: checkpoint method {} != run method {}",
                meta.method,
                cfg.method.name()
            ));
        }
        if meta.seed != cfg.seed {
            return Err(anyhow!("resume: checkpoint seed {} != run seed {}", meta.seed, cfg.seed));
        }
        if meta.epochs_total != cfg.epochs as u64 {
            return Err(anyhow!(
                "resume: checkpoint plans {} total epochs, run plans {}",
                meta.epochs_total,
                cfg.epochs
            ));
        }
        if meta.batch != self.batch as u64 {
            return Err(anyhow!(
                "resume: checkpoint batch {} != run batch {}",
                meta.batch,
                self.batch
            ));
        }
        if meta.m.to_bits() != cfg.m.to_bits()
            || meta.r.to_bits() != cfg.r.to_bits()
            || meta.a.to_bits() != cfg.a.to_bits()
        {
            return Err(anyhow!(
                "resume: checkpoint (m,r,a)=({},{},{}) != run ({},{},{})",
                meta.m,
                meta.r,
                meta.a,
                cfg.m,
                cfg.r,
                cfg.a
            ));
        }
        if meta.lr_start.to_bits() != cfg.lr_start.to_bits()
            || meta.lr_fin.to_bits() != cfg.lr_fin.to_bits()
        {
            return Err(anyhow!(
                "resume: checkpoint lr {}→{} != run lr {}→{}",
                meta.lr_start,
                meta.lr_fin,
                cfg.lr_start,
                cfg.lr_fin
            ));
        }
        if meta.epoch_next >= cfg.epochs as u64 {
            return Err(anyhow!(
                "resume: checkpoint already covers all {} epochs (nothing to continue)",
                cfg.epochs
            ));
        }
        self.rng = rng;
        self.start_epoch = meta.epoch_next;
        self.sync_from_model();
        Ok(meta.epoch_next)
    }

    /// Serialize the complete live run state as v2 checkpoint bytes —
    /// the bit-equality witness the resume tests compare (model, BN/EMA,
    /// optimizer moments + timestep, Prng, meta).
    pub fn run_state_bytes(&self, epoch_next: u64) -> Vec<u8> {
        let meta = run_meta(&self.cfg, self.batch, epoch_next, self.opt.t());
        checkpoint::serialize_run(&self.model, &self.opt, &self.rng, &meta)
    }

    /// One native training step on the leading `valid` rows: forward with
    /// cache, ternary-operand backward, Adam/SGD preconditioning, DST
    /// **directly on the packed states**, BN running-stat EMA. Rows ≥
    /// `valid` (prefetcher padding) contribute nothing — a padded partial
    /// batch trains exactly like a batch of `valid` samples.
    pub fn step(&mut self, x: &[f32], labels: &[i32], valid: usize, lr: f64) -> Result<StepStats> {
        self.sw_exec.start();
        let outs = self.engine.step(x, labels, valid, &self.model, &mut self.dirty)?;
        self.sw_exec.stop();

        self.sw_update.start();
        self.opt.begin_step();
        let n_params = self.model.descs.len();
        let mut dst_stats = DstStats::default();
        for i in 0..n_params {
            let grad = &outs[3 + i];
            let desc = &self.model.descs[i];
            match &mut self.model.values[i] {
                ParamValue::Discrete(packed) => {
                    debug_assert_eq!(desc.kind, ParamKind::Weight);
                    // the increment is gradient-side state; the weights
                    // themselves never leave the packed domain
                    let dw = &mut self.dw_buf[..grad.len()];
                    self.opt.increment(i, grad, lr, dw);
                    let stats =
                        dst_update_packed(packed, dw, self.cfg.m, &mut self.rng, self.cfg.threads);
                    self.dst_updates += 1;
                    if stats.transitions > 0 {
                        self.dirty[i] = true;
                        self.transitioned_updates += 1;
                    }
                    dst_stats.merge(&stats);
                }
                ParamValue::Dense(dense) => {
                    let scale = if desc.kind == ParamKind::Weight {
                        1.0 // fp baseline weights use the full LR
                    } else {
                        self.cfg.dense_lr_scale
                    };
                    self.opt.apply_dense(i, dense, grad, lr * scale);
                }
            }
        }
        let bn_off = 3 + n_params;
        for (j, s) in self.model.bn_state.iter_mut().enumerate() {
            s.copy_from_slice(&outs[bn_off + j]);
        }
        self.sw_update.stop();

        let loss = outs[0][0] as f64;
        let acc = outs[1][0] as f64 / valid as f64;
        let spars = &outs[2];
        let sparsity = if spars.is_empty() {
            0.0
        } else {
            spars.iter().map(|&v| v as f64).sum::<f64>() / spars.len() as f64
        };
        Ok(StepStats {
            loss,
            acc,
            sparsity,
            sparsity_per_layer: spars.iter().map(|&v| v as f64).collect(),
            dst: dst_stats,
        })
    }

    /// Accuracy over a dataset on a fresh inference-engine snapshot of
    /// the current model (packed weights → bitplanes, BN running stats →
    /// folded thresholds). Device-free, like everything else here.
    pub fn evaluate(&mut self, ds: &dyn Dataset) -> Result<f64> {
        let mut eng = NativeEngine::from_model(
            &self.cfg.arch,
            self.cfg.method,
            &self.model,
            self.cfg.r,
            self.batch,
            self.n_classes,
            self.cfg.threads,
        )?;
        evaluate_engine(&mut eng, ds)
    }

    /// Full run through the shared epoch loop (`drive_epochs`), with
    /// the prefetcher's **padded** final batch so every training sample
    /// contributes exactly once per epoch (pad rows are masked out of
    /// loss, gradients and BN statistics).
    pub fn run(&mut self, train: &dyn Dataset, test: &dyn Dataset) -> Result<TrainReport> {
        if train.len() < self.batch {
            return Err(anyhow!(
                "train split ({} samples) smaller than the batch ({}); lower --batch",
                train.len(),
                self.batch
            ));
        }
        if train.sample_len() != self.engine.sample_len() {
            return Err(anyhow!(
                "dataset sample length {} != network input {}",
                train.sample_len(),
                self.engine.sample_len()
            ));
        }
        let cfg = self.cfg.clone();
        let out = drive_epochs(self, &cfg, train, test)?;
        let (packed, fp32) = self.model.weight_memory_bytes();
        Ok(TrainReport {
            test_acc: out.rec.last("test_acc").unwrap_or(0.0),
            final_train_loss: out.rec.last("epoch_loss").unwrap_or(f64::NAN),
            weight_zero_fraction: self.model.weight_zero_fraction(),
            mean_act_sparsity: out.rec.tail_mean("act_sparsity", 50),
            packed_bytes: packed,
            fp32_bytes: fp32,
            // the paper's claim, numerically: no masters, no mirrors
            hidden_fp32_bytes: 0,
            weight_f32_mirror_bytes: 0,
            step_time_ms: out.wall_ms / out.steps.max(1) as f64,
            exec_time_ms: self.sw_exec.mean_ms(),
            dst_time_ms: self.sw_update.mean_ms(),
            marshal_time_ms: 0.0, // there is no boundary to marshal across
            step_p50_ms: percentile(&out.step_ms, 50.0),
            step_p99_ms: percentile(&out.step_ms, 99.0),
            steps_per_sec: out.steps as f64 / (out.wall_ms / 1e3).max(1e-9),
            recorder: out.rec,
        })
    }
}

impl LoopBackend for NativeTrainer {
    fn loop_batch_size(&self) -> usize {
        self.batch
    }

    fn pad_final_batch(&self) -> bool {
        true
    }

    fn prepare_run(&mut self) -> Result<()> {
        // construction already marks every tensor dirty, and the step loop
        // keeps the engine's bitplanes exact thereafter; re-marking here
        // would repack every tensor on a second run() and spuriously break
        // the repack ≤ transitioned-updates invariant. External model
        // mutation (checkpoint load) must call sync_from_model explicitly.
        Ok(())
    }

    fn step_batch(&mut self, b: &Batch, lr: f64) -> Result<StepStats> {
        self.step(&b.x, &b.y, b.valid, lr)
    }

    fn eval_split(&mut self, ds: &dyn Dataset) -> Result<f64> {
        self.evaluate(ds)
    }

    fn start_epoch(&self) -> u64 {
        self.start_epoch
    }

    fn save_run_checkpoint(&mut self, epoch_next: u64) -> Result<()> {
        let meta = run_meta(&self.cfg, self.batch, epoch_next, self.opt.t());
        checkpoint::save_run(
            &self.cfg.checkpoint_path,
            &self.model,
            &self.opt,
            &self.rng,
            &meta,
            self.cfg.faults.as_deref(),
        )
        .map_err(|e| anyhow!(e.to_string()))
    }
}

/// Convenience: open datasets, build a trainer, run, return the report.
pub fn run_training(rt: &mut Runtime, manifest: &Manifest, cfg: TrainConfig) -> Result<TrainReport> {
    let train = crate::data::open(&cfg.dataset, true, cfg.train_len).map_err(|e| anyhow!(e))?;
    let test = crate::data::open(&cfg.dataset, false, cfg.test_len).map_err(|e| anyhow!(e))?;
    let mut tr = Trainer::new(rt, manifest, cfg)?;
    tr.run(train.as_ref(), test.as_ref())
}

/// [`run_training`]'s native twin: no `Runtime`, manifest optional
/// (shapes fall back to the catalogue architecture without one).
pub fn run_training_native(manifest: Option<&Manifest>, cfg: TrainConfig) -> Result<TrainReport> {
    let train = crate::data::open(&cfg.dataset, true, cfg.train_len).map_err(|e| anyhow!(e))?;
    let test = crate::data::open(&cfg.dataset, false, cfg.test_len).map_err(|e| anyhow!(e))?;
    let mut tr = NativeTrainer::new(manifest, cfg)?;
    tr.run(train.as_ref(), test.as_ref())
}

/// One training backend with its backend-specific context, so callers
/// that run many jobs (the sweep harness, the benches) dispatch once:
/// the XLA path needs a live PJRT runtime plus the artifact manifest,
/// the native path is fully device-free and treats the manifest as an
/// optional source of shapes/batch size.
pub enum TrainBackend<'a> {
    /// Lowered train graph on the PJRT client.
    Xla { rt: &'a mut Runtime, manifest: &'a Manifest },
    /// Device-free native DST step loop ([`NativeTrainer`]).
    Native { manifest: Option<&'a Manifest> },
}

impl TrainBackend<'_> {
    pub fn name(&self) -> &'static str {
        match self {
            TrainBackend::Xla { .. } => "xla",
            TrainBackend::Native { .. } => "native",
        }
    }
}

/// Run one training job on whichever backend the caller holds —
/// [`run_training`] or [`run_training_native`], one dispatch point.
pub fn run_training_any(backend: &mut TrainBackend<'_>, cfg: TrainConfig) -> Result<TrainReport> {
    match backend {
        TrainBackend::Xla { rt, manifest } => run_training(rt, manifest, cfg),
        TrainBackend::Native { manifest } => run_training_native(*manifest, cfg),
    }
}
