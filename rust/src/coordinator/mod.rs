//! Training coordinator (Layer 3).
//!
//! Owns the full training loop: batching, graph execution via the PJRT
//! runtime, the DST weight update (the paper's contribution — weights never
//! leave the discrete space), Adam preconditioning, the paper's per-epoch
//! exponential LR decay, evaluation, and checkpointing.

pub mod checkpoint;
pub mod hidden;
pub mod method;
pub mod optimizer;
pub mod schedule;
pub mod trainer;

pub use hidden::HiddenWeights;
pub use method::Method;
pub use optimizer::{Optimizer, OptKind};
pub use schedule::LrSchedule;
pub use trainer::{
    evaluate_engine, run_training, run_training_any, run_training_native, NativeTrainer,
    StepStats, TrainBackend, TrainConfig, TrainReport, Trainer, UpdateRule,
};
