//! Parameter-sweep harness for the paper's ablation figures:
//! Fig. 8 (m), Fig. 9 (a), Fig. 10 (r -> activation sparsity),
//! Fig. 13 (N1 x N2 grid). Each point is a short training run; r/a/hl are
//! runtime scalars, so on the XLA backend every point reuses the same
//! compiled executable, while the native backend runs every point — the
//! full (N1, N2) grid included — with no manifest and no PJRT client
//! ([`TrainBackend`] / `run_training_any`).

use anyhow::Result;

use crate::coordinator::method::Method;
use crate::coordinator::trainer::{run_training_any, TrainBackend, TrainConfig};

/// Which hyper-parameter a sweep varies.
#[derive(Clone, Debug, PartialEq)]
pub enum SweepParam {
    M,
    A,
    R,
    /// (N1, N2) grid point
    Levels(Vec<(u32, u32)>),
}

/// One sweep point's outcome.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub label: String,
    /// swept scalar value — `None` for (N1, N2) grid points, which carry
    /// [`SweepPoint::levels`] instead (the old `n1·100 + n2` encoding
    /// collided for N2 ≥ 100 and is gone)
    pub value: Option<f64>,
    /// the (N1, N2) pair of a levels-grid point
    pub levels: Option<(u32, u32)>,
    pub test_acc: f64,
    pub act_sparsity: f64,
    pub weight_zero_fraction: f64,
}

/// Run a 1-D sweep of `param` over `values` with a common base config.
pub fn sweep_scalar(
    backend: &mut TrainBackend<'_>,
    base: &TrainConfig,
    param: &str,
    values: &[f64],
) -> Result<Vec<SweepPoint>> {
    let mut out = Vec::new();
    for &v in values {
        let mut cfg = base.clone();
        match param {
            "m" => cfg.m = v as f32,
            "a" => cfg.a = v as f32,
            "r" => cfg.r = v as f32,
            other => anyhow::bail!("unknown sweep param {other:?} (m|a|r)"),
        }
        let rep = run_training_any(backend, cfg)?;
        out.push(SweepPoint {
            label: format!("{param}={v}"),
            value: Some(v),
            levels: None,
            test_acc: rep.test_acc,
            act_sparsity: rep.mean_act_sparsity,
            weight_zero_fraction: rep.weight_zero_fraction,
        });
    }
    Ok(out)
}

/// Fig. 13: accuracy over the (N1, N2) grid. On the native backend every
/// point runs device-free — multi-level weight spaces and activations
/// execute on the multi-bitplane kernels.
pub fn sweep_levels(
    backend: &mut TrainBackend<'_>,
    base: &TrainConfig,
    grid: &[(u32, u32)],
) -> Result<Vec<SweepPoint>> {
    let mut out = Vec::new();
    for &(n1, n2) in grid {
        let mut cfg = base.clone();
        cfg.method = Method::Multi { n1, n2 };
        let rep = run_training_any(backend, cfg)?;
        out.push(SweepPoint {
            label: format!("N1={n1},N2={n2}"),
            value: None,
            levels: Some((n1, n2)),
            test_acc: rep.test_acc,
            act_sparsity: rep.mean_act_sparsity,
            weight_zero_fraction: rep.weight_zero_fraction,
        });
    }
    Ok(out)
}

/// Render sweep points as an aligned text table (benches print this).
/// Levels-grid points get explicit N1/N2 columns.
pub fn render_table(title: &str, points: &[SweepPoint]) -> String {
    use std::fmt::Write as _;
    let has_levels = points.iter().any(|p| p.levels.is_some());
    let mut s = String::new();
    let _ = writeln!(s, "== {title} ==");
    if has_levels {
        let _ = writeln!(
            s,
            "{:<16} {:>4} {:>4} {:>10} {:>14} {:>14}",
            "point", "N1", "N2", "test_acc", "act_sparsity", "w_zero_frac"
        );
    } else {
        let _ = writeln!(
            s,
            "{:<16} {:>10} {:>14} {:>14}",
            "point", "test_acc", "act_sparsity", "w_zero_frac"
        );
    }
    for p in points {
        if has_levels {
            let (n1, n2) = p
                .levels
                .map(|(a, b)| (a.to_string(), b.to_string()))
                .unwrap_or_default();
            let _ = writeln!(
                s,
                "{:<16} {:>4} {:>4} {:>9.2}% {:>14.3} {:>14.3}",
                p.label,
                n1,
                n2,
                100.0 * p.test_acc,
                p.act_sparsity,
                p.weight_zero_fraction
            );
        } else {
            let _ = writeln!(
                s,
                "{:<16} {:>9.2}% {:>14.3} {:>14.3}",
                p.label,
                100.0 * p.test_acc,
                p.act_sparsity,
                p.weight_zero_fraction
            );
        }
    }
    s
}

/// One CSV line per point, `label,value,n1,n2,test_acc,act_sparsity,
/// w_zero_frac` with empty fields where a column does not apply.
pub fn render_csv(points: &[SweepPoint]) -> String {
    let mut s = String::from("label,value,n1,n2,test_acc,act_sparsity,w_zero_frac\n");
    for p in points {
        let value = p.value.map(|v| v.to_string()).unwrap_or_default();
        let (n1, n2) = p
            .levels
            .map(|(a, b)| (a.to_string(), b.to_string()))
            .unwrap_or_default();
        s.push_str(&format!(
            "{},{},{},{},{},{},{}\n",
            p.label, value, n1, n2, p.test_acc, p.act_sparsity, p.weight_zero_fraction
        ));
    }
    s
}

/// Best point by test accuracy (NaN-safe: total order, so a NaN point can
/// never panic the sweep report).
pub fn best(points: &[SweepPoint]) -> Option<&SweepPoint> {
    points.iter().max_by(|a, b| a.test_acc.total_cmp(&b.test_acc))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(label: &str, value: f64, acc: f64) -> SweepPoint {
        SweepPoint {
            label: label.into(),
            value: Some(value),
            levels: None,
            test_acc: acc,
            act_sparsity: 0.3,
            weight_zero_fraction: 0.3,
        }
    }

    fn pts() -> Vec<SweepPoint> {
        vec![pt("m=1", 1.0, 0.7), pt("m=3", 3.0, 0.9), pt("m=10", 10.0, 0.85)]
    }

    fn level_pts() -> Vec<SweepPoint> {
        vec![
            SweepPoint {
                label: "N1=1,N2=1".into(),
                value: None,
                levels: Some((1, 1)),
                test_acc: 0.8,
                act_sparsity: 0.4,
                weight_zero_fraction: 0.33,
            },
            SweepPoint {
                label: "N1=6,N2=130".into(),
                value: None,
                levels: Some((6, 130)),
                test_acc: 0.9,
                act_sparsity: 0.1,
                weight_zero_fraction: 0.2,
            },
        ]
    }

    #[test]
    fn best_picks_max_acc() {
        assert_eq!(best(&pts()).unwrap().label, "m=3");
        assert!(best(&[]).is_none());
    }

    #[test]
    fn table_renders_every_point() {
        let t = render_table("fig8", &pts());
        assert!(t.contains("fig8"));
        assert!(t.contains("m=1") && t.contains("m=3") && t.contains("m=10"));
        assert!(t.contains("90.00%"));
    }

    /// (N1, N2) are carried explicitly: no `n1·100 + n2` collision even
    /// for N2 ≥ 100, and the table grows dedicated columns.
    #[test]
    fn levels_points_carry_n1_n2_explicitly() {
        let pts = level_pts();
        assert_eq!(pts[1].levels, Some((6, 130)));
        assert_eq!(pts[1].value, None);
        let t = render_table("fig13", &pts);
        assert!(t.contains(" N1 ") && t.contains(" N2 "), "{t}");
        assert!(t.contains("130"), "{t}");
        let csv = render_csv(&pts);
        assert!(csv.starts_with("label,value,n1,n2,"));
        assert!(csv.contains("N1=6,N2=130,,6,130,0.9,"), "{csv}");
        // scalar sweeps leave the level columns empty instead
        let csv2 = render_csv(&pts());
        assert!(csv2.contains("m=3,3,,,0.9,"), "{csv2}");
    }
}
