//! Parameter-sweep harness for the paper's ablation figures:
//! Fig. 8 (m), Fig. 9 (a), Fig. 10 (r -> activation sparsity),
//! Fig. 13 (N1 x N2 grid). Each point is a short training run on the MLP
//! graphs; r/a/hl are runtime scalars, so every point reuses the same
//! compiled executable.

use anyhow::Result;

use crate::coordinator::method::Method;
use crate::coordinator::trainer::{run_training, TrainConfig};
use crate::runtime::client::Runtime;
use crate::runtime::manifest::Manifest;

/// Which hyper-parameter a sweep varies.
#[derive(Clone, Debug, PartialEq)]
pub enum SweepParam {
    M,
    A,
    R,
    /// (N1, N2) grid point
    Levels(Vec<(u32, u32)>),
}

/// One sweep point's outcome.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub label: String,
    pub value: f64,
    pub test_acc: f64,
    pub act_sparsity: f64,
    pub weight_zero_fraction: f64,
}

/// Run a 1-D sweep of `param` over `values` with a common base config.
pub fn sweep_scalar(
    rt: &mut Runtime,
    manifest: &Manifest,
    base: &TrainConfig,
    param: &str,
    values: &[f64],
) -> Result<Vec<SweepPoint>> {
    let mut out = Vec::new();
    for &v in values {
        let mut cfg = base.clone();
        match param {
            "m" => cfg.m = v as f32,
            "a" => cfg.a = v as f32,
            "r" => cfg.r = v as f32,
            other => anyhow::bail!("unknown sweep param {other:?} (m|a|r)"),
        }
        let rep = run_training(rt, manifest, cfg)?;
        out.push(SweepPoint {
            label: format!("{param}={v}"),
            value: v,
            test_acc: rep.test_acc,
            act_sparsity: rep.mean_act_sparsity,
            weight_zero_fraction: rep.weight_zero_fraction,
        });
    }
    Ok(out)
}

/// Fig. 13: accuracy over the (N1, N2) grid.
pub fn sweep_levels(
    rt: &mut Runtime,
    manifest: &Manifest,
    base: &TrainConfig,
    grid: &[(u32, u32)],
) -> Result<Vec<SweepPoint>> {
    let mut out = Vec::new();
    for &(n1, n2) in grid {
        let mut cfg = base.clone();
        cfg.method = Method::Multi { n1, n2 };
        let rep = run_training(rt, manifest, cfg)?;
        out.push(SweepPoint {
            label: format!("N1={n1},N2={n2}"),
            value: (n1 * 100 + n2) as f64,
            test_acc: rep.test_acc,
            act_sparsity: rep.mean_act_sparsity,
            weight_zero_fraction: rep.weight_zero_fraction,
        });
    }
    Ok(out)
}

/// Render sweep points as an aligned text table (benches print this).
pub fn render_table(title: &str, points: &[SweepPoint]) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(s, "== {title} ==");
    let _ = writeln!(
        s,
        "{:<16} {:>10} {:>14} {:>14}",
        "point", "test_acc", "act_sparsity", "w_zero_frac"
    );
    for p in points {
        let _ = writeln!(
            s,
            "{:<16} {:>9.2}% {:>14.3} {:>14.3}",
            p.label,
            100.0 * p.test_acc,
            p.act_sparsity,
            p.weight_zero_fraction
        );
    }
    s
}

/// Best point by test accuracy (NaN-safe: total order, so a NaN point can
/// never panic the sweep report).
pub fn best(points: &[SweepPoint]) -> Option<&SweepPoint> {
    points.iter().max_by(|a, b| a.test_acc.total_cmp(&b.test_acc))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts() -> Vec<SweepPoint> {
        vec![
            SweepPoint { label: "m=1".into(), value: 1.0, test_acc: 0.7, act_sparsity: 0.3, weight_zero_fraction: 0.3 },
            SweepPoint { label: "m=3".into(), value: 3.0, test_acc: 0.9, act_sparsity: 0.35, weight_zero_fraction: 0.31 },
            SweepPoint { label: "m=10".into(), value: 10.0, test_acc: 0.85, act_sparsity: 0.4, weight_zero_fraction: 0.29 },
        ]
    }

    #[test]
    fn best_picks_max_acc() {
        assert_eq!(best(&pts()).unwrap().label, "m=3");
        assert!(best(&[]).is_none());
    }

    #[test]
    fn table_renders_every_point() {
        let t = render_table("fig8", &pts());
        assert!(t.contains("fig8"));
        assert!(t.contains("m=1") && t.contains("m=3") && t.contains("m=10"));
        assert!(t.contains("90.00%"));
    }
}
