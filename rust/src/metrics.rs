//! Metrics tracking and emission for training/evaluation runs.
//!
//! A `Recorder` accumulates named scalar series (loss, accuracy, sparsity,
//! step time …) and renders them as CSV, JSON, summary statistics, or a
//! terminal sparkline — the benches use the latter to show Fig. 7/8/9/10
//! curves inline.
//!
//! This module is also the single home of the latency-percentile math:
//! [`percentile_sorted`] / [`percentile`] implement exact nearest-rank
//! selection, and [`LatencySummary`] bundles the count/mean/p50/p99/max
//! digest that both `TrainReport` (step latency) and the serving stack
//! (request latency, `BENCH_serve.json`) report — one definition, one set
//! of edge-case tests.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::json::Json;

/// Accumulates scalar series keyed by metric name.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    series: BTreeMap<String, Vec<f64>>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, name: &str, value: f64) {
        self.series.entry(name.to_string()).or_default().push(value);
    }

    pub fn get(&self, name: &str) -> &[f64] {
        self.series.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn last(&self, name: &str) -> Option<f64> {
        self.get(name).last().copied()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.series.keys()
    }

    pub fn len(&self, name: &str) -> usize {
        self.get(name).len()
    }

    // ---- statistics -------------------------------------------------------

    pub fn mean(&self, name: &str) -> f64 {
        let xs = self.get(name);
        if xs.is_empty() {
            return f64::NAN;
        }
        xs.iter().sum::<f64>() / xs.len() as f64
    }

    pub fn max(&self, name: &str) -> f64 {
        self.get(name).iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn min(&self, name: &str) -> f64 {
        self.get(name).iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Mean of the final `k` points (converged value estimate).
    pub fn tail_mean(&self, name: &str, k: usize) -> f64 {
        let xs = self.get(name);
        if xs.is_empty() {
            return f64::NAN;
        }
        let tail = &xs[xs.len().saturating_sub(k)..];
        tail.iter().sum::<f64>() / tail.len() as f64
    }

    // ---- rendering ---------------------------------------------------------

    /// CSV with one column per series (rows padded with empty cells).
    pub fn to_csv(&self) -> String {
        let names: Vec<&String> = self.series.keys().collect();
        let rows = names.iter().map(|n| self.series[*n].len()).max().unwrap_or(0);
        let mut out = String::new();
        out.push_str("step");
        for n in &names {
            out.push(',');
            out.push_str(n);
        }
        out.push('\n');
        for r in 0..rows {
            let _ = write!(out, "{r}");
            for n in &names {
                out.push(',');
                if let Some(v) = self.series[*n].get(r) {
                    let _ = write!(out, "{v}");
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.series
                .iter()
                .map(|(k, v)| (k.clone(), Json::arr_f64(v)))
                .collect(),
        )
    }

    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }

    /// Unicode sparkline of a series (terminal-friendly curve rendering).
    pub fn sparkline(&self, name: &str, width: usize) -> String {
        let xs = self.get(name);
        if xs.is_empty() {
            return String::new();
        }
        let blocks = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let lo = self.min(name);
        let hi = self.max(name);
        let span = (hi - lo).max(1e-12);
        let step = (xs.len() as f64 / width as f64).max(1.0);
        let mut out = String::new();
        let mut i = 0.0;
        while (i as usize) < xs.len() && out.chars().count() < width {
            // bucket average
            let a = i as usize;
            let b = ((i + step) as usize).min(xs.len()).max(a + 1);
            let v = xs[a..b].iter().sum::<f64>() / (b - a) as f64;
            let idx = (((v - lo) / span) * 7.0).round() as usize;
            out.push(blocks[idx.min(7)]);
            i += step;
        }
        out
    }
}

// ---- latency percentiles ---------------------------------------------------

/// Exact nearest-rank percentile on **already sorted** samples.
///
/// Returns the smallest element such that at least `⌈p/100 · n⌉` samples are
/// ≤ it (rank clamped to `[1, n]`, so `p = 0` yields the minimum and
/// `p = 100` the maximum). No interpolation: the result is always an observed
/// sample, which is what a latency digest should report. Empty input → 0.0.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// [`percentile_sorted`] on an unsorted slice (clones and sorts).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    percentile_sorted(&v, p)
}

/// Count/mean/p50/p99/max digest of a latency sample set (milliseconds by
/// convention — the field names say so). Shared by `TrainReport` step timing
/// and the serve stats endpoint / `BENCH_serve.json`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    pub fn from_unsorted(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self::default();
        }
        let mut v = samples.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        LatencySummary {
            count: v.len(),
            mean_ms: v.iter().sum::<f64>() / v.len() as f64,
            p50_ms: percentile_sorted(&v, 50.0),
            p99_ms: percentile_sorted(&v, 99.0),
            max_ms: *v.last().unwrap(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean_ms", Json::num(self.mean_ms)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("max_ms", Json::num(self.max_ms)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_stats() {
        let mut r = Recorder::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            r.push("loss", v);
        }
        assert_eq!(r.len("loss"), 4);
        assert_eq!(r.mean("loss"), 2.5);
        assert_eq!(r.min("loss"), 1.0);
        assert_eq!(r.max("loss"), 4.0);
        assert_eq!(r.tail_mean("loss", 2), 3.5);
        assert_eq!(r.last("loss"), Some(4.0));
    }

    #[test]
    fn missing_series_is_empty() {
        let r = Recorder::new();
        assert!(r.get("none").is_empty());
        assert!(r.mean("none").is_nan());
    }

    #[test]
    fn csv_layout() {
        let mut r = Recorder::new();
        r.push("a", 1.0);
        r.push("a", 2.0);
        r.push("b", 9.0);
        let csv = r.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "step,a,b");
        assert_eq!(lines[1], "0,1,9");
        assert_eq!(lines[2], "1,2,"); // padded
    }

    #[test]
    fn json_roundtrip() {
        let mut r = Recorder::new();
        r.push("x", 0.5);
        let j = r.to_json();
        assert_eq!(
            j.get("x").unwrap().as_arr().unwrap()[0].as_f64(),
            Some(0.5)
        );
    }

    // ---- percentile edge cases (satellite: one definition, tested) -------

    #[test]
    fn percentile_single_sample() {
        // n=1: every percentile is that sample.
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[7.0], p), 7.0);
        }
        let s = LatencySummary::from_unsorted(&[7.0]);
        assert_eq!(s.count, 1);
        assert_eq!(s.p50_ms, 7.0);
        assert_eq!(s.p99_ms, 7.0);
        assert_eq!(s.max_ms, 7.0);
        assert_eq!(s.mean_ms, 7.0);
    }

    #[test]
    fn percentile_two_samples() {
        // n=2: nearest-rank p50 is rank ⌈0.5·2⌉ = 1 → the smaller sample;
        // anything above 50% needs rank 2 → the larger.
        assert_eq!(percentile(&[2.0, 1.0], 50.0), 1.0);
        assert_eq!(percentile(&[2.0, 1.0], 50.1), 2.0);
        assert_eq!(percentile(&[2.0, 1.0], 99.0), 2.0);
        assert_eq!(percentile(&[2.0, 1.0], 0.0), 1.0);
        assert_eq!(percentile(&[2.0, 1.0], 100.0), 2.0);
    }

    #[test]
    fn percentile_ties() {
        // Ties: the result is still an observed sample and rank selection
        // is stable under duplicated values.
        let xs = [3.0, 3.0, 3.0, 3.0, 9.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 80.0), 3.0); // rank ⌈0.8·5⌉ = 4 → last tie
        assert_eq!(percentile(&xs, 81.0), 9.0); // rank 5
        let all_same = [5.0; 8];
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&all_same, p), 5.0);
        }
    }

    #[test]
    fn percentile_nearest_rank_hundred() {
        // 1..=100: p50 → rank 50 → 50.0, p99 → rank 99 → 99.0, p100 → 100.0.
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn percentile_empty_and_unsorted_input() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(LatencySummary::from_unsorted(&[]), LatencySummary::default());
        // `percentile` sorts internally; order of the input is irrelevant.
        assert_eq!(percentile(&[9.0, 1.0, 5.0], 50.0), 5.0);
    }

    #[test]
    fn latency_summary_json_fields() {
        let j = LatencySummary::from_unsorted(&[1.0, 2.0, 3.0]).to_json();
        assert_eq!(j.get("count").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(j.get("p50_ms").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(j.get("max_ms").and_then(|v| v.as_f64()), Some(3.0));
    }

    #[test]
    fn sparkline_monotone() {
        let mut r = Recorder::new();
        for i in 0..64 {
            r.push("up", i as f64);
        }
        let s = r.sparkline("up", 8);
        assert_eq!(s.chars().count(), 8);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
    }
}
