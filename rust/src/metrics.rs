//! Metrics tracking and emission for training/evaluation runs.
//!
//! A `Recorder` accumulates named scalar series (loss, accuracy, sparsity,
//! step time …) and renders them as CSV, JSON, summary statistics, or a
//! terminal sparkline — the benches use the latter to show Fig. 7/8/9/10
//! curves inline.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::json::Json;

/// Accumulates scalar series keyed by metric name.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    series: BTreeMap<String, Vec<f64>>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, name: &str, value: f64) {
        self.series.entry(name.to_string()).or_default().push(value);
    }

    pub fn get(&self, name: &str) -> &[f64] {
        self.series.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn last(&self, name: &str) -> Option<f64> {
        self.get(name).last().copied()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.series.keys()
    }

    pub fn len(&self, name: &str) -> usize {
        self.get(name).len()
    }

    // ---- statistics -------------------------------------------------------

    pub fn mean(&self, name: &str) -> f64 {
        let xs = self.get(name);
        if xs.is_empty() {
            return f64::NAN;
        }
        xs.iter().sum::<f64>() / xs.len() as f64
    }

    pub fn max(&self, name: &str) -> f64 {
        self.get(name).iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn min(&self, name: &str) -> f64 {
        self.get(name).iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Mean of the final `k` points (converged value estimate).
    pub fn tail_mean(&self, name: &str, k: usize) -> f64 {
        let xs = self.get(name);
        if xs.is_empty() {
            return f64::NAN;
        }
        let tail = &xs[xs.len().saturating_sub(k)..];
        tail.iter().sum::<f64>() / tail.len() as f64
    }

    // ---- rendering ---------------------------------------------------------

    /// CSV with one column per series (rows padded with empty cells).
    pub fn to_csv(&self) -> String {
        let names: Vec<&String> = self.series.keys().collect();
        let rows = names.iter().map(|n| self.series[*n].len()).max().unwrap_or(0);
        let mut out = String::new();
        out.push_str("step");
        for n in &names {
            out.push(',');
            out.push_str(n);
        }
        out.push('\n');
        for r in 0..rows {
            let _ = write!(out, "{r}");
            for n in &names {
                out.push(',');
                if let Some(v) = self.series[*n].get(r) {
                    let _ = write!(out, "{v}");
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.series
                .iter()
                .map(|(k, v)| (k.clone(), Json::arr_f64(v)))
                .collect(),
        )
    }

    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }

    /// Unicode sparkline of a series (terminal-friendly curve rendering).
    pub fn sparkline(&self, name: &str, width: usize) -> String {
        let xs = self.get(name);
        if xs.is_empty() {
            return String::new();
        }
        let blocks = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let lo = self.min(name);
        let hi = self.max(name);
        let span = (hi - lo).max(1e-12);
        let step = (xs.len() as f64 / width as f64).max(1.0);
        let mut out = String::new();
        let mut i = 0.0;
        while (i as usize) < xs.len() && out.chars().count() < width {
            // bucket average
            let a = i as usize;
            let b = ((i + step) as usize).min(xs.len()).max(a + 1);
            let v = xs[a..b].iter().sum::<f64>() / (b - a) as f64;
            let idx = (((v - lo) / span) * 7.0).round() as usize;
            out.push(blocks[idx.min(7)]);
            i += step;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_stats() {
        let mut r = Recorder::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            r.push("loss", v);
        }
        assert_eq!(r.len("loss"), 4);
        assert_eq!(r.mean("loss"), 2.5);
        assert_eq!(r.min("loss"), 1.0);
        assert_eq!(r.max("loss"), 4.0);
        assert_eq!(r.tail_mean("loss", 2), 3.5);
        assert_eq!(r.last("loss"), Some(4.0));
    }

    #[test]
    fn missing_series_is_empty() {
        let r = Recorder::new();
        assert!(r.get("none").is_empty());
        assert!(r.mean("none").is_nan());
    }

    #[test]
    fn csv_layout() {
        let mut r = Recorder::new();
        r.push("a", 1.0);
        r.push("a", 2.0);
        r.push("b", 9.0);
        let csv = r.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "step,a,b");
        assert_eq!(lines[1], "0,1,9");
        assert_eq!(lines[2], "1,2,"); // padded
    }

    #[test]
    fn json_roundtrip() {
        let mut r = Recorder::new();
        r.push("x", 0.5);
        let j = r.to_json();
        assert_eq!(
            j.get("x").unwrap().as_arr().unwrap()[0].as_f64(),
            Some(0.5)
        );
    }

    #[test]
    fn sparkline_monotone() {
        let mut r = Recorder::new();
        for i in 0..64 {
            r.push("up", i as f64);
        }
        let s = r.sparkline("up", 8);
        assert_eq!(s.chars().count(), 8);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
    }
}
