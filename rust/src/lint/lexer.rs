//! A lightweight Rust tokenizer — just enough lexical structure for the
//! repo's invariant checks, nothing more.
//!
//! The full grammar is irrelevant here: every `gxnor-lint` rule matches
//! short token sequences (`thread :: spawn`, `. lock ( ) . unwrap`, a
//! float literal inside a known function body). What *does* matter is
//! never matching inside comments or string literals, and never mistaking
//! a lifetime for a char literal or a range `0..n` for a float — those
//! are exactly the mistakes a regex-based checker makes, and why this is
//! a tokenizer and not a grep. Handled precisely:
//!
//! * line comments (`//`, `///`, `//!`) and *nested* block comments,
//!   captured per line so suppression comments and `// SAFETY:` audits
//!   can be located by line number;
//! * string / raw string (`r"…"`, `r#"…"#`) / byte string / char
//!   literals, with escapes and embedded newlines;
//! * `'a` lifetimes vs `'a'` char literals;
//! * numeric literals with radix prefixes, `_` separators, exponents and
//!   suffixes — classified int vs float so `0..n`, `x.0` and `1.max(2)`
//!   are ints/puncts while `1.0`, `1e3` and `1f32` are floats;
//! * `::` fused into a single punct token (every path-pattern rule
//!   matches it).
//!
//! Everything else (a byte of punctuation) is a one-character `Punct`.

/// Token class. `Ident` includes keywords — rules match on text.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Int,
    Float,
    Punct,
    Str,
    Char,
    Lifetime,
}

/// One token: class, verbatim text (empty for string/char bodies — no
/// rule needs their content), and 1-based line of its first character.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// One comment line (block comments are split into one entry per line),
/// with the leading `//`/`/*` markers stripped.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// Tokenized file: code tokens and comment lines, both line-addressed.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

pub fn lex(src: &str) -> Lexed {
    Lexer { b: src.as_bytes(), src, i: 0, line: 1, out: Lexed::default() }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    src: &'a str,
    i: usize,
    line: u32,
    out: Lexed,
}

impl<'a> Lexer<'a> {
    fn run(mut self) -> Lexed {
        while self.i < self.b.len() {
            let c = self.b[self.i];
            match c {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                _ if c.is_ascii_whitespace() => self.i += 1,
                b'/' if self.peek(1) == Some(b'/') => self.line_comment(),
                b'/' if self.peek(1) == Some(b'*') => self.block_comment(),
                b'"' => self.string(TokKind::Str),
                b'\'' => self.lifetime_or_char(),
                b'r' | b'b' if self.raw_or_byte() => {}
                _ if c.is_ascii_digit() => self.number(),
                _ if c == b'_' || c.is_ascii_alphabetic() => self.ident(),
                _ => self.punct(),
            }
        }
        self.out
    }

    fn peek(&self, k: usize) -> Option<u8> {
        self.b.get(self.i + k).copied()
    }

    fn line_comment(&mut self) {
        let start = self.i + 2;
        while self.i < self.b.len() && self.b[self.i] != b'\n' {
            self.i += 1;
        }
        // strip doc-comment extras: the third `/` of `///`, the `!` of `//!`
        let text = self.src[start..self.i].trim_start_matches(['/', '!']).to_string();
        self.out.comments.push(Comment { line: self.line, text });
    }

    fn block_comment(&mut self) {
        let start_line = self.line;
        let start = self.i + 2;
        self.i += 2;
        let mut depth = 1usize;
        while self.i < self.b.len() && depth > 0 {
            match (self.b[self.i], self.peek(1)) {
                (b'/', Some(b'*')) => {
                    depth += 1;
                    self.i += 2;
                }
                (b'*', Some(b'/')) => {
                    depth -= 1;
                    self.i += 2;
                }
                (b'\n', _) => {
                    self.line += 1;
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
        let end = if depth == 0 { self.i - 2 } else { self.i };
        for (k, l) in self.src[start..end].lines().enumerate() {
            self.out
                .comments
                .push(Comment { line: start_line + k as u32, text: l.trim().to_string() });
        }
    }

    /// Ordinary (escaped) string or byte-string body; `self.i` is at the
    /// opening quote.
    fn string(&mut self, kind: TokKind) {
        let line = self.line;
        self.i += 1;
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'\\' => {
                    self.i += 1;
                    if self.i < self.b.len() {
                        if self.b[self.i] == b'\n' {
                            self.line += 1;
                        }
                        self.i += 1;
                    }
                }
                b'"' => {
                    self.i += 1;
                    break;
                }
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
        self.out.toks.push(Tok { kind, text: String::new(), line });
    }

    /// Raw/byte literal starters: `r"`, `r#`, `b"`, `b'`, `br"`, `br#`.
    /// Returns false (consuming nothing) when `r`/`b` begins an ident.
    fn raw_or_byte(&mut self) -> bool {
        let c0 = self.b[self.i];
        let rest = &self.b[self.i + 1..];
        match (c0, rest.first().copied()) {
            (b'r', Some(b'"' | b'#')) => {
                self.i += 1;
                self.raw_string()
            }
            (b'b', Some(b'r')) if matches!(rest.get(1), Some(b'"' | b'#')) => {
                self.i += 2;
                self.raw_string()
            }
            (b'b', Some(b'"')) => {
                self.i += 1;
                self.string(TokKind::Str);
                true
            }
            (b'b', Some(b'\'')) => {
                self.i += 1;
                self.char_literal();
                true
            }
            _ => false,
        }
    }

    /// `self.i` is at the `#`s or quote of a raw string. Returns false if
    /// it turns out not to be one (e.g. `r#ident` raw identifiers).
    fn raw_string(&mut self) -> bool {
        let line = self.line;
        let save = self.i;
        let mut hashes = 0usize;
        while self.peek(hashes) == Some(b'#') {
            hashes += 1;
        }
        if self.peek(hashes) != Some(b'"') {
            self.i = save; // raw identifier — re-lex as ident from the `#`
            self.ident();
            return true;
        }
        self.i += hashes + 1;
        'scan: while self.i < self.b.len() {
            if self.b[self.i] == b'\n' {
                self.line += 1;
            } else if self.b[self.i] == b'"' {
                for k in 0..hashes {
                    if self.peek(1 + k) != Some(b'#') {
                        self.i += 1;
                        continue 'scan;
                    }
                }
                self.i += 1 + hashes;
                break;
            }
            self.i += 1;
        }
        self.out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line });
        true
    }

    fn lifetime_or_char(&mut self) {
        let line = self.line;
        let c1 = self.peek(1);
        let is_name = c1.is_some_and(|c| c == b'_' || c.is_ascii_alphanumeric());
        // `'a` + no closing quote -> lifetime; `'a'` -> char literal
        if is_name && self.peek(1) != Some(b'\\') && self.peek(2) != Some(b'\'') {
            let start = self.i + 1;
            self.i += 1;
            while self.i < self.b.len()
                && (self.b[self.i] == b'_' || self.b[self.i].is_ascii_alphanumeric())
            {
                self.i += 1;
            }
            self.out.toks.push(Tok {
                kind: TokKind::Lifetime,
                text: self.src[start..self.i].to_string(),
                line,
            });
        } else {
            self.char_literal();
        }
    }

    /// `self.i` at the opening `'` of a char literal.
    fn char_literal(&mut self) {
        let line = self.line;
        self.i += 1;
        if self.peek(0) == Some(b'\\') {
            self.i += 2; // escape introducer + escaped char (or first of \x..)
        }
        while self.i < self.b.len() && self.b[self.i] != b'\'' {
            if self.b[self.i] == b'\n' {
                self.line += 1;
            }
            self.i += 1;
        }
        self.i += 1; // closing quote
        self.out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
    }

    fn number(&mut self) {
        let line = self.line;
        let start = self.i;
        let mut is_float = false;
        if self.b[self.i] == b'0' && matches!(self.peek(1), Some(b'x' | b'o' | b'b')) {
            // radix literal: digits+suffix, never a float (0x1e3 is hex)
            self.i += 2;
            while self.i < self.b.len()
                && (self.b[self.i] == b'_' || self.b[self.i].is_ascii_alphanumeric())
            {
                self.i += 1;
            }
        } else {
            self.digits();
            if self.peek(0) == Some(b'.') && self.peek(1).is_some_and(|c| c.is_ascii_digit()) {
                is_float = true; // 1.5
                self.i += 1;
                self.digits();
            } else if self.peek(0) == Some(b'.')
                && !self
                    .peek(1)
                    .is_some_and(|c| c == b'.' || c == b'_' || c.is_ascii_alphabetic())
            {
                is_float = true; // trailing-dot `1.` (not `0..n`, not `1.max(…)`)
                self.i += 1;
            }
            if matches!(self.peek(0), Some(b'e' | b'E')) {
                let mut j = 1usize;
                if matches!(self.peek(j), Some(b'+' | b'-')) {
                    j += 1;
                }
                if self.peek(j).is_some_and(|c| c.is_ascii_digit()) {
                    is_float = true; // 1e3, 2.5e-4
                    self.i += j;
                    self.digits();
                }
            }
            let sstart = self.i;
            while self.i < self.b.len()
                && (self.b[self.i] == b'_' || self.b[self.i].is_ascii_alphanumeric())
            {
                self.i += 1;
            }
            let suffix = &self.src[sstart..self.i];
            if suffix.starts_with("f32") || suffix.starts_with("f64") {
                is_float = true; // 1f32
            }
        }
        self.out.toks.push(Tok {
            kind: if is_float { TokKind::Float } else { TokKind::Int },
            text: self.src[start..self.i].to_string(),
            line,
        });
    }

    fn digits(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] == b'_' || self.b[self.i].is_ascii_digit())
        {
            self.i += 1;
        }
    }

    fn ident(&mut self) {
        let line = self.line;
        let start = self.i;
        while self.i < self.b.len()
            && (self.b[self.i] == b'_'
                || self.b[self.i] == b'#' // raw-ident `r#match`
                || self.b[self.i].is_ascii_alphanumeric())
        {
            self.i += 1;
        }
        self.out.toks.push(Tok {
            kind: TokKind::Ident,
            text: self.src[start..self.i].to_string(),
            line,
        });
    }

    fn punct(&mut self) {
        let line = self.line;
        let c = self.b[self.i];
        if !c.is_ascii() {
            self.i += 1; // stray non-ASCII outside strings/comments: skip
            return;
        }
        if c == b':' && self.peek(1) == Some(b':') {
            self.i += 2;
            self.out.toks.push(Tok { kind: TokKind::Punct, text: "::".into(), line });
        } else {
            self.i += 1;
            self.out
                .toks
                .push(Tok { kind: TokKind::Punct, text: (c as char).to_string(), line });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).toks.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_are_captured_not_tokenized() {
        let l = lex("let x = 1; // thread::spawn in a comment\n/* HashMap */ let y = 2;");
        assert!(l.toks.iter().all(|t| t.text != "thread" && t.text != "HashMap"));
        assert_eq!(l.comments.len(), 2);
        assert!(l.comments[0].text.contains("thread::spawn"));
        assert_eq!(l.comments[1].line, 2);
    }

    #[test]
    fn nested_block_comments_and_doc_lines() {
        let l = lex("/* a /* nested */ still comment */ fn f() {}\n/// doc Instant\nlet i = 0;");
        assert!(l.toks.iter().all(|t| t.text != "Instant" && t.text != "still"));
        assert!(l.comments.iter().any(|c| c.text.contains("doc Instant")));
        // the fn after the comment is a token on line 1
        assert!(l.toks.iter().any(|t| t.text == "fn" && t.line == 1));
    }

    #[test]
    fn strings_hide_their_content() {
        let l = lex("let s = \"unsafe { thread::spawn }\"; let e = \"esc \\\" quote\";");
        assert!(l.toks.iter().all(|t| t.text != "unsafe" && t.text != "thread"));
        assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Str).count(), 2);
        // raw strings: the closing quote must match the hash count
        let raw = "let r = r##\"HashMap \"# still inside\"##; let after = 1;";
        let l = lex(raw);
        assert!(l.toks.iter().all(|t| t.text != "HashMap"));
        assert!(l.toks.iter().any(|t| t.text == "after"));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let ks = kinds("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert!(ks.iter().any(|(k, t)| *k == TokKind::Lifetime && t == "a"));
        assert_eq!(ks.iter().filter(|(k, _)| *k == TokKind::Char).count(), 1);
        let ks = kinds(r"let c = '\n'; let tick = '\''; ");
        assert_eq!(ks.iter().filter(|(k, _)| *k == TokKind::Char).count(), 2);
    }

    #[test]
    fn float_vs_int_classification() {
        for (src, kind) in [
            ("1.5", TokKind::Float),
            ("1e3", TokKind::Float),
            ("2.5e-4", TokKind::Float),
            ("1f32", TokKind::Float),
            ("3f64", TokKind::Float),
            ("1.", TokKind::Float),
            ("7", TokKind::Int),
            ("7u64", TokKind::Int),
            ("0x1e3", TokKind::Int),
            ("0b1010", TokKind::Int),
            ("1_000", TokKind::Int),
        ] {
            let first = &lex(src).toks[0];
            assert_eq!(first.kind, kind, "{src}");
        }
        // ranges and tuple indexes stay integral
        let ks = kinds("for i in 0..n { x.0 + 1.max(2) }");
        assert!(ks.iter().all(|(k, _)| *k != TokKind::Float));
    }

    #[test]
    fn double_colon_is_one_token_and_lines_track() {
        let l = lex("std::thread::spawn(|| {});\nlet x\n= 3;");
        let path: Vec<&str> = l.toks.iter().take(5).map(|t| t.text.as_str()).collect();
        assert_eq!(path, vec!["std", "::", "thread", "::", "spawn"]);
        let x = l.toks.iter().find(|t| t.text == "x").expect("x token");
        assert_eq!(x.line, 2);
        let three = l.toks.iter().find(|t| t.text == "3").expect("3 token");
        assert_eq!(three.line, 3);
    }
}
