//! The repo-invariant rule set: what each rule forbids, where it
//! applies, and the paper-level rationale `--explain` prints.
//!
//! Rules are deliberately *scoped*: `Instant::now` is fine in the bench
//! harness and poison in the virtual-clock batch queue; a `HashMap` is
//! fine as the runtime's executable cache and poison in an accumulation
//! path. Scoping is why these live in `gxnor-lint` instead of clippy —
//! clippy's `disallowed-methods` is crate-global (the globally bannable
//! subset *is* mirrored in `clippy.toml`).

use super::FileAnalysis;
use crate::lint::lexer::TokKind;

/// Static description of one rule (the `--explain` / README material).
pub struct Rule {
    pub id: &'static str,
    pub title: &'static str,
    /// Where it applies, as shown to humans.
    pub scope: &'static str,
    /// Why the invariant exists — the text behind `--explain <ID>`.
    pub rationale: &'static str,
}

pub const RULES: &[Rule] = &[
    Rule {
        id: "D1",
        title: "no raw parallelism probes or detached spawns",
        scope: "rust/src (non-test); homes: util/pool.rs via justified lint:allow",
        rationale: "Bit-identical results for any --threads value is a headline invariant: \
                    every parallel path must size itself through util::pool::resolve_threads \
                    (which honors --threads and GXNOR_THREADS) and spawn detached daemons \
                    through pool::spawn_service. A raw std::thread::available_parallelism, \
                    thread::spawn, or thread::Builder elsewhere silently forks the thread \
                    policy — exactly the bug once shipped in ternary/dst.rs, where the f32 \
                    DST path ignored the thread contract. Scoped std::thread::scope workers \
                    are fine: they split work the caller already sized.",
    },
    Rule {
        id: "D2",
        title: "no wall-clock reads in kernel or virtual-clock code",
        scope: "rust/src/engine/, rust/src/ternary/, rust/src/serve/queue.rs",
        rationale: "The batch queue is specified against a virtual clock (now_ns is passed \
                    in) so SLO cut decisions are replayable in tests, and the engine/ternary \
                    layers are pure functions of their inputs so parity against the f64 \
                    oracles is exact. An Instant::now or SystemTime inside them reintroduces \
                    wall-clock nondeterminism where the design spent effort removing it. \
                    Time belongs in the harness (bench/serve drivers), which passes it down.",
    },
    Rule {
        id: "D3",
        title: "no hash-ordered containers in accumulation paths",
        scope: "rust/src: engine/, ternary/, coordinator/, serve/, data/, sweep/, hwsim/, \
                metrics.rs (non-test)",
        rationale: "Float accumulation order changes results; HashMap/HashSet iteration \
                    order is arbitrary (and RandomState-seeded in general). Every reduction \
                    in the determinism-critical layers iterates slices, fixed shard ranges, \
                    or BTree containers so merged totals are identical for any thread count. \
                    A hash container in these paths is a latent reordering bug even when \
                    today's use never iterates — use a BTreeMap/BTreeSet or an indexed Vec.",
    },
    Rule {
        id: "D4",
        title: "environment reads only in configuration homes",
        scope: "rust/src (non-test); homes: util/pool.rs, util/fault.rs, config.rs, cli.rs",
        rationale: "Runs must be reproducible from their recorded configuration. env::var \
                    reads scattered through the tree are invisible inputs: they do not \
                    appear in sweep manifests or bench provenance. All environment input \
                    flows through the config/cli layer (and the two sanctioned runtime \
                    knobs, GXNOR_THREADS in util/pool.rs and GXNOR_FAULTS in util/fault.rs) \
                    so a recorded config replays bit-identically.",
    },
    Rule {
        id: "E1",
        title: "exact-integer kernels stay float-free",
        scope: "rust/src/engine/bitplane.rs: fn bodies gated_dot* and dot_planes_word",
        rationale: "The gated-XNOR dot is an exact integer: popcounts over sign/nonzero \
                    bitplanes, 2*pos - active. The kernel parity tests prove bit-equality \
                    against f64 oracles precisely because no rounding exists to argue \
                    about. A float literal or `as f32`/`as f64` cast inside these bodies \
                    would turn an exactness proof into a tolerance argument. Scaling to \
                    f32 happens in the GEMM wrappers, outside the exact core.",
    },
    Rule {
        id: "M1",
        title: "no full-precision weight mirror in the step loop",
        scope: "rust/src: engine/mod.rs, engine/backward.rs, ternary/dst.rs, \
                ternary/packed.rs, coordinator/trainer.rs (non-test)",
        rationale: "Remark 2 of the paper (GXNOR-Net, arXiv:1705.09283): weights live \
                    permanently in the discrete space; there is no full-precision hidden \
                    copy to update and requantize. The packed update path keeps that \
                    literal — states stream through bounded per-chunk buffers \
                    (unpack_into), never a full-tensor f32 expansion. A `.unpack()` call \
                    or a weight-mirror Vec<f32> in the step loop quietly reintroduces the \
                    memory footprint the paper exists to eliminate.",
    },
    Rule {
        id: "R1",
        title: "lock acquisition goes through lock_recover",
        scope: "rust/src (non-test)",
        rationale: ".lock().unwrap() turns one panicked thread into a cascade: the mutex \
                    is poisoned and every later .unwrap() panics too — in serving, that \
                    converts a single replica crash into whole-service death. \
                    util::lock::lock_recover takes the guard and shrugs off poisoning \
                    (every protected value here — stats counters, a Receiver — is valid \
                    regardless of where its holder panicked). There is no reason to \
                    .lock().unwrap() anywhere lock_recover applies.",
    },
    Rule {
        id: "R2",
        title: "no bare unwrap/expect on serve request paths",
        scope: "rust/src/serve/ (non-test)",
        rationale: "The serving layer's failure model is classified replies (SHED, \
                    DEADLINE, RETRY, ERROR) and supervised crash recovery — a panic is \
                    never an error-handling strategy there, because one panicking \
                    connection or replica thread takes state the whole service shares. \
                    Return io::Result/classified errors instead; restructure Option \
                    dances (if-let, ok_or_else) rather than asserting with expect.",
    },
    Rule {
        id: "U1",
        title: "unsafe only in audited homes, always with a SAFETY comment",
        scope: "everywhere; homes: util/align.rs, runtime/client.rs",
        rationale: "The crate needs exactly two unsafe capabilities: cache-line-aligned \
                    word buffers (util/align.rs) and the byte-view at the PJRT FFI \
                    boundary (runtime/client.rs). Keeping every unsafe block inside those \
                    two audited files — each annotated with a `// SAFETY:` argument — \
                    means the entire unsafe surface is re-reviewable in minutes. New \
                    unsafe elsewhere needs a design conversation, not a suppression.",
    },
    Rule {
        id: "S1",
        title: "suppressions name a real rule and carry a justification",
        scope: "everywhere",
        rationale: "A suppression is a reviewed exception, not an off switch. \
                    `// lint:allow(<RULE>): <why>` must name a known rule and give a \
                    non-empty justification on the same comment line, placed on (or \
                    directly above) the flagged line. Unjustified or malformed \
                    suppressions are themselves diagnostics, and they do not suppress.",
    },
];

pub fn rule(id: &str) -> Option<&'static Rule> {
    RULES.iter().find(|r| r.id == id)
}

/// Sequence-of-idents/puncts matcher: does `toks[i..]` start with `pat`
/// (text equality; `Ident` and `Punct` both match on text)?
fn seq(a: &FileAnalysis, i: usize, pat: &[&str]) -> bool {
    pat.iter().enumerate().all(|(k, want)| {
        a.lex.toks.get(i + k).is_some_and(|t| {
            t.text == *want && matches!(t.kind, TokKind::Ident | TokKind::Punct)
        })
    })
}

/// A diagnostic before suppression filtering.
pub struct RawDiag {
    pub rule: &'static str,
    pub line: u32,
    pub msg: String,
}

fn diag(out: &mut Vec<RawDiag>, rule: &'static str, line: u32, msg: impl Into<String>) {
    out.push(RawDiag { rule, line, msg: msg.into() });
}

/// Run every token-level rule over one analyzed file. (S1, which checks
/// the suppressions themselves, lives in the engine: `lint_source`.)
pub fn check(a: &FileAnalysis) -> Vec<RawDiag> {
    let mut out = Vec::new();
    let scope = &a.scope;
    for (i, t) in a.lex.toks.iter().enumerate() {
        let line = t.line;
        let non_test = !a.in_test(line);

        // D1 — raw parallelism probes / detached spawns (src, non-test)
        if scope.in_src && non_test {
            if t.text == "available_parallelism" {
                diag(&mut out, "D1", line,
                    "raw available_parallelism: size thread counts via util::pool::resolve_threads (honors --threads/GXNOR_THREADS)");
            }
            if seq(a, i, &["thread", "::", "spawn"]) {
                diag(&mut out, "D1", line,
                    "detached thread::spawn: route daemons through util::pool::spawn_service");
            }
            if seq(a, i, &["thread", "::", "Builder"]) {
                diag(&mut out, "D1", line,
                    "thread::Builder: route daemons through util::pool::spawn_service");
            }
        }

        // D2 — wall-clock reads in virtual-clock / kernel code
        if scope.d2 && matches!(t.text.as_str(), "Instant" | "SystemTime") {
            diag(&mut out, "D2", line,
                format!("{} in virtual-clock/kernel code: take now_ns (or no time at all) from the caller", t.text));
        }

        // D3 — hash-ordered containers in accumulation paths (non-test)
        if scope.d3
            && non_test
            && matches!(t.text.as_str(), "HashMap" | "HashSet" | "hash_map" | "hash_set")
        {
            diag(&mut out, "D3", line,
                format!("{} in a determinism-critical path: iteration order is arbitrary — use BTreeMap/BTreeSet or an indexed Vec", t.text));
        }

        // D4 — environment reads outside the configuration homes (non-test)
        if scope.d4
            && non_test
            && seq(a, i, &["env", "::"])
            && a.lex.toks.get(i + 2).is_some_and(|n| {
                matches!(n.text.as_str(), "var" | "var_os" | "set_var" | "remove_var")
            })
        {
            diag(&mut out, "D4", line,
                "environment read outside the config homes (util/pool.rs, util/fault.rs, config.rs, cli.rs): invisible input breaks replayability");
        }

        // M1 — f32 weight mirrors in the step loop (non-test)
        if scope.m1 && non_test {
            if seq(a, i, &[".", "unpack", "(", ")"]) {
                diag(&mut out, "M1", line,
                    "full-tensor unpack() in the step loop: stream states through unpack_into chunk buffers (Remark 2: no f32 mirror)");
            }
            if t.text == "let" {
                let name = match a.lex.toks.get(i + 1) {
                    Some(m) if m.text == "mut" => a.lex.toks.get(i + 2),
                    other => other,
                };
                if let Some(n) = name {
                    if n.kind == TokKind::Ident && mirror_name(&n.text) {
                        diag(&mut out, "M1", n.line,
                            format!("binding `{}` looks like an f32 weight mirror: the packed state is the only weight storage (Remark 2)", n.text));
                    }
                }
            }
        }

        // R1 — .lock().unwrap() where lock_recover applies (src, non-test)
        if scope.in_src
            && non_test
            && seq(a, i, &[".", "lock", "(", ")", "."])
            && a.lex.toks.get(i + 5).is_some_and(|n| {
                matches!(n.text.as_str(), "unwrap" | "expect")
            })
        {
            diag(&mut out, "R1", line,
                ".lock().unwrap() cascades poisoning across threads: take the guard via util::lock::lock_recover");
        }

        // R2 — bare unwrap/expect on serve request paths (non-test)
        if scope.r2 && non_test {
            if seq(a, i, &[".", "unwrap", "(", ")"]) {
                diag(&mut out, "R2", line,
                    "bare unwrap() on a serve request path: classify the failure (io::Result / Reply variants) instead of panicking");
            }
            if seq(a, i, &[".", "expect", "("]) {
                diag(&mut out, "R2", line,
                    "bare expect() on a serve request path: restructure (if-let / ok_or_else) instead of asserting");
            }
        }

        // U1 — unsafe placement and SAFETY audit comments
        if t.text == "unsafe" && t.kind == TokKind::Ident {
            if !scope.unsafe_home {
                diag(&mut out, "U1", line,
                    "unsafe outside the audited homes (util/align.rs, runtime/client.rs): the crate's unsafe surface is closed by design");
            } else if !a.has_safety_comment(line) {
                diag(&mut out, "U1", line,
                    "unsafe block without a `// SAFETY:` comment on the preceding lines");
            }
        }
    }

    // E1 — float contamination inside the exact-integer kernel bodies
    if scope.e1 {
        for f in &a.fns {
            if !(f.name.starts_with("gated_dot") || f.name == "dot_planes_word") {
                continue;
            }
            for k in f.body.clone() {
                let t = &a.lex.toks[k];
                if t.kind == TokKind::Float {
                    diag(&mut out, "E1", t.line,
                        format!("float literal `{}` inside exact-integer kernel `{}`: the gated dot must stay an exact popcount integer", t.text, f.name));
                }
                if t.text == "as"
                    && a.lex.toks.get(k + 1).is_some_and(|n| {
                        matches!(n.text.as_str(), "f32" | "f64")
                    })
                {
                    diag(&mut out, "E1", t.line,
                        format!("float cast inside exact-integer kernel `{}`: scaling belongs in the GEMM wrappers", f.name));
                }
            }
        }
    }

    out
}

/// Does a `let` binding name smell like a full-precision weight mirror?
fn mirror_name(name: &str) -> bool {
    let n = name.to_ascii_lowercase();
    n.contains("mirror")
        || (n.contains("f32") && (n.starts_with("w_") || n.starts_with("weight")))
        || n == "full_weights"
        || n == "w_full"
        || n == "weights_full"
}
