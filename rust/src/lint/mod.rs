//! `gxnor-lint` — the repo-invariant static analysis pass.
//!
//! The compiler proves memory safety; the test suite spot-checks
//! behavior. What neither can check are the *conventions* this repo's
//! correctness arguments stand on: every parallel path sizes itself
//! through `util::pool`, kernels stay exact-integer, no f32 weight
//! mirror exists in the step loop (Remark 2 of the paper), serve request
//! paths never panic. Those contracts only hold while every new line
//! keeps holding them — so this module checks them mechanically, on
//! every PR, with file:line diagnostics.
//!
//! Pipeline: [`lexer`] tokenizes (comments/strings can never match a
//! rule), a structure pass finds `#[cfg(test)]` regions, function body
//! spans, and suppression comments, then [`rules`] runs ~10 scoped
//! token-pattern checks. See `rules::RULES` for the catalog and
//! `gxnor-lint --explain <ID>` for rationale.
//!
//! ## Suppressions
//!
//! A diagnostic can be waived with a comment of the form
//! `// <ns>:allow(RULE): justification` (where `<ns>` is `lint`) placed
//! on, or directly above, the offending line. The justification is
//! mandatory: an allow without one does not suppress — it raises S1
//! instead. Suppressions are reviewed exceptions, not an off switch.

pub mod lexer;
pub mod rules;

use std::fmt;
use std::io;
use std::ops::Range;
use std::path::{Path, PathBuf};

use lexer::{lex, Lexed, TokKind};

/// Minimum justification length for a suppression to count as justified
/// (filters out `lint:allow(D1): x`-style rubber stamps).
const MIN_JUSTIFICATION: usize = 8;

/// A finalized diagnostic.
#[derive(Clone, Debug)]
pub struct Diag {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Diag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Which rules apply to a file, derived from its repo-relative path.
/// Fixture tests exercise rules by linting synthetic sources *as if*
/// they lived at an in-scope path.
#[derive(Clone, Debug, Default)]
pub struct Scope {
    /// Under `rust/src/` (D1/R1 and the scoped rules below).
    pub in_src: bool,
    /// Virtual-clock / kernel purity files (engine/, ternary/, serve/queue.rs).
    pub d2: bool,
    /// Determinism-critical accumulation dirs.
    pub d3: bool,
    /// Everything in src except the env-read homes.
    pub d4: bool,
    /// The bitplane kernel file.
    pub e1: bool,
    /// Step-loop files under the Remark-2 mirror ban.
    pub m1: bool,
    /// serve/ request paths.
    pub r2: bool,
    /// One of the two audited unsafe homes.
    pub unsafe_home: bool,
    /// Under `rust/tests/` — the whole file is test code.
    pub all_test: bool,
}

impl Scope {
    pub fn for_path(rel: &str) -> Scope {
        let rel = rel.replace('\\', "/");
        let src = rel.strip_prefix("rust/src/");
        let in_src = src.is_some();
        let p = src.unwrap_or("");
        const D3_DIRS: &[&str] = &[
            "engine/", "ternary/", "coordinator/", "serve/", "data/", "sweep/", "hwsim/",
        ];
        Scope {
            in_src,
            d2: in_src
                && (p.starts_with("engine/")
                    || p.starts_with("ternary/")
                    || p == "serve/queue.rs"),
            d3: in_src
                && (D3_DIRS.iter().any(|d| p.starts_with(d))
                    || p == "metrics.rs"
                    || p.starts_with("metrics/")),
            d4: in_src
                && !matches!(p, "util/pool.rs" | "util/fault.rs" | "config.rs" | "cli.rs"),
            e1: p == "engine/bitplane.rs",
            m1: matches!(
                p,
                "engine/mod.rs"
                    | "engine/backward.rs"
                    | "ternary/dst.rs"
                    | "ternary/packed.rs"
                    | "coordinator/trainer.rs"
            ),
            r2: in_src && p.starts_with("serve/"),
            unsafe_home: matches!(p, "util/align.rs" | "runtime/client.rs"),
            all_test: rel.starts_with("rust/tests/"),
        }
    }
}

/// A function body located in the token stream (for E1's per-kernel scan).
#[derive(Clone, Debug)]
pub struct FnSpan {
    pub name: String,
    /// Token-index range of the body, braces included.
    pub body: Range<usize>,
}

/// One parsed suppression comment.
#[derive(Clone, Debug)]
pub struct Suppression {
    pub line: u32,
    pub rules: Vec<String>,
    pub justified: bool,
    pub malformed: bool,
}

/// Everything the rule pass needs to know about one file.
pub struct FileAnalysis {
    pub rel: String,
    pub scope: Scope,
    pub lex: Lexed,
    /// Inclusive line ranges of `#[cfg(test)]`-gated bodies.
    pub test_ranges: Vec<(u32, u32)>,
    pub fns: Vec<FnSpan>,
    pub suppressions: Vec<Suppression>,
}

impl FileAnalysis {
    /// Is `line` test code (a `#[cfg(test)]` body, or a `rust/tests/` file)?
    pub fn in_test(&self, line: u32) -> bool {
        self.scope.all_test || self.test_ranges.iter().any(|&(a, b)| (a..=b).contains(&line))
    }

    /// Is there a `SAFETY:` comment on `line` or the three lines above it?
    pub fn has_safety_comment(&self, line: u32) -> bool {
        self.lex
            .comments
            .iter()
            .any(|c| c.line <= line && c.line + 3 >= line && c.text.contains("SAFETY:"))
    }
}

pub fn analyze(rel: &str, src: &str) -> FileAnalysis {
    let lex = lex(src);
    let test_ranges = find_test_ranges(&lex);
    let fns = find_fns(&lex);
    let suppressions = parse_suppressions(&lex);
    FileAnalysis {
        rel: rel.replace('\\', "/"),
        scope: Scope::for_path(rel),
        lex,
        test_ranges,
        fns,
        suppressions,
    }
}

/// Token index of the `}` matching the `{` at `open`, if balanced.
fn match_brace(lex: &Lexed, open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (k, t) in lex.toks.iter().enumerate().skip(open) {
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(k);
                    }
                }
                _ => {}
            }
        }
    }
    None
}

/// `#[cfg(test)]` attribute occurrences → line ranges of the `{ … }`
/// body that follows (a test module, almost always). An attribute on a
/// braceless item (`#[cfg(test)] use …;`) gates nothing scannable and is
/// skipped.
fn find_test_ranges(lex: &Lexed) -> Vec<(u32, u32)> {
    const PAT: &[&str] = &["#", "[", "cfg", "(", "test", ")", "]"];
    let mut out = Vec::new();
    let toks = &lex.toks;
    let mut i = 0usize;
    while i < toks.len() {
        let hit = PAT
            .iter()
            .enumerate()
            .all(|(k, want)| toks.get(i + k).is_some_and(|t| t.text == *want));
        if hit {
            let mut j = i + PAT.len();
            while j < toks.len() && toks[j].text != "{" && toks[j].text != ";" {
                j += 1;
            }
            if j < toks.len() && toks[j].text == "{" {
                if let Some(close) = match_brace(lex, j) {
                    out.push((toks[i].line, toks[close].line));
                    i = j + 1;
                    continue;
                }
            }
        }
        i += 1;
    }
    out
}

/// Locate every `fn name … { body }` (nested functions included).
fn find_fns(lex: &Lexed) -> Vec<FnSpan> {
    let toks = &lex.toks;
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if !(toks[i].kind == TokKind::Ident && toks[i].text == "fn") {
            continue;
        }
        let Some(name_tok) = toks.get(i + 1) else { continue };
        if name_tok.kind != TokKind::Ident {
            continue; // `fn(...)` pointer type
        }
        // body `{` is the first one outside parens/brackets; a `;` there
        // instead means a bodyless declaration (trait method, extern)
        let (mut pd, mut bd) = (0i64, 0i64);
        let mut j = i + 2;
        let mut open = None;
        while j < toks.len() {
            match toks[j].text.as_str() {
                "(" => pd += 1,
                ")" => pd -= 1,
                "[" => bd += 1,
                "]" => bd -= 1,
                "{" if pd == 0 && bd == 0 => {
                    open = Some(j);
                    break;
                }
                ";" if pd == 0 && bd == 0 => break,
                _ => {}
            }
            j += 1;
        }
        if let Some(o) = open {
            if let Some(c) = match_brace(lex, o) {
                out.push(FnSpan { name: name_tok.text.clone(), body: o..c + 1 });
            }
        }
    }
    out
}

/// Parse `<ns>:allow(RULE[, RULE…]): justification` comments (`<ns>` is
/// `lint`; spelled indirectly here so this very comment isn't parsed).
fn parse_suppressions(lex: &Lexed) -> Vec<Suppression> {
    let marker = concat!("lint", ":allow(");
    let mut out = Vec::new();
    for c in &lex.comments {
        let t = c.text.trim();
        let Some(rest) = t.strip_prefix(marker) else { continue };
        match rest.split_once(')') {
            None => out.push(Suppression {
                line: c.line,
                rules: Vec::new(),
                justified: false,
                malformed: true,
            }),
            Some((ids, tail)) => {
                let rules: Vec<String> = ids
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
                let justification = tail
                    .trim_start()
                    .strip_prefix(':')
                    .map(str::trim)
                    .unwrap_or_default();
                out.push(Suppression {
                    line: c.line,
                    malformed: rules.is_empty(),
                    justified: justification.chars().count() >= MIN_JUSTIFICATION,
                    rules,
                });
            }
        }
    }
    out
}

/// Lint one source text as if it lived at repo-relative path `rel`.
/// This is the engine's core entry point — the tree walker and the
/// fixture tests both come through here.
pub fn lint_source(rel: &str, src: &str) -> Vec<Diag> {
    let a = analyze(rel, src);
    let mut raw = rules::check(&a);

    // S1: the suppressions themselves must be well-formed, name known
    // rules, and justify themselves. S1 diagnostics are not suppressible.
    for s in &a.suppressions {
        if s.malformed {
            raw.push(rules::RawDiag {
                rule: "S1",
                line: s.line,
                msg: "malformed suppression: expected `allow(RULE): justification`".into(),
            });
            continue;
        }
        for r in &s.rules {
            if rules::rule(r).is_none() {
                raw.push(rules::RawDiag {
                    rule: "S1",
                    line: s.line,
                    msg: format!("suppression names unknown rule `{r}`"),
                });
            }
        }
        if !s.justified {
            raw.push(rules::RawDiag {
                rule: "S1",
                line: s.line,
                msg: "suppression without a justification (`allow(RULE): <why>`); it does not suppress".into(),
            });
        }
    }

    let suppressed = |d: &rules::RawDiag| {
        d.rule != "S1"
            && a.suppressions.iter().any(|s| {
                !s.malformed
                    && s.justified
                    && s.rules.iter().any(|r| r == d.rule)
                    && (s.line == d.line || s.line + 1 == d.line)
            })
    };
    let mut diags: Vec<Diag> = raw
        .into_iter()
        .filter(|d| !suppressed(d))
        .map(|d| Diag { file: a.rel.clone(), line: d.line, rule: d.rule, msg: d.msg })
        .collect();
    diags.sort_by(|x, y| (x.line, x.rule).cmp(&(y.line, y.rule)));
    diags
}

/// The subtrees a full run scans, relative to the repo root.
pub const LINT_ROOTS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

/// Path components that are never linted: fixture files are deliberate
/// violations, vendor/ is third-party surface, target/ is build output.
fn skip_component(name: &str) -> bool {
    matches!(name, "lint_fixtures" | "vendor" | "target" | ".git")
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> =
        std::fs::read_dir(dir)?.collect::<io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let name = e.file_name();
        let name = name.to_string_lossy();
        if skip_component(&name) {
            continue;
        }
        let p = e.path();
        let ty = e.file_type()?;
        if ty.is_dir() {
            walk_rs(&p, out)?;
        } else if name.ends_with(".rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint the whole repo tree under `root` (the directory containing
/// `rust/` and `examples/`). Returns diagnostics sorted by file, line,
/// rule.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Diag>> {
    let mut files = Vec::new();
    for sub in LINT_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            walk_rs(&dir, &mut files)?;
        }
    }
    let mut diags = Vec::new();
    for f in &files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(f)?;
        diags.extend(lint_source(&rel, &src));
    }
    diags.sort_by(|x, y| (&x.file, x.line, x.rule).cmp(&(&y.file, y.line, y.rule)));
    Ok(diags)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_derivation() {
        let s = Scope::for_path("rust/src/serve/queue.rs");
        assert!(s.in_src && s.d2 && s.d3 && s.d4 && s.r2 && !s.e1 && !s.unsafe_home);
        let s = Scope::for_path("rust/src/util/pool.rs");
        assert!(s.in_src && !s.d2 && !s.d3 && !s.d4);
        let s = Scope::for_path("rust/src/engine/bitplane.rs");
        assert!(s.e1 && s.d2 && s.d3);
        let s = Scope::for_path("rust/tests/integration.rs");
        assert!(!s.in_src && s.all_test);
        let s = Scope::for_path("examples/quickstart.rs");
        assert!(!s.in_src && !s.all_test && !s.d4);
    }

    #[test]
    fn test_region_detection() {
        let src = "fn real() {}\n#[cfg(test)]\nmod tests {\n  fn t() {}\n}\nfn after() {}\n";
        let a = analyze("rust/src/util/x.rs", src);
        assert_eq!(a.test_ranges.len(), 1);
        assert!(!a.in_test(1));
        assert!(a.in_test(4));
        assert!(!a.in_test(6));
    }

    #[test]
    fn fn_span_detection() {
        let src = "fn gated_dot(a: &[u64]) -> i64 {\n  let x = 1;\n  x\n}\nfn other() { 1.5; }\n";
        let a = analyze("rust/src/engine/bitplane.rs", src);
        let names: Vec<&str> = a.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["gated_dot", "other"]);
    }

    #[test]
    fn suppression_parsing_and_justification() {
        let src = concat!(
            "// lint",
            ":allow(D1): long enough reason here\nlet a = 1;\n",
            "// lint",
            ":allow(D2)\nlet b = 2;\n",
            "// lint",
            ":allow(D3): no\nlet c = 3;\n",
        );
        let a = analyze("rust/src/util/x.rs", src);
        assert_eq!(a.suppressions.len(), 3);
        assert!(a.suppressions[0].justified);
        assert!(!a.suppressions[1].justified);
        assert!(!a.suppressions[2].justified, "8-char floor filters rubber stamps");
    }
}
