//! Hand-rolled CLI argument parser (no clap in the offline vendor set).
//!
//! Model: `gxnor <subcommand> [--flag] [--opt value] [--opt=value] [pos..]`.
//! Declarative enough for help generation, small enough to test exhaustively.

use std::collections::BTreeMap;

/// Declared option (with value) or flag (boolean).
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// A parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    /// Parse option `name` as `T`, or `default` when absent. A present
    /// but unparsable value is an **error naming the flag and the bad
    /// value** — `--epochs abc` must not silently train the default
    /// number of epochs.
    fn opt_parsed<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
        kind: &str,
    ) -> Result<T, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| format!("--{name}: invalid value {s:?} (expected {kind})")),
        }
    }

    pub fn opt_f32(&self, name: &str, default: f32) -> Result<f32, String> {
        self.opt_parsed(name, default, "a number")
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        self.opt_parsed(name, default, "a number")
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        self.opt_parsed(name, default, "a non-negative integer")
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        self.opt_parsed(name, default, "a non-negative integer")
    }

    /// Parse option `name` as a socket address (`host:port`), or `default`
    /// when absent. Same error contract as the numeric `opt_*` helpers:
    /// a present but unparsable value names the flag and the bad value.
    pub fn opt_socket_addr(
        &self,
        name: &str,
        default: &str,
    ) -> Result<std::net::SocketAddr, String> {
        let s = self.opt_or(name, default);
        s.parse().map_err(|_| {
            format!("--{name}: invalid value {s:?} (expected host:port, e.g. 127.0.0.1:7433)")
        })
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// A subcommand with its option declarations.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, takes_value: true, default: Some(default), help });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, takes_value: true, default: None, help });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, takes_value: false, default: None, help });
        self
    }

    /// Parse argv (already stripped of program name and subcommand).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        // seed defaults
        for spec in &self.opts {
            if let Some(d) = spec.default {
                out.opts.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name} for `{}`", self.name))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} needs a value"))?
                        }
                    };
                    out.opts.insert(name.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(tok.clone());
            }
            i += 1;
        }
        // check required
        for spec in &self.opts {
            if spec.takes_value && spec.default.is_none() && !out.opts.contains_key(spec.name) {
                return Err(format!("missing required --{} for `{}`", spec.name, self.name));
            }
        }
        Ok(out)
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n", self.name, self.about);
        for o in &self.opts {
            let kind = if o.takes_value {
                match o.default {
                    Some(d) => format!("<value, default {d}>"),
                    None => "<value, required>".into(),
                }
            } else {
                "(flag)".into()
            };
            s.push_str(&format!("  --{:<16} {kind:<28} {}\n", o.name, o.help));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "train a model")
            .opt("epochs", "10", "number of epochs")
            .opt("method", "gxnor", "training method")
            .req("dataset", "dataset name")
            .flag("verbose", "log more")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_applied() {
        let a = cmd().parse(&argv(&["--dataset", "mnist"])).unwrap();
        assert_eq!(a.opt_usize("epochs", 0).unwrap(), 10);
        assert_eq!(a.opt_or("method", ""), "gxnor");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_and_space_forms() {
        let a = cmd()
            .parse(&argv(&["--dataset=svhn", "--epochs", "3", "--verbose"]))
            .unwrap();
        assert_eq!(a.opt_or("dataset", ""), "svhn");
        assert_eq!(a.opt_usize("epochs", 0).unwrap(), 3);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn missing_required_rejected() {
        assert!(cmd().parse(&argv(&["--epochs", "3"])).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&argv(&["--dataset", "x", "--bogus", "1"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&argv(&["--dataset", "x", "--verbose=yes"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = cmd().parse(&argv(&["--dataset", "x", "ckpt.bin"])).unwrap();
        assert_eq!(a.positional, vec!["ckpt.bin"]);
    }

    /// A present but malformed numeric value is an error naming the flag
    /// and the value — never a silent fall-back to the default.
    #[test]
    fn numeric_parsers_reject_bad_values() {
        let a = cmd()
            .parse(&argv(&["--dataset", "x", "--epochs", "bad"]))
            .unwrap();
        let err = a.opt_usize("epochs", 42).unwrap_err();
        assert!(err.contains("--epochs") && err.contains("bad"), "{err}");
        assert!(a.opt_f32("epochs", 1.5).is_err());
        assert!(a.opt_f64("epochs", 1.5).is_err());
        assert!(a.opt_u64("epochs", 1).is_err());
        // absent option (no declared default): the caller's default
        let b = Args::default();
        assert_eq!(b.opt_usize("epochs", 42).unwrap(), 42);
        assert_eq!(b.opt_f64("lr", 0.5).unwrap(), 0.5);
        // valid values parse
        let c = cmd().parse(&argv(&["--dataset", "x", "--epochs", "7"])).unwrap();
        assert_eq!(c.opt_usize("epochs", 0).unwrap(), 7);
        assert_eq!(c.opt_f32("epochs", 0.0).unwrap(), 7.0);
    }

    /// Same error contract for socket addresses: `--addr nonsense` names
    /// the flag and the value instead of silently binding the default.
    #[test]
    fn socket_addr_parses_and_rejects() {
        let a = Args::default();
        assert_eq!(
            a.opt_socket_addr("addr", "127.0.0.1:7433").unwrap(),
            "127.0.0.1:7433".parse::<std::net::SocketAddr>().unwrap()
        );
        // port 0 (ephemeral, used by tests/bench) is valid
        assert!(a.opt_socket_addr("addr", "127.0.0.1:0").is_ok());
        let cmd = Command::new("serve", "serve").opt("addr", "127.0.0.1:7433", "listen address");
        let b = cmd.parse(&argv(&["--addr", "localhost"])).unwrap();
        let err = b.opt_socket_addr("addr", "127.0.0.1:7433").unwrap_err();
        assert!(err.contains("--addr") && err.contains("localhost"), "{err}");
    }

    #[test]
    fn help_mentions_options() {
        let h = cmd().help();
        assert!(h.contains("--epochs"));
        assert!(h.contains("required"));
    }
}
