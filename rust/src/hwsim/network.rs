//! Whole-network event-driven analysis: walk an architecture's weighted
//! layers with measured (or assumed) state distributions and produce the
//! per-layer operation table — Section 3.C scaled from one neuron
//! (Table 2) to the full networks of Table 1.

use std::fmt::Write as _;

use crate::engine::LayerGateReport;
use crate::hwsim::counts::{expected_counts, NetArch, OpCounts};
use crate::hwsim::energy::EnergyModel;
use crate::nn::arch::{geometry, Arch, LayerGeometry};

/// Per-layer result of a network walk.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub geometry: LayerGeometry,
    pub counts: OpCounts,
}

/// Expected op counts for one *sample* through every weighted layer.
///
/// `pw0` is the weight zero-state probability; `px0_per_layer` gives the
/// activation sparsity entering each weighted layer (first entry is the
/// input layer — real-valued inputs have ~0 zero fraction).
pub fn network_counts(
    arch: &Arch,
    net: NetArch,
    pw0: f64,
    px0_per_layer: &[f64],
) -> Vec<LayerReport> {
    let geo = geometry(arch);
    assert!(
        px0_per_layer.len() >= geo.len(),
        "need one activation sparsity per weighted layer ({} < {})",
        px0_per_layer.len(),
        geo.len()
    );
    geo.into_iter()
        .enumerate()
        .map(|(i, g)| {
            let mut c = expected_counts(net, g.fan_in as u64, pw0, px0_per_layer[i]);
            // scale per-neuron expectations to the layer's neuron count
            let n = g.neuron_evals as u64;
            c.mult *= n;
            c.acc *= n;
            c.xnor *= n;
            c.bitcount *= n;
            c.resting *= n;
            c.total *= n;
            LayerReport { geometry: g, counts: c }
        })
        .collect()
}

/// Per-sample op counts from what the engine *actually executed*: each
/// weighted layer whose name appears in `reports` (the native engine's
/// [`crate::engine::NativeEngine::gate_report`]) contributes its measured
/// gate tallies normalized to one sample; layers the engine ran unpacked
/// (the first layer, which sees the real-valued input) fall back to the
/// Table 2 analytic expectation with `pw0_fallback` weight sparsity and
/// px0 = 0.
///
/// Normalization is exact for `total` and `evals` (both are
/// samples × a per-sample constant); `xnor`/`bitcount` are per-sample
/// *means* rounded to the nearest integer, and `resting` is re-derived as
/// `total − xnor` so the resting identity survives rounding. Rates
/// (`resting_probability`) are therefore within 1/total of the raw
/// measured rate — indistinguishable at report precision.
pub fn measured_network_counts(
    arch: &Arch,
    reports: &[LayerGateReport],
    pw0_fallback: f64,
) -> Vec<LayerReport> {
    let geo = geometry(arch);
    geo.into_iter()
        .map(|g| {
            let measured = reports
                .iter()
                .find(|r| r.name == g.name)
                .filter(|r| r.stats.evals > 0);
            let counts = match measured {
                Some(rep) => {
                    let s = &rep.stats;
                    let ne = g.neuron_evals as u64;
                    assert!(
                        s.evals % ne == 0,
                        "{}: {} neuron evals not a multiple of {} per sample",
                        g.name,
                        s.evals,
                        ne
                    );
                    let samples = s.evals / ne;
                    let total = s.total / samples;
                    let xnor =
                        ((s.xnor as f64 / samples as f64).round() as u64).min(total);
                    let bitcount =
                        ((s.bitcount as f64 / samples as f64).round() as u64).min(ne);
                    OpCounts {
                        mult: 0,
                        acc: 0,
                        xnor,
                        bitcount,
                        resting: total - xnor,
                        total,
                    }
                }
                None => {
                    let mut c =
                        expected_counts(NetArch::Gxnor, g.fan_in as u64, pw0_fallback, 0.0);
                    let n = g.neuron_evals as u64;
                    c.xnor *= n;
                    c.bitcount *= n;
                    c.resting *= n;
                    c.total *= n;
                    c
                }
            };
            LayerReport { geometry: g, counts }
        })
        .collect()
}

/// Render the per-layer table plus totals and a relative-energy summary.
pub fn render_network_table(
    arch_name: &str,
    reports_by_net: &[(NetArch, Vec<LayerReport>)],
) -> String {
    let energy = EnergyModel::default();
    let mut out = String::new();
    let _ = writeln!(out, "network: {arch_name} (per-sample op counts)");
    let fp_total: f64 = reports_by_net
        .iter()
        .find(|(n, _)| *n == NetArch::FullPrecision)
        .map(|(_, reps)| reps.iter().map(|r| energy.energy_pj(&r.counts)).sum())
        .unwrap_or(f64::NAN);
    for (net, reps) in reports_by_net {
        let _ = writeln!(out, "\n  {}", net.name());
        let _ = writeln!(
            out,
            "  {:<24} {:>12} {:>12} {:>12} {:>9}",
            "layer", "active ops", "resting", "total", "rest %"
        );
        let mut tot = OpCounts::default();
        for r in reps {
            let _ = writeln!(
                out,
                "  {:<24} {:>12} {:>12} {:>12} {:>8.1}%",
                r.geometry.name,
                r.counts.active_ops(),
                r.counts.resting,
                r.counts.total,
                100.0 * r.counts.resting_probability()
            );
            tot.merge(&r.counts);
        }
        let e: f64 = reps.iter().map(|r| energy.energy_pj(&r.counts)).sum();
        let _ = writeln!(
            out,
            "  {:<24} {:>12} {:>12} {:>12} {:>8.1}%   energy vs fp: {:.5}",
            "TOTAL",
            tot.active_ops(),
            tot.resting,
            tot.total,
            100.0 * tot.resting_probability(),
            e / fp_total
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::arch::build_arch;

    #[test]
    fn gxnor_network_rests_more_than_twn() {
        let arch = build_arch("cnn_mnist").unwrap();
        let px0 = vec![0.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0]; // input dense
        let gx = network_counts(&arch, NetArch::Gxnor, 1.0 / 3.0, &px0);
        let twn = network_counts(&arch, NetArch::Twn, 1.0 / 3.0, &px0);
        let total = |reps: &[LayerReport]| {
            let mut t = OpCounts::default();
            for r in reps {
                t.merge(&r.counts);
            }
            t
        };
        let g = total(&gx);
        let t = total(&twn);
        assert!(g.resting_probability() > t.resting_probability());
        assert_eq!(g.total, t.total);
    }

    #[test]
    fn first_layer_never_rests_on_dense_input(){
        // real-valued inputs: px0 = 0 -> only zero weights rest
        let arch = build_arch("mlp").unwrap();
        let px0 = vec![0.0, 0.4, 0.4];
        let reps = network_counts(&arch, NetArch::Gxnor, 1.0 / 3.0, &px0);
        let p0 = reps[0].counts.resting_probability();
        assert!((p0 - 1.0 / 3.0).abs() < 0.01, "{p0}");
        let p1 = reps[1].counts.resting_probability();
        assert!(p1 > p0);
    }

    #[test]
    fn table_renders_totals_and_energy() {
        let arch = build_arch("mlp").unwrap();
        let px0 = vec![0.0, 0.36, 0.36];
        let by_net: Vec<_> = [NetArch::FullPrecision, NetArch::Gxnor]
            .iter()
            .map(|&n| (n, network_counts(&arch, n, 1.0 / 3.0, &px0)))
            .collect();
        let t = render_network_table("mlp", &by_net);
        assert!(t.contains("TOTAL"));
        assert!(t.contains("GXNOR-Nets"));
        assert!(t.contains("energy vs fp"));
    }

    #[test]
    fn measured_counts_normalize_per_sample_and_fall_back() {
        use crate::engine::bitplane::{GateStats, KernelStrategy};
        let arch = build_arch("mlp").unwrap();
        let geo = geometry(&arch);
        // fake a 3-sample measurement of the two deep FC layers (the
        // first layer runs unpacked, exactly like the real engine)
        let samples = 3u64;
        let reports: Vec<LayerGateReport> = geo[1..]
            .iter()
            .map(|g| {
                let ne = g.neuron_evals as u64;
                let m = g.fan_in as u64;
                LayerGateReport {
                    name: g.name.clone(),
                    fan_in: g.fan_in,
                    w_zero_fraction: 1.0 / 3.0,
                    stats: GateStats {
                        // deliberately not divisible by `samples`
                        xnor: samples * ne * m / 2 + 1,
                        total: samples * ne * m,
                        bitcount: samples * ne,
                        evals: samples * ne,
                        x_nonzero: samples * m * 2 / 3,
                        x_count: samples * m,
                        occ_hist: [0, 0, 0, samples, 0],
                    },
                    strategy: KernelStrategy::TileSkip,
                }
            })
            .collect();
        let reps = measured_network_counts(&arch, &reports, 1.0 / 3.0);
        assert_eq!(reps.len(), geo.len());
        // unmeasured first layer: analytic fallback at px0 = 0
        let g0 = &reps[0].geometry;
        let mut want0 = expected_counts(NetArch::Gxnor, g0.fan_in as u64, 1.0 / 3.0, 0.0);
        let n0 = g0.neuron_evals as u64;
        want0.xnor *= n0;
        want0.bitcount *= n0;
        want0.resting *= n0;
        want0.total *= n0;
        assert_eq!(reps[0].counts, want0);
        // measured layers: per-sample totals exact, identities survive
        for (rep, raw) in reps[1..].iter().zip(&reports) {
            let ne = rep.geometry.neuron_evals as u64;
            let m = rep.geometry.fan_in as u64;
            assert_eq!(rep.counts.total, ne * m);
            assert_eq!(rep.counts.bitcount, ne);
            assert_eq!(rep.counts.xnor + rep.counts.resting, rep.counts.total);
            // rate within rounding of the raw measured rate
            let raw_rate = raw.stats.resting_rate();
            assert!(
                (rep.counts.resting_probability() - raw_rate).abs() < 1.0 / (ne * m) as f64,
                "{}",
                rep.geometry.name
            );
        }
    }

    #[test]
    fn conv_layers_dominate_cnn_ops() {
        let arch = build_arch("cnn_cifar").unwrap();
        let px0 = vec![1.0 / 3.0; 8];
        let reps = network_counts(&arch, NetArch::Gxnor, 1.0 / 3.0, &px0);
        let conv_ops: u64 = reps[..6].iter().map(|r| r.counts.total).sum();
        let fc_ops: u64 = reps[6..].iter().map(|r| r.counts.total).sum();
        assert!(conv_ops > 10 * fc_ops);
    }
}
