//! Rendering of Table 2 and the Fig. 12 gating example.

use std::fmt::Write as _;

use crate::engine::LayerGateReport;
use crate::hwsim::counts::{
    count_neuron, expected_counts, gate_rate_matches, gxnor_resting_probability, NetArch, OpCounts,
};
use crate::hwsim::energy::EnergyModel;
use crate::util::prng::Prng;

/// Table 2 under the uniform-state assumption for an M-input neuron.
/// `pw0`/`px0` override the zero-state probabilities (pass 1/3 each for
/// the paper's numbers; pass measured fractions for the empirical table).
pub fn table2(m: u64, pw0: f64, px0: f64) -> String {
    let e = EnergyModel::default();
    let fp_base = expected_counts(NetArch::FullPrecision, m, pw0, px0);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<22} {:>14} {:>14} {:>10} {:>10} {:>9} {:>10}",
        "Networks", "Multiplication", "Accumulation", "XNOR", "BitCount", "Resting", "RelEnergy"
    );
    for arch in NetArch::ALL {
        let c = expected_counts(arch, m, pw0, px0);
        // exact analytic resting probability (integer-count rounding would
        // distort small M: 55.56% must print as 55.6%, not 56.0%)
        let p_rest = match arch {
            NetArch::Twn => pw0,
            NetArch::Gxnor => gxnor_resting_probability(pw0, px0),
            _ => 0.0,
        };
        let (mult, acc, xnor) = match arch {
            NetArch::Twn => (
                "0".to_string(),
                format!("0~{m}"),
                "0".to_string(),
            ),
            NetArch::Gxnor => (
                "0".to_string(),
                "0".to_string(),
                format!("0~{m}"),
            ),
            _ => (c.mult.to_string(), c.acc.to_string(), c.xnor.to_string()),
        };
        let bitcount = match arch {
            NetArch::Gxnor => "0/1".to_string(),
            _ => c.bitcount.to_string(),
        };
        let _ = writeln!(
            out,
            "{:<22} {:>14} {:>14} {:>10} {:>10} {:>8.1}% {:>10.4}",
            arch.name(),
            mult,
            acc,
            xnor,
            bitcount,
            100.0 * p_rest,
            e.relative(&c, &fp_base),
        );
    }
    let _ = writeln!(
        out,
        "(M = {m}; zero-state probability: weights {pw0:.3}, activations {px0:.3})"
    );
    out
}

/// The Fig. 12 experiment: a 3-neuron, 7-input ternary network — nominal
/// 21 XNOR ops; report the measured active count under sampled uniform
/// states. Returns (nominal, mean_active).
pub fn fig12_example(trials: usize, seed: u64) -> (u64, f64) {
    let mut rng = Prng::new(seed);
    let mut active = 0u64;
    for _ in 0..trials {
        for _neuron in 0..3 {
            let w: Vec<f32> = (0..7).map(|_| rng.below(3) as f32 - 1.0).collect();
            let x: Vec<f32> = (0..7).map(|_| rng.below(3) as f32 - 1.0).collect();
            active += count_neuron(NetArch::Gxnor, &w, &x).xnor;
        }
    }
    (21, active as f64 / trials as f64)
}

/// Per-layer measured-vs-analytic gate comparison: for each packed layer
/// the engine reported, print the kernel strategy it dispatched, the
/// resting rate it *executed*, and the Table 2 analytic prediction for
/// that layer's measured zero-state fractions. Returns the rendered table
/// and whether every layer passed [`gate_rate_matches`] under `tol`
/// (trained tensors correlate weights with activations, so a few percent
/// of slack over the independence model is expected).
pub fn measured_vs_analytic(reports: &[LayerGateReport], tol: f64) -> (String, bool) {
    let mut out = String::new();
    let mut all_ok = true;
    let _ = writeln!(
        out,
        "{:<24} {:>11} {:>10} {:>10} {:>7}",
        "layer", "strategy", "measured", "analytic", "match"
    );
    for rep in reports {
        let pw0 = rep.w_zero_fraction;
        let px0 = rep.stats.x_zero_fraction();
        let measured = rep.stats.resting_rate();
        let ok = gate_rate_matches(measured, pw0, px0, tol);
        all_ok &= ok;
        let _ = writeln!(
            out,
            "{:<24} {:>11} {:>9.1}% {:>9.1}% {:>7}",
            rep.name,
            rep.strategy.name(),
            100.0 * measured,
            100.0 * gxnor_resting_probability(pw0, px0),
            if ok { "ok" } else { "MISS" }
        );
    }
    (out, all_ok)
}

/// Measured-mode table: op counts from real weight/activation slices
/// (e.g. a trained model's first FC layer against a test batch).
pub fn measured_row(arch: NetArch, w: &[f32], x: &[f32]) -> OpCounts {
    // per-neuron application over x in chunks of w.len()
    let m = w.len();
    let mut total = OpCounts::default();
    for chunk in x.chunks_exact(m) {
        total.merge(&count_neuron(arch, w, chunk));
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_renders_all_rows() {
        let t = table2(100, 1.0 / 3.0, 1.0 / 3.0);
        for name in [
            "Full-precision NNs",
            "BWNs",
            "TWNs",
            "BNNs/XNOR",
            "GXNOR-Nets",
        ] {
            assert!(t.contains(name), "missing {name} in:\n{t}");
        }
        assert!(t.contains("55.6%"), "GXNOR resting missing:\n{t}");
        assert!(t.contains("33.3%"), "TWN resting missing:\n{t}");
    }

    #[test]
    fn fig12_mean_near_nine() {
        let (nominal, mean) = fig12_example(5000, 1);
        assert_eq!(nominal, 21);
        assert!((mean - 9.33).abs() < 0.35, "mean={mean}");
    }

    #[test]
    fn measured_vs_analytic_flags_divergence() {
        use crate::engine::bitplane::{GateStats, KernelStrategy};
        let rep = |xnor: u64, total: u64, x_nonzero: u64, x_count: u64| LayerGateReport {
            name: "fc1 16->8".into(),
            fan_in: 16,
            w_zero_fraction: 1.0 / 3.0,
            stats: GateStats {
                xnor,
                total,
                bitcount: 8,
                evals: 8,
                x_nonzero,
                x_count,
                occ_hist: [0; 5],
            },
            strategy: KernelStrategy::EventList,
        };
        // independence holds exactly: rest = 1 - (2/3)(3/4) = 1/2
        let good = rep(64, 128, 12, 16);
        let (t, ok) = measured_vs_analytic(&[good], 0.02);
        assert!(ok, "{t}");
        assert!(t.contains("event_list"), "{t}");
        assert!(t.contains("ok"), "{t}");
        // wildly off: measured 0% resting vs analytic 50%
        let bad = rep(128, 128, 12, 16);
        let (t, ok) = measured_vs_analytic(&[bad], 0.02);
        assert!(!ok, "{t}");
        assert!(t.contains("MISS"), "{t}");
    }

    #[test]
    fn measured_row_chunks() {
        let w = vec![1.0, 0.0, -1.0];
        let x = vec![1.0, 1.0, 0.0, /* second */ 0.0, 0.0, 0.0];
        let c = measured_row(NetArch::Gxnor, &w, &x);
        // first sample: pairs (1,1)=active, (0,1)=rest, (-1,0)=rest
        // second: all rest
        assert_eq!(c.xnor, 1);
        assert_eq!(c.resting, 5);
    }
}
