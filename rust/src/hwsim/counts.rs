//! Operation counting for the Fig. 11 architectures.
//!
//! For a neuron with M inputs (activations X_i, weights W_i):
//!
//! * **Full-precision NN** (Fig. 11b): M multiplications + M accumulations.
//! * **BWN** (Fig. 11c): multiplexer selects ±X_i -> M accumulations.
//! * **TWN** (Fig. 11d): event-driven accumulation; W_i = 0 rests the unit.
//! * **BNN/XNOR** (Fig. 11e): M XNOR ops + 1 bitcount.
//! * **GXNOR** (Fig. 11f): XNOR+bitcount *gated* on both operands being
//!   non-zero; a resting unit contributes neither op.

/// The network families of Table 2 / Fig. 11.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetArch {
    FullPrecision,
    Bwn,
    Twn,
    Bnn,
    Gxnor,
}

impl NetArch {
    pub const ALL: [NetArch; 5] = [
        NetArch::FullPrecision,
        NetArch::Bwn,
        NetArch::Twn,
        NetArch::Bnn,
        NetArch::Gxnor,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            NetArch::FullPrecision => "Full-precision NNs",
            NetArch::Bwn => "BWNs",
            NetArch::Twn => "TWNs",
            NetArch::Bnn => "BNNs/XNOR",
            NetArch::Gxnor => "GXNOR-Nets",
        }
    }
}

/// Operation tallies for a set of neuron evaluations.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpCounts {
    pub mult: u64,
    pub acc: u64,
    pub xnor: u64,
    pub bitcount: u64,
    /// connections whose compute unit stayed resting
    pub resting: u64,
    /// total connections considered
    pub total: u64,
}

impl OpCounts {
    pub fn resting_probability(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.resting as f64 / self.total as f64
        }
    }

    pub fn merge(&mut self, o: &OpCounts) {
        self.mult += o.mult;
        self.acc += o.acc;
        self.xnor += o.xnor;
        self.bitcount += o.bitcount;
        self.resting += o.resting;
        self.total += o.total;
    }

    /// Active arithmetic/logic ops (the quantity gating reduces).
    pub fn active_ops(&self) -> u64 {
        self.mult + self.acc + self.xnor + self.bitcount
    }
}

/// Count ops for one neuron evaluation: weights `w` against activations
/// `x` (slices of equal length M). Values are interpreted in the
/// discretization the architecture assumes; only zero/non-zero matters for
/// gating.
pub fn count_neuron(arch: NetArch, w: &[f32], x: &[f32]) -> OpCounts {
    assert_eq!(w.len(), x.len());
    let m = w.len() as u64;
    let mut c = OpCounts { total: m, ..Default::default() };
    match arch {
        NetArch::FullPrecision => {
            c.mult = m;
            c.acc = m;
        }
        NetArch::Bwn => {
            // multiplexer chooses +x or -x; accumulation always fires
            c.acc = m;
        }
        NetArch::Twn => {
            // event-driven: W_i = 0 keeps the accumulator resting
            for &wi in w {
                if wi == 0.0 {
                    c.resting += 1;
                } else {
                    c.acc += 1;
                }
            }
        }
        NetArch::Bnn => {
            c.xnor = m;
            c.bitcount = 1;
        }
        NetArch::Gxnor => {
            // gated XNOR: both operands must be non-zero to wake the unit
            let mut active = 0;
            for (&wi, &xi) in w.iter().zip(x) {
                if wi != 0.0 && xi != 0.0 {
                    active += 1;
                } else {
                    c.resting += 1;
                }
            }
            c.xnor = active;
            c.bitcount = if active > 0 { 1 } else { 0 };
        }
    }
    c
}

/// Analytic resting probability of a gated-XNOR unit: it rests iff either
/// operand is in the zero state, p = 1 − (1 − pw0)(1 − px0) (Table 2,
/// GXNOR row). Under the uniform assumption pw0 = px0 = 1/3 this is 5/9.
pub fn gxnor_resting_probability(pw0: f64, px0: f64) -> f64 {
    1.0 - (1.0 - pw0) * (1.0 - px0)
}

/// Cross-check a *measured* gated-op rate (e.g. the native engine's
/// `GateStats::resting_rate`) against the Table 2 analytic prediction for
/// the measured zero-state probabilities, within absolute tolerance `tol`
/// (sampling noise). This is the loop-closure between the paper's
/// analytical architecture study and executed packed-domain code.
pub fn gate_rate_matches(measured_resting_rate: f64, pw0: f64, px0: f64, tol: f64) -> bool {
    (measured_resting_rate - gxnor_resting_probability(pw0, px0)).abs() <= tol
}

/// Lower the packed kernel's *measured* [`GateStats`] into hwsim
/// [`OpCounts`] — the bridge between what the engine actually executed
/// (tile skips, event lists and all) and the Fig. 11 operation model.
/// GXNOR execution does no multiplies or accumulates: every woken
/// connection is one XNOR, every neuron evaluation that woke at least
/// once is one bitcount, and everything else rested.
pub fn ops_from_gate_stats(s: &crate::engine::bitplane::GateStats) -> OpCounts {
    OpCounts {
        mult: 0,
        acc: 0,
        xnor: s.xnor,
        bitcount: s.bitcount,
        resting: s.resting(),
        total: s.total,
    }
}

/// Table 2's analytic expectations for an M-input neuron, parameterized by
/// the zero-state probabilities of weights (`pw0`) and activations (`px0`).
/// The paper's uniform-state assumption is pw0 = px0 = 1/3.
pub fn expected_counts(arch: NetArch, m: u64, pw0: f64, px0: f64) -> OpCounts {
    let mf = m as f64;
    match arch {
        NetArch::FullPrecision => OpCounts {
            mult: m, acc: m, xnor: 0, bitcount: 0, resting: 0, total: m,
        },
        NetArch::Bwn => OpCounts { mult: 0, acc: m, xnor: 0, bitcount: 0, resting: 0, total: m },
        NetArch::Twn => {
            let rest = (mf * pw0).round() as u64;
            OpCounts { mult: 0, acc: m - rest, xnor: 0, bitcount: 0, resting: rest, total: m }
        }
        NetArch::Bnn => OpCounts { mult: 0, acc: 0, xnor: m, bitcount: 1, resting: 0, total: m },
        NetArch::Gxnor => {
            // resting iff W=0 or X=0
            let p_rest = gxnor_resting_probability(pw0, px0);
            let rest = (mf * p_rest).round() as u64;
            OpCounts {
                mult: 0,
                acc: 0,
                xnor: m - rest,
                bitcount: 1,
                resting: rest,
                total: m,
            }
        }
    }
}

/// Measure op counts over a whole dense layer: activations `x` (batch ×
/// M) against every output neuron's weight column (M × N, row-major
/// `w[m * n_out + n]`).
pub fn count_layer(arch: NetArch, x: &[f32], w: &[f32], m: usize, n_out: usize) -> OpCounts {
    assert_eq!(w.len(), m * n_out);
    assert_eq!(x.len() % m, 0);
    let batch = x.len() / m;
    let mut total = OpCounts::default();
    let mut wcol = vec![0.0f32; m];
    for n in 0..n_out {
        for i in 0..m {
            wcol[i] = w[i * n_out + n];
        }
        for b in 0..batch {
            total.merge(&count_neuron(arch, &wcol, &x[b * m..(b + 1) * m]));
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_uniform_state_resting_probabilities() {
        // Table 2: FP 0%, BWN 0%, TWN 33.3%, BNN 0%, GXNOR 55.6%
        let m = 9_000u64;
        let p = |arch| expected_counts(arch, m, 1.0 / 3.0, 1.0 / 3.0).resting_probability();
        assert_eq!(p(NetArch::FullPrecision), 0.0);
        assert_eq!(p(NetArch::Bwn), 0.0);
        assert!((p(NetArch::Twn) - 1.0 / 3.0).abs() < 1e-3);
        assert_eq!(p(NetArch::Bnn), 0.0);
        assert!((p(NetArch::Gxnor) - 5.0 / 9.0).abs() < 1e-3);
    }

    #[test]
    fn table2_operation_kinds() {
        let m = 100u64;
        let fp = expected_counts(NetArch::FullPrecision, m, 0.0, 0.0);
        assert_eq!((fp.mult, fp.acc), (m, m));
        let bwn = expected_counts(NetArch::Bwn, m, 0.0, 0.0);
        assert_eq!((bwn.mult, bwn.acc), (0, m));
        let bnn = expected_counts(NetArch::Bnn, m, 0.0, 0.0);
        assert_eq!((bnn.xnor, bnn.bitcount), (m, 1));
        let twn = expected_counts(NetArch::Twn, m, 1.0 / 3.0, 0.0);
        assert_eq!(twn.acc, 67); // 0~M band of Table 2
        let gx = expected_counts(NetArch::Gxnor, m, 1.0 / 3.0, 1.0 / 3.0);
        assert_eq!(gx.xnor, 44); // (2/3)^2 of 100, rounded
    }

    #[test]
    fn gating_measured_vs_analytic() {
        // uniform ternary weights/acts: measured resting prob ~ 5/9
        use crate::util::prng::Prng;
        let mut rng = Prng::new(5);
        let m = 30_000;
        let tern = |rng: &mut Prng| (rng.below(3) as f32) - 1.0;
        let w: Vec<f32> = (0..m).map(|_| tern(&mut rng)).collect();
        let x: Vec<f32> = (0..m).map(|_| tern(&mut rng)).collect();
        let c = count_neuron(NetArch::Gxnor, &w, &x);
        assert!((c.resting_probability() - 5.0 / 9.0).abs() < 0.02);
        assert_eq!(c.xnor + c.resting, m as u64);
    }

    #[test]
    fn fig12_example_21_to_9_xnor() {
        // Fig. 12: a 3-neuron / 7-input ternary network: 21 nominal XNOR
        // ops reduce to ~21 * 4/9 ≈ 9 under uniform states.
        use crate::util::prng::Prng;
        let mut rng = Prng::new(11);
        let mut active_sum = 0u64;
        let trials = 4000;
        for _ in 0..trials {
            for _neuron in 0..3 {
                let w: Vec<f32> = (0..7).map(|_| (rng.below(3) as f32) - 1.0).collect();
                let x: Vec<f32> = (0..7).map(|_| (rng.below(3) as f32) - 1.0).collect();
                active_sum += count_neuron(NetArch::Gxnor, &w, &x).xnor;
            }
        }
        let mean_active = active_sum as f64 / trials as f64;
        assert!(
            (mean_active - 21.0 * 4.0 / 9.0).abs() < 0.3,
            "mean active {mean_active} vs 9.33"
        );
    }

    /// Loop closure with the executed engine: the bitplane kernel's
    /// *measured* gate rate over uniform random ternary tensors must match
    /// the Table 2 analytic prediction computed from the tensors' actual
    /// zero-state fractions, within 2% sampling tolerance (the acceptance
    /// bound this PR pins).
    #[test]
    fn native_kernel_gate_rate_matches_table2() {
        use crate::engine::bitplane::{gated_xnor_gemm, BitplaneCols, GateStats, PackScratch};
        use crate::util::prng::Prng;
        let mut rng = Prng::new(23);
        let (rows, m, n) = (64usize, 128usize, 48usize);
        let tern = |rng: &mut Prng| rng.below(3) as f32 - 1.0;
        let a: Vec<f32> = (0..rows * m).map(|_| tern(&mut rng)).collect();
        let w: Vec<f32> = (0..m * n).map(|_| tern(&mut rng)).collect();
        let cols = BitplaneCols::pack_cols(&w, m, n);
        let mut out = vec![0.0f32; rows * n];
        let mut stats = GateStats::default();
        gated_xnor_gemm(&a, rows, &cols, &mut out, &mut stats, &mut PackScratch::new());
        // measured zero-state probabilities of the actual tensors
        let pw0 = w.iter().filter(|&&v| v == 0.0).count() as f64 / w.len() as f64;
        let px0 = stats.x_zero_fraction();
        assert!(
            gate_rate_matches(stats.resting_rate(), pw0, px0, 0.02),
            "measured {:.4} vs analytic {:.4} (pw0 {pw0:.3}, px0 {px0:.3})",
            stats.resting_rate(),
            gxnor_resting_probability(pw0, px0)
        );
        // the uniform-state paper number (5/9) also holds loosely
        assert!(
            gate_rate_matches(stats.resting_rate(), 1.0 / 3.0, 1.0 / 3.0, 0.02),
            "measured {:.4} vs 5/9",
            stats.resting_rate()
        );
        // and the kernel's counting identities hold exactly
        assert_eq!(stats.xnor + stats.resting(), stats.total);
        assert_eq!(stats.total, (rows * m * n) as u64);
    }

    #[test]
    fn gxnor_resting_probability_analytic_points() {
        assert!((gxnor_resting_probability(1.0 / 3.0, 1.0 / 3.0) - 5.0 / 9.0).abs() < 1e-12);
        assert_eq!(gxnor_resting_probability(0.0, 0.0), 0.0);
        assert_eq!(gxnor_resting_probability(1.0, 0.0), 1.0);
        assert!(gate_rate_matches(0.56, 1.0 / 3.0, 1.0 / 3.0, 0.02));
        assert!(!gate_rate_matches(0.70, 1.0 / 3.0, 1.0 / 3.0, 0.02));
    }

    #[test]
    fn ops_from_gate_stats_preserves_identities() {
        use crate::engine::bitplane::GateStats;
        let s = GateStats {
            xnor: 40,
            total: 90,
            bitcount: 6,
            evals: 6,
            x_nonzero: 10,
            x_count: 15,
            occ_hist: [0; 5],
        };
        let c = ops_from_gate_stats(&s);
        assert_eq!((c.mult, c.acc), (0, 0));
        assert_eq!(c.xnor, 40);
        assert_eq!(c.bitcount, 6);
        assert_eq!(c.resting, 50);
        assert_eq!(c.total, 90);
        assert_eq!(c.xnor + c.resting, c.total);
        assert_eq!(c.resting_probability(), s.resting_rate());
    }

    #[test]
    fn zero_weight_neuron_fully_rests() {
        let w = vec![0.0; 16];
        let x = vec![1.0; 16];
        let c = count_neuron(NetArch::Gxnor, &w, &x);
        assert_eq!(c.xnor, 0);
        assert_eq!(c.bitcount, 0);
        assert_eq!(c.resting_probability(), 1.0);
    }

    #[test]
    fn count_layer_aggregates() {
        // 2-batch, 3-in, 2-out, all non-zero
        let x = vec![1.0; 6];
        let w = vec![1.0; 6];
        let c = count_layer(NetArch::Bnn, &x, &w, 3, 2);
        assert_eq!(c.xnor, 3 * 2 * 2);
        assert_eq!(c.bitcount, 4); // one per neuron eval
    }

    #[test]
    fn merge_accumulates() {
        let mut a = count_neuron(NetArch::FullPrecision, &[1.0; 4], &[1.0; 4]);
        let b = count_neuron(NetArch::FullPrecision, &[1.0; 6], &[1.0; 6]);
        a.merge(&b);
        assert_eq!(a.mult, 10);
        assert_eq!(a.total, 10);
    }
}
