//! Relative energy model over op counts.
//!
//! Per-op energies follow the standard 45 nm figures (Horowitz, ISSCC'14)
//! the efficient-DNN literature uses: f32 multiply ≈ 3.7 pJ, f32 add ≈
//! 0.9 pJ, and bit-level logic ops orders of magnitude cheaper. Absolute
//! joules are not the claim (the paper itself stays qualitative — "the
//! power consumption can be reduced to a certain extent"); the *ratios*
//! between the Fig. 11 architectures are what the report prints.

use crate::hwsim::counts::OpCounts;

#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    pub mult_pj: f64,
    pub acc_pj: f64,
    pub xnor_pj: f64,
    pub bitcount_pj: f64,
    /// static/gating overhead charged per *woken* unit (control logic)
    pub wake_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            mult_pj: 3.7,     // f32 multiply, 45nm
            acc_pj: 0.9,      // f32 add
            xnor_pj: 0.0032,  // 1-bit gate + latch (est.)
            bitcount_pj: 0.1, // popcount tree per neuron
            wake_pj: 0.0016,  // control-gate signal generation (Conclusion's caveat)
        }
    }
}

impl EnergyModel {
    pub fn energy_pj(&self, c: &OpCounts) -> f64 {
        let woken = (c.total - c.resting) as f64;
        c.mult as f64 * self.mult_pj
            + c.acc as f64 * self.acc_pj
            + c.xnor as f64 * self.xnor_pj
            + c.bitcount as f64 * self.bitcount_pj
            + woken * self.wake_pj
    }

    /// Energy of `c` relative to a baseline count.
    pub fn relative(&self, c: &OpCounts, baseline: &OpCounts) -> f64 {
        let b = self.energy_pj(baseline);
        if b == 0.0 {
            f64::NAN
        } else {
            self.energy_pj(c) / b
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::counts::{expected_counts, NetArch};

    #[test]
    fn ordering_matches_paper_qualitative_claims() {
        // per-M-input-neuron energies: FP > BWN > TWN > BNN > GXNOR
        let m = 1000;
        let e = EnergyModel::default();
        let u = 1.0 / 3.0;
        let fp = e.energy_pj(&expected_counts(NetArch::FullPrecision, m, u, u));
        let bwn = e.energy_pj(&expected_counts(NetArch::Bwn, m, u, u));
        let twn = e.energy_pj(&expected_counts(NetArch::Twn, m, u, u));
        let bnn = e.energy_pj(&expected_counts(NetArch::Bnn, m, u, u));
        let gx = e.energy_pj(&expected_counts(NetArch::Gxnor, m, u, u));
        assert!(fp > bwn && bwn > twn && twn > bnn && bnn > gx,
            "fp={fp} bwn={bwn} twn={twn} bnn={bnn} gx={gx}");
        // logic nets are orders of magnitude below arithmetic nets
        assert!(fp / bnn > 100.0);
        // gating buys BNN -> GXNOR savings even with wake overhead charged
        assert!(gx < 0.6 * bnn, "gx={gx} bnn={bnn}");
    }

    #[test]
    fn sparser_activations_cost_less() {
        let e = EnergyModel::default();
        let m = 1000;
        let dense = e.energy_pj(&expected_counts(NetArch::Gxnor, m, 1.0 / 3.0, 0.1));
        let sparse = e.energy_pj(&expected_counts(NetArch::Gxnor, m, 1.0 / 3.0, 0.7));
        assert!(sparse < dense);
    }

    #[test]
    fn relative_baseline() {
        let e = EnergyModel::default();
        let m = 100;
        let fp = expected_counts(NetArch::FullPrecision, m, 0.0, 0.0);
        assert!((e.relative(&fp, &fp) - 1.0).abs() < 1e-12);
    }
}
