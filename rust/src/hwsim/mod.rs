//! Event-driven hardware computing-architecture simulator (Section 3.C).
//!
//! Models the six implementations of Fig. 11 at the operation level and
//! reproduces Table 2 (operation overheads + resting probability) and the
//! Fig. 12 gating example (21 XNOR -> ~9 under uniform ternary states),
//! both analytically (uniform-state assumption, as the paper's Table 2)
//! and *measured* over real weight/activation tensors coming out of
//! training. `network` scales the per-neuron analysis to whole
//! architectures, layer by layer.

pub mod counts;
pub mod energy;
pub mod network;
pub mod report;

pub use counts::{
    count_neuron, expected_counts, gate_rate_matches, gxnor_resting_probability,
    ops_from_gate_stats, NetArch, OpCounts,
};
pub use energy::EnergyModel;
pub use network::{measured_network_counts, network_counts, render_network_table, LayerReport};
