//! `gxnor-lint` — CLI driver for the repo-invariant static analysis
//! pass (see `gxnor::lint` for the engine and `rules::RULES` for the
//! catalog).
//!
//! ```text
//! gxnor-lint [--root <dir>] [--deny-all] [paths…]   lint the tree (or just paths)
//! gxnor-lint --explain <RULE>                        print one rule's rationale
//! gxnor-lint --list-rules                            one line per rule
//! ```
//!
//! Exit status: 0 when clean (or advisory mode), 1 on diagnostics under
//! `--deny-all` (the CI entry point), 2 on usage/IO errors.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use gxnor::lint::{self, rules};

fn usage() -> &'static str {
    "usage: gxnor-lint [--root <dir>] [--deny-all] [paths…]\n\
     \x20      gxnor-lint --explain <RULE> | --list-rules\n\
     \n\
     Lints rust/src, rust/tests, rust/benches and examples/ under the\n\
     repo root against the repo-invariant rules (determinism, kernel\n\
     exactness, the Remark-2 mirror ban, serve robustness). With\n\
     --deny-all any diagnostic is fatal (exit 1) — the CI entry point.\n\
     Explicit [paths…] lint just those files, addressed relative to the\n\
     root so scoped rules resolve."
}

fn explain(id: &str) -> ExitCode {
    match rules::rule(id) {
        Some(r) => {
            println!("{}: {}", r.id, r.title);
            println!("scope: {}", r.scope);
            println!();
            // rationale strings are continuation-joined; reflow to ~76 cols
            let mut col = 0usize;
            for w in r.rationale.split_whitespace() {
                if col + w.len() + 1 > 76 && col > 0 {
                    println!();
                    col = 0;
                }
                if col > 0 {
                    print!(" ");
                    col += 1;
                }
                print!("{w}");
                col += w.len();
            }
            println!();
            ExitCode::SUCCESS
        }
        None => {
            eprintln!("gxnor-lint: unknown rule `{id}` (try --list-rules)");
            ExitCode::from(2)
        }
    }
}

/// The repo root is the directory holding `rust/src`; accept being
/// launched from the root itself or from inside `rust/` (where cargo
/// puts the working directory for `cargo run`).
fn detect_root() -> Option<PathBuf> {
    let cwd = std::env::current_dir().ok()?;
    for cand in [cwd.clone(), cwd.join(".."), cwd.join("../..")] {
        if cand.join("rust/src").is_dir() {
            return Some(cand);
        }
    }
    None
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut deny_all = false;
    let mut root: Option<PathBuf> = None;
    let mut paths: Vec<String> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--help" | "-h" => {
                println!("{}", usage());
                return ExitCode::SUCCESS;
            }
            "--list-rules" => {
                for r in rules::RULES {
                    println!("{:<3} {:<55} [{}]", r.id, r.title, r.scope);
                }
                return ExitCode::SUCCESS;
            }
            "--explain" => {
                let Some(id) = it.next() else {
                    eprintln!("gxnor-lint: --explain needs a rule id");
                    return ExitCode::from(2);
                };
                return explain(id);
            }
            "--deny-all" => deny_all = true,
            "--root" => {
                let Some(r) = it.next() else {
                    eprintln!("gxnor-lint: --root needs a directory");
                    return ExitCode::from(2);
                };
                root = Some(PathBuf::from(r));
            }
            p if !p.starts_with('-') => paths.push(p.to_string()),
            other => {
                eprintln!("gxnor-lint: unknown flag `{other}`\n{}", usage());
                return ExitCode::from(2);
            }
        }
    }

    let Some(root) = root.or_else(detect_root) else {
        eprintln!("gxnor-lint: cannot find the repo root (no rust/src here); pass --root");
        return ExitCode::from(2);
    };

    let diags = if paths.is_empty() {
        match lint::lint_tree(&root) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("gxnor-lint: walking {}: {e}", root.display());
                return ExitCode::from(2);
            }
        }
    } else {
        let mut d = Vec::new();
        for rel in &paths {
            let full = root.join(rel);
            match std::fs::read_to_string(&full) {
                Ok(src) => d.extend(lint::lint_source(rel, &src)),
                Err(e) => {
                    eprintln!("gxnor-lint: {}: {e}", full.display());
                    return ExitCode::from(2);
                }
            }
        }
        d
    };

    for d in &diags {
        println!("{d}");
    }
    summarize(&diags, &root);
    if diags.is_empty() || !deny_all {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn summarize(diags: &[lint::Diag], root: &Path) {
    if diags.is_empty() {
        println!("gxnor-lint: clean ({})", root.display());
        return;
    }
    let mut by_rule: Vec<(&str, usize)> = Vec::new();
    for d in diags {
        match by_rule.iter_mut().find(|(r, _)| *r == d.rule) {
            Some((_, n)) => *n += 1,
            None => by_rule.push((d.rule, 1)),
        }
    }
    by_rule.sort();
    let parts: Vec<String> =
        by_rule.iter().map(|(r, n)| format!("{r}×{n}")).collect();
    println!(
        "gxnor-lint: {} diagnostic{} ({}) — see --explain <RULE>",
        diags.len(),
        if diags.len() == 1 { "" } else { "s" },
        parts.join(", ")
    );
}
