//! Ternary-operand backward kernels for the native training engine.
//!
//! Every GEMM in the backward pass has one operand that is already a
//! sign/nonzero bitplane: the weights (discrete under every non-fp
//! method) or the cached quantized activations. Both backward matmuls
//! therefore reduce to **gate-controlled ±accumulation of f32 values
//! with zero multiplies**, the backward twin of the forward gated-XNOR
//! unit. Multi-level (`Z_N`, N ≥ 2) operands ride the same kernels
//! through their magnitude digit planes (`bitplane::PlaneSpec`): the
//! per-lane weight becomes `±q` and one power-of-two grid scale is
//! applied at the end, which stays *exactly* equal to the f64 scalar
//! oracles because every product and the scaling are exact in f64:
//!
//! * `dX = dY·Wᵀ` — [`f32_rows_times_tern_cols`]: each output element
//!   streams one packed weight row (planes over the output-channel lanes,
//!   [`BitplaneCols::pack_rows_of`]) against the f32 cotangent row,
//!   adding/subtracting gated lanes. Resting tiles are skipped outright
//!   via the packers' occupancy maps (`BitplaneCols::col_occ`) before a
//!   single plane word loads — the event-driven zero-state gate, now in
//!   the backward pass.
//! * `dW = Xᵀ·dY` — [`accum_dw_packed`]: the cached activation bitplanes
//!   ([`PackScratch`], packed once in the forward) are walked row by row;
//!   every set lane axpys the f32 `dY` row into its `dW` row with the
//!   plane's sign. The kernel takes a *word range* of fan-in lanes so
//!   workers own disjoint `dW` row blocks: each gradient element is
//!   accumulated by exactly one worker in global batch-row order, which
//!   is what makes the merged gradient bit-identical for any thread
//!   count (no cross-worker floating-point reduction exists at all).
//!
//! Accumulation is f64 throughout. Because the ternary operand only ever
//! contributes ±1 (exact in f64) and lanes are visited in ascending
//! order, both kernels are **exactly** equal to the gated f64 scalar
//! oracles ([`f32_rows_times_tern_cols_oracle`], [`accum_dw_scalar`]) —
//! the property tests assert `==`, not tolerance.
//!
//! The rest of the file is the non-GEMM backward math: the paper's
//! rectangular/triangular derivative window for the quantizer (eqs. 7/8,
//! mirroring `python/compile/kernels/ref.py::quantize_bwd`), the L2-SVM
//! squared-hinge loss gradient, BatchNorm train-mode backward in
//! channel-sharded form, and max-pool gradient routing with XLA's
//! first-max tie order.

use super::bitplane::{BitplaneCols, PackScratch, LANE_WORDS};
use super::ActMode;

// ---------------------------------------------------------------------------
// Ternary-operand GEMM kernels
// ---------------------------------------------------------------------------

/// One word of the gated signed sum: walk the set gate bits, ±accumulate
/// the f32 values. Shared by the lane body and the scalar tail so every
/// lane width accumulates in the identical ascending order.
#[inline(always)]
fn signed_sum_word(sw: u64, zw: u64, base: usize, f: &[f32], acc: &mut f64) {
    let mut gate = zw;
    while gate != 0 {
        let b = gate.trailing_zeros() as usize;
        let v = f[base + b] as f64;
        if (sw >> b) & 1 == 1 {
            *acc += v;
        } else {
            *acc -= v;
        }
        gate &= gate - 1;
    }
}

/// Gated signed sum of one packed plane pair against an f32 vector:
/// `Σ_lane ±f[lane]` over set lanes, +/− by the sign plane, f64
/// accumulation in ascending lane order. The zero-skip gate runs at
/// kernel-lane granularity (the backward twin of the forward lane skip):
/// one OR across [`LANE_WORDS`] nonzero words rests the whole lane. Lanes
/// past `f.len()` must be clear (packing guarantees it up to the padded
/// stride). Delegates to [`gated_signed_sum_lanes`] at the shipped width.
#[inline]
pub fn gated_signed_sum(sign: &[u64], nz: &[u64], f: &[f32]) -> f64 {
    gated_signed_sum_lanes::<LANE_WORDS>(sign, nz, f)
}

/// [`gated_signed_sum`] guided by a precomputed occupancy map (per-tile
/// nonzero popcounts, [`BitplaneCols::col_occ`]): a tile whose map entry
/// is zero is stepped over without loading a single plane word — the OR
/// test the lane walk would have computed is already answered. The f64
/// adds still happen at exactly the set gate bits in ascending lane
/// order, so results stay bit-identical to the plain walk and the
/// scalar oracle.
fn gated_signed_sum_occ(sign: &[u64], nz: &[u64], occ: &[u32], f: &[f32]) -> f64 {
    let n = nz.len();
    debug_assert!(sign.len() >= n && occ.len() * LANE_WORDS >= n);
    let mut acc = 0.0f64;
    let mut k = 0;
    while k + LANE_WORDS <= n {
        if occ[k / LANE_WORDS] != 0 {
            for w in k..k + LANE_WORDS {
                signed_sum_word(sign[w], nz[w], w * 64, f, &mut acc);
            }
        }
        k += LANE_WORDS;
    }
    // plane strides are lane-padded, so columns never leave a tail; keep
    // the scalar finish for safety with ad-hoc slices
    for w in k..n {
        signed_sum_word(sign[w], nz[w], w * 64, f, &mut acc);
    }
    acc
}

/// [`gated_signed_sum`] at an explicit lane width `L` — public for the
/// bench harness's width sweep; every width is bit-identical (the f64
/// adds happen in the same ascending lane order regardless of grouping).
pub fn gated_signed_sum_lanes<const L: usize>(sign: &[u64], nz: &[u64], f: &[f32]) -> f64 {
    let n = nz.len();
    debug_assert!(sign.len() >= n);
    let mut acc = 0.0f64;
    let main = n - n % L.max(1);
    let mut k = 0;
    while k < main {
        let mut lane_or = 0u64;
        for i in 0..L {
            lane_or |= nz[k + i];
        }
        if lane_or != 0 {
            for w in k..k + L {
                signed_sum_word(sign[w], nz[w], w * 64, f, &mut acc);
            }
        }
        k += L;
    }
    for w in main..n {
        signed_sum_word(sign[w], nz[w], w * 64, f, &mut acc);
    }
    acc
}

/// One word of the multi-bitplane signed sum: gather the digit magnitude
/// `q` per set lane, ±accumulate `q·f`.
#[inline(always)]
fn signed_sum_word_multi(sw: u64, zw: u64, mag: &[&[u64]], wi: usize, f: &[f32], acc: &mut f64) {
    let base = wi * 64;
    let mut gate = zw;
    while gate != 0 {
        let b = gate.trailing_zeros() as usize;
        let mut q = 0u64;
        for (p, m) in mag.iter().enumerate() {
            q |= ((m[wi] >> b) & 1) << p;
        }
        let v = f[base + b] as f64 * q as f64;
        if (sw >> b) & 1 == 1 {
            *acc += v;
        } else {
            *acc -= v;
        }
        gate &= gate - 1;
    }
}

/// [`gated_signed_sum`] for a multi-bitplane operand: per set lane the
/// integer magnitude `q` is gathered from the digit planes and the f32
/// value accumulates with weight `±q` (f64, ascending lane order; the
/// caller applies the grid scale once at the end — exact, the scale is a
/// power of two and commutes with every rounding). The zero skip is
/// answered by the occupancy map (`occ[t] == 0` ⟺ the lane OR the old
/// walk computed is zero), so resting tiles cost two array reads.
#[inline]
fn gated_signed_sum_multi(sign: &[u64], nz: &[u64], mag: &[&[u64]], occ: &[u32], f: &[f32]) -> f64 {
    let n = nz.len();
    debug_assert!(occ.len() * LANE_WORDS >= n);
    let mut acc = 0.0f64;
    let main = n - n % LANE_WORDS;
    let mut k = 0;
    while k < main {
        if occ[k / LANE_WORDS] != 0 {
            for w in k..k + LANE_WORDS {
                signed_sum_word_multi(sign[w], nz[w], mag, w, f, &mut acc);
            }
        }
        k += LANE_WORDS;
    }
    for w in main..n {
        signed_sum_word_multi(sign[w], nz[w], mag, w, f, &mut acc);
    }
    acc
}

/// `out[r, j] = Σ_i a[r, i] · T[i, j]` where the discrete matrix is
/// packed as per-column planes over its `planes.m` fan-in lanes —
/// ternary/binary single-plane or any multi-bitplane `Z_N` layout.
/// Serves two call sites with one kernel:
///
/// * forward layers fed f32 inputs with discrete weights (`planes` =
///   weight columns, `k = fan_in`);
/// * backward `dX = dY·Wᵀ` (`planes` = weight *rows* via
///   [`BitplaneCols::pack_rows_of`] / `pack_rows_from_packed`,
///   `k = n_out`, out lanes = fan-in).
pub fn f32_rows_times_tern_cols(a: &[f32], rows: usize, planes: &BitplaneCols, out: &mut [f32]) {
    let k = planes.m;
    let n = planes.n;
    assert_eq!(a.len(), rows * k);
    assert_eq!(out.len(), rows * n);
    if planes.n_mag() == 0 {
        for r in 0..rows {
            let ar = &a[r * k..(r + 1) * k];
            let orow = &mut out[r * n..(r + 1) * n];
            for (j, o) in orow.iter_mut().enumerate() {
                let (s, z) = planes.col(j);
                // resting weight rows/columns skip whole tiles via the
                // occupancy map before any plane word loads
                *o = gated_signed_sum_occ(s, z, planes.col_occ(j), ar) as f32;
            }
        }
        return;
    }
    let scale = planes.scale() as f64;
    let mut mags: Vec<&[u64]> = Vec::new();
    // column-outer walk so each column's digit-plane list is built once,
    // not once per (row, column)
    for j in 0..n {
        let (s, z) = planes.col(j);
        planes.fill_col_mag(j, &mut mags);
        let occ = planes.col_occ(j);
        for r in 0..rows {
            let ar = &a[r * k..(r + 1) * k];
            out[r * n + j] = (gated_signed_sum_multi(s, z, &mags, occ, ar) * scale) as f32;
        }
    }
}

/// Gated f64 scalar oracle for [`f32_rows_times_tern_cols`]: identical
/// gating (zero entries skipped) and identical ascending-index
/// accumulation order, so the packed kernel matches it bit for bit —
/// for ternary operands *and* every multi-level grid (grid values are
/// sign·q·2^{−k}, so each product and the final scaling are exact).
pub fn f32_rows_times_tern_cols_oracle(
    a: &[f32],
    rows: usize,
    t: &[f32],
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), rows * k);
    assert_eq!(t.len(), k * n);
    assert_eq!(out.len(), rows * n);
    for r in 0..rows {
        let ar = &a[r * k..(r + 1) * k];
        for j in 0..n {
            let mut acc = 0.0f64;
            for (i, &av) in ar.iter().enumerate() {
                let w = t[i * n + j];
                if w != 0.0 {
                    acc += av as f64 * w as f64;
                }
            }
            out[r * n + j] = acc as f32;
        }
    }
}

/// `dW[i, j] += Σ_r X[r, i] · dY[r, j]` for the fan-in lanes covered by
/// words `[word_lo, word_hi)` of the packed activation rows, written into
/// the caller's `dw` block (row-major over `hi_lane − lo_lane` rows of
/// `n`, f64). Rows are walked in ascending global order; a worker owns
/// its lane range outright, so sharding the word ranges across threads
/// changes nothing about any accumulated value. The zero skip runs over
/// [`LANE_WORDS`]-word groups: lane-aligned ranges (what the engine
/// shards hand out) answer it from the activation occupancy map with two
/// array reads, ragged ranges OR the group's words.
pub fn accum_dw_packed(
    pack: &PackScratch,
    rows: usize,
    dy: &[f32],
    n: usize,
    word_lo: usize,
    word_hi: usize,
    dw: &mut [f64],
) {
    let words = pack.words();
    let hi = word_hi.min(words);
    let lane_lo = word_lo * 64;
    assert!(dy.len() >= rows * n);
    if pack.n_mag() > 0 {
        return accum_dw_packed_multi(pack, rows, dy, n, word_lo, hi, dw);
    }
    // engine shards hand out lane-aligned word ranges, so each group maps
    // onto one occupancy-map tile; ragged ranges (tests) keep the OR walk
    let occ_aligned = word_lo % LANE_WORDS == 0;
    for r in 0..rows {
        let (s, z) = pack.row(r);
        let occ = pack.row_occ(r);
        let dyr = &dy[r * n..(r + 1) * n];
        let mut w0 = word_lo;
        while w0 < hi {
            let w1 = (w0 + LANE_WORDS).min(hi);
            // occ[t] == 0 means every word of the tile (a superset of
            // this group) is zero — skipping is safe even for a partial
            // trailing group; a nonzero map falls through to the
            // per-word gate checks
            let resting = if occ_aligned {
                occ[w0 / LANE_WORDS] == 0
            } else {
                (w0..w1).fold(0u64, |o, w| o | z[w]) == 0
            };
            if resting {
                w0 = w1;
                continue;
            }
            for wi in w0..w1 {
                let mut gate = z[wi];
                if gate == 0 {
                    continue;
                }
                let sw = s[wi];
                let base = wi * 64 - lane_lo;
                while gate != 0 {
                    let b = gate.trailing_zeros() as usize;
                    let drow = &mut dw[(base + b) * n..(base + b) * n + n];
                    if (sw >> b) & 1 == 1 {
                        for (d, &g) in drow.iter_mut().zip(dyr) {
                            *d += g as f64;
                        }
                    } else {
                        for (d, &g) in drow.iter_mut().zip(dyr) {
                            *d -= g as f64;
                        }
                    }
                    gate &= gate - 1;
                }
            }
            w0 = w1;
        }
    }
}

/// [`accum_dw_packed`] over a multi-bitplane activation layout: per set
/// lane the coefficient `±q·scale` (the lane's exact f64 grid value) axpys
/// the `dY` row — the same per-element expression as the scalar oracle's
/// `dw += x·g`, so the two remain bit-identical.
fn accum_dw_packed_multi(
    pack: &PackScratch,
    rows: usize,
    dy: &[f32],
    n: usize,
    word_lo: usize,
    word_hi: usize,
    dw: &mut [f64],
) {
    let lane_lo = word_lo * 64;
    let scale = pack.scale() as f64;
    let mut mags: Vec<&[u64]> = Vec::new();
    let occ_aligned = word_lo % LANE_WORDS == 0;
    for r in 0..rows {
        let (s, z) = pack.row(r);
        let occ = pack.row_occ(r);
        pack.fill_row_mag(r, &mut mags);
        let dyr = &dy[r * n..(r + 1) * n];
        let mut w0 = word_lo;
        while w0 < word_hi {
            let w1 = (w0 + LANE_WORDS).min(word_hi);
            let resting = if occ_aligned {
                occ[w0 / LANE_WORDS] == 0
            } else {
                (w0..w1).fold(0u64, |o, w| o | z[w]) == 0
            };
            if resting {
                w0 = w1;
                continue;
            }
            for wi in w0..w1 {
                let mut gate = z[wi];
                if gate == 0 {
                    continue;
                }
                let sw = s[wi];
                let base = wi * 64 - lane_lo;
                while gate != 0 {
                    let b = gate.trailing_zeros() as usize;
                    let mut q = 0u64;
                    for (p, m) in mags.iter().enumerate() {
                        q |= ((m[wi] >> b) & 1) << p;
                    }
                    let coef = if (sw >> b) & 1 == 1 {
                        q as f64 * scale
                    } else {
                        -(q as f64) * scale
                    };
                    let drow = &mut dw[(base + b) * n..(base + b) * n + n];
                    for (d, &g) in drow.iter_mut().zip(dyr) {
                        *d += coef * g as f64;
                    }
                    gate &= gate - 1;
                }
            }
            w0 = w1;
        }
    }
}

/// Scalar `dW` accumulation for f32 inputs (first layer, fp modes), over
/// the lane range `[lane_lo, lane_hi)`, into the caller's `dw` block.
/// Exact-zero inputs are skipped with the same gating semantics as the
/// packed kernel, so for ternary-valued f32 inputs this doubles as the
/// packed kernel's bit-exact oracle (±1·g is exact in f64).
#[allow(clippy::too_many_arguments)]
pub fn accum_dw_scalar(
    x: &[f32],
    rows: usize,
    m: usize,
    dy: &[f32],
    n: usize,
    lane_lo: usize,
    lane_hi: usize,
    dw: &mut [f64],
) {
    assert!(x.len() >= rows * m);
    assert!(dy.len() >= rows * n);
    for r in 0..rows {
        let xr = &x[r * m..(r + 1) * m];
        let dyr = &dy[r * n..(r + 1) * n];
        for i in lane_lo..lane_hi.min(m) {
            let xv = xr[i] as f64;
            if xv == 0.0 {
                continue;
            }
            let drow = &mut dw[(i - lane_lo) * n..(i - lane_lo) * n + n];
            for (d, &g) in drow.iter_mut().zip(dyr) {
                *d += xv * g as f64;
            }
        }
    }
}

/// `out[r, i] = Σ_j dy[r, j] · w[i, j]` with a dense f32 weight matrix
/// (`w` row-major m × n) — the `dX` fallback for the fp baseline's dense
/// weights. f64 accumulation in ascending `j` order.
pub fn f32_rows_times_dense_rows(
    dy: &[f32],
    rows: usize,
    w: &[f32],
    m: usize,
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(dy.len(), rows * n);
    assert_eq!(w.len(), m * n);
    assert_eq!(out.len(), rows * m);
    for r in 0..rows {
        let dyr = &dy[r * n..(r + 1) * n];
        for i in 0..m {
            let wr = &w[i * n..(i + 1) * n];
            let mut acc = 0.0f64;
            for (&g, &wv) in dyr.iter().zip(wr) {
                acc += g as f64 * wv as f64;
            }
            out[r * m + i] = acc as f32;
        }
    }
}

// ---------------------------------------------------------------------------
// Quantizer derivative (eqs. 7/8) and loss
// ---------------------------------------------------------------------------

/// Approximate derivative of the quantizer at pre-activation `y` — the
/// paper's rectangular window (eq. 7): a pulse of half-width `a` and
/// height `1/(2a)` centred on every discontinuity of `phi_r`
/// (`|y| = r + k·step`, `k = 0..hl−1`). `bin` mode uses the BNN
/// straight-through hardtanh window; `fp` is the identity derivative.
/// Mirrors `python/compile/kernels/ref.py::quantize_bwd` (rect window).
#[inline]
pub fn quant_bwd(y: f32, r: f32, a: f32, hl: f32, mode: ActMode) -> f32 {
    match mode {
        ActMode::Fp => 1.0,
        ActMode::Bin => {
            if y.abs() <= 1.0 {
                1.0
            } else {
                0.0
            }
        }
        ActMode::Multi => {
            let step = (1.0 - r) / hl;
            let u = y.abs() - r;
            // hl < 1 (the N2 = 0 space) has a single discontinuity (k = 0);
            // the raw `hl - 1` would be negative and f32::clamp panics on
            // an inverted range
            let k = (u / step).round().clamp(0.0, (hl - 1.0).max(0.0));
            let dist = (u - k * step).abs();
            if dist <= a {
                1.0 / (2.0 * a)
            } else {
                0.0
            }
        }
    }
}

/// One row of the L2-SVM squared hinge loss [23] and its gradient:
/// `loss_r = Σ_c max(0, 1 − t·o)²` with targets `t ∈ {−1, +1}`;
/// `dlogits[c] = −2·t·margin·inv_rows` (the mean's `1/rows` folded in).
/// Returns the row's (un-normalized) loss contribution.
pub fn svm_row_loss_grad(
    logits: &[f32],
    label: i32,
    inv_rows: f32,
    dlogits: &mut [f32],
) -> f64 {
    let mut loss = 0.0f64;
    for (c, (&o, d)) in logits.iter().zip(dlogits.iter_mut()).enumerate() {
        let t = if c as i32 == label { 1.0f32 } else { -1.0 };
        let margin = (1.0 - t * o).max(0.0);
        loss += margin as f64 * margin as f64;
        *d = -2.0 * t * margin * inv_rows;
    }
    loss
}

// ---------------------------------------------------------------------------
// BatchNorm train-mode backward (channel-sharded form)
// ---------------------------------------------------------------------------

/// Per-channel backward sums over a channel-last tensor for channels
/// `[c0, c1)`: `out[(c−c0)·2] += Σ dy`, `out[(c−c0)·2+1] += Σ dy·x̂`
/// with `x̂ = (z − mean)·inv_std`. One worker owns a channel range and
/// walks all rows in order — the two sums feed `dbeta`/`dgamma` and the
/// `dz` correction terms, and are bit-identical for any channel sharding.
#[allow(clippy::too_many_arguments)]
pub fn bn_bwd_channel_sums(
    dy: &[f32],
    z: &[f32],
    mean: &[f32],
    inv_std: &[f32],
    channels: usize,
    c0: usize,
    c1: usize,
    out: &mut [f64],
) {
    assert_eq!(dy.len(), z.len());
    debug_assert_eq!(dy.len() % channels, 0);
    assert_eq!(out.len(), (c1 - c0) * 2);
    for (dyr, zr) in dy.chunks_exact(channels).zip(z.chunks_exact(channels)) {
        for c in c0..c1 {
            let g = dyr[c] as f64;
            let xhat = ((zr[c] - mean[c]) * inv_std[c]) as f64;
            out[(c - c0) * 2] += g;
            out[(c - c0) * 2 + 1] += g * xhat;
        }
    }
}

/// Elementwise BN backward over a row range, given the pre-divided
/// per-channel terms: `dz = gamma·inv_std·(dy − s1/N − x̂·(s2/N))` where
/// `s1 = Σ dy`, `s2 = Σ dy·x̂` over the whole (masked) batch. Writes in
/// place over `dy`.
#[allow(clippy::too_many_arguments)]
pub fn bn_bwd_dz_rows(
    dy: &mut [f32],
    z: &[f32],
    gamma: &[f32],
    mean: &[f32],
    inv_std: &[f32],
    s1_over_n: &[f32],
    s2_over_n: &[f32],
    channels: usize,
) {
    assert_eq!(dy.len(), z.len());
    for (dyr, zr) in dy.chunks_exact_mut(channels).zip(z.chunks_exact(channels)) {
        for c in 0..channels {
            let xhat = (zr[c] - mean[c]) * inv_std[c];
            dyr[c] = gamma[c] * inv_std[c] * (dyr[c] - s1_over_n[c] - xhat * s2_over_n[c]);
        }
    }
}

/// Train-mode BN forward statistics for channels `[c0, c1)`: two-pass
/// mean then biased variance (matching `jnp.var`), f64 sums over all
/// rows in order. `out[(c−c0)·2] = mean`, `out[(c−c0)·2+1] = var`.
pub fn bn_fwd_channel_stats(
    z: &[f32],
    channels: usize,
    c0: usize,
    c1: usize,
    out: &mut [f64],
) {
    debug_assert_eq!(z.len() % channels, 0);
    assert_eq!(out.len(), (c1 - c0) * 2);
    let rows = z.len() / channels;
    let n = rows.max(1) as f64;
    for c in c0..c1 {
        let mut sum = 0.0f64;
        for zr in z.chunks_exact(channels) {
            sum += zr[c] as f64;
        }
        out[(c - c0) * 2] = sum / n;
    }
    for c in c0..c1 {
        let mean = out[(c - c0) * 2];
        let mut sq = 0.0f64;
        for zr in z.chunks_exact(channels) {
            let d = zr[c] as f64 - mean;
            sq += d * d;
        }
        out[(c - c0) * 2 + 1] = sq / n;
    }
}

// ---------------------------------------------------------------------------
// Max-pool backward and conv patch scatter
// ---------------------------------------------------------------------------

/// Route one sample's pooled gradient back to the argmax of each window.
/// Tie order is XLA's `SelectAndScatter` with a `GE` select: the *first*
/// maximum in window scan order (ky, then kx) wins — ties are common
/// here because pooling runs over quantized ternary activations.
pub fn maxpool_bwd_sample(
    x: &[f32],
    h: usize,
    w: usize,
    c: usize,
    size: usize,
    dy: &[f32],
    dx: &mut [f32],
) {
    let (oh, ow) = (h / size, w / size);
    assert_eq!(x.len(), h * w * c);
    assert_eq!(dy.len(), oh * ow * c);
    assert_eq!(dx.len(), h * w * c);
    dx.fill(0.0);
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..c {
                let mut best = f32::NEG_INFINITY;
                let mut bi = 0usize;
                for ky in 0..size {
                    for kx in 0..size {
                        let idx = ((oy * size + ky) * w + ox * size + kx) * c + ch;
                        if x[idx] > best {
                            best = x[idx];
                            bi = idx;
                        }
                    }
                }
                dx[bi] += dy[(oy * ow + ox) * c + ch];
            }
        }
    }
}

/// Scatter-add one conv patch gradient back into the sample image — the
/// exact inverse walk of `gather_patch` (HWIO patch order, zero-padding
/// positions dropped).
#[allow(clippy::too_many_arguments)]
pub fn scatter_patch_add(
    dpatch: &[f32],
    h: usize,
    w: usize,
    cin: usize,
    k: usize,
    pad: usize,
    oy: usize,
    ox: usize,
    dx: &mut [f32],
) {
    let mut idx = 0usize;
    for ky in 0..k {
        let iy = oy as isize + ky as isize - pad as isize;
        for kx in 0..k {
            let ix = ox as isize + kx as isize - pad as isize;
            if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                let base = ((iy as usize) * w + ix as usize) * cin;
                for ci in 0..cin {
                    dx[base + ci] += dpatch[idx + ci];
                }
            }
            idx += cin;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::gather_patch;
    use crate::util::prng::Prng;

    fn random_ternary(rng: &mut Prng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.below(3) as f32 - 1.0).collect()
    }

    #[test]
    fn f32_times_tern_cols_matches_oracle_exactly() {
        let mut rng = Prng::new(3);
        for &(rows, k, n) in &[(1usize, 1usize, 1usize), (3, 63, 5), (2, 64, 8), (4, 130, 17)] {
            let a: Vec<f32> = (0..rows * k).map(|_| rng.normal_f32()).collect();
            let t = random_ternary(&mut rng, k * n);
            let planes = BitplaneCols::pack_cols(&t, k, n);
            let mut got = vec![0.0f32; rows * n];
            let mut want = vec![0.0f32; rows * n];
            f32_rows_times_tern_cols(&a, rows, &planes, &mut got);
            f32_rows_times_tern_cols_oracle(&a, rows, &t, k, n, &mut want);
            assert_eq!(got, want, "rows={rows} k={k} n={n}");
        }
    }

    #[test]
    fn dx_through_packed_rows_matches_transposed_oracle() {
        // dX = dY·Wᵀ via pack_rows_of must equal the oracle on Wᵀ
        let mut rng = Prng::new(5);
        let (rows, m, n) = (3usize, 70usize, 9usize);
        let w = random_ternary(&mut rng, m * n);
        let dy: Vec<f32> = (0..rows * n).map(|_| rng.normal_f32()).collect();
        let wrows = BitplaneCols::pack_rows_of(&w, m, n);
        let mut got = vec![0.0f32; rows * m];
        f32_rows_times_tern_cols(&dy, rows, &wrows, &mut got);
        let mut wt = vec![0.0f32; n * m];
        for i in 0..m {
            for j in 0..n {
                wt[j * m + i] = w[i * n + j];
            }
        }
        let mut want = vec![0.0f32; rows * m];
        f32_rows_times_tern_cols_oracle(&dy, rows, &wt, n, m, &mut want);
        assert_eq!(got, want);
    }

    #[test]
    fn accum_dw_packed_matches_scalar_and_is_shard_invariant() {
        let mut rng = Prng::new(7);
        let (rows, m, n) = (5usize, 200usize, 7usize);
        let x = random_ternary(&mut rng, rows * m);
        let dy: Vec<f32> = (0..rows * n).map(|_| rng.normal_f32()).collect();
        let mut pack = PackScratch::new();
        pack.pack_rows(&x, rows, m);
        let words = pack.words();

        // one shard covering everything
        let mut whole = vec![0.0f64; m * n];
        accum_dw_packed(&pack, rows, &dy, n, 0, words, &mut whole);

        // the scalar oracle (ternary x as f32: ±1·g is exact in f64)
        let mut oracle = vec![0.0f64; m * n];
        accum_dw_scalar(&x, rows, m, &dy, n, 0, m, &mut oracle);
        assert_eq!(whole, oracle);

        // word-range sharding must reproduce the same values bit for bit;
        // `words` is the lane-padded stride, so shards past the logical
        // fan-in clamp both lane bounds (their words carry no gate bits)
        for split in [1usize, 2, 3] {
            let mut sharded = vec![0.0f64; m * n];
            let mut w0 = 0;
            while w0 < words {
                let w1 = (w0 + split).min(words);
                let lane_lo = (w0 * 64).min(m);
                let lane_hi = (w1 * 64).min(m);
                accum_dw_packed(
                    &pack,
                    rows,
                    &dy,
                    n,
                    w0,
                    w1,
                    &mut sharded[lane_lo * n..lane_hi * n],
                );
                w0 = w1;
            }
            assert_eq!(sharded, whole, "split={split}");
        }
    }

    /// Satellite: the backward signed sum is lane-width invariant — every
    /// width groups the same ascending f64 adds, so results are `==`.
    #[test]
    fn gated_signed_sum_is_lane_width_invariant() {
        let mut rng = Prng::new(29);
        for m in [1usize, 63, 64, 65, 200, 513] {
            let t = random_ternary(&mut rng, m);
            let f: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
            let planes = BitplaneCols::pack_rows_of(&t, 1, m);
            let (s, z) = planes.col(0);
            let whole = gated_signed_sum(s, z, &f);
            assert_eq!(whole.to_bits(), gated_signed_sum_lanes::<1>(s, z, &f).to_bits(), "m={m}");
            assert_eq!(whole.to_bits(), gated_signed_sum_lanes::<4>(s, z, &f).to_bits(), "m={m}");
            assert_eq!(whole.to_bits(), gated_signed_sum_lanes::<8>(s, z, &f).to_bits(), "m={m}");
        }
    }

    #[test]
    fn dense_dx_fallback_matches_definition() {
        let mut rng = Prng::new(11);
        let (rows, m, n) = (2usize, 5usize, 4usize);
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal_f32()).collect();
        let dy: Vec<f32> = (0..rows * n).map(|_| rng.normal_f32()).collect();
        let mut out = vec![0.0f32; rows * m];
        f32_rows_times_dense_rows(&dy, rows, &w, m, n, &mut out);
        for r in 0..rows {
            for i in 0..m {
                let want: f64 = (0..n).map(|j| dy[r * n + j] as f64 * w[i * n + j] as f64).sum();
                assert!((out[r * m + i] as f64 - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn rect_window_matches_reference_points() {
        // hl = 1, r = 0.5, a = 0.5: pulse on |y| ∈ [0, 1] around |y| = 0.5
        let m = ActMode::Multi;
        assert_eq!(quant_bwd(0.5, 0.5, 0.5, 1.0, m), 1.0);
        assert_eq!(quant_bwd(-0.5, 0.5, 0.5, 1.0, m), 1.0);
        assert_eq!(quant_bwd(0.0, 0.5, 0.5, 1.0, m), 1.0); // dist = 0.5 <= a
        assert_eq!(quant_bwd(1.1, 0.5, 0.5, 1.0, m), 0.0); // dist = 0.6 > a
        assert_eq!(quant_bwd(-3.0, 0.5, 0.5, 1.0, m), 0.0);
        // narrower pulse: a = 0.2 -> height 2.5
        assert_eq!(quant_bwd(0.6, 0.5, 0.2, 1.0, m), 2.5);
        assert_eq!(quant_bwd(0.9, 0.5, 0.2, 1.0, m), 0.0);
        // hl = 2: discontinuities at |y| = 0.5 and 0.75 (step 0.25)
        assert_eq!(quant_bwd(0.74, 0.5, 0.05, 2.0, m), 10.0);
        assert_eq!(quant_bwd(0.62, 0.5, 0.05, 2.0, m), 0.0);
        // bin: hardtanh window
        assert_eq!(quant_bwd(0.9, 0.5, 0.5, 1.0, ActMode::Bin), 1.0);
        assert_eq!(quant_bwd(-1.2, 0.5, 0.5, 1.0, ActMode::Bin), 0.0);
        assert_eq!(quant_bwd(7.0, 0.5, 0.5, 1.0, ActMode::Fp), 1.0);
    }

    #[test]
    fn svm_loss_and_grad_hand_example() {
        // 3 classes, label 1, logits [2, 0.5, -2]:
        // t = [-1, +1, -1]; margins = [max(0,1+2), max(0,1-0.5), max(0,1-2)]
        //                           = [3, 0.5, 0]
        let logits = [2.0f32, 0.5, -2.0];
        let mut d = [0.0f32; 3];
        let loss = svm_row_loss_grad(&logits, 1, 1.0, &mut d);
        assert!((loss - (9.0 + 0.25)).abs() < 1e-9);
        assert_eq!(d, [6.0, -1.0, 0.0]); // -2·t·margin
        // inv_rows folds the batch mean into the gradient
        let mut d2 = [0.0f32; 3];
        svm_row_loss_grad(&logits, 1, 0.25, &mut d2);
        assert_eq!(d2, [1.5, -0.25, 0.0]);
    }

    #[test]
    fn bn_stats_and_backward_are_consistent() {
        // two rows, one channel: z = [1, 3] -> mean 2, var 1
        let z = [1.0f32, 3.0];
        let mut stats = vec![0.0f64; 2];
        bn_fwd_channel_stats(&z, 1, 0, 1, &mut stats);
        assert!((stats[0] - 2.0).abs() < 1e-12);
        assert!((stats[1] - 1.0).abs() < 1e-12);
        let mean = [2.0f32];
        let inv_std = [1.0f32]; // eps ignored for the hand check
        let dy = [1.0f32, 0.0];
        let mut sums = vec![0.0f64; 2];
        bn_bwd_channel_sums(&dy, &z, &mean, &inv_std, 1, 0, 1, &mut sums);
        assert!((sums[0] - 1.0).abs() < 1e-12); // Σ dy
        assert!((sums[1] + 1.0).abs() < 1e-12); // Σ dy·x̂, x̂ = [-1, 1]
        // dz = gamma·inv_std·(dy − s1/N − x̂·s2/N), N = 2
        let mut g = dy;
        bn_bwd_dz_rows(&mut g, &z, &[1.0], &mean, &inv_std, &[0.5], &[-0.5], 1);
        assert!((g[0] - (1.0 - 0.5 - 0.5)).abs() < 1e-6, "{g:?}"); // x̂=-1
        assert!((g[1] - (0.0 - 0.5 + 0.5)).abs() < 1e-6, "{g:?}"); // x̂=+1
    }

    #[test]
    fn maxpool_bwd_routes_to_first_max() {
        // 2x2 window with a tie: both 1.0 — first in scan order wins
        let x = [1.0f32, 1.0, 0.0, -1.0];
        let mut dx = [9.0f32; 4];
        maxpool_bwd_sample(&x, 2, 2, 1, 2, &[5.0], &mut dx);
        assert_eq!(dx, [5.0, 0.0, 0.0, 0.0]);
        // strict max elsewhere
        let x2 = [0.0f32, 1.0, 2.0, -1.0];
        let mut dx2 = [0.0f32; 4];
        maxpool_bwd_sample(&x2, 2, 2, 1, 2, &[3.0], &mut dx2);
        assert_eq!(dx2, [0.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn scatter_patch_inverts_gather() {
        // gradient identity: scatter(gather-mask) accumulates each pixel
        // once per window it appears in
        let (h, w, cin, k, pad) = (4usize, 4usize, 2usize, 3usize, 1usize);
        let mut rng = Prng::new(19);
        let sample: Vec<f32> = (0..h * w * cin).map(|_| rng.normal_f32()).collect();
        let mut patch = vec![0.0f32; k * k * cin];
        let mut dx = vec![0.0f32; h * w * cin];
        let mut counts = vec![0.0f32; h * w * cin];
        for oy in 0..h {
            for ox in 0..w {
                gather_patch(&sample, h, w, cin, k, pad, oy, ox, &mut patch);
                // dpatch = patch: scatter accumulates v · (#windows covering)
                scatter_patch_add(&patch, h, w, cin, k, pad, oy, ox, &mut dx);
                let ones = vec![1.0f32; k * k * cin];
                scatter_patch_add(&ones, h, w, cin, k, pad, oy, ox, &mut counts);
            }
        }
        for i in 0..dx.len() {
            assert!(
                (dx[i] - sample[i] * counts[i]).abs() < 1e-4,
                "pixel {i}: {} vs {}",
                dx[i],
                sample[i] * counts[i]
            );
        }
    }
}
