//! Native gated-XNOR CPU inference engine (the paper's Section 3.C,
//! executed instead of merely analyzed).
//!
//! The engine runs the forward pass directly in the packed domain: hidden
//! activations are quantized into bit planes (sign/nonzero for ternary and
//! binary; sign plus magnitude digit planes for the multi-level `Z_N`
//! spaces of eq. 2 / Fig. 13 — see `bitplane::PlaneSpec`), BatchNorm is
//! folded into per-channel thresholds at load time, and every Dense/Conv
//! layer whose operands are discrete evaluates via word-parallel
//! XNOR + popcount with the zero-state gate — words where either nonzero
//! plane is empty are skipped outright, and multi-level operands add a
//! short digit-plane-pair sum over the same word kernel. Binary and
//! ternary are the 0-/1-plane special cases, exactly the paper's
//! subsumption claim. Layers fed full-precision values (the input layer;
//! every layer under the `fp` activation modes) fall back to an
//! f64-accumulated scalar GEMM/conv so *every* Table 1 method **and every
//! `multi:N1,N2` space** runs natively and can be paritied against the
//! XLA infer graph.
//!
//! Shape propagation is driven by [`crate::nn::arch`]: the topology comes
//! from the named architecture with weighted-layer dimensions overridden
//! by the model's actual weight shapes (`arch_from_weights`), so
//! width-scaled artifacts work unchanged.
//!
//! Two things make the engine saturate the CPU instead of walking scalar
//! loops: **packed-domain im2col** — each sample's conv patches are packed
//! once into a reusable [`PackScratch`] bitplane pool and the whole patch
//! matrix fires through the same column-tiled XNOR+popcount+zero-skip
//! kernel dense layers use ([`bitplane::gated_packed_rows`]) — and
//! **multi-core batching**: `infer_batch` shards the batch by contiguous
//! sample range across scoped worker threads (`util::pool`), each with
//! its own [`ShardState`] scratch. Per-shard [`GateStats`] merge back in
//! shard order; every tally is an integer sum over disjoint samples, so
//! logits and merged stats are bit-identical for any thread count. The
//! per-pixel scalar conv walk survives as the cross-check oracle (and the
//! fp fallback): `NativeEngine::force_scalar_path`.
//!
//! While it runs, the engine tallies the gated operations that *actually*
//! fired per layer ([`GateStats`]); `hwsim::counts` cross-checks these
//! measured rates against the Table 2 analytical predictions.
//!
//! **Training** runs natively too: [`NativeTrainEngine`] is the
//! forward-with-cache + backward half of the paper's DST training loop —
//! train-mode BatchNorm (batch statistics, not the folded thresholds),
//! the rectangular-window straight-through derivative, and
//! ternary-operand backward GEMMs ([`backward`]) where the weight or
//! activation side streams as the same sign/nonzero bitplanes the
//! forward uses. Weight bitplanes are built **directly from the packed
//! 2-bit states** and rebuilt only when a DST update actually moved a
//! state, so the step loop never materializes an f32 weight tensor.

pub mod backward;
pub mod bitplane;

use anyhow::{anyhow, Result};

use crate::coordinator::checkpoint;
use crate::coordinator::method::Method;
use crate::nn::arch::{arch_from_weights, build_arch, geometry, param_descs, Arch, Layer};
use crate::nn::init::init_model;
use crate::nn::params::{ModelState, ParamKind, ParamValue};
use crate::runtime::exec::ExecEngine;
use crate::runtime::manifest::Manifest;
use crate::ternary::DiscreteSpace;
use crate::util::pool;
use crate::nn::params::ParamDesc;
use bitplane::{
    choose_strategy, gated_gemm_spec_with, gated_packed_rows_with, scalar_gemm, BitplaneCols,
    GateStats, KernelStrategy, PackScratch, PlaneSpec,
};

/// Must match `python/compile/model.py::BN_EPS` (parity depends on it).
const BN_EPS: f32 = 1e-4;

/// Must match `python/compile/model.py::BN_MOMENTUM` (running-stat EMA).
const BN_MOMENTUM: f32 = 0.9;

/// Minimum *average* samples per shard under auto threading
/// (`threads = 0`): workers are capped at `batch / MIN_AUTO_SHARD`, so a
/// shard carries enough forward work to amortize its scoped spawn/join
/// (~tens of µs; the ragged tail shard may run a couple of samples
/// short). Explicit thread counts bypass the floor.
const MIN_AUTO_SHARD: usize = 8;

/// Activation discretization mode (mirrors the lowered graphs').
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActMode {
    /// Full-precision activations (fp/bwn/twn baselines).
    Fp,
    /// sign(x) into {-1, +1} (BNN family).
    Bin,
    /// phi_r multi-step quantizer (GXNOR when hl = 1).
    Multi,
}

/// Per-channel ternarization rule with BatchNorm folded in. For the
/// ternary quantizer, `phi_r(z·s + b)` reduces to two thresholds on the
/// raw pre-activation z: with s > 0, q = +1 iff z > hi and q = -1 iff
/// z < lo where hi = (r − b)/s, lo = (−r − b)/s; s < 0 flips the
/// comparisons; s = 0 makes the channel constant.
#[derive(Clone, Copy, Debug)]
enum TernRule {
    Pos { hi: f32, lo: f32 },
    Neg { hi: f32, lo: f32 },
    Const(f32),
}

/// BatchNorm state folded at load time: y = z·scale + shift per channel,
/// plus the derived threshold rules for the ternary fast path.
struct BnFold {
    scale: Vec<f32>,
    shift: Vec<f32>,
    tern: Option<Vec<TernRule>>,
}

/// The linear op of one weighted layer.
#[derive(Clone, Copy, Debug)]
enum LinOp {
    Dense { m: usize, n: usize },
    Conv { k: usize, cin: usize, cout: usize, same: bool },
}

impl LinOp {
    fn fan_in(&self) -> usize {
        match *self {
            LinOp::Dense { m, .. } => m,
            LinOp::Conv { k, cin, .. } => k * k * cin,
        }
    }
}

/// One weighted layer, prepared for execution.
struct EngineLayer {
    name: String,
    op: LinOp,
    /// f32 grid values, (fan_in × out) row-major (HWIO flattens to this).
    w: Vec<f32>,
    /// Packed weight columns — present iff this layer runs the XNOR path.
    cols: Option<BitplaneCols>,
    bn: Option<BnFold>,
    w_zero_fraction: f64,
}

/// Per-layer report of the gated ops the engine actually executed.
#[derive(Clone, Debug)]
pub struct LayerGateReport {
    pub name: String,
    pub fan_in: usize,
    /// Zero-state fraction of this layer's packed weights.
    pub w_zero_fraction: f64,
    pub stats: GateStats,
    /// Kernel strategy for this layer: the forced one if
    /// [`NativeEngine::set_strategy`] pinned it, otherwise what the
    /// adaptive dispatch picks for the layer's measured mean activation
    /// occupancy. Derived from the merged stats, so it is identical for
    /// every thread count even though individual shards may have
    /// dispatched differently batch to batch.
    pub strategy: KernelStrategy,
}

/// Reusable conv patch-gather scratch (one k·k·cin f32 row). Sized lazily
/// per layer; capacity persists across `infer_batch` calls so the
/// steady-state conv walk allocates nothing (same allocate-once discipline
/// as the shard buffers).
#[derive(Default)]
struct ConvScratch {
    patch: Vec<f32>,
}

/// Everything one worker thread mutates while forwarding its sample
/// range: ping-pong activation buffers, conv patch gather scratch, the
/// packed-row pool, and this shard's per-layer gate tallies. One
/// `ShardState` per worker; capacity persists across `infer_batch` calls
/// so the steady-state forward allocates nothing on any thread.
struct ShardState {
    buf_a: Vec<f32>,
    buf_b: Vec<f32>,
    conv: ConvScratch,
    pack: PackScratch,
    gate: Vec<GateStats>,
}

impl ShardState {
    fn new(n_layers: usize) -> Self {
        ShardState {
            buf_a: Vec::new(),
            buf_b: Vec::new(),
            conv: ConvScratch::default(),
            pack: PackScratch::new(),
            gate: vec![GateStats::default(); n_layers],
        }
    }
}

/// The native backend: one network + one weight/BN snapshot. The batch is
/// sharded by contiguous sample range across `threads` scoped workers
/// (`util::pool`), each with its own [`ShardState`]; per-shard gate
/// tallies merge back in shard order, and because every tally is an
/// integer sum over disjoint samples, logits *and* merged [`GateStats`]
/// are bit-identical for any thread count.
pub struct NativeEngine {
    arch: Arch,
    mode: ActMode,
    r: f32,
    hl: f32,
    batch: usize,
    n_classes: usize,
    sample_len: usize,
    /// largest per-sample activation numel across the network
    max_sample_numel: usize,
    /// requested worker count; 0 = auto (see [`NativeEngine::set_threads`])
    threads: usize,
    /// bit-plane layout of the quantized activations (single-plane for
    /// binary/ternary, digit planes for multi-level spaces)
    act_spec: PlaneSpec,
    layers: Vec<EngineLayer>,
    /// merged tallies across shards and calls (exact: integer sums)
    gate: Vec<GateStats>,
    shards: Vec<ShardState>,
    logits: Vec<f32>,
    /// `Some(s)` pins every packed GEMM to one kernel strategy (bench
    /// A/Bs and the parity tests); `None` = adaptive per-shard dispatch
    /// from measured occupancy. All strategies are exact, so this only
    /// moves time, never bits.
    force_strategy: Option<KernelStrategy>,
}

impl NativeEngine {
    /// Build an engine from a trained (or freshly initialized) model.
    /// `arch_name` must be a catalogue architecture; its layer dimensions
    /// are overridden by the model's weight shapes. `threads` is the
    /// worker count `infer_batch` shards samples across (0 = auto, up to
    /// one per core); see [`NativeEngine::set_threads`].
    pub fn from_model(
        arch_name: &str,
        method: Method,
        model: &ModelState,
        r: f32,
        batch: usize,
        n_classes: usize,
        threads: usize,
    ) -> Result<NativeEngine> {
        if batch == 0 {
            return Err(anyhow!("native engine needs batch > 0"));
        }
        let weight_shapes: Vec<Vec<usize>> = model
            .descs
            .iter()
            .filter(|d| d.kind == ParamKind::Weight)
            .map(|d| d.shape.clone())
            .collect();
        let arch = arch_from_weights(arch_name, &weight_shapes).map_err(|e| anyhow!(e))?;
        let max_sample_numel = walk_dims(&arch, 1, n_classes)?;

        let mode = match method.graph_mode() {
            "fp" => ActMode::Fp,
            "bin" => ActMode::Bin,
            _ => ActMode::Multi,
        };
        let hl = method.hl();
        // every quantized activation packs: binary/ternary as sign + zero
        // gate, multi-level (hl > 1) as sign + magnitude digit planes —
        // only real-valued (fp-mode) activations stay un-packable
        let acts_packable = mode == ActMode::Bin || mode == ActMode::Multi;
        let act_spec = if mode == ActMode::Multi {
            PlaneSpec::for_levels(hl)
        } else {
            PlaneSpec::SINGLE
        };

        let weighted: Vec<Layer> = arch
            .layers
            .iter()
            .copied()
            .filter(|l| matches!(l, Layer::Conv { .. } | Layer::Dense { .. }))
            .collect();
        let geo = geometry(&arch);
        let n_w = weighted.len();
        let mut layers = Vec::with_capacity(n_w);
        let mut pi = 0usize; // cursor into model params (W, gamma, beta, ...)
        let mut si = 0usize; // cursor into bn_state (rmean, rvar, ...)
        for (li, l) in weighted.iter().enumerate() {
            let wdesc = model
                .descs
                .get(pi)
                .ok_or_else(|| anyhow!("model ends before weight of layer {li}"))?;
            if wdesc.kind != ParamKind::Weight {
                return Err(anyhow!(
                    "param order: expected weight at index {pi}, found {:?}",
                    wdesc.name
                ));
            }
            let wval = &model.values[pi];
            pi += 1;
            let op = match *l {
                Layer::Dense { din, dout } => LinOp::Dense { m: din, n: dout },
                Layer::Conv { cin, cout, k, same } => LinOp::Conv { k, cin, cout, same },
                _ => unreachable!(),
            };
            let (m, n) = match op {
                LinOp::Dense { m, n } => (m, n),
                LinOp::Conv { k, cin, cout, .. } => (k * k * cin, cout),
            };
            let w = wval.to_f32();
            if w.len() != m * n {
                return Err(anyhow!(
                    "weight {}: numel {} != {}x{}",
                    wdesc.name,
                    w.len(),
                    m,
                    n
                ));
            }
            // any discrete space packs: ternary/binary single-plane or
            // the multi-bitplane magnitude decomposition
            let (w_space, w_zero_fraction) = match wval {
                ParamValue::Discrete(p) => (Some(p.space()), p.zero_fraction()),
                ParamValue::Dense(_) => (None, 0.0),
            };
            let hidden = li + 1 < n_w;
            let bn = if hidden {
                if pi + 1 >= model.descs.len() {
                    return Err(anyhow!("model ends before BN params of layer {li}"));
                }
                let g_desc = &model.descs[pi];
                let b_desc = &model.descs[pi + 1];
                if g_desc.kind != ParamKind::Gamma || b_desc.kind != ParamKind::Beta {
                    return Err(anyhow!(
                        "param order: expected gamma/beta after {}, found {:?}/{:?}",
                        wdesc.name,
                        g_desc.name,
                        b_desc.name
                    ));
                }
                let gamma = model.values[pi].to_f32();
                let beta = model.values[pi + 1].to_f32();
                pi += 2;
                let rmean = model
                    .bn_state
                    .get(si)
                    .ok_or_else(|| anyhow!("missing rmean for layer {li}"))?;
                let rvar = model
                    .bn_state
                    .get(si + 1)
                    .ok_or_else(|| anyhow!("missing rvar for layer {li}"))?;
                si += 2;
                if gamma.len() != n || beta.len() != n || rmean.len() != n || rvar.len() != n {
                    return Err(anyhow!("BN shape mismatch at layer {li}"));
                }
                Some(make_bn_fold(&gamma, &beta, rmean, rvar, mode, r, hl))
            } else {
                None
            };
            // the first weighted layer always sees the raw (real-valued)
            // input, so only deeper layers can run in the packed domain
            let cols = match w_space {
                Some(space) if li > 0 && acts_packable => {
                    Some(BitplaneCols::pack_cols_space(&w, m, n, space))
                }
                _ => None,
            };
            layers.push(EngineLayer {
                name: geo[li].name.clone(),
                op,
                w,
                cols,
                bn,
                w_zero_fraction,
            });
        }

        let (ih, iw, ic) = arch.input;
        let sample_len = ih * iw * ic;
        Ok(NativeEngine {
            mode,
            r,
            hl,
            batch,
            n_classes,
            sample_len,
            max_sample_numel,
            threads,
            act_spec,
            gate: vec![GateStats::default(); layers.len()],
            layers,
            shards: Vec::new(),
            logits: vec![0.0; batch * n_classes],
            force_strategy: None,
            arch,
        })
    }

    /// Re-shard subsequent `infer_batch` calls across `threads` workers.
    /// 0 = auto: up to one per available core, capped so shards average
    /// at least [`MIN_AUTO_SHARD`] samples — scoped spawn/join must
    /// never dominate a tiny forward. An explicit count is honored
    /// exactly (the parity tests and the bench sweep rely on that). Safe
    /// to change between calls: logits and the merged [`GateStats`] are
    /// bit-identical for every value (pinned by the parity tests) —
    /// sharding only redistributes whole samples, and every tally is an
    /// integer sum over them.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads;
    }

    /// Worker count for a `b`-sample call under the current setting.
    fn effective_threads(&self, b: usize) -> usize {
        let cap = if self.threads == 0 {
            // floor division: shards average >= MIN_AUTO_SHARD samples
            // (b = 9 -> 1 worker, b = 17 -> 2 workers at 9 + 8)
            pool::resolve_threads(0).min((b / MIN_AUTO_SHARD).max(1))
        } else {
            self.threads
        };
        cap.min(b).max(1)
    }

    /// Strip the packed weight columns so every layer runs the scalar
    /// oracle path (per-pixel conv walk + f64-accumulated GEMM). This is
    /// the cross-check baseline for the im2col kernel tests — never
    /// faster, always exact.
    pub fn force_scalar_path(&mut self) {
        for l in self.layers.iter_mut() {
            l.cols = None;
        }
    }

    /// Strip packed columns from Conv layers only, leaving dense layers
    /// packed. The `perf` bench's conv A/B uses this arm so the measured
    /// speedup isolates the conv lowering (im2col vs per-pixel scalar)
    /// instead of folding in the unrelated dense-layer lowering.
    pub fn force_scalar_conv(&mut self) {
        for l in self.layers.iter_mut() {
            if matches!(l.op, LinOp::Conv { .. }) {
                l.cols = None;
            }
        }
    }

    /// Pin every packed GEMM to one kernel strategy, or restore adaptive
    /// per-shard dispatch with `None`. Lane, tile-skip and event-list all
    /// produce bit-identical logits and [`GateStats`] (pinned by the
    /// parity tests), so forcing a strategy is purely a performance /
    /// benchmarking knob.
    pub fn set_strategy(&mut self, strategy: Option<KernelStrategy>) {
        self.force_strategy = strategy;
    }

    /// Per-layer gated-op tallies for the XNOR-path layers, accumulated
    /// since construction or the last [`NativeEngine::reset_gate_stats`].
    pub fn gate_report(&self) -> Vec<LayerGateReport> {
        self.layers
            .iter()
            .zip(&self.gate)
            .filter(|(l, _)| l.cols.is_some())
            .map(|(l, g)| LayerGateReport {
                name: l.name.clone(),
                fan_in: l.op.fan_in(),
                w_zero_fraction: l.w_zero_fraction,
                stats: *g,
                strategy: self
                    .force_strategy
                    .unwrap_or_else(|| choose_strategy(1.0 - g.x_zero_fraction())),
            })
            .collect()
    }

    /// Merged gate tallies across all XNOR-path layers.
    pub fn total_gate_stats(&self) -> GateStats {
        let mut t = GateStats::default();
        for g in &self.gate {
            t.merge(g);
        }
        t
    }

    pub fn reset_gate_stats(&mut self) {
        self.gate.fill(GateStats::default());
    }

    /// Whether any layer runs the packed XNOR path (gxnor/bnn-style runs).
    pub fn has_packed_layers(&self) -> bool {
        self.layers.iter().any(|l| l.cols.is_some())
    }

    /// Grow the shard pool to `n_shards` workers whose ping-pong buffers
    /// hold `chunk` samples each (capacity only ever grows — changing the
    /// thread count between calls reuses what is already allocated).
    fn ensure_shards(&mut self, n_shards: usize, chunk: usize) {
        let need = chunk * self.max_sample_numel;
        while self.shards.len() < n_shards {
            self.shards.push(ShardState::new(self.layers.len()));
        }
        for sh in &mut self.shards[..n_shards] {
            if sh.buf_a.len() < need {
                sh.buf_a.resize(need, 0.0);
                sh.buf_b.resize(need, 0.0);
            }
        }
    }

    /// Run 1..=`self.batch` samples and return how many ran. The batch
    /// given at construction is a *capacity*, not a contract: the serving
    /// layer coalesces arrivals into whatever fill the SLO allowed, so a
    /// partial batch must run as-is. Per-sample independence (contiguous
    /// sample-range shards, no cross-sample op) makes the logits for a
    /// sample bit-identical regardless of how many neighbours ran with it
    /// — pinned by `tests/serve.rs`.
    fn forward(&mut self, x: &[f32]) -> Result<usize> {
        let sl = self.sample_len;
        if x.is_empty() || x.len() % sl != 0 {
            return Err(anyhow!(
                "native engine: input {} is not a positive multiple of sample_len {}",
                x.len(),
                sl
            ));
        }
        let b = x.len() / sl;
        if b > self.batch {
            return Err(anyhow!(
                "native engine: {} samples exceed construction batch {}",
                b,
                self.batch
            ));
        }
        // contiguous sample-range shards, at most one per worker thread;
        // each writes a disjoint logits slice with its own ShardState
        let t = self.effective_threads(b);
        let chunk = pool::shard_chunk(b, t);
        let n_shards = crate::util::div_ceil(b, chunk);
        self.ensure_shards(n_shards, chunk);
        for sh in self.shards[..n_shards].iter_mut() {
            sh.gate.fill(GateStats::default());
        }
        let layers = &self.layers;
        let arch = &self.arch;
        let (mode, r, hl) = (self.mode, self.r, self.hl);
        let spec = self.act_spec;
        let (nc, sl) = (self.n_classes, self.sample_len);
        let strat = self.force_strategy;
        let tasks: Vec<_> = x
            .chunks(chunk * sl)
            .zip(self.logits[..b * nc].chunks_mut(chunk * nc))
            .zip(self.shards[..n_shards].iter_mut())
            .map(|((xc, lc), shard)| {
                move || {
                    forward_range(
                        arch,
                        layers,
                        mode,
                        r,
                        hl,
                        spec,
                        strat,
                        xc,
                        xc.len() / sl,
                        lc,
                        shard,
                    )
                }
            })
            .collect();
        pool::scope_run(tasks);
        // deterministic merge: shard order × layer index, integer sums —
        // identical totals no matter how many workers ran
        for sh in &self.shards[..n_shards] {
            for (g, sg) in self.gate.iter_mut().zip(&sh.gate) {
                g.merge(sg);
            }
        }
        Ok(b)
    }

    /// Flattened per-sample input length (`h*w*c` of the arch input).
    pub fn sample_len(&self) -> usize {
        self.sample_len
    }
}

impl ExecEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn threads(&self) -> usize {
        self.effective_threads(self.batch)
    }

    /// Partial batches are native here: `x` may hold any 1..=`batch()`
    /// samples and the returned slice covers exactly the samples given.
    fn supports_partial_batch(&self) -> bool {
        true
    }

    fn infer_batch(&mut self, x: &[f32]) -> Result<&[f32]> {
        let b = self.forward(x)?;
        Ok(&self.logits[..b * self.n_classes])
    }
}

/// Build a native engine straight from the artifact manifest's metadata
/// and a checkpoint file — no PJRT device and no `Runtime` involved
/// (serving deployments that never link a real XLA backend use exactly
/// this). Param descriptors, batch size and class count come from the
/// arch's infer graph (same batch>16 preference as the trainer, so
/// accuracies are comparable); every weight/BN value comes from the
/// checkpoint.
pub fn native_engine_from_checkpoint(
    manifest: &Manifest,
    arch: &str,
    method: Method,
    r: f32,
    ckpt_path: &str,
    threads: usize,
) -> Result<NativeEngine> {
    let mode = method.graph_mode();
    let infer_g = manifest
        .graphs
        .iter()
        .find(|g| g.arch == arch && g.mode == mode && g.kind == "infer" && g.batch > 16)
        .or_else(|| {
            manifest
                .graphs
                .iter()
                .find(|g| g.arch == arch && g.mode == mode && g.kind == "infer")
        })
        .ok_or_else(|| anyhow!("no infer graph for arch={arch} mode={mode} in manifest"))?;
    let bn_names: Vec<String> = infer_g.bn_state.iter().map(|s| s.name.clone()).collect();
    let bn_shapes: Vec<usize> = infer_g.bn_state.iter().map(|s| s.numel()).collect();
    let space = method.weight_space().unwrap_or(DiscreteSpace::TERNARY);
    // seed is irrelevant: restore() replaces every tensor or errors out
    let mut model = init_model(infer_g.params.clone(), bn_names, &bn_shapes, space, 0);
    checkpoint::load(&mut model, ckpt_path).map_err(|e| anyhow!(e))?;
    NativeEngine::from_model(arch, method, &model, r, infer_g.batch, infer_g.n_classes, threads)
}

/// Assemble a `(ModelState, n_classes)` pair for device-free serving and
/// eval without *requiring* lowered artifacts. Parameter descriptors come
/// from the manifest's infer graph when one is available (same batch>16
/// preference as [`native_engine_from_checkpoint`], so shapes match what
/// the trainer produced) and from the catalogue architecture otherwise;
/// tensor values come from the checkpoint when a path is given, else a
/// seeded fresh init — the latter is only meaningful for latency benching,
/// where logits are exercised but never inspected for accuracy. The
/// serving replica pool builds one [`NativeEngine::from_model`] per
/// replica from the returned state.
pub fn model_from_checkpoint_or_init(
    manifest: Option<&Manifest>,
    arch: &str,
    method: Method,
    ckpt_path: Option<&str>,
    seed: u64,
) -> Result<(ModelState, usize)> {
    let mode = method.graph_mode();
    let space = method.weight_space().unwrap_or(DiscreteSpace::TERNARY);
    let infer_g = manifest.and_then(|m| {
        m.graphs
            .iter()
            .find(|g| g.arch == arch && g.mode == mode && g.kind == "infer" && g.batch > 16)
            .or_else(|| {
                m.graphs
                    .iter()
                    .find(|g| g.arch == arch && g.mode == mode && g.kind == "infer")
            })
    });
    let (descs, bn_names, bn_shapes, n_classes) = match infer_g {
        Some(g) => (
            g.params.clone(),
            g.bn_state.iter().map(|s| s.name.clone()).collect::<Vec<String>>(),
            g.bn_state.iter().map(|s| s.numel()).collect::<Vec<usize>>(),
            g.n_classes,
        ),
        None => {
            let a = build_arch(arch).map_err(|e| anyhow!(e))?;
            let (descs, bn_names, bn_shapes) = param_descs(&a);
            // catalogue archs all end in a 10-way classifier (MNIST/CIFAR
            // label space), same fallback the native trainer uses
            (descs, bn_names, bn_shapes, 10)
        }
    };
    let mut model = init_model(descs, bn_names, &bn_shapes, space, seed);
    if let Some(p) = ckpt_path {
        checkpoint::load(&mut model, p).map_err(|e| anyhow!(e))?;
    }
    Ok((model, n_classes))
}

/// Validate the shape walk and return the largest per-batch activation
/// numel (buffer sizing).
fn walk_dims(arch: &Arch, batch: usize, n_classes: usize) -> Result<usize> {
    let (mut h, mut w, mut c) = arch.input;
    let mut max_numel = batch * h * w * c;
    for (li, l) in arch.layers.iter().enumerate() {
        match *l {
            Layer::Conv { cin, cout, k, same } => {
                if c != cin {
                    return Err(anyhow!("layer {li}: conv expects {cin} channels, got {c}"));
                }
                if !same && (h < k || w < k) {
                    return Err(anyhow!("layer {li}: {h}x{w} input below {k}x{k} kernel"));
                }
                let (oh, ow) = if same { (h, w) } else { (h - k + 1, w - k + 1) };
                h = oh;
                w = ow;
                c = cout;
            }
            Layer::Pool { size } => {
                h /= size;
                w /= size;
            }
            Layer::Flatten => {
                c = h * w * c;
                h = 1;
                w = 1;
            }
            Layer::Dense { din, dout } => {
                if h * w * c != din {
                    return Err(anyhow!(
                        "layer {li}: dense expects {din} inputs, got {}",
                        h * w * c
                    ));
                }
                h = 1;
                w = 1;
                c = dout;
            }
        }
        max_numel = max_numel.max(batch * h * w * c);
    }
    if h != 1 || w != 1 || c != n_classes {
        return Err(anyhow!("network output {h}x{w}x{c} != {n_classes} classes"));
    }
    Ok(max_numel)
}

/// Forward one contiguous sample range through the whole network into its
/// disjoint logits slice. This is the per-worker body `infer_batch`
/// shards: everything it mutates lives in `shard` or `logits`, so shards
/// never contend. `x` holds `b` samples; `logits` holds exactly
/// `b × n_classes` floats. Shapes were validated at construction
/// (`walk_dims`), so the walk itself is infallible.
#[allow(clippy::too_many_arguments)]
fn forward_range(
    arch: &Arch,
    layers: &[EngineLayer],
    mode: ActMode,
    r: f32,
    hl: f32,
    act_spec: PlaneSpec,
    strategy: Option<KernelStrategy>,
    x: &[f32],
    b: usize,
    logits: &mut [f32],
    shard: &mut ShardState,
) {
    let mut cur = std::mem::take(&mut shard.buf_a);
    let mut nxt = std::mem::take(&mut shard.buf_b);
    cur[..x.len()].copy_from_slice(x);
    let (mut h, mut w, mut c) = arch.input;
    let mut wi = 0usize;
    for li in 0..arch.layers.len() {
        match arch.layers[li] {
            Layer::Pool { size } => {
                let (oh, ow) = (h / size, w / size);
                let out = &mut nxt[..b * oh * ow * c];
                maxpool(&cur[..b * h * w * c], b, h, w, c, size, out);
                std::mem::swap(&mut cur, &mut nxt);
                h = oh;
                w = ow;
            }
            Layer::Flatten => {
                // NHWC is already contiguous per sample: pure reshape
                c = h * w * c;
                h = 1;
                w = 1;
            }
            Layer::Conv { .. } | Layer::Dense { .. } => {
                let el = &layers[wi];
                let (oh, ow, oc) = run_linear(
                    el,
                    &cur[..b * h * w * c],
                    b,
                    h,
                    w,
                    c,
                    act_spec,
                    strategy,
                    &mut nxt,
                    &mut shard.gate[wi],
                    &mut shard.conv,
                    &mut shard.pack,
                );
                std::mem::swap(&mut cur, &mut nxt);
                h = oh;
                w = ow;
                c = oc;
                if let Some(bn) = &el.bn {
                    bn_quantize(&mut cur[..b * h * w * c], c, bn, mode, r, hl);
                }
                wi += 1;
            }
        }
    }
    logits.copy_from_slice(&cur[..logits.len()]);
    shard.buf_a = cur;
    shard.buf_b = nxt;
}

/// Execute one weighted layer; returns the output (h, w, c).
#[allow(clippy::too_many_arguments)]
fn run_linear(
    el: &EngineLayer,
    cur: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    act_spec: PlaneSpec,
    strategy: Option<KernelStrategy>,
    nxt: &mut [f32],
    stats: &mut GateStats,
    conv: &mut ConvScratch,
    pack: &mut PackScratch,
) -> (usize, usize, usize) {
    match el.op {
        LinOp::Dense { m, n } => {
            debug_assert_eq!(h * w * c, m);
            if let Some(cols) = &el.cols {
                gated_gemm_spec_with(
                    cur,
                    b,
                    act_spec,
                    cols,
                    &mut nxt[..b * n],
                    stats,
                    pack,
                    strategy,
                );
            } else {
                scalar_gemm(cur, b, &el.w, m, n, &mut nxt[..b * n]);
            }
            (1, 1, n)
        }
        LinOp::Conv { k, cin, cout, same } => {
            debug_assert_eq!(c, cin);
            let pad = if same { (k - 1) / 2 } else { 0 };
            let (oh, ow) = if same { (h, w) } else { (h - k + 1, w - k + 1) };
            let m = k * k * cin;
            conv.patch.resize(m, 0.0);
            if let Some(cols) = &el.cols {
                // packed-domain im2col: pack every patch of a sample once
                // into the reusable bitplane scratch (one row per output
                // pixel), then fire the whole patch matrix through the
                // tiled XNOR kernel — conv becomes the same GEMM dense
                // layers run, weight bitplanes streamed tile by tile
                let rows = oh * ow;
                for s in 0..b {
                    let sample = &cur[s * h * w * cin..(s + 1) * h * w * cin];
                    pack.reset_spec(rows, m, act_spec);
                    for oy in 0..oh {
                        for ox in 0..ow {
                            gather_patch(sample, h, w, cin, k, pad, oy, ox, &mut conv.patch);
                            pack.set_row(oy * ow + ox, &conv.patch);
                        }
                    }
                    // NHWC output: row = pixel, col = channel — exactly the
                    // GEMM's (rows × cout) layout, written in place
                    let out = &mut nxt[s * rows * cout..(s + 1) * rows * cout];
                    gated_packed_rows_with(pack, cols, out, stats, strategy);
                }
            } else {
                // scalar oracle walk (also the fp / first-layer fallback)
                for s in 0..b {
                    let sample = &cur[s * h * w * cin..(s + 1) * h * w * cin];
                    for oy in 0..oh {
                        for ox in 0..ow {
                            gather_patch(sample, h, w, cin, k, pad, oy, ox, &mut conv.patch);
                            let base = ((s * oh + oy) * ow + ox) * cout;
                            let out = &mut nxt[base..base + cout];
                            scalar_gemm(&conv.patch, 1, &el.w, m, cout, out);
                        }
                    }
                }
            }
            (oh, ow, cout)
        }
    }
}

/// Gather one k×k×cin patch (NHWC, zero padding) into `out` in HWIO row
/// order, matching the flattened weight layout.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gather_patch(
    sample: &[f32],
    h: usize,
    w: usize,
    cin: usize,
    k: usize,
    pad: usize,
    oy: usize,
    ox: usize,
    out: &mut [f32],
) {
    let mut idx = 0usize;
    for ky in 0..k {
        let iy = oy as isize + ky as isize - pad as isize;
        for kx in 0..k {
            let ix = ox as isize + kx as isize - pad as isize;
            if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                out[idx..idx + cin].fill(0.0);
            } else {
                let base = ((iy as usize) * w + ix as usize) * cin;
                out[idx..idx + cin].copy_from_slice(&sample[base..base + cin]);
            }
            idx += cin;
        }
    }
}

/// Max-pool size×size, stride = size, NHWC.
fn maxpool(inp: &[f32], b: usize, h: usize, w: usize, c: usize, size: usize, out: &mut [f32]) {
    let (oh, ow) = (h / size, w / size);
    for s in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    for ky in 0..size {
                        for kx in 0..size {
                            let v = inp[((s * h + oy * size + ky) * w + ox * size + kx) * c + ch];
                            if v > m {
                                m = v;
                            }
                        }
                    }
                    out[((s * oh + oy) * ow + ox) * c + ch] = m;
                }
            }
        }
    }
}

/// Fold BN running state + affine into per-channel scale/shift, and into
/// direct pre-activation thresholds for the ternary quantizer.
fn make_bn_fold(
    gamma: &[f32],
    beta: &[f32],
    rmean: &[f32],
    rvar: &[f32],
    mode: ActMode,
    r: f32,
    hl: f32,
) -> BnFold {
    let n = gamma.len();
    let mut scale = vec![0.0f32; n];
    let mut shift = vec![0.0f32; n];
    for ch in 0..n {
        let s = gamma[ch] / (rvar[ch] + BN_EPS).sqrt();
        scale[ch] = s;
        shift[ch] = beta[ch] - rmean[ch] * s;
    }
    let tern = (mode == ActMode::Multi && hl == 1.0).then(|| {
        (0..n)
            .map(|ch| {
                let s = scale[ch];
                let b = shift[ch];
                if s > 0.0 {
                    TernRule::Pos { hi: (r - b) / s, lo: (-r - b) / s }
                } else if s < 0.0 {
                    TernRule::Neg { hi: (r - b) / s, lo: (-r - b) / s }
                } else {
                    TernRule::Const(phi_multi(b, r, 1.0))
                }
            })
            .collect()
    });
    BnFold { scale, shift, tern }
}

/// The multi-step quantizer phi_r (eq. 22; eq. 5 when hl = 1), matching
/// `python/compile/kernels/ref.py::quantize_fwd`.
fn phi_multi(y: f32, r: f32, hl: f32) -> f32 {
    let step = (1.0 - r) / hl;
    let mag = (((y.abs() - r) / step).ceil()).clamp(0.0, hl) / hl;
    if y > 0.0 {
        mag
    } else if y < 0.0 {
        -mag
    } else {
        0.0
    }
}

/// Apply folded BN + activation quantization in place over a channel-last
/// tensor. Ternary channels use the pre-computed threshold rules (no
/// affine evaluation at all); other modes evaluate y = z·scale + shift.
/// Rows are walked with `chunks_exact_mut` so the channel lookup is a zip,
/// not a per-element div/mod — this runs over every hidden activation.
fn bn_quantize(z: &mut [f32], channels: usize, bn: &BnFold, mode: ActMode, r: f32, hl: f32) {
    debug_assert_eq!(z.len() % channels, 0);
    if let Some(rules) = &bn.tern {
        for row in z.chunks_exact_mut(channels) {
            for (v, rule) in row.iter_mut().zip(rules) {
                *v = match *rule {
                    TernRule::Pos { hi, lo } => {
                        if *v > hi {
                            1.0
                        } else if *v < lo {
                            -1.0
                        } else {
                            0.0
                        }
                    }
                    TernRule::Neg { hi, lo } => {
                        if *v < hi {
                            1.0
                        } else if *v > lo {
                            -1.0
                        } else {
                            0.0
                        }
                    }
                    TernRule::Const(q) => q,
                };
            }
        }
        return;
    }
    for row in z.chunks_exact_mut(channels) {
        for ((v, &s), &sh) in row.iter_mut().zip(&bn.scale).zip(&bn.shift) {
            let y = *v * s + sh;
            *v = match mode {
                ActMode::Fp => y,
                ActMode::Bin => {
                    if y >= 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                }
                ActMode::Multi => phi_multi(y, r, hl),
            };
        }
    }
}

// ===========================================================================
// Native training engine: forward-with-cache + ternary-operand backward
// ===========================================================================

/// Contiguous index ranges covering `n` items, at most one per resolved
/// worker. Used by every phase of the training engine; because each
/// output element is owned by exactly one range and computed in a fixed
/// iteration order, the *results* never depend on how many ranges this
/// returns — sharding is purely a throughput knob.
fn shard_ranges(n: usize, threads: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let chunk = shard_len(n, threads);
    (0..n).step_by(chunk).map(|s| (s, (s + chunk).min(n))).collect()
}

/// Items per shard for `n` items on the resolved worker count — the
/// chunk length fed to `chunks`/`chunks_mut` when building task lists.
fn shard_len(n: usize, threads: usize) -> usize {
    let t = pool::resolve_threads(threads).min(n.max(1));
    pool::shard_chunk(n, t)
}

/// Dense (f32) parameter slice, or a descriptive error.
fn dense_param(model: &crate::nn::params::ModelState, idx: usize) -> Result<&[f32]> {
    match &model.values[idx] {
        ParamValue::Dense(v) => Ok(v),
        ParamValue::Discrete(_) => Err(anyhow!("param {idx}: expected dense f32 values")),
    }
}

/// One weighted layer of the training engine. The weight itself lives in
/// the trainer's `ModelState` (packed 2-bit states for discrete methods,
/// dense f32 for the fp baseline); the engine holds only the derived
/// bitplanes, rebuilt when a DST update actually moved a state.
struct TrainLayer {
    name: String,
    op: LinOp,
    /// index of this arch layer in `arch.layers`
    arch_idx: usize,
    /// param index of the weight tensor
    w_param: usize,
    /// param index of gamma (beta = gamma + 1); hidden layers only
    gamma_param: Option<usize>,
    /// weights live on a discrete Z_N grid (bitplane-packable; N >= 2
    /// spaces use the multi-bitplane magnitude decomposition)
    w_discrete: bool,
    /// weight columns over fan-in lanes — forward operand
    cols: Option<BitplaneCols>,
    /// weight rows over output-channel lanes — `dX = dY·Wᵀ` operand
    wrows: Option<BitplaneCols>,
    /// this layer's GEMM input rows are packed ternary activations
    acts_packed: bool,
}

/// Per-weighted-layer forward cache: everything backprop needs.
#[derive(Default)]
struct WCache {
    /// linear output (pre-BN), GEMM rows × out channels
    z: Vec<f32>,
    /// BN output (pre-quantization) — the rectangular window's argument
    y: Vec<f32>,
    /// train-mode batch statistics (masked to the valid rows)
    mean: Vec<f32>,
    var: Vec<f32>,
    inv_std: Vec<f32>,
    /// packed GEMM input rows (dense rows / conv im2col patches) — the
    /// ternary operand `dW = Xᵀ·dY` streams, packed once in the forward
    x_pack: PackScratch,
    /// f32 im2col patch matrix for conv layers fed real-valued inputs
    x_patches: Vec<f32>,
}

/// Forward activations retained for the backward pass.
struct TrainCache {
    /// copy of the batch input (valid rows)
    xin: Vec<f32>,
    /// output activation of every arch layer (post-quant for hidden
    /// weighted layers, raw logits for the last, pooled/flattened maps
    /// for the rest), valid rows × numel
    acts: Vec<Vec<f32>>,
    wl: Vec<WCache>,
    /// per-hidden-layer zero-activation fraction of this step
    spars: Vec<f32>,
}

/// The native DST training engine: train-mode forward with cache plus
/// ternary-operand backward, no PJRT boundary and no f32 weight tensor
/// anywhere in the step loop.
///
/// **Determinism:** every parallel phase shards *output ownership* —
/// logits/activations by sample range, `dW` rows by fan-in word range,
/// BN reductions by channel range — and each owner accumulates in a
/// fixed (global batch-row) order with no cross-worker floating-point
/// reduction anywhere. Gradients, loss, BN statistics and therefore DST
/// transitions are bit-identical for **any** thread count, including
/// `threads = 0` (auto); the shard layout is invisible by construction,
/// not by tolerance. Pinned by `tests/train_native.rs`.
pub struct NativeTrainEngine {
    arch: Arch,
    mode: ActMode,
    r: f32,
    a: f32,
    hl: f32,
    /// bit-plane layout of the quantized activations (digit planes for
    /// multi-level spaces; see [`PlaneSpec`])
    act_spec: PlaneSpec,
    batch: usize,
    n_classes: usize,
    sample_len: usize,
    threads: usize,
    n_params: usize,
    wl: Vec<TrainLayer>,
    /// output (h, w, c) of every arch layer
    dims: Vec<(usize, usize, usize)>,
    cache: TrainCache,
    gbuf_a: Vec<f32>,
    gbuf_b: Vec<f32>,
    /// f64 gradient accumulator for the largest weight tensor
    dw64: Vec<f64>,
    /// step outputs, graph-layout: [loss, ncorrect, spars, grads…, bn…]
    outs: Vec<Vec<f32>>,
    /// weight-bitplane rebuilds since construction (excludes the initial
    /// packs) — the repack-skip satellite's counter: must stay ≤ the
    /// number of DST updates that actually moved a state
    repack_count: u64,
}

impl NativeTrainEngine {
    /// Build a training engine for `arch_name` with layer dimensions
    /// taken from the weight shapes in `descs` (manifest params or
    /// [`crate::nn::arch::param_descs`]). Weight *values* are not needed
    /// here — bitplanes are built lazily from the model on the first
    /// step (every tensor starts dirty).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        arch_name: &str,
        method: Method,
        descs: &[ParamDesc],
        batch: usize,
        n_classes: usize,
        r: f32,
        a: f32,
        threads: usize,
    ) -> Result<NativeTrainEngine> {
        if batch == 0 {
            return Err(anyhow!("native training engine needs batch > 0"));
        }
        let weight_shapes: Vec<Vec<usize>> = descs
            .iter()
            .filter(|d| d.kind == ParamKind::Weight)
            .map(|d| d.shape.clone())
            .collect();
        let arch = arch_from_weights(arch_name, &weight_shapes).map_err(|e| anyhow!(e))?;
        let mode = match method.graph_mode() {
            "fp" => ActMode::Fp,
            "bin" => ActMode::Bin,
            _ => ActMode::Multi,
        };
        let hl = method.hl();
        let w_discrete = method.weight_space().is_some();
        // binary/ternary *and* multi-level quantized activations pack;
        // only fp-mode (real-valued) activations stay un-packable
        let acts_packable = mode == ActMode::Bin || mode == ActMode::Multi;
        let act_spec = if mode == ActMode::Multi {
            PlaneSpec::for_levels(hl)
        } else {
            PlaneSpec::SINGLE
        };

        // dims walk (and shape validation) over the arch layers
        let (mut h, mut w, mut c) = arch.input;
        let sample_len = h * w * c;
        let mut dims = Vec::with_capacity(arch.layers.len());
        let mut max_numel = sample_len;
        for (li, l) in arch.layers.iter().enumerate() {
            match *l {
                Layer::Conv { cin, cout, k, same } => {
                    if c != cin {
                        return Err(anyhow!("layer {li}: conv expects {cin} channels, got {c}"));
                    }
                    if !same && (h < k || w < k) {
                        return Err(anyhow!("layer {li}: {h}x{w} input below {k}x{k} kernel"));
                    }
                    let (oh, ow) = if same { (h, w) } else { (h - k + 1, w - k + 1) };
                    h = oh;
                    w = ow;
                    c = cout;
                }
                Layer::Pool { size } => {
                    h /= size;
                    w /= size;
                }
                Layer::Flatten => {
                    c = h * w * c;
                    h = 1;
                    w = 1;
                }
                Layer::Dense { din, dout } => {
                    if h * w * c != din {
                        return Err(anyhow!(
                            "layer {li}: dense expects {din} inputs, got {}",
                            h * w * c
                        ));
                    }
                    h = 1;
                    w = 1;
                    c = dout;
                }
            }
            dims.push((h, w, c));
            max_numel = max_numel.max(h * w * c);
        }
        if h != 1 || w != 1 || c != n_classes {
            return Err(anyhow!("network output {h}x{w}x{c} != {n_classes} classes"));
        }

        // weighted-layer metadata + param-order validation
        let geo = geometry(&arch);
        let weighted: Vec<(usize, Layer)> = arch
            .layers
            .iter()
            .enumerate()
            .filter(|(_, l)| matches!(l, Layer::Conv { .. } | Layer::Dense { .. }))
            .map(|(i, l)| (i, *l))
            .collect();
        let n_w = weighted.len();
        if n_w == 0 {
            return Err(anyhow!("arch {arch_name} has no weighted layers"));
        }
        let mut wl = Vec::with_capacity(n_w);
        let mut pi = 0usize;
        for (li, (arch_idx, l)) in weighted.iter().enumerate() {
            let wdesc = descs
                .get(pi)
                .ok_or_else(|| anyhow!("param list ends before weight of layer {li}"))?;
            if wdesc.kind != ParamKind::Weight {
                return Err(anyhow!(
                    "param order: expected weight at index {pi}, found {:?}",
                    wdesc.name
                ));
            }
            let w_param = pi;
            pi += 1;
            let op = match *l {
                Layer::Dense { din, dout } => LinOp::Dense { m: din, n: dout },
                Layer::Conv { cin, cout, k, same } => LinOp::Conv { k, cin, cout, same },
                _ => unreachable!(),
            };
            let n_out = match op {
                LinOp::Dense { n, .. } => n,
                LinOp::Conv { cout, .. } => cout,
            };
            let hidden = li + 1 < n_w;
            let gamma_param = if hidden {
                let g = descs
                    .get(pi)
                    .ok_or_else(|| anyhow!("param list ends before gamma of layer {li}"))?;
                let b = descs
                    .get(pi + 1)
                    .ok_or_else(|| anyhow!("param list ends before beta of layer {li}"))?;
                if g.kind != ParamKind::Gamma || b.kind != ParamKind::Beta {
                    return Err(anyhow!(
                        "param order: expected gamma/beta after {:?}, found {:?}/{:?}",
                        wdesc.name,
                        g.name,
                        b.name
                    ));
                }
                if g.numel() != n_out || b.numel() != n_out {
                    return Err(anyhow!("BN shape mismatch at layer {li}"));
                }
                let gp = pi;
                pi += 2;
                Some(gp)
            } else {
                None
            };
            wl.push(TrainLayer {
                name: geo[li].name.clone(),
                op,
                arch_idx: *arch_idx,
                w_param,
                gamma_param,
                w_discrete,
                cols: None,
                wrows: None,
                acts_packed: *arch_idx > 0 && w_discrete && acts_packable,
            });
        }
        if pi != descs.len() {
            return Err(anyhow!(
                "arch {arch_name} uses {pi} params, descriptor list has {}",
                descs.len()
            ));
        }
        let n_params = descs.len();
        let n_hidden = n_w - 1;

        // cache + output buffers, allocated once
        let acts: Vec<Vec<f32>> = dims
            .iter()
            .map(|&(h, w, c)| vec![0.0f32; batch * h * w * c])
            .collect();
        let wcaches: Vec<WCache> = wl
            .iter()
            .map(|l| {
                let (oh, ow, oc) = dims[l.arch_idx];
                let out_numel = batch * oh * ow * oc;
                let bn_ch = if l.gamma_param.is_some() { oc } else { 0 };
                WCache {
                    z: vec![0.0; out_numel],
                    y: vec![0.0; if bn_ch > 0 { out_numel } else { 0 }],
                    mean: vec![0.0; bn_ch],
                    var: vec![0.0; bn_ch],
                    inv_std: vec![0.0; bn_ch],
                    x_pack: PackScratch::new(),
                    x_patches: Vec::new(),
                }
            })
            .collect();
        let mut outs: Vec<Vec<f32>> = Vec::with_capacity(3 + n_params + 2 * n_hidden);
        outs.push(vec![0.0]); // loss
        outs.push(vec![0.0]); // ncorrect
        outs.push(vec![0.0; n_hidden]); // sparsity per hidden layer
        for d in descs {
            outs.push(vec![0.0; d.numel()]);
        }
        for l in &wl {
            if let Some(gp) = l.gamma_param {
                let ch = descs[gp].numel();
                outs.push(vec![0.0; ch]); // new rmean
                outs.push(vec![0.0; ch]); // new rvar
            }
        }
        let max_w_numel = descs
            .iter()
            .filter(|d| d.kind == ParamKind::Weight)
            .map(|d| d.numel())
            .max()
            .unwrap_or(0);

        Ok(NativeTrainEngine {
            mode,
            r,
            a,
            hl,
            act_spec,
            batch,
            n_classes,
            sample_len,
            threads,
            n_params,
            cache: TrainCache {
                xin: vec![0.0; batch * sample_len],
                acts,
                wl: wcaches,
                spars: vec![0.0; n_hidden],
            },
            gbuf_a: vec![0.0; batch * max_numel],
            gbuf_b: vec![0.0; batch * max_numel],
            dw64: vec![0.0; max_w_numel],
            outs,
            repack_count: 0,
            wl,
            dims,
            arch,
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    pub fn sample_len(&self) -> usize {
        self.sample_len
    }

    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads;
    }

    /// Weight-bitplane rebuilds since construction, *excluding* the
    /// initial packs. The repack-skip invariant — repacks ≤ DST updates
    /// that moved a state — is asserted over this counter in the tests.
    pub fn repack_count(&self) -> u64 {
        self.repack_count
    }

    /// Bytes held by the derived weight bitplanes (sign/nz planes for the
    /// forward and dX operands) — the engine's entire weight-side
    /// footprint beyond the trainer's packed 2-bit states.
    pub fn bitplane_bytes(&self) -> usize {
        self.wl
            .iter()
            .map(|l| {
                l.cols.as_ref().map_or(0, |c| c.plane_bytes())
                    + l.wrows.as_ref().map_or(0, |c| c.plane_bytes())
            })
            .sum()
    }

    /// Number of step outputs and their layout, mirroring the lowered
    /// train graph: `[loss, ncorrect, sparsity, grads…, new_bn_state…]`.
    pub fn n_outputs(&self) -> usize {
        self.outs.len()
    }

    /// One full training forward+backward on the first `valid` samples of
    /// `x`. `dirty[i]` marks weight params whose packed states changed
    /// since the engine last saw them (DST transitions > 0); only those
    /// get their bitplanes rebuilt — the repack-skip satellite — and the
    /// flag is cleared here. Rows ≥ `valid` (prefetcher padding) are
    /// never read: they contribute nothing to loss, gradients or BN
    /// statistics, so a padded partial batch trains exactly like a batch
    /// of `valid` samples.
    pub fn step(
        &mut self,
        x: &[f32],
        labels: &[i32],
        valid: usize,
        model: &crate::nn::params::ModelState,
        dirty: &mut [bool],
    ) -> Result<&[Vec<f32>]> {
        if valid == 0 || valid > self.batch {
            return Err(anyhow!("valid rows {valid} outside 1..={}", self.batch));
        }
        if x.len() < valid * self.sample_len {
            return Err(anyhow!(
                "batch input {} floats < {valid}x{}",
                x.len(),
                self.sample_len
            ));
        }
        if labels.len() < valid {
            return Err(anyhow!("labels {} < valid rows {valid}", labels.len()));
        }
        if model.values.len() != self.n_params || dirty.len() != self.n_params {
            return Err(anyhow!("model/dirty param count mismatch"));
        }
        let n_hidden = self.wl.len() - 1;
        if model.bn_state.len() != 2 * n_hidden {
            return Err(anyhow!(
                "model carries {} BN state tensors, arch needs {}",
                model.bn_state.len(),
                2 * n_hidden
            ));
        }
        self.refresh_weight_planes(model, dirty)?;
        self.forward_cached(x, valid, model)?;
        self.backward(labels, valid, model)?;
        Ok(&self.outs)
    }

    /// Rebuild the bitplanes of dirty ternary weight tensors straight
    /// from their packed states (no f32 expansion anywhere).
    fn refresh_weight_planes(
        &mut self,
        model: &crate::nn::params::ModelState,
        dirty: &mut [bool],
    ) -> Result<()> {
        for l in self.wl.iter_mut() {
            if !l.w_discrete || !dirty[l.w_param] {
                continue;
            }
            let (m, n) = match l.op {
                LinOp::Dense { m, n } => (m, n),
                LinOp::Conv { k, cin, cout, .. } => (k * k * cin, cout),
            };
            let packed = match &model.values[l.w_param] {
                ParamValue::Discrete(p) => p,
                ParamValue::Dense(_) => {
                    return Err(anyhow!("{}: discrete method with dense weights", l.name))
                }
            };
            if packed.len() != m * n {
                return Err(anyhow!("{}: weight numel {} != {m}x{n}", l.name, packed.len()));
            }
            let had = l.cols.is_some();
            l.cols = Some(BitplaneCols::pack_cols_from_packed(packed, m, n));
            l.wrows = Some(BitplaneCols::pack_rows_from_packed(packed, m, n));
            if had {
                self.repack_count += 1;
            }
            dirty[l.w_param] = false;
        }
        Ok(())
    }

    /// Train-mode forward over the valid rows, retaining everything the
    /// backward pass needs: per-layer activations, pre-BN `z`, pre-quant
    /// `y` (the rectangular window's argument), masked batch statistics,
    /// and the packed activation planes that become `dW`'s ternary
    /// operand.
    fn forward_cached(
        &mut self,
        x: &[f32],
        valid: usize,
        model: &crate::nn::params::ModelState,
    ) -> Result<()> {
        let threads = self.threads;
        let (mode, r, hl) = (self.mode, self.r, self.hl);
        let act_spec = self.act_spec;
        let sl = self.sample_len;
        let TrainCache { xin, acts, wl: wcaches, spars } = &mut self.cache;
        xin[..valid * sl].copy_from_slice(&x[..valid * sl]);
        let mut wi = 0usize;
        for li in 0..self.arch.layers.len() {
            let (in_h, in_w, in_c) = if li == 0 { self.arch.input } else { self.dims[li - 1] };
            let in_numel = in_h * in_w * in_c;
            let (prev, rest) = acts.split_at_mut(li);
            let cur = &mut rest[0];
            let xs: &[f32] = if li == 0 {
                &xin[..valid * in_numel]
            } else {
                &prev[li - 1][..valid * in_numel]
            };
            match self.arch.layers[li] {
                Layer::Pool { size } => {
                    let (oh, ow, oc) = self.dims[li];
                    let out_n = oh * ow * oc;
                    let chunk = shard_len(valid, threads);
                    let tasks: Vec<_> = xs
                        .chunks(chunk * in_numel)
                        .zip(cur[..valid * out_n].chunks_mut(chunk * out_n))
                        .map(|(xc, oc_chunk)| {
                            let b = xc.len() / in_numel;
                            move || maxpool(xc, b, in_h, in_w, in_c, size, oc_chunk)
                        })
                        .collect();
                    pool::scope_run(tasks);
                }
                Layer::Flatten => {
                    cur[..valid * in_numel].copy_from_slice(xs);
                }
                Layer::Conv { .. } | Layer::Dense { .. } => {
                    let l = &self.wl[wi];
                    let wc = &mut wcaches[wi];
                    let (oh, ow, n) = self.dims[li];
                    let (m, pix) = match l.op {
                        LinOp::Dense { m, .. } => (m, 1usize),
                        LinOp::Conv { k, cin, .. } => (k * k * cin, oh * ow),
                    };
                    let rows = valid * pix;

                    // 1. GEMM input representation (cached for backward)
                    if l.acts_packed {
                        wc.x_pack.reset_spec(rows, m, act_spec);
                        match l.op {
                            LinOp::Dense { .. } => {
                                let chunk = shard_len(rows, threads);
                                let tasks: Vec<_> = wc
                                    .x_pack
                                    .split_rows_mut(chunk)
                                    .into_iter()
                                    .zip(xs.chunks(chunk * m))
                                    .map(|(mut pr, xc)| {
                                        move || {
                                            for rl in 0..pr.rows() {
                                                pr.set_row(rl, &xc[rl * m..(rl + 1) * m]);
                                            }
                                        }
                                    })
                                    .collect();
                                pool::scope_run(tasks);
                            }
                            LinOp::Conv { k, cin, same, .. } => {
                                let pad = if same { (k - 1) / 2 } else { 0 };
                                let chunk = shard_len(valid, threads);
                                let tasks: Vec<_> = wc
                                    .x_pack
                                    .split_rows_mut(chunk * pix)
                                    .into_iter()
                                    .zip(xs.chunks(chunk * in_numel))
                                    .map(|(mut pr, xc)| {
                                        move || {
                                            let b = xc.len() / in_numel;
                                            let mut patch = vec![0.0f32; m];
                                            for s in 0..b {
                                                let sample =
                                                    &xc[s * in_numel..(s + 1) * in_numel];
                                                for oy in 0..oh {
                                                    for ox in 0..ow {
                                                        gather_patch(
                                                            sample, in_h, in_w, cin, k, pad,
                                                            oy, ox, &mut patch,
                                                        );
                                                        pr.set_row(
                                                            s * pix + oy * ow + ox,
                                                            &patch,
                                                        );
                                                    }
                                                }
                                            }
                                        }
                                    })
                                    .collect();
                                pool::scope_run(tasks);
                            }
                        }
                    } else if let LinOp::Conv { k, cin, same, .. } = l.op {
                        // f32 patches (first conv layer; fp modes)
                        let pad = if same { (k - 1) / 2 } else { 0 };
                        if wc.x_patches.len() < rows * m {
                            wc.x_patches.resize(rows * m, 0.0);
                        }
                        let chunk = shard_len(valid, threads);
                        let tasks: Vec<_> = wc.x_patches[..rows * m]
                            .chunks_mut(chunk * pix * m)
                            .zip(xs.chunks(chunk * in_numel))
                            .map(|(pc, xc)| {
                                move || {
                                    let b = xc.len() / in_numel;
                                    for s in 0..b {
                                        let sample = &xc[s * in_numel..(s + 1) * in_numel];
                                        for oy in 0..oh {
                                            for ox in 0..ow {
                                                let row = s * pix + oy * ow + ox;
                                                gather_patch(
                                                    sample,
                                                    in_h,
                                                    in_w,
                                                    cin,
                                                    k,
                                                    pad,
                                                    oy,
                                                    ox,
                                                    &mut pc[row * m..(row + 1) * m],
                                                );
                                            }
                                        }
                                    }
                                }
                            })
                            .collect();
                        pool::scope_run(tasks);
                    }

                    // 2. z = input × W (rows × n)
                    {
                        let zs = &mut wc.z[..rows * n];
                        let chunk = shard_len(rows, threads);
                        if l.acts_packed {
                            // the same L1-tiled XNOR+popcount kernel the
                            // inference engine runs, sharded by row range
                            // (exact integer dots: split-invisible)
                            let pack = &wc.x_pack;
                            let cols = l
                                .cols
                                .as_ref()
                                .ok_or_else(|| anyhow!("{}: weight planes not built", l.name))?;
                            let tasks: Vec<_> = zs
                                .chunks_mut(chunk * n)
                                .enumerate()
                                .map(|(ci, zc)| {
                                    let r0 = ci * chunk;
                                    move || {
                                        let r1 = r0 + zc.len() / n;
                                        let mut stats = GateStats::default();
                                        bitplane::gated_packed_rows_range(
                                            pack, r0, r1, cols, zc, &mut stats,
                                        );
                                    }
                                })
                                .collect();
                            pool::scope_run(tasks);
                        } else if l.w_discrete {
                            let cols = l
                                .cols
                                .as_ref()
                                .ok_or_else(|| anyhow!("{}: weight planes not built", l.name))?;
                            let xsrc: &[f32] = match l.op {
                                LinOp::Dense { .. } => xs,
                                LinOp::Conv { .. } => &wc.x_patches[..rows * m],
                            };
                            let tasks: Vec<_> = zs
                                .chunks_mut(chunk * n)
                                .zip(xsrc.chunks(chunk * m))
                                .map(|(zc, xc)| {
                                    move || {
                                        let b = xc.len() / m;
                                        backward::f32_rows_times_tern_cols(xc, b, cols, zc);
                                    }
                                })
                                .collect();
                            pool::scope_run(tasks);
                        } else {
                            let wsl = dense_param(model, l.w_param)?;
                            let xsrc: &[f32] = match l.op {
                                LinOp::Dense { .. } => xs,
                                LinOp::Conv { .. } => &wc.x_patches[..rows * m],
                            };
                            let tasks: Vec<_> = zs
                                .chunks_mut(chunk * n)
                                .zip(xsrc.chunks(chunk * m))
                                .map(|(zc, xc)| {
                                    move || {
                                        let b = xc.len() / m;
                                        scalar_gemm(xc, b, wsl, m, n, zc);
                                    }
                                })
                                .collect();
                            pool::scope_run(tasks);
                        }
                    }

                    // 3. BN (batch statistics over the valid rows) + quant,
                    //    or raw logits for the output layer
                    if let Some(gp) = l.gamma_param {
                        let gamma = dense_param(model, gp)?;
                        let beta = dense_param(model, gp + 1)?;
                        let z = &wc.z[..rows * n];
                        let mut sums = vec![0.0f64; 2 * n];
                        {
                            let cchunk = shard_len(n, threads);
                            let tasks: Vec<_> = sums
                                .chunks_mut(2 * cchunk)
                                .enumerate()
                                .map(|(ci, sc)| {
                                    let c0 = ci * cchunk;
                                    let c1 = (c0 + sc.len() / 2).min(n);
                                    move || backward::bn_fwd_channel_stats(z, n, c0, c1, sc)
                                })
                                .collect();
                            pool::scope_run(tasks);
                        }
                        for ch in 0..n {
                            wc.mean[ch] = sums[2 * ch] as f32;
                            wc.var[ch] = sums[2 * ch + 1] as f32;
                            wc.inv_std[ch] = 1.0 / (wc.var[ch] + BN_EPS).sqrt();
                        }
                        // y = (z − mean)·inv_std·gamma + beta; h = quant(y)
                        let (mean, inv_std) = (&wc.mean, &wc.inv_std);
                        let y = &mut wc.y[..rows * n];
                        let h_out = &mut cur[..rows * n];
                        let chunk = shard_len(rows, threads);
                        let tasks: Vec<_> = y
                            .chunks_mut(chunk * n)
                            .zip(h_out.chunks_mut(chunk * n))
                            .zip(z.chunks(chunk * n))
                            .map(|((yc, hc), zc)| {
                                move || -> u64 {
                                    let mut zeros = 0u64;
                                    for ((yrow, hrow), zrow) in yc
                                        .chunks_exact_mut(n)
                                        .zip(hc.chunks_exact_mut(n))
                                        .zip(zc.chunks_exact(n))
                                    {
                                        for ch in 0..n {
                                            let yv = (zrow[ch] - mean[ch]) * inv_std[ch]
                                                * gamma[ch]
                                                + beta[ch];
                                            yrow[ch] = yv;
                                            let q = match mode {
                                                ActMode::Fp => yv,
                                                ActMode::Bin => {
                                                    if yv >= 0.0 {
                                                        1.0
                                                    } else {
                                                        -1.0
                                                    }
                                                }
                                                ActMode::Multi => phi_multi(yv, r, hl),
                                            };
                                            hrow[ch] = q;
                                            zeros += (q == 0.0) as u64;
                                        }
                                    }
                                    zeros
                                }
                            })
                            .collect();
                        let zeros: u64 = pool::scope_map(tasks).into_iter().sum();
                        spars[wi] = zeros as f32 / (rows * n) as f32;
                    } else {
                        cur[..rows * n].copy_from_slice(&wc.z[..rows * n]);
                    }
                    wi += 1;
                }
            }
        }
        Ok(())
    }

    /// Backward pass: loss gradient, then a reverse walk of the arch with
    /// the ternary-operand GEMMs of [`backward`]. Fills `outs`.
    fn backward(
        &mut self,
        labels: &[i32],
        valid: usize,
        model: &crate::nn::params::ModelState,
    ) -> Result<()> {
        let threads = self.threads;
        let (mode, r, a, hl) = (self.mode, self.r, self.a, self.hl);
        let nc = self.n_classes;
        let cache = &self.cache;
        let outs = &mut self.outs;
        let dw64 = &mut self.dw64;
        let ga = &mut self.gbuf_a;
        let gb = &mut self.gbuf_b;

        // sparsity straight from the forward
        outs[2].copy_from_slice(&cache.spars);

        // loss + dlogits
        let last_li = self.wl.last().map(|l| l.arch_idx).unwrap();
        let logits = &cache.acts[last_li][..valid * nc];
        let inv = 1.0f32 / valid as f32;
        let mut loss = 0.0f64;
        let mut ncorrect = 0u32;
        for row in 0..valid {
            let lrow = &logits[row * nc..(row + 1) * nc];
            loss += backward::svm_row_loss_grad(
                lrow,
                labels[row],
                inv,
                &mut ga[row * nc..(row + 1) * nc],
            );
            if crate::util::argmax(lrow) as i32 == labels[row] {
                ncorrect += 1;
            }
        }
        outs[0][0] = (loss / valid as f64) as f32;
        outs[1][0] = ncorrect as f32;

        // reverse arch walk; `ga` holds the gradient w.r.t. the current
        // layer's output, `gb` receives the gradient w.r.t. its input
        let mut wi = self.wl.len();
        for li in (0..self.arch.layers.len()).rev() {
            let (ih, iw, ic) = if li == 0 { self.arch.input } else { self.dims[li - 1] };
            let in_numel = ih * iw * ic;
            let (qh, qw, qc) = self.dims[li];
            match self.arch.layers[li] {
                Layer::Flatten => { /* pure reshape: gradient unchanged */ }
                Layer::Pool { size } => {
                    let xs: &[f32] = if li == 0 {
                        &cache.xin[..valid * in_numel]
                    } else {
                        &cache.acts[li - 1][..valid * in_numel]
                    };
                    let out_n = qh * qw * qc;
                    let g = &ga[..valid * out_n];
                    let chunk = shard_len(valid, threads);
                    let tasks: Vec<_> = gb[..valid * in_numel]
                        .chunks_mut(chunk * in_numel)
                        .zip(xs.chunks(chunk * in_numel))
                        .zip(g.chunks(chunk * out_n))
                        .map(|((dxc, xc), gc)| {
                            move || {
                                let b = xc.len() / in_numel;
                                for s in 0..b {
                                    backward::maxpool_bwd_sample(
                                        &xc[s * in_numel..(s + 1) * in_numel],
                                        ih,
                                        iw,
                                        ic,
                                        size,
                                        &gc[s * out_n..(s + 1) * out_n],
                                        &mut dxc[s * in_numel..(s + 1) * in_numel],
                                    );
                                }
                            }
                        })
                        .collect();
                    pool::scope_run(tasks);
                    std::mem::swap(ga, gb);
                }
                Layer::Conv { .. } | Layer::Dense { .. } => {
                    wi -= 1;
                    let l = &self.wl[wi];
                    let wc = &cache.wl[wi];
                    let (m, n, pix) = match l.op {
                        LinOp::Dense { m, n } => (m, n, 1usize),
                        LinOp::Conv { k, cin, cout, .. } => (k * k * cin, cout, qh * qw),
                    };
                    let rows = valid * pix;

                    // quantizer window + BN backward (hidden layers)
                    if let Some(gp) = l.gamma_param {
                        let y = &wc.y[..rows * n];
                        {
                            // g ← g · quant'(y), elementwise
                            let gsl = &mut ga[..rows * n];
                            let chunk = shard_len(rows, threads);
                            let tasks: Vec<_> = gsl
                                .chunks_mut(chunk * n)
                                .zip(y.chunks(chunk * n))
                                .map(|(gc, yc)| {
                                    move || {
                                        for (gv, &yv) in gc.iter_mut().zip(yc) {
                                            *gv *= backward::quant_bwd(yv, r, a, hl, mode);
                                        }
                                    }
                                })
                                .collect();
                            pool::scope_run(tasks);
                        }
                        let z = &wc.z[..rows * n];
                        let (mean, inv_std) = (&wc.mean, &wc.inv_std);
                        let mut sums = vec![0.0f64; 2 * n];
                        {
                            let g = &ga[..rows * n];
                            let cchunk = shard_len(n, threads);
                            let tasks: Vec<_> = sums
                                .chunks_mut(2 * cchunk)
                                .enumerate()
                                .map(|(ci, sc)| {
                                    let c0 = ci * cchunk;
                                    let c1 = (c0 + sc.len() / 2).min(n);
                                    move || {
                                        backward::bn_bwd_channel_sums(
                                            g, z, mean, inv_std, n, c0, c1, sc,
                                        )
                                    }
                                })
                                .collect();
                            pool::scope_run(tasks);
                        }
                        // dgamma = Σ dy·x̂, dbeta = Σ dy
                        for ch in 0..n {
                            outs[3 + gp][ch] = sums[2 * ch + 1] as f32;
                            outs[3 + gp + 1][ch] = sums[2 * ch] as f32;
                        }
                        let gamma = dense_param(model, gp)?;
                        let nf = rows as f64;
                        let s1n: Vec<f32> = (0..n).map(|ch| (sums[2 * ch] / nf) as f32).collect();
                        let s2n: Vec<f32> =
                            (0..n).map(|ch| (sums[2 * ch + 1] / nf) as f32).collect();
                        let chunk = shard_len(rows, threads);
                        let (s1r, s2r) = (&s1n, &s2n);
                        let tasks: Vec<_> = ga[..rows * n]
                            .chunks_mut(chunk * n)
                            .zip(z.chunks(chunk * n))
                            .map(|(gc, zc)| {
                                move || {
                                    backward::bn_bwd_dz_rows(
                                        gc, zc, gamma, mean, inv_std, s1r, s2r, n,
                                    )
                                }
                            })
                            .collect();
                        pool::scope_run(tasks);
                    }

                    // dW = Xᵀ·dY, f64, fan-in ownership sharding
                    {
                        let wslot = &mut dw64[..m * n];
                        wslot.fill(0.0);
                        let g = &ga[..rows * n];
                        if l.acts_packed {
                            let pack = &wc.x_pack;
                            // shard the *logical* fan-in words — the pack's
                            // stride is lane-padded and the padding words
                            // carry no gate bits, so they need no owner —
                            // in whole kernel-lane blocks so every worker's
                            // word range starts cache-line aligned
                            let words = bitplane::words_for(m);
                            let blocks = crate::util::div_ceil(words, bitplane::LANE_WORDS);
                            let wranges = shard_ranges(blocks, threads);
                            let mut rest: &mut [f64] = wslot;
                            let mut tasks = Vec::with_capacity(wranges.len());
                            for &(b0, b1) in &wranges {
                                let w0 = b0 * bitplane::LANE_WORDS;
                                let w1 = (b1 * bitplane::LANE_WORDS).min(words);
                                let lane_lo = (w0 * 64).min(m);
                                let lane_hi = (w1 * 64).min(m);
                                let (chunk, r2) = rest.split_at_mut((lane_hi - lane_lo) * n);
                                rest = r2;
                                tasks.push(move || {
                                    backward::accum_dw_packed(pack, rows, g, n, w0, w1, chunk)
                                });
                            }
                            pool::scope_run(tasks);
                        } else {
                            let xsrc: &[f32] = match l.op {
                                LinOp::Dense { .. } => {
                                    if li == 0 {
                                        &cache.xin[..valid * m]
                                    } else {
                                        &cache.acts[li - 1][..valid * m]
                                    }
                                }
                                LinOp::Conv { .. } => &wc.x_patches[..rows * m],
                            };
                            let lranges = shard_ranges(m, threads);
                            let mut rest: &mut [f64] = wslot;
                            let mut tasks = Vec::with_capacity(lranges.len());
                            for &(l0, l1) in &lranges {
                                let (chunk, r2) = rest.split_at_mut((l1 - l0) * n);
                                rest = r2;
                                tasks.push(move || {
                                    backward::accum_dw_scalar(xsrc, rows, m, g, n, l0, l1, chunk)
                                });
                            }
                            pool::scope_run(tasks);
                        }
                        let go = &mut outs[3 + l.w_param];
                        for (o, &v) in go.iter_mut().zip(wslot.iter()) {
                            *o = v as f32;
                        }
                    }

                    // dX = dY·Wᵀ — not needed below the first weighted layer
                    if wi == 0 {
                        break;
                    }
                    let g = &ga[..rows * n];
                    match l.op {
                        LinOp::Dense { .. } => {
                            let chunk = shard_len(rows, threads);
                            if let Some(wrows) = &l.wrows {
                                let tasks: Vec<_> = gb[..rows * m]
                                    .chunks_mut(chunk * m)
                                    .zip(g.chunks(chunk * n))
                                    .map(|(oc, gc)| {
                                        move || {
                                            let b = gc.len() / n;
                                            backward::f32_rows_times_tern_cols(
                                                gc, b, wrows, oc,
                                            );
                                        }
                                    })
                                    .collect();
                                pool::scope_run(tasks);
                            } else {
                                let wsl = dense_param(model, l.w_param)?;
                                let tasks: Vec<_> = gb[..rows * m]
                                    .chunks_mut(chunk * m)
                                    .zip(g.chunks(chunk * n))
                                    .map(|(oc, gc)| {
                                        move || {
                                            let b = gc.len() / n;
                                            backward::f32_rows_times_dense_rows(
                                                gc, b, wsl, m, n, oc,
                                            );
                                        }
                                    })
                                    .collect();
                                pool::scope_run(tasks);
                            }
                        }
                        LinOp::Conv { k, cin, same, .. } => {
                            let pad = if same { (k - 1) / 2 } else { 0 };
                            let wrows = l.wrows.as_ref();
                            let wsl: Option<&[f32]> = if wrows.is_none() {
                                Some(dense_param(model, l.w_param)?)
                            } else {
                                None
                            };
                            let chunk = shard_len(valid, threads);
                            let out_n = pix * n;
                            let tasks: Vec<_> = gb[..valid * in_numel]
                                .chunks_mut(chunk * in_numel)
                                .zip(g.chunks(chunk * out_n))
                                .map(|(dxc, gc)| {
                                    move || {
                                        let b = gc.len() / out_n;
                                        let mut dpatch = vec![0.0f32; m];
                                        for s in 0..b {
                                            let dx = &mut dxc[s * in_numel..(s + 1) * in_numel];
                                            dx.fill(0.0);
                                            for oy in 0..qh {
                                                for ox in 0..qw {
                                                    let gr = &gc[(s * pix + oy * qw + ox) * n..]
                                                        [..n];
                                                    match (wrows, wsl) {
                                                        (Some(wr), _) => {
                                                            backward::f32_rows_times_tern_cols(
                                                                gr,
                                                                1,
                                                                wr,
                                                                &mut dpatch,
                                                            )
                                                        }
                                                        (None, Some(ws)) => {
                                                            backward::f32_rows_times_dense_rows(
                                                                gr,
                                                                1,
                                                                ws,
                                                                m,
                                                                n,
                                                                &mut dpatch,
                                                            )
                                                        }
                                                        _ => unreachable!(),
                                                    }
                                                    backward::scatter_patch_add(
                                                        &dpatch, ih, iw, cin, k, pad, oy, ox,
                                                        dx,
                                                    );
                                                }
                                            }
                                        }
                                    }
                                })
                                .collect();
                            pool::scope_run(tasks);
                        }
                    }
                    std::mem::swap(ga, gb);
                }
            }
        }

        // BN running-state EMA (masked batch stats, matching the graph)
        let mut out_idx = 3 + self.n_params;
        let mut bn_idx = 0usize;
        for (wi2, l) in self.wl.iter().enumerate() {
            if l.gamma_param.is_none() {
                continue;
            }
            let wc = &cache.wl[wi2];
            let old_mean = &model.bn_state[2 * bn_idx];
            let old_var = &model.bn_state[2 * bn_idx + 1];
            {
                let (o_mean, o_var) = {
                    let (a0, b0) = outs.split_at_mut(out_idx + 1);
                    (&mut a0[out_idx], &mut b0[0])
                };
                for ch in 0..wc.mean.len() {
                    o_mean[ch] = BN_MOMENTUM * old_mean[ch] + (1.0 - BN_MOMENTUM) * wc.mean[ch];
                    o_var[ch] = BN_MOMENTUM * old_var[ch] + (1.0 - BN_MOMENTUM) * wc.var[ch];
                }
            }
            out_idx += 2;
            bn_idx += 1;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::init::init_model;
    use crate::nn::params::ParamDesc;
    use crate::ternary::DiscreteSpace;
    use crate::util::prng::Prng;

    /// A narrow MLP model (784-16-16-10) in the given weight space.
    fn tiny_mlp(space: DiscreteSpace, seed: u64) -> ModelState {
        let d = |name: &str, shape: Vec<usize>, kind, layer| ParamDesc {
            name: name.into(),
            shape,
            kind,
            layer,
        };
        use ParamKind::*;
        init_model(
            vec![
                d("W0", vec![784, 16], Weight, 0),
                d("gamma0", vec![16], Gamma, 0),
                d("beta0", vec![16], Beta, 0),
                d("W1", vec![16, 16], Weight, 1),
                d("gamma1", vec![16], Gamma, 1),
                d("beta1", vec![16], Beta, 1),
                d("W2", vec![16, 10], Weight, 2),
            ],
            vec!["rmean0".into(), "rvar0".into(), "rmean1".into(), "rvar1".into()],
            &[16, 16, 16, 16],
            space,
            seed,
        )
    }

    fn random_batch(batch: usize, len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        (0..batch * len).map(|_| rng.range_f32(-1.0, 1.0)).collect()
    }

    #[test]
    fn gxnor_engine_runs_and_gates() {
        let model = tiny_mlp(DiscreteSpace::TERNARY, 5);
        let mut eng =
            NativeEngine::from_model("mlp", Method::Gxnor, &model, 0.5, 4, 10, 1).unwrap();
        assert_eq!(eng.batch(), 4);
        assert_eq!(eng.n_classes(), 10);
        assert!(eng.has_packed_layers());
        let x = random_batch(4, 784, 1);
        let logits = eng.infer_batch(&x).unwrap().to_vec();
        assert_eq!(logits.len(), 40);
        assert!(logits.iter().all(|v| v.is_finite()));
        // deterministic
        let logits2 = eng.infer_batch(&x).unwrap().to_vec();
        assert_eq!(logits, logits2);
        // gated layers: fc1 and fc2 (fc0 sees the raw input)
        let rep = eng.gate_report();
        assert_eq!(rep.len(), 2);
        // two identical forward passes: fc1 saw 2 batches × 4 rows × 16 evals × 16 fan-in
        assert_eq!(rep[0].stats.total, 2 * 4 * 16 * 16);
        assert_eq!(rep[1].stats.total, 2 * 4 * 10 * 16);
        assert_eq!(rep[0].stats.xnor + rep[0].stats.resting(), rep[0].stats.total);
        eng.reset_gate_stats();
        assert_eq!(eng.total_gate_stats(), GateStats::default());
    }

    #[test]
    fn xnor_path_matches_f32_path_on_same_model() {
        // force the f32 fallback by rebuilding the gated layers densely:
        // run the same model through gxnor (packed) and through a clone
        // whose packed columns are stripped — logits must agree exactly
        // (the packed dot is an exact integer).
        let model = tiny_mlp(DiscreteSpace::TERNARY, 11);
        let mut packed =
            NativeEngine::from_model("mlp", Method::Gxnor, &model, 0.5, 2, 10, 1).unwrap();
        let mut dense =
            NativeEngine::from_model("mlp", Method::Gxnor, &model, 0.5, 2, 10, 1).unwrap();
        dense.force_scalar_path();
        let x = random_batch(2, 784, 9);
        let a = packed.infer_batch(&x).unwrap().to_vec();
        let b = dense.infer_batch(&x).unwrap().to_vec();
        for (i, (u, v)) in a.iter().zip(&b).enumerate() {
            assert!(
                (u - v).abs() < 1e-3,
                "logit {i}: packed {u} vs dense {v}"
            );
        }
    }

    #[test]
    fn forced_strategies_are_bit_identical() {
        // lane / tile-skip / event-list / adaptive must agree to the bit:
        // same logits, same merged GateStats, for MLP and CNN shapes.
        for (arch, model, len) in [
            ("mlp", tiny_mlp(DiscreteSpace::TERNARY, 21), 784),
            ("cnn_mnist", tiny_cnn(23), 784),
        ] {
            let x = random_batch(3, len, 13);
            let mut base =
                NativeEngine::from_model(arch, Method::Gxnor, &model, 0.5, 3, 10, 1).unwrap();
            let ref_logits = base.infer_batch(&x).unwrap().to_vec();
            let ref_stats = base.total_gate_stats();
            for s in [
                KernelStrategy::Lane,
                KernelStrategy::TileSkip,
                KernelStrategy::EventList,
            ] {
                let mut eng =
                    NativeEngine::from_model(arch, Method::Gxnor, &model, 0.5, 3, 10, 1)
                        .unwrap();
                eng.set_strategy(Some(s));
                let logits = eng.infer_batch(&x).unwrap().to_vec();
                assert_eq!(logits, ref_logits, "{arch}/{s:?} logits");
                assert_eq!(eng.total_gate_stats(), ref_stats, "{arch}/{s:?} stats");
                for rep in eng.gate_report() {
                    assert_eq!(rep.strategy, s, "{arch}/{s:?} report pins the forced kernel");
                }
            }
        }
    }

    #[test]
    fn adaptive_report_strategy_tracks_occupancy() {
        let model = tiny_mlp(DiscreteSpace::TERNARY, 5);
        let mut eng =
            NativeEngine::from_model("mlp", Method::Gxnor, &model, 0.5, 4, 10, 1).unwrap();
        let x = random_batch(4, 784, 1);
        eng.infer_batch(&x).unwrap();
        for rep in eng.gate_report() {
            let occ = 1.0 - rep.stats.x_zero_fraction();
            assert_eq!(rep.strategy, choose_strategy(occ), "{}", rep.name);
            // the occupancy histogram saw exactly the rows the kernel ran
            // (x_count = rows × fan-in, one histogram entry per row)
            assert_eq!(
                rep.stats.occ_hist.iter().sum::<u64>(),
                rep.stats.x_count / rep.fan_in as u64,
                "{}",
                rep.name
            );
        }
    }

    #[test]
    fn bnn_engine_has_no_zero_activations() {
        let model = tiny_mlp(DiscreteSpace::BINARY, 3);
        let mut eng = NativeEngine::from_model("mlp", Method::Bnn, &model, 0.5, 4, 10, 1).unwrap();
        assert!(eng.has_packed_layers());
        let x = random_batch(4, 784, 2);
        eng.infer_batch(&x).unwrap();
        for rep in eng.gate_report() {
            assert_eq!(rep.stats.x_zero_fraction(), 0.0, "{}", rep.name);
            assert_eq!(rep.w_zero_fraction, 0.0, "{}", rep.name);
            // binary×binary never rests: every connection fires
            assert_eq!(rep.stats.resting(), 0, "{}", rep.name);
        }
    }

    #[test]
    fn fp_and_twn_methods_use_dense_path() {
        for (method, space) in [
            (Method::Twn, DiscreteSpace::TERNARY),
            (Method::Bwn, DiscreteSpace::BINARY),
        ] {
            let model = tiny_mlp(space, 8);
            let mut eng =
                NativeEngine::from_model("mlp", method, &model, 0.5, 2, 10, 1).unwrap();
            // fp activations: nothing runs packed
            assert!(!eng.has_packed_layers(), "{:?}", method);
            let x = random_batch(2, 784, 4);
            let logits = eng.infer_batch(&x).unwrap();
            assert!(logits.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn threshold_fold_matches_affine_phi() {
        // the ternary threshold rules must agree with y = z*s + b -> phi_r,
        // away from the knife edge where float rounding may differ
        let mut rng = Prng::new(17);
        let n = 8;
        let gamma: Vec<f32> = (0..n).map(|_| rng.range_f32(-2.0, 2.0)).collect();
        let beta: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let rmean: Vec<f32> = (0..n).map(|_| rng.range_f32(-0.5, 0.5)).collect();
        let rvar: Vec<f32> = (0..n).map(|_| rng.range_f32(0.1, 2.0)).collect();
        let r = 0.5f32;
        let bn = make_bn_fold(&gamma, &beta, &rmean, &rvar, ActMode::Multi, r, 1.0);
        assert!(bn.tern.is_some());
        for trial in 0..2000usize {
            let z = rng.range_f32(-4.0, 4.0);
            let ch = trial % n;
            // thresholds path (single-channel view of channel `ch`)
            let mut zq = [z];
            let bn1 = BnFold {
                scale: vec![bn.scale[ch]],
                shift: vec![bn.shift[ch]],
                tern: bn.tern.as_ref().map(|t| vec![t[ch]]),
            };
            bn_quantize(&mut zq, 1, &bn1, ActMode::Multi, r, 1.0);
            // affine + phi path
            let y = z * bn.scale[ch] + bn.shift[ch];
            if (y.abs() - r).abs() < 1e-4 {
                continue; // knife edge: either rounding is acceptable
            }
            assert_eq!(zq[0], phi_multi(y, r, 1.0), "ch {ch} z {z} y {y}");
        }
    }

    #[test]
    fn phi_multi_matches_reference_points() {
        // hl = 1 (ternary), r = 0.5: zero window is |y| <= 0.5
        assert_eq!(phi_multi(0.0, 0.5, 1.0), 0.0);
        assert_eq!(phi_multi(0.4, 0.5, 1.0), 0.0);
        assert_eq!(phi_multi(0.6, 0.5, 1.0), 1.0);
        assert_eq!(phi_multi(-0.7, 0.5, 1.0), -1.0);
        assert_eq!(phi_multi(3.0, 0.5, 1.0), 1.0);
        // hl = 2 (N2 = 2): states at 0, ±0.5, ±1
        assert_eq!(phi_multi(0.6, 0.5, 2.0), 0.5);
        assert_eq!(phi_multi(0.9, 0.5, 2.0), 1.0);
        assert_eq!(phi_multi(-0.6, 0.5, 2.0), -0.5);
    }

    #[test]
    fn rejects_malformed_models() {
        // wrong weighted-layer count for the arch
        let model = tiny_mlp(DiscreteSpace::TERNARY, 1);
        assert!(
            NativeEngine::from_model("cnn_mnist", Method::Gxnor, &model, 0.5, 2, 10, 1).is_err()
        );
        assert!(NativeEngine::from_model("mlp", Method::Gxnor, &model, 0.5, 0, 10, 1).is_err());
        assert!(NativeEngine::from_model("nope", Method::Gxnor, &model, 0.5, 2, 10, 1).is_err());
    }

    #[test]
    fn cnn_topology_runs_natively() {
        // a narrow cnn_mnist: 8C5-MP2-8C5-MP2-8FC-10
        let model = tiny_cnn(21);
        let mut eng =
            NativeEngine::from_model("cnn_mnist", Method::Gxnor, &model, 0.5, 2, 10, 1).unwrap();
        let x = random_batch(2, 28 * 28, 6);
        let logits = eng.infer_batch(&x).unwrap();
        assert_eq!(logits.len(), 20);
        assert!(logits.iter().all(|v| v.is_finite()));
        // conv1 (fed ternarized maps) and both later layers run gated
        let rep = eng.gate_report();
        assert_eq!(rep.len(), 3);
        assert!(rep[0].name.starts_with("conv1"), "{}", rep[0].name);
        assert!(rep[0].stats.total > 0);
    }

    /// A narrow cnn_mnist model shared by the im2col / threading tests.
    fn tiny_cnn(seed: u64) -> ModelState {
        let d = |name: &str, shape: Vec<usize>, kind, layer| ParamDesc {
            name: name.into(),
            shape,
            kind,
            layer,
        };
        use ParamKind::*;
        init_model(
            vec![
                d("W0", vec![5, 5, 1, 8], Weight, 0),
                d("gamma0", vec![8], Gamma, 0),
                d("beta0", vec![8], Beta, 0),
                d("W1", vec![5, 5, 8, 8], Weight, 1),
                d("gamma1", vec![8], Gamma, 1),
                d("beta1", vec![8], Beta, 1),
                d("W2", vec![128, 8], Weight, 2),
                d("gamma2", vec![8], Gamma, 2),
                d("beta2", vec![8], Beta, 2),
                d("W3", vec![8, 10], Weight, 3),
            ],
            vec![
                "rmean0".into(),
                "rvar0".into(),
                "rmean1".into(),
                "rvar1".into(),
                "rmean2".into(),
                "rvar2".into(),
            ],
            &[8, 8, 8, 8, 8, 8],
            DiscreteSpace::TERNARY,
            seed,
        )
    }

    /// The im2col conv must be bit-identical to the per-pixel scalar
    /// oracle: both compute exact small-integer dots over ternary
    /// operands, so even the f32 outputs agree exactly.
    #[test]
    fn im2col_conv_matches_scalar_conv_oracle() {
        let model = tiny_cnn(29);
        let mut packed =
            NativeEngine::from_model("cnn_mnist", Method::Gxnor, &model, 0.5, 3, 10, 1).unwrap();
        let mut oracle =
            NativeEngine::from_model("cnn_mnist", Method::Gxnor, &model, 0.5, 3, 10, 1).unwrap();
        oracle.force_scalar_path();
        assert!(packed.has_packed_layers());
        assert!(!oracle.has_packed_layers());
        let mut rng = Prng::new(77);
        for trial in 0..3 {
            // random *ternary* inputs: the first conv stays on the scalar
            // path in `packed` too, so every divergence would be im2col's
            let x: Vec<f32> = (0..3 * 28 * 28).map(|_| rng.below(3) as f32 - 1.0).collect();
            let a = packed.infer_batch(&x).unwrap().to_vec();
            let b = oracle.infer_batch(&x).unwrap().to_vec();
            assert_eq!(a, b, "trial {trial}: im2col diverges from scalar oracle");
        }
    }

    /// Sharding the batch across workers must not change logits or the
    /// merged gate tallies — including thread counts that do not divide
    /// the batch (shard-boundary coverage) or exceed it.
    #[test]
    fn threaded_forward_is_bit_identical() {
        let model = tiny_cnn(55);
        let batch = 5usize;
        let x = random_batch(batch, 28 * 28, 8);
        let mut want_logits = Vec::new();
        let mut want_gate = Vec::new();
        for threads in [1usize, 2, 3, 7] {
            let mut eng = NativeEngine::from_model(
                "cnn_mnist",
                Method::Gxnor,
                &model,
                0.5,
                batch,
                10,
                threads,
            )
            .unwrap();
            // two calls: accumulation across calls must shard-merge too
            eng.infer_batch(&x).unwrap();
            let logits = eng.infer_batch(&x).unwrap().to_vec();
            let gate: Vec<GateStats> = eng.gate_report().iter().map(|r| r.stats).collect();
            if threads == 1 {
                want_logits = logits;
                want_gate = gate;
            } else {
                assert_eq!(logits, want_logits, "threads={threads}: logits diverge");
                assert_eq!(gate, want_gate, "threads={threads}: gate stats diverge");
            }
        }
        // switching thread count on a live engine is equally exact
        let mut eng =
            NativeEngine::from_model("cnn_mnist", Method::Gxnor, &model, 0.5, batch, 10, 4)
                .unwrap();
        eng.infer_batch(&x).unwrap();
        eng.set_threads(2);
        let logits = eng.infer_batch(&x).unwrap().to_vec();
        assert_eq!(logits, want_logits);
        let gate: Vec<GateStats> = eng.gate_report().iter().map(|r| r.stats).collect();
        assert_eq!(gate, want_gate);
    }
}
