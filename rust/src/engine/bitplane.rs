//! Word-parallel bit-plane kernels for the gated-XNOR forward pass.
//!
//! A ternary vector v ∈ {-1, 0, +1}^M is stored as two u64 bit planes:
//! the **sign** plane (bit set iff v_i = +1) and the **nonzero** plane
//! (bit set iff v_i ≠ 0). A binary vector ({-1, +1}) is the special case
//! whose nonzero plane is all ones. The dot product of two such vectors is
//!
//! ```text
//! gate  = a_nz & w_nz                      (both operands non-zero)
//! agree = !(a_sign ^ w_sign) & gate        (XNOR of the sign bits, gated)
//! dot  += 2·popcount(agree) − popcount(gate)
//! ```
//!
//! which is the paper's Fig. 11f compute unit executed 64 lanes at a time:
//! an XNOR fires only where `gate` is set; everywhere else the unit rests.
//! Words whose gate is all-zero are skipped outright — the event-driven
//! zero-state gate at word granularity. [`GateStats`] counts the ops that
//! actually fired so the hwsim's Table 2 predictions can be cross-checked
//! against executed reality (`hwsim::counts::gate_rate_matches`).

/// u64 words needed to hold `m` lanes.
pub const fn words_for(m: usize) -> usize {
    crate::util::div_ceil(m, 64)
}

/// Pack grid values into sign/nonzero planes. Values must lie in
/// {-1.0, 0.0, +1.0}; lanes past `vals.len()` are cleared (they gate off).
pub fn pack_row_into(vals: &[f32], sign: &mut [u64], nz: &mut [u64]) {
    let words = words_for(vals.len());
    debug_assert!(sign.len() >= words && nz.len() >= words);
    sign[..words].fill(0);
    nz[..words].fill(0);
    for (i, &v) in vals.iter().enumerate() {
        debug_assert!(
            v == -1.0 || v == 0.0 || v == 1.0,
            "non-ternary value {v} in bitplane pack"
        );
        let b = 1u64 << (i % 64);
        if v > 0.0 {
            sign[i / 64] |= b;
        }
        if v != 0.0 {
            nz[i / 64] |= b;
        }
    }
}

/// The columns of a row-major (m × n) weight matrix, each packed into
/// sign/nonzero planes (done once at engine load; HWIO conv weights
/// flatten to exactly this layout with m = k·k·cin).
///
/// The struct is direction-agnostic: it holds `n` plane pairs of `m`
/// lanes each. [`BitplaneCols::pack_rows_of`] packs the *rows* of a
/// matrix instead (n lanes per plane, m planes) — the layout the
/// backward pass streams for `dX = dY·Wᵀ`, where each output element
/// walks one weight row across its output-channel lanes.
pub struct BitplaneCols {
    sign: Vec<u64>,
    nz: Vec<u64>,
    pub m: usize,
    pub n: usize,
    pub words: usize,
}

impl BitplaneCols {
    pub fn pack_cols(w: &[f32], m: usize, n: usize) -> Self {
        assert_eq!(w.len(), m * n, "weight matrix shape mismatch");
        let words = words_for(m);
        let mut sign = vec![0u64; words * n];
        let mut nz = vec![0u64; words * n];
        for i in 0..m {
            let wi = i / 64;
            let b = 1u64 << (i % 64);
            for (j, &v) in w[i * n..(i + 1) * n].iter().enumerate() {
                debug_assert!(
                    v == -1.0 || v == 0.0 || v == 1.0,
                    "non-ternary weight {v} in bitplane pack"
                );
                if v > 0.0 {
                    sign[j * words + wi] |= b;
                }
                if v != 0.0 {
                    nz[j * words + wi] |= b;
                }
            }
        }
        BitplaneCols { sign, nz, m, n, words }
    }

    /// Pack the *rows* of a row-major (rows × lanes) matrix: one plane
    /// pair per row, `lanes` lanes each. `col(i)` then returns row `i`'s
    /// planes. This is the weight layout of the backward `dX` kernel.
    pub fn pack_rows_of(w: &[f32], rows: usize, lanes: usize) -> Self {
        assert_eq!(w.len(), rows * lanes, "weight matrix shape mismatch");
        let words = words_for(lanes);
        let mut sign = vec![0u64; words * rows];
        let mut nz = vec![0u64; words * rows];
        for i in 0..rows {
            let (lo, hi) = (i * words, (i + 1) * words);
            pack_row_into(&w[i * lanes..(i + 1) * lanes], &mut sign[lo..hi], &mut nz[lo..hi]);
        }
        BitplaneCols { sign, nz, m: lanes, n: rows, words }
    }

    /// [`BitplaneCols::pack_cols`] reading grid values straight out of a
    /// packed discrete tensor — no f32 expansion of the weights is ever
    /// materialized (the training engine's no-hidden-weight path). The
    /// tensor must hold at most three states (binary/ternary).
    pub fn pack_cols_from_packed(p: &crate::ternary::PackedTensor, m: usize, n: usize) -> Self {
        assert_eq!(p.len(), m * n, "packed tensor shape mismatch");
        assert!(p.space().n_states() <= 3, "bitplanes need a binary/ternary space");
        let words = words_for(m);
        let mut sign = vec![0u64; words * n];
        let mut nz = vec![0u64; words * n];
        for i in 0..m {
            let wi = i / 64;
            let b = 1u64 << (i % 64);
            for j in 0..n {
                let v = p.get(i * n + j);
                if v > 0.0 {
                    sign[j * words + wi] |= b;
                }
                if v != 0.0 {
                    nz[j * words + wi] |= b;
                }
            }
        }
        BitplaneCols { sign, nz, m, n, words }
    }

    /// [`BitplaneCols::pack_rows_of`] straight out of a packed tensor
    /// (row-major rows × lanes), again without any f32 weight buffer.
    pub fn pack_rows_from_packed(
        p: &crate::ternary::PackedTensor,
        rows: usize,
        lanes: usize,
    ) -> Self {
        assert_eq!(p.len(), rows * lanes, "packed tensor shape mismatch");
        assert!(p.space().n_states() <= 3, "bitplanes need a binary/ternary space");
        let words = words_for(lanes);
        let mut sign = vec![0u64; words * rows];
        let mut nz = vec![0u64; words * rows];
        for i in 0..rows {
            let base = i * words;
            for j in 0..lanes {
                let v = p.get(i * lanes + j);
                let b = 1u64 << (j % 64);
                if v > 0.0 {
                    sign[base + j / 64] |= b;
                }
                if v != 0.0 {
                    nz[base + j / 64] |= b;
                }
            }
        }
        BitplaneCols { sign, nz, m: lanes, n: rows, words }
    }

    /// Bytes held by the sign + nonzero planes (memory accounting).
    pub fn plane_bytes(&self) -> usize {
        (self.sign.len() + self.nz.len()) * 8
    }

    /// (sign, nonzero) planes of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u64], &[u64]) {
        let s = j * self.words;
        (&self.sign[s..s + self.words], &self.nz[s..s + self.words])
    }
}

/// Gated-XNOR dot product of one packed row against one packed column.
/// Returns `(dot, active)`: the exact integer Σ aᵢ·wᵢ and the number of
/// XNOR ops that fired (lanes where both operands were non-zero).
#[inline]
pub fn gated_dot(a_sign: &[u64], a_nz: &[u64], w_sign: &[u64], w_nz: &[u64]) -> (i64, u64) {
    let mut dot = 0i64;
    let mut active = 0u64;
    for k in 0..w_sign.len() {
        let gate = a_nz[k] & w_nz[k];
        if gate == 0 {
            // every unit in this word rests: no XNOR, no accumulate
            continue;
        }
        let agree = !(a_sign[k] ^ w_sign[k]) & gate;
        let fired = gate.count_ones() as i64;
        dot += 2 * agree.count_ones() as i64 - fired;
        active += fired as u64;
    }
    (dot, active)
}

/// Tallies of what the gated kernel actually executed (per layer).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GateStats {
    /// XNOR ops that fired (both operands non-zero).
    pub xnor: u64,
    /// Nominal connections considered (fan-in × neuron evaluations).
    pub total: u64,
    /// Neuron evaluations whose accumulator woke at least once.
    pub bitcount: u64,
    /// Neuron evaluations performed.
    pub evals: u64,
    /// Non-zero activation states among those packed.
    pub x_nonzero: u64,
    /// Activation states packed (fan-in per row × rows).
    pub x_count: u64,
}

impl GateStats {
    /// Connections whose compute unit stayed resting.
    pub fn resting(&self) -> u64 {
        self.total - self.xnor
    }

    /// Measured resting probability (Table 2's last column, executed).
    pub fn resting_rate(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.resting() as f64 / self.total as f64
        }
    }

    /// Measured zero-state fraction of the activations the kernel saw.
    pub fn x_zero_fraction(&self) -> f64 {
        if self.x_count == 0 {
            0.0
        } else {
            1.0 - self.x_nonzero as f64 / self.x_count as f64
        }
    }

    pub fn merge(&mut self, o: &GateStats) {
        self.xnor += o.xnor;
        self.total += o.total;
        self.bitcount += o.bitcount;
        self.evals += o.evals;
        self.x_nonzero += o.x_nonzero;
        self.x_count += o.x_count;
    }
}

/// Caller-owned pool of packed activation rows: the sign/nonzero planes
/// of a (rows × m) ternary matrix, row-major. `reset` reuses capacity, so
/// a scratch held across `infer_batch` calls makes the steady-state pack
/// allocation-free — this replaced the fresh per-call `Vec`s that used to
/// be the last allocation in the inference hot loop. The packed-domain
/// im2col conv fills one scratch per sample (one row per output pixel)
/// and dense layers pack the whole sub-batch; both then fire through the
/// same tiled kernel, [`gated_packed_rows`].
#[derive(Default)]
pub struct PackScratch {
    sign: Vec<u64>,
    nz: Vec<u64>,
    words: usize,
    rows: usize,
}

impl PackScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size for `rows` rows of `m` lanes, reusing capacity. Row contents
    /// are garbage until written by `set_row`.
    pub fn reset(&mut self, rows: usize, m: usize) {
        self.words = words_for(m);
        self.rows = rows;
        let need = rows * self.words;
        if self.sign.len() < need {
            self.sign.resize(need, 0);
            self.nz.resize(need, 0);
        }
    }

    /// Pack one row of grid values ({-1, 0, +1}); `vals` must have exactly
    /// the lane count `reset` was given (tail lanes of the last word are
    /// cleared, so stale bits from a previous, wider use cannot leak).
    pub fn set_row(&mut self, row: usize, vals: &[f32]) {
        debug_assert!(row < self.rows);
        debug_assert_eq!(words_for(vals.len()), self.words, "row width mismatch");
        let (lo, hi) = (row * self.words, (row + 1) * self.words);
        pack_row_into(vals, &mut self.sign[lo..hi], &mut self.nz[lo..hi]);
    }

    /// Pack a full row-major (rows × m) matrix.
    pub fn pack_rows(&mut self, a: &[f32], rows: usize, m: usize) {
        assert_eq!(a.len(), rows * m);
        self.reset(rows, m);
        for row in 0..rows {
            self.set_row(row, &a[row * m..(row + 1) * m]);
        }
    }

    /// (sign, nonzero) planes of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u64], &[u64]) {
        let s = i * self.words;
        (&self.sign[s..s + self.words], &self.nz[s..s + self.words])
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Plane words per row (current `reset` width).
    pub fn words(&self) -> usize {
        self.words
    }

    /// Split the current `rows` into disjoint mutable row-range views of
    /// `rows_per_chunk` rows each (the last may be shorter), so scoped
    /// workers can pack disjoint row ranges of one shared scratch in
    /// parallel — the training engine fills the whole batch's activation
    /// planes this way and the backward pass then streams them.
    pub fn split_rows_mut(&mut self, rows_per_chunk: usize) -> Vec<PackRowsMut<'_>> {
        let words = self.words;
        let lim = self.rows * words;
        let step = rows_per_chunk.max(1) * words;
        if lim == 0 || words == 0 {
            return Vec::new();
        }
        self.sign[..lim]
            .chunks_mut(step)
            .zip(self.nz[..lim].chunks_mut(step))
            .map(|(sign, nz)| PackRowsMut { sign, nz, words })
            .collect()
    }
}

/// A disjoint mutable row range of a [`PackScratch`] (see
/// [`PackScratch::split_rows_mut`]). Row indices are local to the view.
pub struct PackRowsMut<'a> {
    sign: &'a mut [u64],
    nz: &'a mut [u64],
    words: usize,
}

impl PackRowsMut<'_> {
    pub fn rows(&self) -> usize {
        self.sign.len() / self.words
    }

    /// Pack one row of grid values ({-1, 0, +1}); `row` is local to this
    /// view and `vals` must match the scratch's lane width.
    pub fn set_row(&mut self, row: usize, vals: &[f32]) {
        debug_assert!(row < self.rows());
        debug_assert_eq!(words_for(vals.len()), self.words, "row width mismatch");
        let (lo, hi) = (row * self.words, (row + 1) * self.words);
        pack_row_into(vals, &mut self.sign[lo..hi], &mut self.nz[lo..hi]);
    }
}

/// Bytes of weight bit-planes a column tile may occupy: half a typical
/// 32 KiB L1d, leaving the other half for the streaming activation rows.
const TILE_BYTES: usize = 16 * 1024;

/// Columns per tile for a given plane width: sign + nz cost 16 bytes per
/// word per column. Wide layers (large fan-in) get narrow tiles; the
/// clamp keeps degenerate shapes sane.
fn col_tile(words: usize) -> usize {
    (TILE_BYTES / (16 * words.max(1))).clamp(4, 256)
}

/// Every packed row against every weight column, tiled over output-column
/// blocks sized to L1 so each tile's weight bit-planes stay cache-hot
/// while the activation rows stream past (instead of re-walking the full
/// weight matrix per row and thrashing). Writes `out[row·n + col]`; the
/// dot is an exact integer, so results are bit-identical to the untiled
/// walk in any tile order. This is the single home of the GateStats
/// counting semantics — the dense GEMM and the im2col conv both land here.
pub fn gated_packed_rows(
    pack: &PackScratch,
    cols: &BitplaneCols,
    out: &mut [f32],
    stats: &mut GateStats,
) {
    gated_packed_rows_range(pack, 0, pack.rows, cols, out, stats);
}

/// [`gated_packed_rows`] over the row range `[r0, r1)` only, writing into
/// `out` sized `(r1 − r0) × n`. This is the unit the training engine's
/// data-parallel forward shards across workers: each shard runs the same
/// tiled walk over its own rows, and because every dot is an exact
/// integer, the concatenated result (and any stats merge) is identical to
/// one full-range call for every split.
pub fn gated_packed_rows_range(
    pack: &PackScratch,
    r0: usize,
    r1: usize,
    cols: &BitplaneCols,
    out: &mut [f32],
    stats: &mut GateStats,
) {
    let rows = r1 - r0;
    let n = cols.n;
    debug_assert!(r1 <= pack.rows);
    debug_assert_eq!(pack.words, cols.words, "row/column plane width mismatch");
    assert_eq!(out.len(), rows * n);
    let m = cols.m as u64;
    for row in r0..r1 {
        let (_, nz) = pack.row(row);
        stats.x_nonzero += nz.iter().map(|w| w.count_ones() as u64).sum::<u64>();
        stats.x_count += m;
    }
    let tile = col_tile(cols.words);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + tile).min(n);
        for row in r0..r1 {
            let (rs, rn) = pack.row(row);
            let orow = &mut out[(row - r0) * n..(row - r0) * n + n];
            for j in j0..j1 {
                let (ws, wn) = cols.col(j);
                let (dot, active) = gated_dot(rs, rn, ws, wn);
                orow[j] = dot as f32;
                stats.xnor += active;
                if active > 0 {
                    stats.bitcount += 1;
                }
            }
        }
        j0 = j1;
    }
    // per (row, col) evaluation: fan-in connections considered, one eval
    stats.total += rows as u64 * n as u64 * m;
    stats.evals += (rows * n) as u64;
}

/// Gated-XNOR GEMM: `out[row·n + col] = Σᵢ a[row·m + i]·w[i, col]` for
/// ternary operands. Rows are packed into the caller-owned `pack` scratch
/// (reused across calls — no per-call allocation), then run through the
/// tiled kernel.
pub fn gated_xnor_gemm(
    a: &[f32],
    rows: usize,
    cols: &BitplaneCols,
    out: &mut [f32],
    stats: &mut GateStats,
    pack: &mut PackScratch,
) {
    assert_eq!(a.len(), rows * cols.m);
    pack.pack_rows(a, rows, cols.m);
    gated_packed_rows(pack, cols, out, stats);
}

/// Scalar GEMM with f64 accumulation:
/// `out[row·n + col] = Σᵢ a[row·m + i]·w[i·n + col]`. Doubles as the
/// reference the bitplane kernel is pinned against in the tests and as
/// the engine's full-precision fallback path (first layer, fp modes).
pub fn scalar_gemm(a: &[f32], rows: usize, w: &[f32], m: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), rows * m);
    assert_eq!(w.len(), m * n);
    assert_eq!(out.len(), rows * n);
    for row in 0..rows {
        let ar = &a[row * m..(row + 1) * m];
        for j in 0..n {
            let mut acc = 0.0f64;
            for i in 0..m {
                acc += ar[i] as f64 * w[i * n + j] as f64;
            }
            out[row * n + j] = acc as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn random_ternary(rng: &mut Prng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.below(3) as f32 - 1.0).collect()
    }

    #[test]
    fn gated_gemm_matches_scalar_reference() {
        let mut rng = Prng::new(7);
        // shapes straddle word edges AND column-tile edges: m = 130 gives
        // words = 3 (tile 341 -> clamped 256), so n = 300 spans two
        // tiles; m = 4100 makes the tile genuinely narrow (words = 65 ->
        // tile 15, n = 40 spans three)
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 63, 5),
            (4, 64, 8),
            (2, 65, 3),
            (5, 200, 17),
            (2, 130, 300),
            (3, 4100, 40),
        ];
        // one scratch reused across every shape: capacity reuse must not
        // leak rows/lanes between calls
        let mut pack = PackScratch::new();
        for &(rows, m, n) in &shapes {
            let a = random_ternary(&mut rng, rows * m);
            let w = random_ternary(&mut rng, m * n);
            let cols = BitplaneCols::pack_cols(&w, m, n);
            let mut got = vec![0.0f32; rows * n];
            let mut want = vec![0.0f32; rows * n];
            let mut stats = GateStats::default();
            gated_xnor_gemm(&a, rows, &cols, &mut got, &mut stats, &mut pack);
            scalar_gemm(&a, rows, &w, m, n, &mut want);
            assert_eq!(got, want, "rows={rows} m={m} n={n}");
            assert_eq!(stats.total, (rows * m * n) as u64);
            assert_eq!(stats.evals, (rows * n) as u64);
            assert_eq!(stats.x_count, (rows * m) as u64);
        }
    }

    #[test]
    fn tiled_kernel_stats_are_tile_order_independent() {
        // the same matmul through a tiny fan-in (wide tile, one block) and
        // a huge fan-in is covered above; here pin that the tallies of a
        // multi-tile walk equal the per-element definition computed by hand
        let mut rng = Prng::new(41);
        let (rows, m, n) = (3usize, 70usize, 300usize);
        let a = random_ternary(&mut rng, rows * m);
        let w = random_ternary(&mut rng, m * n);
        let cols = BitplaneCols::pack_cols(&w, m, n);
        let mut out = vec![0.0f32; rows * n];
        let mut stats = GateStats::default();
        let mut pack = PackScratch::new();
        gated_xnor_gemm(&a, rows, &cols, &mut out, &mut stats, &mut pack);
        let mut xnor = 0u64;
        let mut bitcount = 0u64;
        for row in 0..rows {
            for j in 0..n {
                let fired = (0..m)
                    .filter(|&i| a[row * m + i] != 0.0 && w[i * n + j] != 0.0)
                    .count() as u64;
                xnor += fired;
                if fired > 0 {
                    bitcount += 1;
                }
            }
        }
        assert_eq!(stats.xnor, xnor);
        assert_eq!(stats.bitcount, bitcount);
        let x_nonzero = a.iter().filter(|&&v| v != 0.0).count() as u64;
        assert_eq!(stats.x_nonzero, x_nonzero);
    }

    #[test]
    fn pack_scratch_reuse_shrinks_cleanly() {
        // wide pack first, then a narrower one: stale lanes must gate off
        let mut pack = PackScratch::new();
        let wide = vec![1.0f32; 2 * 130];
        pack.pack_rows(&wide, 2, 130);
        let narrow = vec![0.0f32, 1.0, -1.0];
        pack.pack_rows(&narrow, 1, 3);
        assert_eq!(pack.rows(), 1);
        let (sign, nz) = pack.row(0);
        assert_eq!(sign, &[0b010u64]);
        assert_eq!(nz, &[0b110u64]);
    }

    #[test]
    fn binary_vectors_never_rest() {
        let mut rng = Prng::new(3);
        let m = 130;
        let a: Vec<f32> = (0..m).map(|_| if rng.below(2) == 0 { -1.0 } else { 1.0 }).collect();
        let w: Vec<f32> = (0..m).map(|_| if rng.below(2) == 0 { -1.0 } else { 1.0 }).collect();
        let cols = BitplaneCols::pack_cols(&w, m, 1);
        let mut out = vec![0.0f32; 1];
        let mut stats = GateStats::default();
        gated_xnor_gemm(&a, 1, &cols, &mut out, &mut stats, &mut PackScratch::new());
        assert_eq!(stats.xnor, m as u64);
        assert_eq!(stats.resting(), 0);
        assert_eq!(stats.x_zero_fraction(), 0.0);
        let mut want = vec![0.0f32; 1];
        scalar_gemm(&a, 1, &w, m, 1, &mut want);
        assert_eq!(out, want);
    }

    #[test]
    fn zero_operands_gate_off_and_tail_lanes_are_clean() {
        // all-zero activations: every word is skipped, dot = 0, bitcount 0
        let m = 100; // tail lanes 100..128 must not leak into counts
        let a = vec![0.0f32; m];
        let w = vec![1.0f32; m];
        let cols = BitplaneCols::pack_cols(&w, m, 1);
        let mut out = vec![9.0f32; 1];
        let mut stats = GateStats::default();
        gated_xnor_gemm(&a, 1, &cols, &mut out, &mut stats, &mut PackScratch::new());
        assert_eq!(out[0], 0.0);
        assert_eq!(stats.xnor, 0);
        assert_eq!(stats.bitcount, 0);
        assert_eq!(stats.resting(), m as u64);
        assert!((stats.x_zero_fraction() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gate_counts_match_hand_example() {
        // Fig. 12-style: w = [1, 0, -1], x = [1, 1, 0]
        // pairs: (1,1) fires (+1), (0,1) rests, (-1,0) rests
        let w = vec![1.0, 0.0, -1.0];
        let x = vec![1.0, 1.0, 0.0];
        let cols = BitplaneCols::pack_cols(&w, 3, 1);
        let mut out = vec![0.0f32; 1];
        let mut stats = GateStats::default();
        gated_xnor_gemm(&x, 1, &cols, &mut out, &mut stats, &mut PackScratch::new());
        assert_eq!(out[0], 1.0);
        assert_eq!(stats.xnor, 1);
        assert_eq!(stats.resting(), 2);
        assert_eq!(stats.bitcount, 1);
    }

    #[test]
    fn pack_rows_of_matches_cols_of_transpose() {
        let mut rng = Prng::new(9);
        let (m, n) = (70usize, 130usize);
        let w = random_ternary(&mut rng, m * n);
        let mut wt = vec![0.0f32; n * m];
        for i in 0..m {
            for j in 0..n {
                wt[j * m + i] = w[i * n + j];
            }
        }
        // rows of w == cols of wᵀ, plane for plane
        let rows = BitplaneCols::pack_rows_of(&w, m, n);
        let cols_t = BitplaneCols::pack_cols(&wt, n, m);
        assert_eq!(rows.m, n);
        assert_eq!(rows.n, m);
        for i in 0..m {
            assert_eq!(rows.col(i), cols_t.col(i), "row {i}");
        }
    }

    #[test]
    fn packing_from_packed_tensor_matches_f32_packing() {
        use crate::ternary::{DiscreteSpace, PackedTensor};
        let mut rng = Prng::new(31);
        for space in [DiscreteSpace::TERNARY, DiscreteSpace::BINARY] {
            let (m, n) = (67usize, 9usize);
            let vals: Vec<f32> =
                (0..m * n).map(|_| space.state(rng.below(space.n_states()))).collect();
            let p = PackedTensor::pack(&vals, &[m, n], space);
            let a = BitplaneCols::pack_cols(&vals, m, n);
            let b = BitplaneCols::pack_cols_from_packed(&p, m, n);
            for j in 0..n {
                assert_eq!(a.col(j), b.col(j), "col {j}");
            }
            let c = BitplaneCols::pack_rows_of(&vals, m, n);
            let d = BitplaneCols::pack_rows_from_packed(&p, m, n);
            for i in 0..m {
                assert_eq!(c.col(i), d.col(i), "row {i}");
            }
            assert!(b.plane_bytes() > 0);
        }
    }

    #[test]
    fn split_rows_mut_packs_like_set_row() {
        let mut rng = Prng::new(13);
        let (rows, m) = (11usize, 90usize);
        let a = random_ternary(&mut rng, rows * m);
        let mut serial = PackScratch::new();
        serial.pack_rows(&a, rows, m);
        let mut par = PackScratch::new();
        par.reset(rows, m);
        let chunks = par.split_rows_mut(4); // 4, 4, 3 rows
        assert_eq!(chunks.len(), 3);
        for (ci, mut ch) in chunks.into_iter().enumerate() {
            for r in 0..ch.rows() {
                let g = ci * 4 + r;
                ch.set_row(r, &a[g * m..(g + 1) * m]);
            }
        }
        for r in 0..rows {
            assert_eq!(par.row(r), serial.row(r), "row {r}");
        }
    }

    #[test]
    fn stats_merge_accumulates() {
        let mut a = GateStats { xnor: 3, total: 10, bitcount: 1, evals: 2, x_nonzero: 4, x_count: 5 };
        let b = GateStats { xnor: 1, total: 10, bitcount: 1, evals: 2, x_nonzero: 1, x_count: 5 };
        a.merge(&b);
        assert_eq!(a.xnor, 4);
        assert_eq!(a.total, 20);
        assert_eq!(a.resting(), 16);
        assert_eq!(a.x_count, 10);
    }
}
