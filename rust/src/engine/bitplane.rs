//! Word-parallel bit-plane kernels for the gated-XNOR forward pass.
//!
//! A ternary vector v ∈ {-1, 0, +1}^M is stored as two u64 bit planes:
//! the **sign** plane (bit set iff v_i = +1) and the **nonzero** plane
//! (bit set iff v_i ≠ 0). A binary vector ({-1, +1}) is the special case
//! whose nonzero plane is all ones. The dot product of two such vectors is
//!
//! ```text
//! gate  = a_nz & w_nz                      (both operands non-zero)
//! agree = !(a_sign ^ w_sign) & gate        (XNOR of the sign bits, gated)
//! dot  += 2·popcount(agree) − popcount(gate)
//! ```
//!
//! which is the paper's Fig. 11f compute unit executed 64 lanes at a time:
//! an XNOR fires only where `gate` is set; everywhere else the unit rests.
//! Words whose gate is all-zero are skipped outright — the event-driven
//! zero-state gate at word granularity. [`GateStats`] counts the ops that
//! actually fired so the hwsim's Table 2 predictions can be cross-checked
//! against executed reality (`hwsim::counts::gate_rate_matches`).
//!
//! # Multi-bitplane decomposition (eq. 2 / Fig. 13 spaces)
//!
//! The same kernels cover every `Z_N` space via a **signed magnitude
//! decomposition**: a value v on the `Z_N` grid is `sign · q · dz` with
//! `q ∈ {0, …, 2^{N−1}}`, so one shared sign plane plus the `N` binary
//! digit planes of `q` (LSB first) represent the whole space; the nonzero
//! plane is the OR of the digit planes and keeps serving as the
//! word-granular zero-skip gate. A dot product of two such vectors is a
//! short sum of the ternary word kernel over digit-plane pairs:
//!
//! ```text
//! Σᵢ aᵢ·wᵢ = dz_a·dz_w · Σ_{p,q} 2^{p+q} · [2·pop(agree & aₚ & w_q) − pop(aₚ & w_q)]
//! ```
//!
//! Binary and ternary are the degenerate cases with a single digit plane
//! (`q ∈ {0, 1}`) that *is* the nonzero plane and `dz = 1` — exactly the
//! layout above, so nothing changes on the hot path. [`PlaneSpec`] names
//! a side's layout; every integer partial dot is exact, so multi-level
//! results equal the f64 scalar oracle bit for bit (the scale factors are
//! powers of two and commute with rounding).
//!
//! # SIMD-wide lanes
//!
//! The kernels walk [`LANE_WORDS`] u64 words (one 64-byte cache line) per
//! iteration with unrolled popcounts and hoist the zero-skip gate to lane
//! granularity: one OR across the lane's gate words decides whether the
//! whole lane rests. To make those lane loads aligned and branch-free,
//! every plane buffer lives in a 64-byte-aligned [`AlignedWords`] and
//! every per-row / per-column stride is padded to a whole lane
//! ([`words_stride`]); padding words are kept zero, so they gate off and
//! contribute nothing to dots or [`GateStats`]. The lane width is a const
//! generic on [`gated_dot_lanes`] / [`gated_packed_rows_range_width`]
//! (the bench harness sweeps 1/4/8); all public entry points use
//! `LANE_WORDS`. Every lane width produces bit-identical results — the
//! dot stays an exact integer — and the optional `portable-simd` feature
//! (nightly `std::simd`) dispatches the 8-word lane body through
//! explicit SIMD with the same contract.

use crate::ternary::DiscreteSpace;
use crate::util::align::AlignedWords;

/// u64 words needed to hold `m` lanes.
pub const fn words_for(m: usize) -> usize {
    crate::util::div_ceil(m, 64)
}

/// u64 words per kernel lane: one 64-byte cache line, matching the
/// alignment of every plane buffer (`util::align`).
pub const LANE_WORDS: usize = crate::util::align::LINE_WORDS;

/// Plane stride (in words) for `m` lanes: [`words_for`] rounded up to a
/// whole kernel lane, so per-row / per-column plane slices start and end
/// on cache-line boundaries. The padding words are always packed to zero
/// (they gate off), which keeps dots, stats, and backward accumulations
/// exactly what the logical `m` lanes dictate.
pub const fn words_stride(m: usize) -> usize {
    crate::util::div_ceil(words_for(m), LANE_WORDS) * LANE_WORDS
}

/// Pack grid values into sign/nonzero planes. Values must lie in
/// {-1.0, 0.0, +1.0}. The destination slices are cleared in full — not
/// just the `words_for(vals.len())` prefix — so every lane up to the
/// caller's (lane-padded) stride gates off even when a reused scratch
/// previously held a wider pack; lane-granular reads never see stale
/// gate bits.
pub fn pack_row_into(vals: &[f32], sign: &mut [u64], nz: &mut [u64]) {
    let words = words_for(vals.len());
    debug_assert!(sign.len() >= words && nz.len() >= words);
    sign.fill(0);
    nz.fill(0);
    for (i, &v) in vals.iter().enumerate() {
        debug_assert!(
            v == -1.0 || v == 0.0 || v == 1.0,
            "non-ternary value {v} in bitplane pack"
        );
        let b = 1u64 << (i % 64);
        if v > 0.0 {
            sign[i / 64] |= b;
        }
        if v != 0.0 {
            nz[i / 64] |= b;
        }
    }
}

/// Bit-plane layout of one packed operand side: a grid value is
/// `sign · q · scale` with the magnitude `q` spread over `mag_planes`
/// binary digit planes (LSB first). `mag_planes == 0` is the
/// binary/ternary layout, where `q ∈ {0, 1}` and the nonzero plane *is*
/// the single digit plane (weight 2^0) — the hot path stays untouched.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlaneSpec {
    /// explicit magnitude digit planes (0 = binary/ternary single-plane)
    pub mag_planes: u32,
    /// grid spacing dz: a packed value is sign · q · scale
    pub scale: f32,
    /// 1/scale — exact, both are powers of two
    pub inv_scale: f32,
}

impl PlaneSpec {
    /// The binary/ternary layout: sign + nonzero planes only, unit scale.
    pub const SINGLE: PlaneSpec = PlaneSpec { mag_planes: 0, scale: 1.0, inv_scale: 1.0 };

    /// Layout for values on the `space` grid. `Z_N` with N ≥ 2 needs the
    /// N digit planes of `q ∈ [0, 2^{N−1}]` and scale `dz = 2^{1−N}`.
    pub fn for_space(space: DiscreteSpace) -> PlaneSpec {
        if space.n_states() <= 3 {
            PlaneSpec::SINGLE
        } else {
            PlaneSpec {
                mag_planes: space.n(),
                scale: space.dz(),
                inv_scale: space.half_levels(),
            }
        }
    }

    /// Layout for the phi_r quantizer's outputs at half-level count `hl`
    /// (= 2^{N−1} for the `Z_N` activation space): values `sign · j / hl`
    /// with `j ∈ 0..=hl`. `hl <= 1` (binary/ternary/N=0) packs single-plane.
    pub fn for_levels(hl: f32) -> PlaneSpec {
        if hl <= 1.0 {
            PlaneSpec::SINGLE
        } else {
            debug_assert!(hl.log2().fract() == 0.0, "hl {hl} is not a power of two");
            PlaneSpec { mag_planes: hl.log2() as u32 + 1, scale: 1.0 / hl, inv_scale: hl }
        }
    }
}

/// Quantize a grid value to its integer digit magnitude `q = |v|·inv_scale`,
/// asserting (debug) that `v` lies on the grid and `q` fits `planes` digit
/// planes — the one lane→planes encoding both packers share.
#[inline]
fn lane_magnitude(v: f32, inv_scale: f32, planes: usize) -> u64 {
    let q = (v.abs() * inv_scale).round() as u64;
    debug_assert!(
        (q as f32 / inv_scale - v.abs()).abs() < 1e-5 && q < (1u64 << planes),
        "off-grid value {v} in multi-bitplane pack"
    );
    q
}

/// [`pack_row_into`]'s multi-plane twin: grid values of spacing
/// `1/inv_scale` become sign/nonzero planes plus the digit planes of the
/// integer magnitude `q = |v|·inv_scale`. Like [`pack_row_into`], every
/// destination slice is cleared in full, so all lanes up to the padded
/// stride gate off.
pub fn pack_row_multi_into(
    vals: &[f32],
    inv_scale: f32,
    sign: &mut [u64],
    nz: &mut [u64],
    mag: &mut [&mut [u64]],
) {
    sign.fill(0);
    nz.fill(0);
    for m in mag.iter_mut() {
        m.fill(0);
    }
    for (i, &v) in vals.iter().enumerate() {
        let q = lane_magnitude(v, inv_scale, mag.len());
        if q == 0 {
            continue;
        }
        let wi = i / 64;
        let b = 1u64 << (i % 64);
        nz[wi] |= b;
        if v > 0.0 {
            sign[wi] |= b;
        }
        for (p, m) in mag.iter_mut().enumerate() {
            if (q >> p) & 1 == 1 {
                m[wi] |= b;
            }
        }
    }
}

/// The columns of a row-major (m × n) weight matrix, each packed into
/// sign/nonzero planes (done once at engine load; HWIO conv weights
/// flatten to exactly this layout with m = k·k·cin).
///
/// The struct is direction-agnostic: it holds `n` plane pairs of `m`
/// lanes each. [`BitplaneCols::pack_rows_of`] packs the *rows* of a
/// matrix instead (n lanes per plane, m planes) — the layout the
/// backward pass streams for `dX = dY·Wᵀ`, where each output element
/// walks one weight row across its output-channel lanes.
pub struct BitplaneCols {
    sign: AlignedWords,
    nz: AlignedWords,
    /// magnitude digit planes (LSB first), each `words * n` like `sign`;
    /// empty for the binary/ternary layout where `nz` is the digit plane
    mag: Vec<AlignedWords>,
    /// grid spacing dz of the packed values (1.0 for binary/ternary)
    scale: f32,
    /// occupancy map: popcount of nonzero-gate bits per `LANE_WORDS` tile,
    /// `words / LANE_WORDS` entries per column — the tile-skip kernels
    /// test it before touching a tile's plane words
    occ: Vec<u32>,
    pub m: usize,
    pub n: usize,
    /// plane stride per column: `words_stride(m)` — lane-padded, padding
    /// words zero
    pub words: usize,
}

/// Per-lane-tile popcounts of a nonzero plane: one entry per
/// [`LANE_WORDS`] words. Plane strides are lane-padded, so the chunks
/// align to per-row / per-column tiles and padding words contribute zero.
fn tile_occ(nz: &[u64]) -> Vec<u32> {
    nz.chunks(LANE_WORDS).map(|c| c.iter().map(|w| w.count_ones()).sum()).collect()
}

/// [`tile_occ`] into caller-owned storage: refresh one row's occupancy
/// entries after its nonzero plane was (re)packed.
fn fill_row_occ(nz: &[u64], occ: &mut [u32]) {
    for (chunk, c) in nz.chunks(LANE_WORDS).zip(occ.iter_mut()) {
        *c = chunk.iter().map(|w| w.count_ones()).sum();
    }
}

impl BitplaneCols {
    pub fn pack_cols(w: &[f32], m: usize, n: usize) -> Self {
        assert_eq!(w.len(), m * n, "weight matrix shape mismatch");
        let words = words_stride(m);
        let mut sign = AlignedWords::zeroed(words * n);
        let mut nz = AlignedWords::zeroed(words * n);
        for i in 0..m {
            let wi = i / 64;
            let b = 1u64 << (i % 64);
            for (j, &v) in w[i * n..(i + 1) * n].iter().enumerate() {
                debug_assert!(
                    v == -1.0 || v == 0.0 || v == 1.0,
                    "non-ternary weight {v} in bitplane pack"
                );
                if v > 0.0 {
                    sign[j * words + wi] |= b;
                }
                if v != 0.0 {
                    nz[j * words + wi] |= b;
                }
            }
        }
        let occ = tile_occ(&nz);
        BitplaneCols { sign, nz, mag: Vec::new(), scale: 1.0, occ, m, n, words }
    }

    /// [`BitplaneCols::pack_cols`] for values on an arbitrary `Z_N` grid:
    /// binary/ternary spaces take the single-plane fast layout, wider
    /// spaces get the multi-bitplane magnitude decomposition.
    pub fn pack_cols_space(w: &[f32], m: usize, n: usize, space: DiscreteSpace) -> Self {
        let spec = PlaneSpec::for_space(space);
        if spec.mag_planes == 0 {
            return Self::pack_cols(w, m, n);
        }
        assert_eq!(w.len(), m * n, "weight matrix shape mismatch");
        let words = words_stride(m);
        let mut cols = BitplaneCols {
            sign: AlignedWords::zeroed(words * n),
            nz: AlignedWords::zeroed(words * n),
            mag: vec![AlignedWords::zeroed(words * n); spec.mag_planes as usize],
            scale: spec.scale,
            occ: Vec::new(),
            m,
            n,
            words,
        };
        for i in 0..m {
            for (j, &v) in w[i * n..(i + 1) * n].iter().enumerate() {
                cols.set_lane_multi(j * words, i, v, spec.inv_scale);
            }
        }
        cols.occ = tile_occ(&cols.nz);
        cols
    }

    /// [`BitplaneCols::pack_rows_of`] for an arbitrary `Z_N` grid.
    pub fn pack_rows_space(w: &[f32], rows: usize, lanes: usize, space: DiscreteSpace) -> Self {
        let spec = PlaneSpec::for_space(space);
        if spec.mag_planes == 0 {
            return Self::pack_rows_of(w, rows, lanes);
        }
        assert_eq!(w.len(), rows * lanes, "weight matrix shape mismatch");
        let words = words_stride(lanes);
        let mut cols = BitplaneCols {
            sign: AlignedWords::zeroed(words * rows),
            nz: AlignedWords::zeroed(words * rows),
            mag: vec![AlignedWords::zeroed(words * rows); spec.mag_planes as usize],
            scale: spec.scale,
            occ: Vec::new(),
            m: lanes,
            n: rows,
            words,
        };
        for i in 0..rows {
            for (j, &v) in w[i * lanes..(i + 1) * lanes].iter().enumerate() {
                cols.set_lane_multi(i * words, j, v, spec.inv_scale);
            }
        }
        cols.occ = tile_occ(&cols.nz);
        cols
    }

    /// Set one lane of one plane-pair column: `base` addresses the
    /// column's first word, `lane` the bit. Used by the `_space` packers;
    /// the lane encoding is [`lane_magnitude`], shared with the row packer.
    #[inline]
    fn set_lane_multi(&mut self, base: usize, lane: usize, v: f32, inv_scale: f32) {
        let q = lane_magnitude(v, inv_scale, self.mag.len());
        if q == 0 {
            return;
        }
        let wi = base + lane / 64;
        let b = 1u64 << (lane % 64);
        self.nz[wi] |= b;
        if v > 0.0 {
            self.sign[wi] |= b;
        }
        for (p, m) in self.mag.iter_mut().enumerate() {
            if (q >> p) & 1 == 1 {
                m[wi] |= b;
            }
        }
    }

    /// Pack the *rows* of a row-major (rows × lanes) matrix: one plane
    /// pair per row, `lanes` lanes each. `col(i)` then returns row `i`'s
    /// planes. This is the weight layout of the backward `dX` kernel.
    pub fn pack_rows_of(w: &[f32], rows: usize, lanes: usize) -> Self {
        assert_eq!(w.len(), rows * lanes, "weight matrix shape mismatch");
        let words = words_stride(lanes);
        let mut sign = AlignedWords::zeroed(words * rows);
        let mut nz = AlignedWords::zeroed(words * rows);
        for i in 0..rows {
            let (lo, hi) = (i * words, (i + 1) * words);
            pack_row_into(&w[i * lanes..(i + 1) * lanes], &mut sign[lo..hi], &mut nz[lo..hi]);
        }
        let occ = tile_occ(&nz);
        BitplaneCols { sign, nz, mag: Vec::new(), scale: 1.0, occ, m: lanes, n: rows, words }
    }

    /// [`BitplaneCols::pack_cols`] reading grid values straight out of a
    /// packed discrete tensor — no f32 expansion of the weights is ever
    /// materialized (the training engine's no-hidden-weight path). Any
    /// `Z_N` space works: wider-than-ternary spaces take the
    /// multi-bitplane layout.
    pub fn pack_cols_from_packed(p: &crate::ternary::PackedTensor, m: usize, n: usize) -> Self {
        assert_eq!(p.len(), m * n, "packed tensor shape mismatch");
        let spec = PlaneSpec::for_space(p.space());
        let words = words_stride(m);
        let mut cols = BitplaneCols {
            sign: AlignedWords::zeroed(words * n),
            nz: AlignedWords::zeroed(words * n),
            mag: vec![AlignedWords::zeroed(words * n); spec.mag_planes as usize],
            scale: spec.scale,
            occ: Vec::new(),
            m,
            n,
            words,
        };
        for i in 0..m {
            let wi = i / 64;
            let b = 1u64 << (i % 64);
            for j in 0..n {
                let v = p.get(i * n + j);
                if spec.mag_planes == 0 {
                    if v > 0.0 {
                        cols.sign[j * words + wi] |= b;
                    }
                    if v != 0.0 {
                        cols.nz[j * words + wi] |= b;
                    }
                } else {
                    cols.set_lane_multi(j * words, i, v, spec.inv_scale);
                }
            }
        }
        cols.occ = tile_occ(&cols.nz);
        cols
    }

    /// [`BitplaneCols::pack_rows_of`] straight out of a packed tensor
    /// (row-major rows × lanes), again without any f32 weight buffer and
    /// for any `Z_N` space.
    pub fn pack_rows_from_packed(
        p: &crate::ternary::PackedTensor,
        rows: usize,
        lanes: usize,
    ) -> Self {
        assert_eq!(p.len(), rows * lanes, "packed tensor shape mismatch");
        let spec = PlaneSpec::for_space(p.space());
        let words = words_stride(lanes);
        let mut cols = BitplaneCols {
            sign: AlignedWords::zeroed(words * rows),
            nz: AlignedWords::zeroed(words * rows),
            mag: vec![AlignedWords::zeroed(words * rows); spec.mag_planes as usize],
            scale: spec.scale,
            occ: Vec::new(),
            m: lanes,
            n: rows,
            words,
        };
        for i in 0..rows {
            let base = i * words;
            for j in 0..lanes {
                let v = p.get(i * lanes + j);
                if spec.mag_planes == 0 {
                    let b = 1u64 << (j % 64);
                    if v > 0.0 {
                        cols.sign[base + j / 64] |= b;
                    }
                    if v != 0.0 {
                        cols.nz[base + j / 64] |= b;
                    }
                } else {
                    cols.set_lane_multi(base, j, v, spec.inv_scale);
                }
            }
        }
        cols.occ = tile_occ(&cols.nz);
        cols
    }

    /// Bytes held by the sign + nonzero (+ magnitude) planes.
    pub fn plane_bytes(&self) -> usize {
        (self.sign.len() + self.nz.len() + self.mag.iter().map(|m| m.len()).sum::<usize>()) * 8
    }

    /// (sign, nonzero) planes of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u64], &[u64]) {
        let s = j * self.words;
        (&self.sign[s..s + self.words], &self.nz[s..s + self.words])
    }

    /// Explicit magnitude digit planes (0 = binary/ternary layout).
    #[inline]
    pub fn n_mag(&self) -> u32 {
        self.mag.len() as u32
    }

    /// Grid spacing of the packed values (1.0 for binary/ternary).
    #[inline]
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Fill `buf` with column `j`'s magnitude digit-plane slices (LSB
    /// first); the single-plane layout contributes its nonzero plane with
    /// digit weight 2^0.
    pub fn fill_col_mag<'a>(&'a self, j: usize, buf: &mut Vec<&'a [u64]>) {
        buf.clear();
        self.append_col_mag(j, buf);
    }

    /// [`BitplaneCols::fill_col_mag`] without the clear — the tiled
    /// kernel batches one tile's columns into a flat pool this way.
    pub fn append_col_mag<'a>(&'a self, j: usize, buf: &mut Vec<&'a [u64]>) {
        let s = j * self.words;
        if self.mag.is_empty() {
            buf.push(&self.nz[s..s + self.words]);
        } else {
            for m in &self.mag {
                buf.push(&m[s..s + self.words]);
            }
        }
    }

    /// Occupancy map of column `j`: nonzero-gate popcount per
    /// [`LANE_WORDS`] tile, `words / LANE_WORDS` entries.
    #[inline]
    pub fn col_occ(&self, j: usize) -> &[u32] {
        let tiles = self.words / LANE_WORDS;
        &self.occ[j * tiles..(j + 1) * tiles]
    }

    /// Fraction of non-zero lanes across the whole packed matrix
    /// (1.0 for degenerate empty shapes — the dense lane path is the
    /// safe default there).
    pub fn occupancy(&self) -> f64 {
        if self.m == 0 || self.n == 0 {
            return 1.0;
        }
        let nzb: u64 = self.occ.iter().map(|&c| c as u64).sum();
        nzb as f64 / (self.m * self.n) as f64
    }
}

/// Gated-XNOR dot product of one packed row against one packed column.
/// Returns `(dot, active)`: the exact integer Σ aᵢ·wᵢ and the number of
/// XNOR ops that fired (lanes where both operands were non-zero).
/// Delegates to [`gated_dot_lanes`] at the shipped lane width.
#[inline]
pub fn gated_dot(a_sign: &[u64], a_nz: &[u64], w_sign: &[u64], w_nz: &[u64]) -> (i64, u64) {
    gated_dot_lanes::<LANE_WORDS>(a_sign, a_nz, w_sign, w_nz)
}

/// [`gated_dot`] at an explicit lane width `L` (u64 words per iteration):
/// the lane body ORs the `L` gate words once — if the whole lane rests it
/// is skipped outright — and otherwise runs `L` unrolled popcount steps
/// with no per-word branch. Slices shorter than a lane multiple finish in
/// a scalar tail. Every `L` produces the same exact integer dot; the
/// width is public so the bench harness can sweep 1/4/8 and the tests can
/// pin width-invariance. With the `portable-simd` feature the
/// `L == LANE_WORDS` body dispatches through `std::simd`.
#[inline]
pub fn gated_dot_lanes<const L: usize>(
    a_sign: &[u64],
    a_nz: &[u64],
    w_sign: &[u64],
    w_nz: &[u64],
) -> (i64, u64) {
    #[cfg(feature = "portable-simd")]
    {
        if L == LANE_WORDS {
            return simd::gated_dot_simd(a_sign, a_nz, w_sign, w_nz);
        }
    }
    let n = w_sign.len();
    debug_assert!(a_sign.len() >= n && a_nz.len() >= n && w_nz.len() >= n);
    let mut pos = 0u64; // popcount of gated sign agreements, all lanes
    let mut active = 0u64;
    let main = n - n % L.max(1);
    let mut k = 0;
    while k < main {
        let mut gates = [0u64; L];
        let mut lane_or = 0u64;
        for i in 0..L {
            gates[i] = a_nz[k + i] & w_nz[k + i];
            lane_or |= gates[i];
        }
        if lane_or != 0 {
            for i in 0..L {
                let agree = !(a_sign[k + i] ^ w_sign[k + i]) & gates[i];
                pos += agree.count_ones() as u64;
                active += gates[i].count_ones() as u64;
            }
        }
        k += L;
    }
    while k < n {
        let gate = a_nz[k] & w_nz[k];
        if gate != 0 {
            let agree = !(a_sign[k] ^ w_sign[k]) & gate;
            pos += agree.count_ones() as u64;
            active += gate.count_ones() as u64;
        }
        k += 1;
    }
    // Σ_words (2·pop(agree) − pop(gate)) = 2·pos − active, exactly
    (2 * pos as i64 - active as i64, active)
}

/// The pre-lane word-at-a-time kernel, kept as the scalar fallback the
/// lane widths are pinned against (tests) and the bench's scalar
/// baseline. Identical contract to [`gated_dot`].
pub fn gated_dot_scalar(a_sign: &[u64], a_nz: &[u64], w_sign: &[u64], w_nz: &[u64]) -> (i64, u64) {
    let mut dot = 0i64;
    let mut active = 0u64;
    for k in 0..w_sign.len() {
        let gate = a_nz[k] & w_nz[k];
        if gate == 0 {
            // every unit in this word rests: no XNOR, no accumulate
            continue;
        }
        let agree = !(a_sign[k] ^ w_sign[k]) & gate;
        let fired = gate.count_ones() as i64;
        dot += 2 * agree.count_ones() as i64 - fired;
        active += fired as u64;
    }
    (dot, active)
}

/// `std::simd` lane body for the 8-word kernel (nightly-only, behind the
/// off-by-default `portable-simd` feature). Same exact-integer contract:
/// popcounts are still taken per word, so results are bit-identical to
/// the scalar lane body.
#[cfg(feature = "portable-simd")]
mod simd {
    use super::LANE_WORDS;
    use std::simd::{num::SimdUint, u64x8};

    pub(super) fn gated_dot_simd(
        a_sign: &[u64],
        a_nz: &[u64],
        w_sign: &[u64],
        w_nz: &[u64],
    ) -> (i64, u64) {
        let n = w_sign.len();
        debug_assert!(a_sign.len() >= n && a_nz.len() >= n && w_nz.len() >= n);
        let mut pos = 0u64;
        let mut active = 0u64;
        let main = n - n % LANE_WORDS;
        let mut k = 0;
        while k < main {
            let gate = u64x8::from_slice(&a_nz[k..]) & u64x8::from_slice(&w_nz[k..]);
            if gate.reduce_or() != 0 {
                let agree =
                    !(u64x8::from_slice(&a_sign[k..]) ^ u64x8::from_slice(&w_sign[k..])) & gate;
                for (g, a) in gate.to_array().into_iter().zip(agree.to_array()) {
                    active += g.count_ones() as u64;
                    pos += a.count_ones() as u64;
                }
            }
            k += LANE_WORDS;
        }
        while k < n {
            let gate = a_nz[k] & w_nz[k];
            if gate != 0 {
                let agree = !(a_sign[k] ^ w_sign[k]) & gate;
                pos += agree.count_ones() as u64;
                active += gate.count_ones() as u64;
            }
            k += 1;
        }
        (2 * pos as i64 - active as i64, active)
    }
}

/// One word of the multi-bitplane dot: union-gate check, digit-pair
/// partial dots. Shared by the lane body and the scalar tail of
/// [`gated_dot_planes_lanes`] so every width runs the identical
/// per-word arithmetic.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn dot_planes_word(
    k: usize,
    a_sign: &[u64],
    a_nz: &[u64],
    a_mag: &[&[u64]],
    w_sign: &[u64],
    w_nz: &[u64],
    w_mag: &[&[u64]],
    dot: &mut i64,
    active: &mut u64,
) {
    let gate = a_nz[k] & w_nz[k];
    if gate == 0 {
        // every unit in this word rests: no XNOR, no accumulate
        return;
    }
    *active += gate.count_ones() as u64;
    let agree = !(a_sign[k] ^ w_sign[k]);
    for (p, ap) in a_mag.iter().enumerate() {
        let apk = ap[k];
        if apk == 0 {
            continue;
        }
        for (q, wq) in w_mag.iter().enumerate() {
            let g = apk & wq[k];
            if g == 0 {
                continue;
            }
            let fired = g.count_ones() as i64;
            let pos = (agree & g).count_ones() as i64;
            *dot += (2 * pos - fired) << (p + q);
        }
    }
}

/// [`gated_dot`] generalized to multi-bitplane operands: `a_mag`/`w_mag`
/// are the magnitude digit-plane lists (LSB first; pass the nonzero plane
/// alone for a binary/ternary side). Returns the exact integer
/// `Σᵢ signᵢ·qa_i·qw_i` — the dot in units of `scale_a · scale_w` — plus
/// the active (both-nonzero) lane count. Whole words rest on the union
/// gate exactly like the ternary kernel; the digit-pair loop is the
/// "short sum of word kernels" of the module docs. Delegates to
/// [`gated_dot_planes_lanes`] at the shipped lane width.
pub fn gated_dot_planes(
    a_sign: &[u64],
    a_nz: &[u64],
    a_mag: &[&[u64]],
    w_sign: &[u64],
    w_nz: &[u64],
    w_mag: &[&[u64]],
) -> (i64, u64) {
    gated_dot_planes_lanes::<LANE_WORDS>(a_sign, a_nz, a_mag, w_sign, w_nz, w_mag)
}

/// [`gated_dot_planes`] at an explicit lane width `L`: the union gate is
/// OR'd across the lane's words once, skipping whole resting lanes before
/// any digit-pair work; a scalar tail covers non-multiple slices. Every
/// width yields the identical exact integer dot.
pub fn gated_dot_planes_lanes<const L: usize>(
    a_sign: &[u64],
    a_nz: &[u64],
    a_mag: &[&[u64]],
    w_sign: &[u64],
    w_nz: &[u64],
    w_mag: &[&[u64]],
) -> (i64, u64) {
    let n = w_sign.len();
    let mut dot = 0i64;
    let mut active = 0u64;
    let main = n - n % L.max(1);
    let mut k0 = 0;
    while k0 < main {
        let mut lane_or = 0u64;
        for i in 0..L {
            lane_or |= a_nz[k0 + i] & w_nz[k0 + i];
        }
        if lane_or != 0 {
            for k in k0..k0 + L {
                dot_planes_word(k, a_sign, a_nz, a_mag, w_sign, w_nz, w_mag, &mut dot, &mut active);
            }
        }
        k0 += L;
    }
    for k in main..n {
        dot_planes_word(k, a_sign, a_nz, a_mag, w_sign, w_nz, w_mag, &mut dot, &mut active);
    }
    (dot, active)
}

/// Upper bin edges of [`GateStats::occ_hist`]: a row with activation
/// occupancy `occ` lands in the first bin whose edge satisfies
/// `occ <= edge`, or in the final catch-all bin. The edges match the
/// bench harness's sparsity-sweep occupancy points, so the measured
/// histogram reads directly against the calibration data.
pub const OCC_HIST_EDGES: [f64; 4] = [0.02, 0.1, 0.5, 0.9];

/// Histogram bin of one row-occupancy measurement (see [`OCC_HIST_EDGES`]).
#[inline]
pub fn occ_bin(occ: f64) -> usize {
    OCC_HIST_EDGES.iter().position(|&e| occ <= e).unwrap_or(OCC_HIST_EDGES.len())
}

/// How a gated GEMM walks the packed operands. All three strategies are
/// pinned `==` to the f64 scalar oracle — the choice is purely a matter
/// of speed at the occupancy the batch actually has.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelStrategy {
    /// Dense lane walk: every word visited, 8-word lane-OR zero skip.
    Lane,
    /// Occupancy-guided tile skip: a `LANE_WORDS` tile is passed over
    /// when the row *or* column occupancy map says it is empty — resting
    /// weight columns compound with resting activations.
    TileSkip,
    /// Event-driven: only the non-zero activation lanes (as sorted
    /// `(index, signed magnitude)` events) are scattered against the
    /// weight planes.
    EventList,
}

impl KernelStrategy {
    /// Stable lowercase name, used in bench JSON and layer reports.
    pub fn name(&self) -> &'static str {
        match self {
            KernelStrategy::Lane => "lane",
            KernelStrategy::TileSkip => "tile_skip",
            KernelStrategy::EventList => "event_list",
        }
    }
}

/// Below this measured occupancy the event-list kernel wins: the work it
/// does is proportional to the events it visits, but it gives up the
/// word-parallel popcounts, so it needs most lanes resting (~1/16 of a
/// 64-lane word alive, calibrated with `cargo bench -- kernels`'s
/// sparsity sweep) before the trade pays.
pub const EVENT_LIST_CROSSOVER: f64 = 0.05;

/// Below this measured occupancy the tile-skip walk beats the dense lane
/// path: it only needs whole 512-lane tiles to rest occasionally, and
/// its per-tile test is two array reads, so the crossover sits near even
/// occupancy splits.
pub const TILE_SKIP_CROSSOVER: f64 = 0.5;

/// Pick the execution strategy for a batch whose measured activation
/// occupancy (fraction of non-zero states, e.g.
/// [`PackScratch::gate_occupancy`]) is `occupancy`. Every strategy is
/// exact, so the dispatch can never change results — only speed.
pub fn choose_strategy(occupancy: f64) -> KernelStrategy {
    if occupancy <= EVENT_LIST_CROSSOVER {
        KernelStrategy::EventList
    } else if occupancy < TILE_SKIP_CROSSOVER {
        KernelStrategy::TileSkip
    } else {
        KernelStrategy::Lane
    }
}

/// Tallies of what the gated kernel actually executed (per layer).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GateStats {
    /// XNOR ops that fired (both operands non-zero).
    pub xnor: u64,
    /// Nominal connections considered (fan-in × neuron evaluations).
    pub total: u64,
    /// Neuron evaluations whose accumulator woke at least once.
    pub bitcount: u64,
    /// Neuron evaluations performed.
    pub evals: u64,
    /// Non-zero activation states among those packed.
    pub x_nonzero: u64,
    /// Activation states packed (fan-in per row × rows).
    pub x_count: u64,
    /// Histogram of per-row activation occupancy over the rows the
    /// kernel consumed, binned by [`OCC_HIST_EDGES`].
    pub occ_hist: [u64; 5],
}

impl GateStats {
    /// Connections whose compute unit stayed resting.
    pub fn resting(&self) -> u64 {
        self.total - self.xnor
    }

    /// Measured resting probability (Table 2's last column, executed).
    pub fn resting_rate(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.resting() as f64 / self.total as f64
        }
    }

    /// Measured zero-state fraction of the activations the kernel saw.
    pub fn x_zero_fraction(&self) -> f64 {
        if self.x_count == 0 {
            0.0
        } else {
            1.0 - self.x_nonzero as f64 / self.x_count as f64
        }
    }

    pub fn merge(&mut self, o: &GateStats) {
        self.xnor += o.xnor;
        self.total += o.total;
        self.bitcount += o.bitcount;
        self.evals += o.evals;
        self.x_nonzero += o.x_nonzero;
        self.x_count += o.x_count;
        for (a, b) in self.occ_hist.iter_mut().zip(o.occ_hist.iter()) {
            *a += b;
        }
    }
}

/// Caller-owned pool of packed activation rows: the sign/nonzero planes
/// of a (rows × m) ternary matrix, row-major. `reset` reuses capacity, so
/// a scratch held across `infer_batch` calls makes the steady-state pack
/// allocation-free — this replaced the fresh per-call `Vec`s that used to
/// be the last allocation in the inference hot loop. The packed-domain
/// im2col conv fills one scratch per sample (one row per output pixel)
/// and dense layers pack the whole sub-batch; both then fire through the
/// same tiled kernel, [`gated_packed_rows`].
#[derive(Default)]
pub struct PackScratch {
    sign: AlignedWords,
    nz: AlignedWords,
    /// magnitude digit planes (multi-bitplane layouts only); capacity is
    /// kept across `reset_spec` calls like the sign/nz planes
    mag: Vec<AlignedWords>,
    /// current layout: 0 digit planes = binary/ternary
    n_mag: u32,
    scale: f32,
    inv_scale: f32,
    /// occupancy map: nonzero-gate popcount per `LANE_WORDS` tile,
    /// `words / LANE_WORDS` entries per row, maintained by `set_row` —
    /// essentially free, since packing already wrote every plane word.
    /// The adaptive dispatch reads it to measure a batch's occupancy and
    /// the tile-skip kernels to pass over resting tiles.
    occ: Vec<u32>,
    words: usize,
    rows: usize,
}

impl PackScratch {
    pub fn new() -> Self {
        PackScratch { scale: 1.0, inv_scale: 1.0, ..Default::default() }
    }

    /// Size for `rows` rows of `m` lanes in the binary/ternary layout,
    /// reusing capacity. Row contents are garbage until written by
    /// `set_row`.
    pub fn reset(&mut self, rows: usize, m: usize) {
        self.reset_spec(rows, m, PlaneSpec::SINGLE);
    }

    /// [`PackScratch::reset`] with an explicit plane layout (the
    /// multi-level engine's activation spaces). Capacity only ever grows,
    /// including the digit-plane pool.
    pub fn reset_spec(&mut self, rows: usize, m: usize, spec: PlaneSpec) {
        self.words = words_stride(m);
        self.rows = rows;
        self.n_mag = spec.mag_planes;
        self.scale = spec.scale;
        self.inv_scale = spec.inv_scale;
        let need = rows * self.words;
        self.sign.ensure(need);
        self.nz.ensure(need);
        let need_occ = rows * (self.words / LANE_WORDS);
        if self.occ.len() < need_occ {
            self.occ.resize(need_occ, 0);
        }
        while self.mag.len() < spec.mag_planes as usize {
            self.mag.push(AlignedWords::new());
        }
        for plane in &mut self.mag[..spec.mag_planes as usize] {
            plane.ensure(need);
        }
    }

    /// Pack one row of grid values onto the current layout's planes;
    /// `vals` must have exactly the lane count `reset` was given (the
    /// whole lane-padded row stride is cleared first, so stale bits from
    /// a previous, wider use cannot leak into lane-granular reads).
    pub fn set_row(&mut self, row: usize, vals: &[f32]) {
        debug_assert!(row < self.rows);
        debug_assert_eq!(words_stride(vals.len()), self.words, "row width mismatch");
        let (lo, hi) = (row * self.words, (row + 1) * self.words);
        if self.n_mag == 0 {
            pack_row_into(vals, &mut self.sign[lo..hi], &mut self.nz[lo..hi]);
        } else {
            let mut mags: Vec<&mut [u64]> = self.mag[..self.n_mag as usize]
                .iter_mut()
                .map(|m| &mut m[lo..hi])
                .collect();
            pack_row_multi_into(
                vals,
                self.inv_scale,
                &mut self.sign[lo..hi],
                &mut self.nz[lo..hi],
                &mut mags,
            );
        }
        let tiles = self.words / LANE_WORDS;
        fill_row_occ(&self.nz[lo..hi], &mut self.occ[row * tiles..(row + 1) * tiles]);
    }

    /// Pack a full row-major (rows × m) matrix (binary/ternary layout).
    pub fn pack_rows(&mut self, a: &[f32], rows: usize, m: usize) {
        self.pack_rows_spec(a, rows, m, PlaneSpec::SINGLE);
    }

    /// Pack a full row-major (rows × m) matrix onto `spec`'s planes.
    pub fn pack_rows_spec(&mut self, a: &[f32], rows: usize, m: usize, spec: PlaneSpec) {
        assert_eq!(a.len(), rows * m);
        self.reset_spec(rows, m, spec);
        for row in 0..rows {
            self.set_row(row, &a[row * m..(row + 1) * m]);
        }
    }

    /// (sign, nonzero) planes of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u64], &[u64]) {
        let s = i * self.words;
        (&self.sign[s..s + self.words], &self.nz[s..s + self.words])
    }

    /// Explicit magnitude digit planes of the current layout.
    #[inline]
    pub fn n_mag(&self) -> u32 {
        self.n_mag
    }

    /// Grid spacing of the current layout (1.0 for binary/ternary).
    #[inline]
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Fill `buf` with row `i`'s magnitude digit-plane slices (LSB first);
    /// the single-plane layout contributes its nonzero plane (weight 2^0).
    pub fn fill_row_mag<'a>(&'a self, i: usize, buf: &mut Vec<&'a [u64]>) {
        buf.clear();
        let s = i * self.words;
        if self.n_mag == 0 {
            buf.push(&self.nz[s..s + self.words]);
        } else {
            for m in &self.mag[..self.n_mag as usize] {
                buf.push(&m[s..s + self.words]);
            }
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Plane words per row: the lane-padded stride `words_stride(m)` of
    /// the current `reset` width. Callers sharding *logical* fan-in words
    /// should use `words_for(m)` — the padding words carry no gate bits.
    pub fn words(&self) -> usize {
        self.words
    }

    /// Occupancy map of row `i`: nonzero-gate popcount per [`LANE_WORDS`]
    /// tile, `words / LANE_WORDS` entries. Valid once `set_row` wrote the
    /// row (like the plane contents themselves).
    #[inline]
    pub fn row_occ(&self, i: usize) -> &[u32] {
        let tiles = self.words / LANE_WORDS;
        &self.occ[i * tiles..(i + 1) * tiles]
    }

    /// Total non-zero activation lanes packed into rows `[r0, r1)` — the
    /// sum of their occupancy maps, no plane walk needed.
    pub fn nz_bits(&self, r0: usize, r1: usize) -> u64 {
        let tiles = self.words / LANE_WORDS;
        self.occ[r0 * tiles..r1 * tiles].iter().map(|&c| c as u64).sum()
    }

    /// Measured activation occupancy of rows `[r0, r1)` at logical lane
    /// count `m`: the fraction of non-zero states the kernels will see.
    /// Degenerate empty ranges report 1.0 so the adaptive dispatch stays
    /// on the dense lane path.
    pub fn gate_occupancy(&self, r0: usize, r1: usize, m: usize) -> f64 {
        let rows = r1 - r0;
        if rows == 0 || m == 0 {
            return 1.0;
        }
        self.nz_bits(r0, r1) as f64 / (rows * m) as f64
    }

    /// Split the current `rows` into disjoint mutable row-range views of
    /// `rows_per_chunk` rows each (the last may be shorter), so scoped
    /// workers can pack disjoint row ranges of one shared scratch in
    /// parallel — the training engine fills the whole batch's activation
    /// planes this way and the backward pass then streams them. Views
    /// carry the current plane layout, digit planes included.
    pub fn split_rows_mut(&mut self, rows_per_chunk: usize) -> Vec<PackRowsMut<'_>> {
        let words = self.words;
        let (n_mag, inv_scale) = (self.n_mag, self.inv_scale);
        let lim = self.rows * words;
        let step = rows_per_chunk.max(1) * words;
        if lim == 0 || words == 0 {
            return Vec::new();
        }
        let tiles = words / LANE_WORDS;
        let mut occ_chunks = self.occ[..self.rows * tiles].chunks_mut(rows_per_chunk.max(1) * tiles);
        let mut mag_chunks: Vec<_> = self.mag[..n_mag as usize]
            .iter_mut()
            .map(|m| m[..lim].chunks_mut(step))
            .collect();
        self.sign[..lim]
            .chunks_mut(step)
            .zip(self.nz[..lim].chunks_mut(step))
            .map(|(sign, nz)| {
                let mag: Vec<&mut [u64]> =
                    mag_chunks.iter_mut().map(|c| c.next().unwrap()).collect();
                let occ = occ_chunks.next().unwrap();
                PackRowsMut { sign, nz, mag, occ, words, inv_scale }
            })
            .collect()
    }
}

/// A disjoint mutable row range of a [`PackScratch`] (see
/// [`PackScratch::split_rows_mut`]). Row indices are local to the view.
pub struct PackRowsMut<'a> {
    sign: &'a mut [u64],
    nz: &'a mut [u64],
    mag: Vec<&'a mut [u64]>,
    occ: &'a mut [u32],
    words: usize,
    inv_scale: f32,
}

impl PackRowsMut<'_> {
    pub fn rows(&self) -> usize {
        self.sign.len() / self.words
    }

    /// Pack one row of grid values onto the view's plane layout; `row` is
    /// local to this view and `vals` must match the scratch's lane width.
    pub fn set_row(&mut self, row: usize, vals: &[f32]) {
        debug_assert!(row < self.rows());
        debug_assert_eq!(words_stride(vals.len()), self.words, "row width mismatch");
        let (lo, hi) = (row * self.words, (row + 1) * self.words);
        if self.mag.is_empty() {
            pack_row_into(vals, &mut self.sign[lo..hi], &mut self.nz[lo..hi]);
        } else {
            let mut mags: Vec<&mut [u64]> =
                self.mag.iter_mut().map(|m| &mut m[lo..hi]).collect();
            pack_row_multi_into(
                vals,
                self.inv_scale,
                &mut self.sign[lo..hi],
                &mut self.nz[lo..hi],
                &mut mags,
            );
        }
        let tiles = self.words / LANE_WORDS;
        fill_row_occ(&self.nz[lo..hi], &mut self.occ[row * tiles..(row + 1) * tiles]);
    }
}

/// Bytes of weight bit-planes a column tile may occupy: half a typical
/// 32 KiB L1d, leaving the other half for the streaming activation rows.
const TILE_BYTES: usize = 16 * 1024;

/// Columns per tile for a given plane width: each column costs
/// `8 · planes_per_col` bytes per word (sign + nz = 2 planes for the
/// binary/ternary layout; multi-level layouts add their digit planes).
/// Wide layers (large fan-in) get narrow tiles; the clamp keeps
/// degenerate shapes sane.
fn col_tile(words: usize, planes_per_col: usize) -> usize {
    (TILE_BYTES / (8 * planes_per_col.max(1) * words.max(1))).clamp(4, 256)
}

/// Every packed row against every weight column, tiled over output-column
/// blocks sized to L1 so each tile's weight bit-planes stay cache-hot
/// while the activation rows stream past (instead of re-walking the full
/// weight matrix per row and thrashing). Writes `out[row·n + col]`; the
/// dot is an exact integer, so results are bit-identical to the untiled
/// walk in any tile order. This is the single home of the GateStats
/// counting semantics — the dense GEMM and the im2col conv both land here.
pub fn gated_packed_rows(
    pack: &PackScratch,
    cols: &BitplaneCols,
    out: &mut [f32],
    stats: &mut GateStats,
) {
    gated_packed_rows_range(pack, 0, pack.rows, cols, out, stats);
}

/// [`gated_packed_rows`] with an optional forced strategy: `None` keeps
/// the adaptive occupancy-measured dispatch, `Some(s)` pins strategy `s`
/// (the engine's diagnostics hook and the bench harness's sweep).
pub fn gated_packed_rows_with(
    pack: &PackScratch,
    cols: &BitplaneCols,
    out: &mut [f32],
    stats: &mut GateStats,
    strategy: Option<KernelStrategy>,
) {
    match strategy {
        Some(s) => gated_packed_rows_strategy(pack, 0, pack.rows, cols, out, stats, s),
        None => gated_packed_rows_range(pack, 0, pack.rows, cols, out, stats),
    }
}

/// [`gated_packed_rows`] over the row range `[r0, r1)` only, writing into
/// `out` sized `(r1 − r0) × n`. This is the unit the training engine's
/// data-parallel forward shards across workers: each shard runs the same
/// tiled walk over its own rows, and because every dot is an exact
/// integer, the concatenated result (and any stats merge) is identical to
/// one full-range call for every split.
///
/// The strategy is chosen **adaptively per call**: the range's measured
/// activation occupancy (read off the occupancy maps the packers already
/// maintain) is compared against the calibrated crossover thresholds
/// ([`choose_strategy`]) — very sparse batches run event-driven, mildly
/// sparse ones tile-skip, dense ones keep the lane walk. All three are
/// exact, so shards of one batch may legally pick different strategies
/// and still concatenate to the bit-identical full-range answer.
pub fn gated_packed_rows_range(
    pack: &PackScratch,
    r0: usize,
    r1: usize,
    cols: &BitplaneCols,
    out: &mut [f32],
    stats: &mut GateStats,
) {
    let strategy = choose_strategy(pack.gate_occupancy(r0, r1, cols.m));
    gated_packed_rows_strategy(pack, r0, r1, cols, out, stats, strategy);
}

/// [`gated_packed_rows_range`] at an explicit [`KernelStrategy`] — the
/// adaptive dispatch resolves here, and the bench harness / parity tests
/// drive each strategy directly.
pub fn gated_packed_rows_strategy(
    pack: &PackScratch,
    r0: usize,
    r1: usize,
    cols: &BitplaneCols,
    out: &mut [f32],
    stats: &mut GateStats,
    strategy: KernelStrategy,
) {
    match strategy {
        KernelStrategy::Lane => {
            gated_packed_rows_range_width::<LANE_WORDS>(pack, r0, r1, cols, out, stats)
        }
        KernelStrategy::TileSkip => gated_packed_rows_tileskip(pack, r0, r1, cols, out, stats),
        KernelStrategy::EventList => gated_packed_rows_events(pack, r0, r1, cols, out, stats),
    }
}

/// [`gated_packed_rows_range`] at an explicit kernel lane width `L` —
/// the same tiled walk over [`gated_dot_lanes`] /
/// [`gated_dot_planes_lanes`]. Public for the bench harness's 1/4/8
/// width sweep and the width-invariance tests; outputs and `GateStats`
/// tallies are bit-identical for every `L` (the innermost kernel counts
/// fired ops once, as exact integers).
pub fn gated_packed_rows_range_width<const L: usize>(
    pack: &PackScratch,
    r0: usize,
    r1: usize,
    cols: &BitplaneCols,
    out: &mut [f32],
    stats: &mut GateStats,
) {
    let rows = r1 - r0;
    let n = cols.n;
    debug_assert!(r1 <= pack.rows);
    debug_assert_eq!(pack.words, cols.words, "row/column plane width mismatch");
    assert_eq!(out.len(), rows * n);
    let m = cols.m as u64;
    row_stats_preamble(pack, r0, r1, m, stats);
    // multi-bitplane operands carry a grid scale; the hot binary/ternary
    // case keeps the raw integer path (scale product is exactly 1.0 there)
    let multi = pack.n_mag() > 0 || cols.n_mag() > 0;
    let scale = pack.scale() as f64 * cols.scale() as f64;
    let mut amag: Vec<&[u64]> = Vec::new();
    // per-tile pool of column digit-plane slices, hoisted out of the row
    // loop (they depend on j alone): `wstride` slices per column, flat
    let wstride = (cols.n_mag() as usize).max(1);
    let mut wplanes: Vec<&[u64]> = Vec::new();
    // the tile budget counts every plane a column streams (2 for
    // binary/ternary — identical tiling to before — plus digit planes)
    let tile = col_tile(cols.words, 2 + cols.n_mag() as usize);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + tile).min(n);
        if multi {
            wplanes.clear();
            for j in j0..j1 {
                cols.append_col_mag(j, &mut wplanes);
            }
        }
        for row in r0..r1 {
            let (rs, rn) = pack.row(row);
            if multi {
                pack.fill_row_mag(row, &mut amag);
            }
            let orow = &mut out[(row - r0) * n..(row - r0) * n + n];
            for j in j0..j1 {
                let (ws, wn) = cols.col(j);
                let (dot, active) = if multi {
                    let wmag = &wplanes[(j - j0) * wstride..(j - j0 + 1) * wstride];
                    gated_dot_planes_lanes::<L>(rs, rn, &amag, ws, wn, wmag)
                } else {
                    gated_dot_lanes::<L>(rs, rn, ws, wn)
                };
                // exact: the integer dot times a power-of-two scale rounds
                // exactly like the f64 scalar oracle's sum of products
                orow[j] = if multi { (dot as f64 * scale) as f32 } else { dot as f32 };
                stats.xnor += active;
                if active > 0 {
                    stats.bitcount += 1;
                }
            }
        }
        j0 = j1;
    }
    // per (row, col) evaluation: fan-in connections considered, one eval
    stats.total += rows as u64 * n as u64 * m;
    stats.evals += (rows * n) as u64;
}

/// Shared per-row stats preamble of every strategy kernel: activation
/// zero-state tallies plus the occupancy-histogram bin of each consumed
/// row, read off the occupancy maps (the per-tile popcounts sum to the
/// plane's popcount, so no plane word is re-walked). Every strategy runs
/// this identically — stats cannot depend on the dispatch choice.
fn row_stats_preamble(pack: &PackScratch, r0: usize, r1: usize, m: u64, stats: &mut GateStats) {
    for row in r0..r1 {
        let nzb: u64 = pack.row_occ(row).iter().map(|&c| c as u64).sum();
        stats.x_nonzero += nzb;
        stats.x_count += m;
        let occ = if m == 0 { 0.0 } else { nzb as f64 / m as f64 };
        stats.occ_hist[occ_bin(occ)] += 1;
    }
}

/// [`gated_packed_rows_range`]'s tile-skip strategy: per (row, column)
/// pair the walk goes tile by tile ([`LANE_WORDS`] words each) and
/// consults both occupancy maps first — a tile whose row map **or**
/// column map reads zero cannot contain a set gate bit, so it is passed
/// over before any plane word is loaded. Resting weight columns thereby
/// compound with resting activations. A skipped tile has `gate ≡ 0` and
/// would have contributed nothing to dots or tallies, so outputs and
/// `GateStats` stay bit-identical to the lane walk (and the f64 scalar
/// oracle).
pub fn gated_packed_rows_tileskip(
    pack: &PackScratch,
    r0: usize,
    r1: usize,
    cols: &BitplaneCols,
    out: &mut [f32],
    stats: &mut GateStats,
) {
    let rows = r1 - r0;
    let n = cols.n;
    debug_assert!(r1 <= pack.rows);
    debug_assert_eq!(pack.words, cols.words, "row/column plane width mismatch");
    assert_eq!(out.len(), rows * n);
    let m = cols.m as u64;
    row_stats_preamble(pack, r0, r1, m, stats);
    let multi = pack.n_mag() > 0 || cols.n_mag() > 0;
    let scale = pack.scale() as f64 * cols.scale() as f64;
    let mut amag: Vec<&[u64]> = Vec::new();
    let wstride = (cols.n_mag() as usize).max(1);
    let mut wplanes: Vec<&[u64]> = Vec::new();
    let tiles = cols.words / LANE_WORDS;
    // same L1 column tiling as the lane walk: the occupancy test decides
    // *whether* a tile's words load, the tiling decides *when*
    let tile = col_tile(cols.words, 2 + cols.n_mag() as usize);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + tile).min(n);
        if multi {
            wplanes.clear();
            for j in j0..j1 {
                cols.append_col_mag(j, &mut wplanes);
            }
        }
        for row in r0..r1 {
            let (rs, rn) = pack.row(row);
            let r_occ = pack.row_occ(row);
            if multi {
                pack.fill_row_mag(row, &mut amag);
            }
            let orow = &mut out[(row - r0) * n..(row - r0) * n + n];
            for j in j0..j1 {
                let (ws, wn) = cols.col(j);
                let w_occ = cols.col_occ(j);
                let mut dot = 0i64;
                let mut active = 0u64;
                for t in 0..tiles {
                    // row×col tile intersection: either side resting
                    // skips the tile outright
                    if r_occ[t] == 0 || w_occ[t] == 0 {
                        continue;
                    }
                    let (k0, k1) = (t * LANE_WORDS, (t + 1) * LANE_WORDS);
                    if multi {
                        let wmag = &wplanes[(j - j0) * wstride..(j - j0 + 1) * wstride];
                        for k in k0..k1 {
                            dot_planes_word(
                                k, rs, rn, &amag, ws, wn, wmag, &mut dot, &mut active,
                            );
                        }
                    } else {
                        let (d, a) = gated_dot_lanes::<LANE_WORDS>(
                            &rs[k0..k1],
                            &rn[k0..k1],
                            &ws[k0..k1],
                            &wn[k0..k1],
                        );
                        dot += d;
                        active += a;
                    }
                }
                orow[j] = if multi { (dot as f64 * scale) as f32 } else { dot as f32 };
                stats.xnor += active;
                if active > 0 {
                    stats.bitcount += 1;
                }
            }
        }
        j0 = j1;
    }
    stats.total += rows as u64 * n as u64 * m;
    stats.evals += (rows * n) as u64;
}

/// One packed row range lowered to an event list: the sorted
/// `(lane index, signed magnitude)` pairs of every non-zero activation,
/// row-major with CSR-style row offsets. Built straight off the nonzero
/// plane with a `trailing_zeros` bit walk; magnitudes come from the
/// digit planes (always 1 for the single-plane layout).
pub struct EventRows {
    events: Vec<(u32, i32)>,
    row_ptr: Vec<usize>,
}

impl EventRows {
    /// Lower rows `[r0, r1)` of `pack` to events. Indices ascend within
    /// each row; padding lanes never appear (their gate bits are zero).
    pub fn from_pack(pack: &PackScratch, r0: usize, r1: usize) -> Self {
        let n_mag = pack.n_mag() as usize;
        let mut events = Vec::with_capacity(pack.nz_bits(r0, r1) as usize);
        let mut row_ptr = Vec::with_capacity(r1 - r0 + 1);
        row_ptr.push(0);
        let mut mags: Vec<&[u64]> = Vec::new();
        for row in r0..r1 {
            let (sign, nz) = pack.row(row);
            if n_mag > 0 {
                pack.fill_row_mag(row, &mut mags);
            }
            for (wi, &zw) in nz.iter().enumerate() {
                let mut z = zw;
                while z != 0 {
                    let b = z.trailing_zeros();
                    let bit = 1u64 << b;
                    let q = if n_mag == 0 {
                        1i32
                    } else {
                        let mut q = 0i32;
                        for (p, mp) in mags.iter().enumerate() {
                            if mp[wi] & bit != 0 {
                                q += 1 << p;
                            }
                        }
                        q
                    };
                    let signed = if sign[wi] & bit != 0 { q } else { -q };
                    events.push((wi as u32 * 64 + b, signed));
                    z &= z - 1;
                }
            }
            row_ptr.push(events.len());
        }
        EventRows { events, row_ptr }
    }

    /// Events of local row `i` (0 = `r0`), ascending by lane index.
    #[inline]
    pub fn row(&self, i: usize) -> &[(u32, i32)] {
        &self.events[self.row_ptr[i]..self.row_ptr[i + 1]]
    }

    /// Total events across the lowered range.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// [`gated_packed_rows_range`]'s event-driven strategy: the row range is
/// lowered to its event list once ([`EventRows`]), then each
/// (row, column) dot visits only the row's events, gating each lane
/// against the column's nonzero plane and gathering the weight magnitude
/// from the digit planes. Work scales with events × columns instead of
/// plane words × columns — the win at very low occupancy. The per-event
/// arithmetic reproduces the digit-plane dot exactly (integer products,
/// same `multi`/scale output conversion), so outputs and `GateStats` are
/// bit-identical to the lane walk and the f64 scalar oracle.
pub fn gated_packed_rows_events(
    pack: &PackScratch,
    r0: usize,
    r1: usize,
    cols: &BitplaneCols,
    out: &mut [f32],
    stats: &mut GateStats,
) {
    let rows = r1 - r0;
    let n = cols.n;
    debug_assert!(r1 <= pack.rows);
    debug_assert_eq!(pack.words, cols.words, "row/column plane width mismatch");
    assert_eq!(out.len(), rows * n);
    let m = cols.m as u64;
    row_stats_preamble(pack, r0, r1, m, stats);
    let events = EventRows::from_pack(pack, r0, r1);
    let multi = pack.n_mag() > 0 || cols.n_mag() > 0;
    let scale = pack.scale() as f64 * cols.scale() as f64;
    let mut wmag: Vec<&[u64]> = Vec::new();
    // column-outer: one column's planes load once while every row's
    // events stream past them (the weight side is the reused operand)
    for j in 0..n {
        let (ws, wn) = cols.col(j);
        cols.fill_col_mag(j, &mut wmag);
        for row in 0..rows {
            let mut dot = 0i64;
            let mut active = 0u64;
            for &(i, q) in events.row(row) {
                let wi = (i >> 6) as usize;
                let bit = 1u64 << (i & 63);
                if wn[wi] & bit == 0 {
                    continue;
                }
                active += 1;
                let mut qw = 0i64;
                for (p, mp) in wmag.iter().enumerate() {
                    if mp[wi] & bit != 0 {
                        qw += 1 << p;
                    }
                }
                // the event carries the activation's signed magnitude;
                // the weight sign applies to the gathered magnitude
                dot += if ws[wi] & bit != 0 { q as i64 * qw } else { -(q as i64) * qw };
            }
            out[row * n + j] = if multi { (dot as f64 * scale) as f32 } else { dot as f32 };
            stats.xnor += active;
            if active > 0 {
                stats.bitcount += 1;
            }
        }
    }
    stats.total += rows as u64 * n as u64 * m;
    stats.evals += (rows * n) as u64;
}

/// Gated-XNOR GEMM: `out[row·n + col] = Σᵢ a[row·m + i]·w[i, col]` for
/// ternary operands. Rows are packed into the caller-owned `pack` scratch
/// (reused across calls — no per-call allocation), then run through the
/// tiled kernel.
pub fn gated_xnor_gemm(
    a: &[f32],
    rows: usize,
    cols: &BitplaneCols,
    out: &mut [f32],
    stats: &mut GateStats,
    pack: &mut PackScratch,
) {
    assert_eq!(a.len(), rows * cols.m);
    pack.pack_rows(a, rows, cols.m);
    gated_packed_rows(pack, cols, out, stats);
}

/// [`gated_xnor_gemm`] for rows on an arbitrary discrete grid: the input
/// rows are packed onto `spec`'s planes (digit planes included) before
/// firing through the same tiled kernel. Binary/ternary `spec`s reduce to
/// `gated_xnor_gemm` exactly.
pub fn gated_gemm_spec(
    a: &[f32],
    rows: usize,
    spec: PlaneSpec,
    cols: &BitplaneCols,
    out: &mut [f32],
    stats: &mut GateStats,
    pack: &mut PackScratch,
) {
    gated_gemm_spec_with(a, rows, spec, cols, out, stats, pack, None);
}

/// [`gated_gemm_spec`] with an optional forced [`KernelStrategy`]:
/// `None` keeps the adaptive occupancy-measured dispatch.
#[allow(clippy::too_many_arguments)]
pub fn gated_gemm_spec_with(
    a: &[f32],
    rows: usize,
    spec: PlaneSpec,
    cols: &BitplaneCols,
    out: &mut [f32],
    stats: &mut GateStats,
    pack: &mut PackScratch,
    strategy: Option<KernelStrategy>,
) {
    assert_eq!(a.len(), rows * cols.m);
    pack.pack_rows_spec(a, rows, cols.m, spec);
    gated_packed_rows_with(pack, cols, out, stats, strategy);
}

/// Scalar GEMM with f64 accumulation:
/// `out[row·n + col] = Σᵢ a[row·m + i]·w[i·n + col]`. Doubles as the
/// reference the bitplane kernel is pinned against in the tests and as
/// the engine's full-precision fallback path (first layer, fp modes).
pub fn scalar_gemm(a: &[f32], rows: usize, w: &[f32], m: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), rows * m);
    assert_eq!(w.len(), m * n);
    assert_eq!(out.len(), rows * n);
    for row in 0..rows {
        let ar = &a[row * m..(row + 1) * m];
        for j in 0..n {
            let mut acc = 0.0f64;
            for i in 0..m {
                acc += ar[i] as f64 * w[i * n + j] as f64;
            }
            out[row * n + j] = acc as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn random_ternary(rng: &mut Prng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.below(3) as f32 - 1.0).collect()
    }

    #[test]
    fn gated_gemm_matches_scalar_reference() {
        let mut rng = Prng::new(7);
        // shapes straddle word edges AND column-tile edges: m = 130 gives
        // words = 3 (tile 341 -> clamped 256), so n = 300 spans two
        // tiles; m = 4100 makes the tile genuinely narrow (words = 65 ->
        // tile 15, n = 40 spans three)
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 63, 5),
            (4, 64, 8),
            (2, 65, 3),
            (5, 200, 17),
            (2, 130, 300),
            (3, 4100, 40),
        ];
        // one scratch reused across every shape: capacity reuse must not
        // leak rows/lanes between calls
        let mut pack = PackScratch::new();
        for &(rows, m, n) in &shapes {
            let a = random_ternary(&mut rng, rows * m);
            let w = random_ternary(&mut rng, m * n);
            let cols = BitplaneCols::pack_cols(&w, m, n);
            let mut got = vec![0.0f32; rows * n];
            let mut want = vec![0.0f32; rows * n];
            let mut stats = GateStats::default();
            gated_xnor_gemm(&a, rows, &cols, &mut got, &mut stats, &mut pack);
            scalar_gemm(&a, rows, &w, m, n, &mut want);
            assert_eq!(got, want, "rows={rows} m={m} n={n}");
            assert_eq!(stats.total, (rows * m * n) as u64);
            assert_eq!(stats.evals, (rows * n) as u64);
            assert_eq!(stats.x_count, (rows * m) as u64);
        }
    }

    #[test]
    fn tiled_kernel_stats_are_tile_order_independent() {
        // the same matmul through a tiny fan-in (wide tile, one block) and
        // a huge fan-in is covered above; here pin that the tallies of a
        // multi-tile walk equal the per-element definition computed by hand
        let mut rng = Prng::new(41);
        let (rows, m, n) = (3usize, 70usize, 300usize);
        let a = random_ternary(&mut rng, rows * m);
        let w = random_ternary(&mut rng, m * n);
        let cols = BitplaneCols::pack_cols(&w, m, n);
        let mut out = vec![0.0f32; rows * n];
        let mut stats = GateStats::default();
        let mut pack = PackScratch::new();
        gated_xnor_gemm(&a, rows, &cols, &mut out, &mut stats, &mut pack);
        let mut xnor = 0u64;
        let mut bitcount = 0u64;
        for row in 0..rows {
            for j in 0..n {
                let fired = (0..m)
                    .filter(|&i| a[row * m + i] != 0.0 && w[i * n + j] != 0.0)
                    .count() as u64;
                xnor += fired;
                if fired > 0 {
                    bitcount += 1;
                }
            }
        }
        assert_eq!(stats.xnor, xnor);
        assert_eq!(stats.bitcount, bitcount);
        let x_nonzero = a.iter().filter(|&&v| v != 0.0).count() as u64;
        assert_eq!(stats.x_nonzero, x_nonzero);
    }

    #[test]
    fn pack_scratch_reuse_shrinks_cleanly() {
        // wide pack first, then a narrower one: stale lanes must gate off
        let mut pack = PackScratch::new();
        let wide = vec![1.0f32; 2 * 130];
        pack.pack_rows(&wide, 2, 130);
        let narrow = vec![0.0f32, 1.0, -1.0];
        pack.pack_rows(&narrow, 1, 3);
        assert_eq!(pack.rows(), 1);
        assert_eq!(pack.words(), words_stride(3));
        let (sign, nz) = pack.row(0);
        assert_eq!(sign[0], 0b010u64);
        assert_eq!(nz[0], 0b110u64);
        // the rest of the lane-padded row stride must be cleared, or
        // lane-granular reads would see the wide pack's stale gate bits
        assert!(sign[1..].iter().all(|&w| w == 0));
        assert!(nz[1..].iter().all(|&w| w == 0));
    }

    /// Satellite: reusing a scratch after a pack with a *larger* stride
    /// must clear every word up to the new row's aligned lane boundary —
    /// `pack_row_into` clears the full stride, not just `words_for(m)`.
    #[test]
    fn pack_reuse_clears_tail_words_to_lane_boundary() {
        let mut pack = PackScratch::new();
        // 600 lanes: words_for = 10, stride = 2 lanes -> words 0..16 dirty
        pack.pack_rows(&vec![1.0f32; 600], 1, 600);
        // 3 lanes: stride = 1 lane; words 1..8 held stale all-ones gates
        pack.pack_rows(&[1.0, -1.0, 0.0], 1, 3);
        assert_eq!(pack.words(), LANE_WORDS);
        let (sign, nz) = pack.row(0);
        assert_eq!((sign[0], nz[0]), (0b001u64, 0b011u64));
        assert!(sign[1..].iter().all(|&w| w == 0) && nz[1..].iter().all(|&w| w == 0));
        // and the packed planes must act clean through the kernel
        let w = vec![1.0f32, 1.0, 1.0];
        let cols = BitplaneCols::pack_cols(&w, 3, 1);
        let mut out = vec![0.0f32; 1];
        let mut stats = GateStats::default();
        gated_packed_rows(&pack, &cols, &mut out, &mut stats);
        assert_eq!(out[0], 0.0); // +1 - 1 + 0
        assert_eq!(stats.xnor, 2);
        // multi-plane layout: same guarantee for the digit planes
        let space = DiscreteSpace::new(2);
        let spec = PlaneSpec::for_space(space);
        pack.pack_rows_spec(&vec![1.0f32; 600], 1, 600, spec);
        pack.pack_rows_spec(&[0.5, -1.0, 0.0], 1, 3, spec);
        let (sign, nz) = pack.row(0);
        assert!(sign[1..].iter().all(|&w| w == 0) && nz[1..].iter().all(|&w| w == 0));
        let mut mags: Vec<&[u64]> = Vec::new();
        pack.fill_row_mag(0, &mut mags);
        for m in &mags {
            assert!(m[1..].iter().all(|&w| w == 0));
        }
    }

    /// Satellite: every lane width — 1, 4, 8, plus the pre-lane scalar
    /// kernel — must produce identical outputs *and* identical GateStats
    /// tallies; the fired/rested counting happens once in the innermost
    /// kernel, so it cannot depend on how many words a lane groups.
    #[test]
    fn gate_stats_are_lane_width_invariant() {
        let mut rng = Prng::new(59);
        for &(wn, an) in &[(1u32, 1u32), (2, 2), (0, 3)] {
            let (wspace, aspace) = (DiscreteSpace::new(wn), DiscreteSpace::new(an));
            // m straddles word and lane boundaries inside one shape set
            for &(rows, m, n) in &[(3usize, 70usize, 9usize), (2, 513, 5), (1, 64, 3)] {
                let a: Vec<f32> =
                    (0..rows * m).map(|_| aspace.state(rng.below(aspace.n_states()))).collect();
                let w: Vec<f32> =
                    (0..m * n).map(|_| wspace.state(rng.below(wspace.n_states()))).collect();
                let cols = BitplaneCols::pack_cols_space(&w, m, n, wspace);
                let mut pack = PackScratch::new();
                pack.pack_rows_spec(&a, rows, m, PlaneSpec::for_space(aspace));
                let mut runs: Vec<(Vec<f32>, GateStats)> = Vec::new();
                for width in [1usize, 4, 8] {
                    let mut out = vec![0.0f32; rows * n];
                    let mut stats = GateStats::default();
                    match width {
                        1 => gated_packed_rows_range_width::<1>(
                            &pack, 0, rows, &cols, &mut out, &mut stats,
                        ),
                        4 => gated_packed_rows_range_width::<4>(
                            &pack, 0, rows, &cols, &mut out, &mut stats,
                        ),
                        _ => gated_packed_rows_range_width::<8>(
                            &pack, 0, rows, &cols, &mut out, &mut stats,
                        ),
                    }
                    runs.push((out, stats));
                }
                // scalar fallback on the ternary hot path: per-element
                // gated_dot_scalar must agree dot-for-dot and count-for-count
                if wn <= 1 && an <= 1 {
                    let mut xnor = 0u64;
                    let mut out = vec![0.0f32; rows * n];
                    for r in 0..rows {
                        let (rs, rn) = pack.row(r);
                        for j in 0..n {
                            let (ws, wz) = cols.col(j);
                            let (dot, active) = gated_dot_scalar(rs, rn, ws, wz);
                            out[r * n + j] = dot as f32;
                            xnor += active;
                        }
                    }
                    assert_eq!(out, runs[0].0, "scalar vs lane1 w=Z_{wn} a=Z_{an} m={m}");
                    assert_eq!(xnor, runs[0].1.xnor, "scalar xnor w=Z_{wn} a=Z_{an} m={m}");
                }
                for (out, stats) in &runs[1..] {
                    assert_eq!(*out, runs[0].0, "outputs w=Z_{wn} a=Z_{an} m={m}");
                    assert_eq!(*stats, runs[0].1, "tallies w=Z_{wn} a=Z_{an} m={m}");
                }
            }
        }
    }

    /// Satellite: ragged tails straddling word and lane boundaries —
    /// M % 512 ∈ {0, 1, 63, 64, 65, 511} — must stay exactly equal to the
    /// f64 scalar oracle for every PlaneSpec layout (single-plane and
    /// multi-bit digit planes) at every lane width.
    #[test]
    fn lane_kernels_match_oracle_at_ragged_tails() {
        let mut rng = Prng::new(67);
        let (rows, n) = (2usize, 5usize);
        for &rem in &[0usize, 1, 63, 64, 65, 511] {
            let m = 512 + rem; // m % 512 == rem (one full 8-word lane, then the tail)
            for &(wn, an) in &[(1u32, 1u32), (2, 2), (0, 4), (3, 1)] {
                let (wspace, aspace) = (DiscreteSpace::new(wn), DiscreteSpace::new(an));
                let a: Vec<f32> =
                    (0..rows * m).map(|_| aspace.state(rng.below(aspace.n_states()))).collect();
                let w: Vec<f32> =
                    (0..m * n).map(|_| wspace.state(rng.below(wspace.n_states()))).collect();
                let cols = BitplaneCols::pack_cols_space(&w, m, n, wspace);
                let mut pack = PackScratch::new();
                pack.pack_rows_spec(&a, rows, m, PlaneSpec::for_space(aspace));
                let mut want = vec![0.0f32; rows * n];
                scalar_gemm(&a, rows, &w, m, n, &mut want);
                for width in [1usize, 4, 8] {
                    let mut got = vec![0.0f32; rows * n];
                    let mut stats = GateStats::default();
                    match width {
                        1 => gated_packed_rows_range_width::<1>(
                            &pack, 0, rows, &cols, &mut got, &mut stats,
                        ),
                        4 => gated_packed_rows_range_width::<4>(
                            &pack, 0, rows, &cols, &mut got, &mut stats,
                        ),
                        _ => gated_packed_rows_range_width::<8>(
                            &pack, 0, rows, &cols, &mut got, &mut stats,
                        ),
                    }
                    assert_eq!(got, want, "m={m} w=Z_{wn} a=Z_{an} width={width}");
                }
            }
        }
    }

    #[test]
    fn strides_are_lane_padded_and_aligned() {
        assert_eq!(words_stride(0), 0);
        assert_eq!(words_stride(1), LANE_WORDS);
        assert_eq!(words_stride(512), LANE_WORDS);
        assert_eq!(words_stride(513), 2 * LANE_WORDS);
        for m in [1usize, 63, 64, 65, 500, 513, 4096] {
            assert!(words_stride(m) % LANE_WORDS == 0);
            assert!(words_stride(m) >= words_for(m));
            let cols = BitplaneCols::pack_cols(&vec![1.0f32; m], m, 1);
            assert_eq!(cols.words, words_stride(m));
            let (s, z) = cols.col(0);
            assert_eq!(s.as_ptr() as usize % 64, 0, "m={m}: column plane unaligned");
            assert_eq!(z.as_ptr() as usize % 64, 0);
            // padding words gate off
            for w in words_for(m)..words_stride(m) {
                assert_eq!(z[w], 0, "m={m}: padding word {w} carries gates");
            }
        }
    }

    #[test]
    fn binary_vectors_never_rest() {
        let mut rng = Prng::new(3);
        let m = 130;
        let a: Vec<f32> = (0..m).map(|_| if rng.below(2) == 0 { -1.0 } else { 1.0 }).collect();
        let w: Vec<f32> = (0..m).map(|_| if rng.below(2) == 0 { -1.0 } else { 1.0 }).collect();
        let cols = BitplaneCols::pack_cols(&w, m, 1);
        let mut out = vec![0.0f32; 1];
        let mut stats = GateStats::default();
        gated_xnor_gemm(&a, 1, &cols, &mut out, &mut stats, &mut PackScratch::new());
        assert_eq!(stats.xnor, m as u64);
        assert_eq!(stats.resting(), 0);
        assert_eq!(stats.x_zero_fraction(), 0.0);
        let mut want = vec![0.0f32; 1];
        scalar_gemm(&a, 1, &w, m, 1, &mut want);
        assert_eq!(out, want);
    }

    #[test]
    fn zero_operands_gate_off_and_tail_lanes_are_clean() {
        // all-zero activations: every word is skipped, dot = 0, bitcount 0
        let m = 100; // tail lanes 100..128 must not leak into counts
        let a = vec![0.0f32; m];
        let w = vec![1.0f32; m];
        let cols = BitplaneCols::pack_cols(&w, m, 1);
        let mut out = vec![9.0f32; 1];
        let mut stats = GateStats::default();
        gated_xnor_gemm(&a, 1, &cols, &mut out, &mut stats, &mut PackScratch::new());
        assert_eq!(out[0], 0.0);
        assert_eq!(stats.xnor, 0);
        assert_eq!(stats.bitcount, 0);
        assert_eq!(stats.resting(), m as u64);
        assert!((stats.x_zero_fraction() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gate_counts_match_hand_example() {
        // Fig. 12-style: w = [1, 0, -1], x = [1, 1, 0]
        // pairs: (1,1) fires (+1), (0,1) rests, (-1,0) rests
        let w = vec![1.0, 0.0, -1.0];
        let x = vec![1.0, 1.0, 0.0];
        let cols = BitplaneCols::pack_cols(&w, 3, 1);
        let mut out = vec![0.0f32; 1];
        let mut stats = GateStats::default();
        gated_xnor_gemm(&x, 1, &cols, &mut out, &mut stats, &mut PackScratch::new());
        assert_eq!(out[0], 1.0);
        assert_eq!(stats.xnor, 1);
        assert_eq!(stats.resting(), 2);
        assert_eq!(stats.bitcount, 1);
    }

    #[test]
    fn pack_rows_of_matches_cols_of_transpose() {
        let mut rng = Prng::new(9);
        let (m, n) = (70usize, 130usize);
        let w = random_ternary(&mut rng, m * n);
        let mut wt = vec![0.0f32; n * m];
        for i in 0..m {
            for j in 0..n {
                wt[j * m + i] = w[i * n + j];
            }
        }
        // rows of w == cols of wᵀ, plane for plane
        let rows = BitplaneCols::pack_rows_of(&w, m, n);
        let cols_t = BitplaneCols::pack_cols(&wt, n, m);
        assert_eq!(rows.m, n);
        assert_eq!(rows.n, m);
        for i in 0..m {
            assert_eq!(rows.col(i), cols_t.col(i), "row {i}");
        }
    }

    #[test]
    fn packing_from_packed_tensor_matches_f32_packing() {
        use crate::ternary::{DiscreteSpace, PackedTensor};
        let mut rng = Prng::new(31);
        for space in [DiscreteSpace::TERNARY, DiscreteSpace::BINARY] {
            let (m, n) = (67usize, 9usize);
            let vals: Vec<f32> =
                (0..m * n).map(|_| space.state(rng.below(space.n_states()))).collect();
            let p = PackedTensor::pack(&vals, &[m, n], space);
            let a = BitplaneCols::pack_cols(&vals, m, n);
            let b = BitplaneCols::pack_cols_from_packed(&p, m, n);
            for j in 0..n {
                assert_eq!(a.col(j), b.col(j), "col {j}");
            }
            let c = BitplaneCols::pack_rows_of(&vals, m, n);
            let d = BitplaneCols::pack_rows_from_packed(&p, m, n);
            for i in 0..m {
                assert_eq!(c.col(i), d.col(i), "row {i}");
            }
            assert!(b.plane_bytes() > 0);
        }
    }

    #[test]
    fn split_rows_mut_packs_like_set_row() {
        let mut rng = Prng::new(13);
        let (rows, m) = (11usize, 90usize);
        let a = random_ternary(&mut rng, rows * m);
        let mut serial = PackScratch::new();
        serial.pack_rows(&a, rows, m);
        let mut par = PackScratch::new();
        par.reset(rows, m);
        let chunks = par.split_rows_mut(4); // 4, 4, 3 rows
        assert_eq!(chunks.len(), 3);
        for (ci, mut ch) in chunks.into_iter().enumerate() {
            for r in 0..ch.rows() {
                let g = ci * 4 + r;
                ch.set_row(r, &a[g * m..(g + 1) * m]);
            }
        }
        for r in 0..rows {
            assert_eq!(par.row(r), serial.row(r), "row {r}");
        }
    }

    #[test]
    fn plane_spec_layouts() {
        assert_eq!(PlaneSpec::for_space(DiscreteSpace::BINARY), PlaneSpec::SINGLE);
        assert_eq!(PlaneSpec::for_space(DiscreteSpace::TERNARY), PlaneSpec::SINGLE);
        let s2 = PlaneSpec::for_space(DiscreteSpace::new(2));
        assert_eq!((s2.mag_planes, s2.scale, s2.inv_scale), (2, 0.5, 2.0));
        let s4 = PlaneSpec::for_space(DiscreteSpace::new(4));
        assert_eq!((s4.mag_planes, s4.scale, s4.inv_scale), (4, 0.125, 8.0));
        // activation layouts: hl = 2^{N-1}
        assert_eq!(PlaneSpec::for_levels(0.5), PlaneSpec::SINGLE);
        assert_eq!(PlaneSpec::for_levels(1.0), PlaneSpec::SINGLE);
        let l2 = PlaneSpec::for_levels(2.0);
        assert_eq!((l2.mag_planes, l2.scale), (2, 0.5));
        assert_eq!(PlaneSpec::for_levels(8.0).mag_planes, 4);
    }

    /// The multi-bitplane GEMM must equal the f64 scalar reference
    /// **exactly** for every (weight space, activation space) pairing,
    /// including mixed single-plane × multi-plane sides and ragged shapes.
    #[test]
    fn multi_bitplane_gemm_matches_scalar_reference() {
        use crate::ternary::DiscreteSpace;
        let mut rng = Prng::new(23);
        let mut pack = PackScratch::new();
        for &(wn, an) in &[(2u32, 2u32), (3, 1), (1, 3), (0, 2), (4, 4), (2, 0), (6, 4)] {
            let (wspace, aspace) = (DiscreteSpace::new(wn), DiscreteSpace::new(an));
            for &(rows, m, n) in &[(1usize, 1usize, 1usize), (3, 63, 5), (2, 130, 17), (4, 70, 9)]
            {
                let a: Vec<f32> =
                    (0..rows * m).map(|_| aspace.state(rng.below(aspace.n_states()))).collect();
                let w: Vec<f32> =
                    (0..m * n).map(|_| wspace.state(rng.below(wspace.n_states()))).collect();
                let cols = BitplaneCols::pack_cols_space(&w, m, n, wspace);
                let mut got = vec![0.0f32; rows * n];
                let mut want = vec![0.0f32; rows * n];
                let mut stats = GateStats::default();
                gated_gemm_spec(
                    &a,
                    rows,
                    PlaneSpec::for_space(aspace),
                    &cols,
                    &mut got,
                    &mut stats,
                    &mut pack,
                );
                scalar_gemm(&a, rows, &w, m, n, &mut want);
                assert_eq!(got, want, "w=Z_{wn} a=Z_{an} rows={rows} m={m} n={n}");
                assert_eq!(stats.total, (rows * m * n) as u64);
                assert_eq!(stats.evals, (rows * n) as u64);
                // active = lanes where both operands are non-zero, exactly
                let xnor: u64 = (0..rows)
                    .flat_map(|r| (0..n).map(move |j| (r, j)))
                    .map(|(r, j)| {
                        (0..m)
                            .filter(|&i| a[r * m + i] != 0.0 && w[i * n + j] != 0.0)
                            .count() as u64
                    })
                    .sum();
                assert_eq!(stats.xnor, xnor, "w=Z_{wn} a=Z_{an}");
            }
        }
    }

    /// Packing multi-level planes straight from a `PackedTensor` must
    /// behave exactly like packing the unpacked f32 grid values.
    #[test]
    fn multi_packing_from_packed_tensor_matches_f32_packing() {
        use crate::ternary::{DiscreteSpace, PackedTensor};
        let mut rng = Prng::new(37);
        for wn in [2u32, 3, 6] {
            let space = DiscreteSpace::new(wn);
            let (m, n) = (67usize, 9usize);
            let vals: Vec<f32> =
                (0..m * n).map(|_| space.state(rng.below(space.n_states()))).collect();
            let p = PackedTensor::pack(&vals, &[m, n], space);
            let a = BitplaneCols::pack_cols_space(&vals, m, n, space);
            let b = BitplaneCols::pack_cols_from_packed(&p, m, n);
            let c = BitplaneCols::pack_rows_space(&vals, m, n, space);
            let d = BitplaneCols::pack_rows_from_packed(&p, m, n);
            // drive both through the kernel on shared activations
            let acts: Vec<f32> = (0..2 * m).map(|_| rng.below(3) as f32 - 1.0).collect();
            let mut pack = PackScratch::new();
            let (mut oa, mut ob) = (vec![0.0f32; 2 * n], vec![0.0f32; 2 * n]);
            let mut stats = GateStats::default();
            gated_xnor_gemm(&acts, 2, &a, &mut oa, &mut stats, &mut pack);
            gated_xnor_gemm(&acts, 2, &b, &mut ob, &mut stats, &mut pack);
            assert_eq!(oa, ob, "N={wn}: cols packing diverges");
            assert_eq!(a.plane_bytes(), b.plane_bytes());
            for i in 0..m {
                assert_eq!(c.col(i), d.col(i), "N={wn} row {i}");
            }
            assert_eq!(c.n_mag(), wn);
            assert_eq!(c.scale(), space.dz());
        }
    }

    /// split_rows_mut must carry the digit planes: parallel-style chunked
    /// packing of a multi-level matrix equals serial set_row packing,
    /// verified through the kernel.
    #[test]
    fn split_rows_mut_packs_multi_planes() {
        use crate::ternary::DiscreteSpace;
        let space = DiscreteSpace::new(2);
        let spec = PlaneSpec::for_space(space);
        let mut rng = Prng::new(41);
        let (rows, m) = (11usize, 90usize);
        let a: Vec<f32> = (0..rows * m).map(|_| space.state(rng.below(5))).collect();
        let mut serial = PackScratch::new();
        serial.pack_rows_spec(&a, rows, m, spec);
        let mut par = PackScratch::new();
        par.reset_spec(rows, m, spec);
        for (ci, mut ch) in par.split_rows_mut(4).into_iter().enumerate() {
            for r in 0..ch.rows() {
                let g = ci * 4 + r;
                ch.set_row(r, &a[g * m..(g + 1) * m]);
            }
        }
        let w: Vec<f32> = (0..m * 3).map(|_| space.state(rng.below(5))).collect();
        let cols = BitplaneCols::pack_cols_space(&w, m, 3, space);
        let (mut oa, mut ob) = (vec![0.0f32; rows * 3], vec![0.0f32; rows * 3]);
        let mut stats = GateStats::default();
        gated_packed_rows(&serial, &cols, &mut oa, &mut stats);
        gated_packed_rows(&par, &cols, &mut ob, &mut stats);
        assert_eq!(oa, ob);
        for r in 0..rows {
            assert_eq!(par.row(r), serial.row(r), "row {r}");
        }
    }

    #[test]
    fn stats_merge_accumulates() {
        let mut a = GateStats {
            xnor: 3,
            total: 10,
            bitcount: 1,
            evals: 2,
            x_nonzero: 4,
            x_count: 5,
            occ_hist: [1, 0, 0, 0, 1],
        };
        let b = GateStats {
            xnor: 1,
            total: 10,
            bitcount: 1,
            evals: 2,
            x_nonzero: 1,
            x_count: 5,
            occ_hist: [0, 2, 0, 0, 1],
        };
        a.merge(&b);
        assert_eq!(a.xnor, 4);
        assert_eq!(a.total, 20);
        assert_eq!(a.resting(), 16);
        assert_eq!(a.x_count, 10);
        assert_eq!(a.occ_hist, [1, 2, 0, 0, 2]);
    }

    /// A ternary row at a target occupancy: lanes are zero except an
    /// `occ` fraction, placed in runs so whole tiles genuinely rest.
    fn sparse_ternary(rng: &mut Prng, len: usize, occ: f64) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        let live = (len as f64 * occ).round() as usize;
        // block-structured: fill tiles front to back, so low occupancy
        // leaves later tiles entirely resting (what the skip maps exploit)
        for slot in v.iter_mut().take(live) {
            *slot = if rng.below(2) == 0 { -1.0 } else { 1.0 };
        }
        v
    }

    /// Tentpole: all three execution strategies must be `==` to the f64
    /// scalar oracle and to each other — outputs *and* GateStats — over
    /// ragged tails, multi-bit spaces, and occupancies from dense to
    /// near-empty (including fully-zero rows and columns).
    #[test]
    fn strategy_kernels_match_oracle_and_each_other() {
        let mut rng = Prng::new(71);
        let strategies =
            [KernelStrategy::Lane, KernelStrategy::TileSkip, KernelStrategy::EventList];
        for &(wn, an) in &[(1u32, 1u32), (2, 2), (0, 3), (3, 1)] {
            let (wspace, aspace) = (DiscreteSpace::new(wn), DiscreteSpace::new(an));
            for &(rows, m, n) in &[(3usize, 70usize, 9usize), (2, 513, 5), (2, 1100, 17)] {
                for &occ in &[1.0f64, 0.5, 0.1, 0.02, 0.0] {
                    let a: Vec<f32> = (0..rows)
                        .flat_map(|_| {
                            let keep = sparse_ternary(&mut rng, m, occ);
                            // map the ternary mask through the space's grid
                            keep.iter()
                                .map(|&t| {
                                    if t == 0.0 {
                                        0.0
                                    } else {
                                        t * aspace.state(rng.below(aspace.n_states())).abs()
                                    }
                                })
                                .collect::<Vec<f32>>()
                        })
                        .collect();
                    let w: Vec<f32> = (0..m * n)
                        .map(|_| wspace.state(rng.below(wspace.n_states())))
                        .collect();
                    let cols = BitplaneCols::pack_cols_space(&w, m, n, wspace);
                    let mut pack = PackScratch::new();
                    pack.pack_rows_spec(&a, rows, m, PlaneSpec::for_space(aspace));
                    let mut want = vec![0.0f32; rows * n];
                    scalar_gemm(&a, rows, &w, m, n, &mut want);
                    let mut runs: Vec<(Vec<f32>, GateStats)> = Vec::new();
                    for &s in &strategies {
                        let mut got = vec![0.0f32; rows * n];
                        let mut stats = GateStats::default();
                        gated_packed_rows_strategy(&pack, 0, rows, &cols, &mut got, &mut stats, s);
                        assert_eq!(
                            got,
                            want,
                            "{} vs oracle w=Z_{wn} a=Z_{an} m={m} occ={occ}",
                            s.name()
                        );
                        runs.push((got, stats));
                    }
                    for (out, stats) in &runs[1..] {
                        assert_eq!(*out, runs[0].0);
                        assert_eq!(*stats, runs[0].1, "tallies w=Z_{wn} a=Z_{an} occ={occ}");
                    }
                    // and the adaptive dispatch (whatever it picks) too
                    let mut got = vec![0.0f32; rows * n];
                    let mut stats = GateStats::default();
                    gated_packed_rows_range(&pack, 0, rows, &cols, &mut got, &mut stats);
                    assert_eq!(got, want);
                    assert_eq!(stats, runs[0].1);
                }
            }
        }
    }

    #[test]
    fn occupancy_maps_match_hand_counts() {
        // row: lanes 0, 64 and 600 set -> tile 0 has 2 bits, tile 1 has 1
        let m = 700; // words_for = 11, stride = 16 -> 2 tiles
        let mut a = vec![0.0f32; m];
        (a[0], a[64], a[600]) = (1.0, -1.0, 1.0);
        let mut pack = PackScratch::new();
        pack.pack_rows(&a, 1, m);
        assert_eq!(pack.row_occ(0), &[2, 1]);
        assert_eq!(pack.nz_bits(0, 1), 3);
        assert!((pack.gate_occupancy(0, 1, m) - 3.0 / 700.0).abs() < 1e-12);
        // column maps agree with the same layout
        let cols = BitplaneCols::pack_cols(&a, m, 1);
        assert_eq!(cols.col_occ(0), &[2, 1]);
        assert!((cols.occupancy() - 3.0 / 700.0).abs() < 1e-12);
        // split_rows_mut views maintain the map too
        let b = vec![1.0f32; m];
        let mut par = PackScratch::new();
        par.reset(2, m);
        for (ci, mut ch) in par.split_rows_mut(1).into_iter().enumerate() {
            ch.set_row(0, if ci == 0 { &a } else { &b });
        }
        assert_eq!(par.row_occ(0), &[2, 1]);
        // a tile spans LANE_WORDS * 64 = 512 lanes
        assert_eq!(par.row_occ(1), &[512, 188]);
        assert_eq!(par.nz_bits(0, 2), 703);
    }

    #[test]
    fn strategy_crossovers_dispatch_as_documented() {
        assert_eq!(choose_strategy(1.0), KernelStrategy::Lane);
        assert_eq!(choose_strategy(TILE_SKIP_CROSSOVER), KernelStrategy::Lane);
        assert_eq!(choose_strategy(0.3), KernelStrategy::TileSkip);
        assert_eq!(choose_strategy(EVENT_LIST_CROSSOVER + 1e-9), KernelStrategy::TileSkip);
        assert_eq!(choose_strategy(EVENT_LIST_CROSSOVER), KernelStrategy::EventList);
        assert_eq!(choose_strategy(0.0), KernelStrategy::EventList);
        assert_eq!(KernelStrategy::Lane.name(), "lane");
        assert_eq!(KernelStrategy::TileSkip.name(), "tile_skip");
        assert_eq!(KernelStrategy::EventList.name(), "event_list");
        // degenerate empty ranges stay on the (always-correct) lane path
        let pack = PackScratch::new();
        assert_eq!(pack.gate_occupancy(0, 0, 100), 1.0);
    }

    #[test]
    fn occ_hist_bins_rows_by_occupancy() {
        assert_eq!(occ_bin(0.0), 0);
        assert_eq!(occ_bin(0.02), 0);
        assert_eq!(occ_bin(0.05), 1);
        assert_eq!(occ_bin(0.3), 2);
        assert_eq!(occ_bin(0.7), 3);
        assert_eq!(occ_bin(1.0), 4);
        let m = 100;
        let mut rng = Prng::new(77);
        let mut a = sparse_ternary(&mut rng, m, 1.0); // occ 1.0 -> bin 4
        a.extend(vec![0.0f32; m]); // occ 0.0 -> bin 0
        a.extend(sparse_ternary(&mut rng, m, 0.3)); // occ 0.3 -> bin 2
        let w = vec![1.0f32; m];
        let cols = BitplaneCols::pack_cols(&w, m, 1);
        let mut out = vec![0.0f32; 3];
        let mut stats = GateStats::default();
        gated_xnor_gemm(&a, 3, &cols, &mut out, &mut stats, &mut PackScratch::new());
        assert_eq!(stats.occ_hist, [1, 0, 1, 0, 1]);
    }

    #[test]
    fn event_rows_lower_pack_exactly() {
        let space = DiscreteSpace::new(2);
        let mut pack = PackScratch::new();
        let vals = [0.0f32, -1.0, 0.5, 0.0, 1.0];
        pack.pack_rows_spec(&vals, 1, 5, PlaneSpec::for_space(space));
        let ev = EventRows::from_pack(&pack, 0, 1);
        // q = |v| * inv_scale (inv_scale = 2 for Z_2), signed
        assert_eq!(ev.row(0), &[(1, -2), (2, 1), (4, 2)]);
        assert_eq!(ev.len(), 3);
        assert!(!ev.is_empty());
        // ternary rows carry ±1 events
        pack.pack_rows(&[1.0, 0.0, -1.0], 1, 3);
        let ev = EventRows::from_pack(&pack, 0, 1);
        assert_eq!(ev.row(0), &[(0, 1), (2, -1)]);
    }
}
