//! Epoch-shuffled batch iteration over a [`Dataset`].
//!
//! Fixed batch size (the lowered graphs have static shapes); the final
//! partial batch of an epoch is dropped, as in the reference
//! implementation. Augmentation (pad/crop/flip) is applied per sample with
//! a per-epoch RNG stream, so runs are reproducible from the seed.

use crate::data::augment::{augment, AugmentCfg};
use crate::data::Dataset;
use crate::util::prng::Prng;

pub struct BatchIter<'a> {
    ds: &'a dyn Dataset,
    batch: usize,
    order: Vec<u32>,
    pos: usize,
    rng: Prng,
    aug: AugmentCfg,
    epoch: u64,
    seed: u64,
}

impl<'a> BatchIter<'a> {
    pub fn new(ds: &'a dyn Dataset, batch: usize, seed: u64, aug: AugmentCfg) -> Self {
        assert!(batch > 0 && batch <= ds.len(), "batch {batch} vs len {}", ds.len());
        let mut it = BatchIter {
            ds,
            batch,
            order: (0..ds.len() as u32).collect(),
            pos: 0,
            rng: Prng::new(seed),
            aug,
            epoch: 0,
            seed,
        };
        it.reshuffle();
        it
    }

    fn reshuffle(&mut self) {
        self.rng = Prng::new(
            self.seed
                .wrapping_add(self.epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
        );
        self.rng.shuffle(&mut self.order);
        self.pos = 0;
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.ds.len() / self.batch
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Fill the next batch. Returns `false` (and advances to the next
    /// epoch, reshuffling) when the current epoch is exhausted.
    pub fn next_batch(&mut self, x: &mut [f32], y: &mut [i32]) -> bool {
        let sample_len = self.ds.sample_len();
        assert_eq!(x.len(), self.batch * sample_len);
        assert_eq!(y.len(), self.batch);
        if self.pos + self.batch > self.order.len() {
            self.epoch += 1;
            self.reshuffle();
            return false;
        }
        let (h, w, c) = self.ds.shape();
        for b in 0..self.batch {
            let idx = self.order[self.pos + b] as usize;
            let out = &mut x[b * sample_len..(b + 1) * sample_len];
            y[b] = self.ds.fill(idx, out) as i32;
            if !self.aug.is_noop() {
                augment(out, h, w, c, &self.aug, &mut self.rng);
            }
        }
        self.pos += self.batch;
        true
    }

    /// Like [`BatchIter::next_batch`], but the epoch's final partial
    /// batch is **padded, not dropped**: returns `Some(valid)` with the
    /// leading `valid` rows real (shuffled + augmented, identical stream
    /// to `next_batch`) and the tail repeating the last valid sample.
    /// Returns `None` at the epoch boundary (then reshuffles, exactly
    /// like `next_batch` returning `false`). Consumers must mask rows
    /// ≥ `valid` — the native trainer zeroes their loss and gradient
    /// contribution, so a padded batch trains as a batch of `valid`.
    pub fn next_batch_padded(&mut self, x: &mut [f32], y: &mut [i32]) -> Option<usize> {
        let sample_len = self.ds.sample_len();
        assert_eq!(x.len(), self.batch * sample_len);
        assert_eq!(y.len(), self.batch);
        let remaining = self.order.len() - self.pos;
        if remaining == 0 {
            self.epoch += 1;
            self.reshuffle();
            return None;
        }
        let valid = remaining.min(self.batch);
        let (h, w, c) = self.ds.shape();
        for b in 0..valid {
            let idx = self.order[self.pos + b] as usize;
            let out = &mut x[b * sample_len..(b + 1) * sample_len];
            y[b] = self.ds.fill(idx, out) as i32;
            if !self.aug.is_noop() {
                augment(out, h, w, c, &self.aug, &mut self.rng);
            }
        }
        for b in valid..self.batch {
            x.copy_within((valid - 1) * sample_len..valid * sample_len, b * sample_len);
            y[b] = y[valid - 1];
        }
        self.pos += valid;
        Some(valid)
    }

    /// Iterate the whole dataset once without shuffling or augmentation
    /// (evaluation). Calls `f(batch_x, batch_y)` per full batch.
    pub fn for_eval(
        ds: &dyn Dataset,
        batch: usize,
        mut f: impl FnMut(&[f32], &[i32]),
    ) {
        let sample_len = ds.sample_len();
        let mut x = vec![0.0f32; batch * sample_len];
        let mut y = vec![0i32; batch];
        let n_batches = ds.len() / batch;
        for nb in 0..n_batches {
            for b in 0..batch {
                let idx = nb * batch + b;
                y[b] = ds.fill(idx, &mut x[b * sample_len..(b + 1) * sample_len]) as i32;
            }
            f(&x, &y);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthDigits;

    #[test]
    fn yields_full_epoch_then_false() {
        let ds = SynthDigits::new(1, 50);
        let mut it = BatchIter::new(&ds, 16, 0, AugmentCfg::none());
        let mut x = vec![0.0; 16 * 784];
        let mut y = vec![0; 16];
        let mut n = 0;
        while it.next_batch(&mut x, &mut y) {
            n += 1;
        }
        assert_eq!(n, 3); // 50/16 = 3 full batches
        assert_eq!(it.epoch(), 1);
        // next epoch restarts
        assert!(it.next_batch(&mut x, &mut y));
    }

    #[test]
    fn epochs_use_different_orders() {
        let ds = SynthDigits::new(1, 64);
        let mut it = BatchIter::new(&ds, 32, 0, AugmentCfg::none());
        let mut x = vec![0.0; 32 * 784];
        let mut y1 = vec![0; 32];
        let mut y2 = vec![0; 32];
        it.next_batch(&mut x, &mut y1);
        while it.next_batch(&mut x, &mut y2) {} // drain epoch 0
        it.next_batch(&mut x, &mut y2); // first batch of epoch 1
        assert_ne!(y1, y2);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = SynthDigits::new(1, 64);
        let run = |seed: u64| {
            let mut it = BatchIter::new(&ds, 16, seed, AugmentCfg::paper());
            let mut x = vec![0.0; 16 * 784];
            let mut y = vec![0; 16];
            it.next_batch(&mut x, &mut y);
            (x, y)
        };
        let (x1, y1) = run(7);
        let (x2, y2) = run(7);
        assert_eq!(y1, y2);
        assert_eq!(x1, x2);
        let (x3, _) = run(8);
        assert_ne!(x1, x3);
    }

    /// The padded iterator must replay `next_batch`'s exact stream for
    /// the full batches and then append one padded partial batch.
    #[test]
    fn padded_iterator_extends_drop_last_stream() {
        let ds = SynthDigits::new(1, 50); // 3 full batches of 16 + 2 left
        let batch = 16;
        let mut a = BatchIter::new(&ds, batch, 9, AugmentCfg::paper());
        let mut b = BatchIter::new(&ds, batch, 9, AugmentCfg::paper());
        let mut xa = vec![0.0; batch * 784];
        let mut ya = vec![0; batch];
        let mut xb = xa.clone();
        let mut yb = ya.clone();
        for i in 0..3 {
            assert!(a.next_batch(&mut xa, &mut ya));
            assert_eq!(b.next_batch_padded(&mut xb, &mut yb), Some(batch), "batch {i}");
            assert_eq!(xa, xb, "batch {i}: pixels diverge");
            assert_eq!(ya, yb, "batch {i}: labels diverge");
        }
        // drop-last epoch ends here; padded epoch adds the 2 leftovers
        assert_eq!(b.next_batch_padded(&mut xb, &mut yb), Some(2));
        // tail rows replicate the last valid sample
        for r in 2..batch {
            assert_eq!(yb[r], yb[1], "row {r}");
            assert_eq!(xb[r * 784..(r + 1) * 784], xb[784..2 * 784], "row {r}");
        }
        // both iterators agree the epoch is over and reshuffle identically
        assert!(!a.next_batch(&mut xa, &mut ya));
        assert_eq!(b.next_batch_padded(&mut xb, &mut yb), None);
        assert_eq!(a.epoch(), 1);
        assert_eq!(b.epoch(), 1);
    }

    #[test]
    fn eval_covers_dataset_in_order() {
        let ds = SynthDigits::new(2, 40);
        let mut labels = Vec::new();
        BatchIter::for_eval(&ds, 10, |_, y| labels.extend_from_slice(y));
        assert_eq!(labels.len(), 40);
        // matches direct fills
        let mut x = vec![0.0; 784];
        for (i, &l) in labels.iter().enumerate() {
            assert_eq!(l, ds.fill(i, &mut x) as i32);
        }
    }
}
