//! Epoch-shuffled batch iteration over a [`Dataset`].
//!
//! Fixed batch size (the lowered graphs have static shapes); the final
//! partial batch of an epoch is dropped, as in the reference
//! implementation. Augmentation (pad/crop/flip) is applied per sample with
//! a per-epoch RNG stream, so runs are reproducible from the seed.

use crate::data::augment::{augment, AugmentCfg};
use crate::data::Dataset;
use crate::util::prng::Prng;

pub struct BatchIter<'a> {
    ds: &'a dyn Dataset,
    batch: usize,
    order: Vec<u32>,
    pos: usize,
    rng: Prng,
    aug: AugmentCfg,
    epoch: u64,
    seed: u64,
}

impl<'a> BatchIter<'a> {
    pub fn new(ds: &'a dyn Dataset, batch: usize, seed: u64, aug: AugmentCfg) -> Self {
        assert!(batch > 0 && batch <= ds.len(), "batch {batch} vs len {}", ds.len());
        let mut it = BatchIter {
            ds,
            batch,
            order: (0..ds.len() as u32).collect(),
            pos: 0,
            rng: Prng::new(seed),
            aug,
            epoch: 0,
            seed,
        };
        it.reshuffle();
        it
    }

    fn reshuffle(&mut self) {
        self.rng = Prng::new(
            self.seed
                .wrapping_add(self.epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
        );
        self.rng.shuffle(&mut self.order);
        self.pos = 0;
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.ds.len() / self.batch
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Fill the next batch. Returns `false` (and advances to the next
    /// epoch, reshuffling) when the current epoch is exhausted.
    pub fn next_batch(&mut self, x: &mut [f32], y: &mut [i32]) -> bool {
        let sample_len = self.ds.sample_len();
        assert_eq!(x.len(), self.batch * sample_len);
        assert_eq!(y.len(), self.batch);
        if self.pos + self.batch > self.order.len() {
            self.epoch += 1;
            self.reshuffle();
            return false;
        }
        let (h, w, c) = self.ds.shape();
        for b in 0..self.batch {
            let idx = self.order[self.pos + b] as usize;
            let out = &mut x[b * sample_len..(b + 1) * sample_len];
            y[b] = self.ds.fill(idx, out) as i32;
            if !self.aug.is_noop() {
                augment(out, h, w, c, &self.aug, &mut self.rng);
            }
        }
        self.pos += self.batch;
        true
    }

    /// Iterate the whole dataset once without shuffling or augmentation
    /// (evaluation). Calls `f(batch_x, batch_y)` per full batch.
    pub fn for_eval(
        ds: &dyn Dataset,
        batch: usize,
        mut f: impl FnMut(&[f32], &[i32]),
    ) {
        let sample_len = ds.sample_len();
        let mut x = vec![0.0f32; batch * sample_len];
        let mut y = vec![0i32; batch];
        let n_batches = ds.len() / batch;
        for nb in 0..n_batches {
            for b in 0..batch {
                let idx = nb * batch + b;
                y[b] = ds.fill(idx, &mut x[b * sample_len..(b + 1) * sample_len]) as i32;
            }
            f(&x, &y);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthDigits;

    #[test]
    fn yields_full_epoch_then_false() {
        let ds = SynthDigits::new(1, 50);
        let mut it = BatchIter::new(&ds, 16, 0, AugmentCfg::none());
        let mut x = vec![0.0; 16 * 784];
        let mut y = vec![0; 16];
        let mut n = 0;
        while it.next_batch(&mut x, &mut y) {
            n += 1;
        }
        assert_eq!(n, 3); // 50/16 = 3 full batches
        assert_eq!(it.epoch(), 1);
        // next epoch restarts
        assert!(it.next_batch(&mut x, &mut y));
    }

    #[test]
    fn epochs_use_different_orders() {
        let ds = SynthDigits::new(1, 64);
        let mut it = BatchIter::new(&ds, 32, 0, AugmentCfg::none());
        let mut x = vec![0.0; 32 * 784];
        let mut y1 = vec![0; 32];
        let mut y2 = vec![0; 32];
        it.next_batch(&mut x, &mut y1);
        while it.next_batch(&mut x, &mut y2) {} // drain epoch 0
        it.next_batch(&mut x, &mut y2); // first batch of epoch 1
        assert_ne!(y1, y2);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = SynthDigits::new(1, 64);
        let run = |seed: u64| {
            let mut it = BatchIter::new(&ds, 16, seed, AugmentCfg::paper());
            let mut x = vec![0.0; 16 * 784];
            let mut y = vec![0; 16];
            it.next_batch(&mut x, &mut y);
            (x, y)
        };
        let (x1, y1) = run(7);
        let (x2, y2) = run(7);
        assert_eq!(y1, y2);
        assert_eq!(x1, x2);
        let (x3, _) = run(8);
        assert_ne!(x1, x3);
    }

    #[test]
    fn eval_covers_dataset_in_order() {
        let ds = SynthDigits::new(2, 40);
        let mut labels = Vec::new();
        BatchIter::for_eval(&ds, 10, |_, y| labels.extend_from_slice(y));
        assert_eq!(labels.len(), 40);
        // matches direct fills
        let mut x = vec![0.0; 784];
        for (i, &l) in labels.iter().enumerate() {
            assert_eq!(l, ds.fill(i, &mut x) as i32);
        }
    }
}
