//! Training-time augmentation, matching the paper's Section 3 recipe for
//! CIFAR10/SVHN: "4 pixels are padded on each side, and a 32x32 crop is
//! further randomly sampled from the padded image and its horizontal flip
//! version". Inference uses the single original view.

use crate::util::prng::Prng;

#[derive(Clone, Copy, Debug)]
pub struct AugmentCfg {
    /// pixels of zero padding on each side before cropping
    pub pad: usize,
    /// enable random horizontal flip
    pub hflip: bool,
}

impl AugmentCfg {
    /// The paper's CIFAR/SVHN recipe.
    pub fn paper() -> Self {
        AugmentCfg { pad: 4, hflip: true }
    }

    pub fn none() -> Self {
        AugmentCfg { pad: 0, hflip: false }
    }

    pub fn is_noop(&self) -> bool {
        self.pad == 0 && !self.hflip
    }
}

/// Apply pad+crop+flip in place. `img` is NHWC (h, w, c) row-major.
pub fn augment(
    img: &mut [f32],
    h: usize,
    w: usize,
    c: usize,
    cfg: &AugmentCfg,
    rng: &mut Prng,
) {
    debug_assert_eq!(img.len(), h * w * c);
    if cfg.is_noop() {
        return;
    }
    let flip = cfg.hflip && rng.next_u64() & 1 == 1;
    let pad = cfg.pad;
    // crop offsets in the padded frame: [0, 2*pad]
    let (dy, dx) = if pad > 0 {
        (rng.below(2 * pad + 1) as isize - pad as isize,
         rng.below(2 * pad + 1) as isize - pad as isize)
    } else {
        (0, 0)
    };
    let src = img.to_vec();
    for y in 0..h {
        for x in 0..w {
            let sy = y as isize + dy;
            let sx0 = x as isize + dx;
            let sx = if flip { w as isize - 1 - sx0 } else { sx0 };
            let dst_base = (y * w + x) * c;
            if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                let src_base = (sy as usize * w + sx as usize) * c;
                img[dst_base..dst_base + c]
                    .copy_from_slice(&src[src_base..src_base + c]);
            } else {
                // zero padding maps to -1 after [-1,1] normalization of black
                for ch in 0..c {
                    img[dst_base + ch] = -1.0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(h: usize, w: usize, c: usize) -> Vec<f32> {
        (0..h * w * c).map(|i| i as f32 / (h * w * c) as f32).collect()
    }

    #[test]
    fn noop_leaves_image() {
        let mut img = ramp(8, 8, 3);
        let orig = img.clone();
        augment(&mut img, 8, 8, 3, &AugmentCfg::none(), &mut Prng::new(1));
        assert_eq!(img, orig);
    }

    #[test]
    fn flip_only_reverses_rows() {
        let cfg = AugmentCfg { pad: 0, hflip: true };
        // find a seed whose first draw flips
        let mut rng = Prng::new(3);
        while rng.clone().next_u64() & 1 == 0 {
            rng.next_u64();
        }
        let mut img = ramp(2, 4, 1);
        let orig = img.clone();
        augment(&mut img, 2, 4, 1, &cfg, &mut rng);
        for y in 0..2 {
            for x in 0..4 {
                assert_eq!(img[y * 4 + x], orig[y * 4 + (3 - x)]);
            }
        }
    }

    #[test]
    fn crop_shifts_content() {
        let cfg = AugmentCfg { pad: 4, hflip: false };
        let mut any_shift = false;
        for seed in 0..20 {
            let mut img = ramp(8, 8, 1);
            let orig = img.clone();
            augment(&mut img, 8, 8, 1, &cfg, &mut Prng::new(seed));
            if img != orig {
                any_shift = true;
            }
            // padding is exactly -1 where out of range
            for &v in &img {
                assert!(v == -1.0 || (0.0..=1.0).contains(&v));
            }
        }
        assert!(any_shift);
    }

    #[test]
    fn augment_preserves_length_and_range() {
        let cfg = AugmentCfg::paper();
        let mut rng = Prng::new(7);
        let mut img: Vec<f32> = (0..32 * 32 * 3)
            .map(|i| ((i % 255) as f32 / 127.5) - 1.0)
            .collect();
        augment(&mut img, 32, 32, 3, &cfg, &mut rng);
        assert_eq!(img.len(), 32 * 32 * 3);
        assert!(img.iter().all(|&v| (-1.0..=1.0).contains(&v)));
    }
}
