//! Procedural CIFAR10/SVHN stand-ins (32x32x3).
//!
//! * `SynthCifar` — each class is a fixed mixture of oriented sinusoidal
//!   textures plus a color tint; samples add random phase, gain, spatial
//!   jitter and pixel noise. Class identity is carried by texture
//!   statistics (not a single template), so convnets beat linear models.
//! * `SynthSvhn` — colorized digits (reusing the stroke rasterizer) over a
//!   textured background: digit-shape classes with photometric nuisance,
//!   the SVHN regime.

use crate::data::synth::{render_digit, SIDE as DIGIT_SIDE};
use crate::data::Dataset;
use crate::util::prng::Prng;

pub const SIDE: usize = 32;
const NCOMP: usize = 6; // texture components per class

struct TexComp {
    fx: f32,
    fy: f32,
    color: [f32; 3],
    amp: f32,
}

fn class_components(class: usize) -> Vec<TexComp> {
    // deterministic per-class texture bank
    let mut rng = Prng::new(0xC1FA_0000 + class as u64);
    (0..NCOMP)
        .map(|_| {
            let freq = rng.range_f32(0.3, 2.2);
            let theta = rng.range_f32(0.0, std::f32::consts::PI);
            TexComp {
                fx: freq * theta.cos(),
                fy: freq * theta.sin(),
                color: [
                    rng.range_f32(-1.0, 1.0),
                    rng.range_f32(-1.0, 1.0),
                    rng.range_f32(-1.0, 1.0),
                ],
                amp: rng.range_f32(0.3, 1.0),
            }
        })
        .collect()
}

/// Textured color classes, 32x32x3 in [-1,1] (NHWC).
pub struct SynthCifar {
    seed: u64,
    len: usize,
    banks: Vec<Vec<TexComp>>,
}

impl SynthCifar {
    pub fn new(seed: u64, len: usize) -> Self {
        SynthCifar {
            seed,
            len,
            banks: (0..10).map(class_components).collect(),
        }
    }
}

impl Dataset for SynthCifar {
    fn len(&self) -> usize {
        self.len
    }

    fn shape(&self) -> (usize, usize, usize) {
        (SIDE, SIDE, 3)
    }

    fn n_classes(&self) -> usize {
        10
    }

    fn fill(&self, idx: usize, out: &mut [f32]) -> u32 {
        let mut rng = Prng::new(
            self.seed
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(idx as u64),
        );
        let label = (rng.next_u64() % 10) as usize;
        let bank = &self.banks[label];
        // per-sample nuisance: phases, gains, offset
        let phases: Vec<f32> = (0..NCOMP)
            .map(|_| rng.range_f32(0.0, 2.0 * std::f32::consts::PI))
            .collect();
        let gains: Vec<f32> = (0..NCOMP).map(|_| rng.range_f32(0.6, 1.4)).collect();
        let (jx, jy) = (rng.range_f32(-3.0, 3.0), rng.range_f32(-3.0, 3.0));
        out.fill(0.0);
        for y in 0..SIDE {
            for x in 0..SIDE {
                let (fx, fy) = (x as f32 + jx, y as f32 + jy);
                let base = (y * SIDE + x) * 3;
                for (k, c) in bank.iter().enumerate() {
                    let v =
                        (c.fx * fx * 0.35 + c.fy * fy * 0.35 + phases[k]).sin()
                            * c.amp
                            * gains[k]
                            / NCOMP as f32;
                    out[base] += v * c.color[0];
                    out[base + 1] += v * c.color[1];
                    out[base + 2] += v * c.color[2];
                }
            }
        }
        for v in out.iter_mut() {
            *v = (*v * 2.0 + rng.normal_f32() * 0.10).clamp(-1.0, 1.0);
        }
        label as u32
    }

    fn name(&self) -> &str {
        "synth_cifar"
    }
}

/// Colorized digits over textured backgrounds, 32x32x3 in [-1,1].
pub struct SynthSvhn {
    seed: u64,
    len: usize,
}

impl SynthSvhn {
    pub fn new(seed: u64, len: usize) -> Self {
        SynthSvhn { seed, len }
    }
}

impl Dataset for SynthSvhn {
    fn len(&self) -> usize {
        self.len
    }

    fn shape(&self) -> (usize, usize, usize) {
        (SIDE, SIDE, 3)
    }

    fn n_classes(&self) -> usize {
        10
    }

    fn fill(&self, idx: usize, out: &mut [f32]) -> u32 {
        let mut rng = Prng::new(
            self.seed
                .wrapping_mul(0x517C_C1B7_2722_0A95)
                .wrapping_add(idx as u64),
        );
        let label = (rng.next_u64() % 10) as usize;
        // digit mask at 28x28
        let mut mask = vec![0.0f32; DIGIT_SIDE * DIGIT_SIDE];
        render_digit(label, &mut rng, &mut mask);
        // photometric nuisance
        let fg: [f32; 3] = [
            rng.range_f32(-1.0, 1.0),
            rng.range_f32(-1.0, 1.0),
            rng.range_f32(-1.0, 1.0),
        ];
        let bg: [f32; 3] = [
            rng.range_f32(-0.6, 0.6),
            rng.range_f32(-0.6, 0.6),
            rng.range_f32(-0.6, 0.6),
        ];
        // low-frequency background texture
        let (bfx, bfy, bph) = (
            rng.range_f32(0.1, 0.5),
            rng.range_f32(0.1, 0.5),
            rng.range_f32(0.0, 6.28),
        );
        let (ox, oy) = (
            rng.below(SIDE - DIGIT_SIDE + 1),
            rng.below(SIDE - DIGIT_SIDE + 1),
        );
        for y in 0..SIDE {
            for x in 0..SIDE {
                let tex = (bfx * x as f32 + bfy * y as f32 + bph).sin() * 0.3;
                let m = if x >= ox && x < ox + DIGIT_SIDE && y >= oy && y < oy + DIGIT_SIDE {
                    mask[(y - oy) * DIGIT_SIDE + (x - ox)]
                } else {
                    0.0
                };
                let base = (y * SIDE + x) * 3;
                for ch in 0..3 {
                    let v = bg[ch] + tex + m * (fg[ch] - bg[ch]);
                    out[base + ch] =
                        (v + rng.normal_f32() * 0.08).clamp(-1.0, 1.0);
                }
            }
        }
        label as u32
    }

    fn name(&self) -> &str {
        "synth_svhn"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_common(ds: &dyn Dataset) {
        let mut x = vec![0.0f32; ds.sample_len()];
        let mut seen = [false; 10];
        for i in 0..200 {
            let l = ds.fill(i, &mut x);
            assert!(x.iter().all(|&v| (-1.0..=1.0).contains(&v)));
            seen[l as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 9, "{seen:?}");
    }

    #[test]
    fn cifar_valid() {
        let ds = SynthCifar::new(3, 1000);
        assert_eq!(ds.shape(), (32, 32, 3));
        check_common(&ds);
    }

    #[test]
    fn svhn_valid() {
        let ds = SynthSvhn::new(5, 1000);
        assert_eq!(ds.shape(), (32, 32, 3));
        check_common(&ds);
    }

    #[test]
    fn cifar_classes_distinct_in_texture_space() {
        // average power spectrum proxy: per-class mean images differ
        let ds = SynthCifar::new(3, 5000);
        let n = ds.sample_len();
        let mut sums = vec![vec![0.0f64; n]; 10];
        let mut counts = [0usize; 10];
        let mut x = vec![0.0f32; n];
        for i in 0..600 {
            let l = ds.fill(i, &mut x) as usize;
            counts[l] += 1;
            for (s, &v) in sums[l].iter_mut().zip(&x) {
                *s += (v as f64).abs(); // mean |activation| carries texture energy
            }
        }
        for c in 0..10 {
            assert!(counts[c] > 10, "class {c} undersampled");
            for s in sums[c].iter_mut() {
                *s /= counts[c] as f64;
            }
        }
        let mut min_dist = f64::INFINITY;
        for i in 0..10 {
            for j in (i + 1)..10 {
                let d: f64 = sums[i]
                    .iter()
                    .zip(&sums[j])
                    .map(|(a, b)| (a - b).powi(2))
                    .sum::<f64>()
                    .sqrt();
                min_dist = min_dist.min(d);
            }
        }
        assert!(min_dist > 0.5, "classes statistically indistinct: {min_dist}");
    }

    #[test]
    fn svhn_digit_visible() {
        // foreground/background contrast exists
        let ds = SynthSvhn::new(5, 100);
        let mut x = vec![0.0f32; ds.sample_len()];
        ds.fill(0, &mut x);
        let mean: f32 = x.iter().sum::<f32>() / x.len() as f32;
        let var: f32 =
            x.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / x.len() as f32;
        assert!(var > 0.01, "image nearly constant (var={var})");
    }
}
