//! Procedural MNIST stand-in: stroke-rasterized digits.
//!
//! Each class is a polyline template (seven-segment-style with diagonals)
//! in the unit square; a sample renders its class template through a
//! random affine transform (rotation, anisotropic scale, translation),
//! random stroke thickness, and additive pixel noise. The task is
//! learnable but not linearly trivial — quantized-network accuracy
//! orderings (Table 1 / Fig. 7–10) reproduce on it.

use crate::data::Dataset;
use crate::util::prng::Prng;

pub const SIDE: usize = 28;

/// Polyline templates per digit; points are (x, y) in [0,1]^2, y down.
/// `f32::NAN` x-coordinates separate strokes.
fn template(digit: usize) -> &'static [(f32, f32)] {
    const B: f32 = f32::NAN;
    // segment endpoints
    // corners: TL(0.25,0.15) TR(0.75,0.15) ML(0.25,0.5) MR(0.75,0.5)
    //          BL(0.25,0.85) BR(0.75,0.85)
    match digit {
        0 => &[
            (0.25, 0.15), (0.75, 0.15), (0.75, 0.85), (0.25, 0.85), (0.25, 0.15),
        ],
        1 => &[(0.45, 0.25), (0.55, 0.15), (0.55, 0.85)],
        2 => &[
            (0.25, 0.15), (0.75, 0.15), (0.75, 0.5), (0.25, 0.5), (0.25, 0.85), (0.75, 0.85),
        ],
        3 => &[
            (0.25, 0.15), (0.75, 0.15), (0.75, 0.85), (0.25, 0.85),
            (B, 0.0), (0.35, 0.5), (0.75, 0.5),
        ],
        4 => &[
            (0.25, 0.15), (0.25, 0.5), (0.75, 0.5),
            (B, 0.0), (0.75, 0.15), (0.75, 0.85),
        ],
        5 => &[
            (0.75, 0.15), (0.25, 0.15), (0.25, 0.5), (0.75, 0.5), (0.75, 0.85), (0.25, 0.85),
        ],
        6 => &[
            (0.75, 0.15), (0.25, 0.15), (0.25, 0.85), (0.75, 0.85), (0.75, 0.5), (0.25, 0.5),
        ],
        7 => &[(0.25, 0.15), (0.75, 0.15), (0.45, 0.85)],
        8 => &[
            (0.25, 0.15), (0.75, 0.15), (0.75, 0.85), (0.25, 0.85), (0.25, 0.15),
            (B, 0.0), (0.25, 0.5), (0.75, 0.5),
        ],
        9 => &[
            (0.75, 0.5), (0.25, 0.5), (0.25, 0.15), (0.75, 0.15), (0.75, 0.85), (0.25, 0.85),
        ],
        _ => unreachable!(),
    }
}

/// Distance from point to segment, all in pixel units.
fn seg_dist(px: f32, py: f32, ax: f32, ay: f32, bx: f32, by: f32) -> f32 {
    let (dx, dy) = (bx - ax, by - ay);
    let len2 = dx * dx + dy * dy;
    let t = if len2 <= 1e-9 {
        0.0
    } else {
        (((px - ax) * dx + (py - ay) * dy) / len2).clamp(0.0, 1.0)
    };
    let (cx, cy) = (ax + t * dx, ay + t * dy);
    ((px - cx).powi(2) + (py - cy).powi(2)).sqrt()
}

/// Render one digit with a given affine jitter into `out` (SIDE*SIDE, [0,1]).
pub fn render_digit(digit: usize, rng: &mut Prng, out: &mut [f32]) {
    debug_assert_eq!(out.len(), SIDE * SIDE);
    let rot = rng.range_f32(-0.30, 0.30); // radians, ~±17°
    let scale_x = rng.range_f32(0.75, 1.10);
    let scale_y = rng.range_f32(0.75, 1.10);
    let tx = rng.range_f32(-2.5, 2.5);
    let ty = rng.range_f32(-2.5, 2.5);
    let thick = rng.range_f32(1.0, 1.9); // stroke half-width in px
    let (sin, cos) = rot.sin_cos();
    let s = SIDE as f32;
    // transform template points to pixel space
    let pts: Vec<(f32, f32)> = template(digit)
        .iter()
        .map(|&(x, y)| {
            if x.is_nan() {
                return (f32::NAN, 0.0);
            }
            // center, scale, rotate, translate
            let (cx, cy) = ((x - 0.5) * scale_x, (y - 0.5) * scale_y);
            let (rx, ry) = (cx * cos - cy * sin, cx * sin + cy * cos);
            ((rx + 0.5) * s + tx, (ry + 0.5) * s + ty)
        })
        .collect();
    // rasterize: soft stroke via distance field
    for py in 0..SIDE {
        for px in 0..SIDE {
            let (fx, fy) = (px as f32 + 0.5, py as f32 + 0.5);
            let mut d = f32::INFINITY;
            for w in pts.windows(2) {
                let (ax, ay) = w[0];
                let (bx, by) = w[1];
                if ax.is_nan() || bx.is_nan() {
                    continue;
                }
                d = d.min(seg_dist(fx, fy, ax, ay, bx, by));
            }
            // smooth falloff over one pixel
            let v = (1.0 - (d - thick)).clamp(0.0, 1.0);
            out[py * SIDE + px] = v;
        }
    }
    // pixel noise
    for v in out.iter_mut() {
        *v = (*v + rng.normal_f32() * 0.08).clamp(0.0, 1.0);
    }
}

/// The procedural digit dataset (28x28x1, 10 classes, values in [-1,1]).
pub struct SynthDigits {
    seed: u64,
    len: usize,
}

impl SynthDigits {
    pub fn new(seed: u64, len: usize) -> Self {
        SynthDigits { seed, len }
    }
}

impl Dataset for SynthDigits {
    fn len(&self) -> usize {
        self.len
    }

    fn shape(&self) -> (usize, usize, usize) {
        (SIDE, SIDE, 1)
    }

    fn n_classes(&self) -> usize {
        10
    }

    fn fill(&self, idx: usize, out: &mut [f32]) -> u32 {
        // per-sample deterministic stream
        let mut rng = Prng::new(
            self.seed
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(idx as u64),
        );
        let label = (rng.next_u64() % 10) as usize;
        render_digit(label, &mut rng, out);
        for v in out.iter_mut() {
            *v = *v * 2.0 - 1.0; // [0,1] -> [-1,1] (paper input normalization)
        }
        label as u32
    }

    fn name(&self) -> &str {
        "synth_mnist"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_index() {
        let ds = SynthDigits::new(1, 100);
        let mut a = vec![0.0; 784];
        let mut b = vec![0.0; 784];
        let la = ds.fill(17, &mut a);
        let lb = ds.fill(17, &mut b);
        assert_eq!(la, lb);
        assert_eq!(a, b);
    }

    #[test]
    fn values_normalized() {
        let ds = SynthDigits::new(1, 10);
        let mut x = vec![0.0; 784];
        for i in 0..10 {
            ds.fill(i, &mut x);
            assert!(x.iter().all(|&v| (-1.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn labels_cover_all_classes() {
        let ds = SynthDigits::new(1, 500);
        let mut seen = [false; 10];
        let mut x = vec![0.0; 784];
        for i in 0..500 {
            seen[ds.fill(i, &mut x) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn same_class_varies_between_samples() {
        let ds = SynthDigits::new(1, 2000);
        let mut x = vec![0.0; 784];
        let mut first: Option<Vec<f32>> = None;
        for i in 0..2000 {
            if ds.fill(i, &mut x) == 3 {
                match &first {
                    None => first = Some(x.clone()),
                    Some(f) => {
                        assert_ne!(f, &x, "two 3s rendered identically");
                        return;
                    }
                }
            }
        }
        panic!("class 3 appeared < 2 times in 2000 samples");
    }

    #[test]
    fn digits_have_ink() {
        // every rendered digit must light up a plausible number of pixels
        let mut rng = Prng::new(9);
        let mut img = vec![0.0; SIDE * SIDE];
        for d in 0..10 {
            render_digit(d, &mut rng, &mut img);
            let ink: f32 = img.iter().sum();
            assert!(ink > 20.0, "digit {d} has almost no ink ({ink})");
            assert!(ink < 500.0, "digit {d} is a blob ({ink})");
        }
    }

    #[test]
    fn classes_are_visually_distinct() {
        // mean intra-class L2 distance must be well below inter-class
        let mut rng = Prng::new(4);
        let render_mean = |d: usize, rng: &mut Prng| {
            let mut acc = vec![0.0f32; SIDE * SIDE];
            let mut img = vec![0.0f32; SIDE * SIDE];
            for _ in 0..8 {
                render_digit(d, rng, &mut img);
                for (a, v) in acc.iter_mut().zip(&img) {
                    *a += v / 8.0;
                }
            }
            acc
        };
        let means: Vec<Vec<f32>> = (0..10).map(|d| render_mean(d, &mut rng)).collect();
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt()
        };
        for i in 0..10 {
            for j in (i + 1)..10 {
                assert!(
                    dist(&means[i], &means[j]) > 1.5,
                    "digits {i} and {j} too similar"
                );
            }
        }
    }
}
