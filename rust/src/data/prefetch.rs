//! Pipelined batch prefetch: assemble batch *k+1* while the graph runs *k*.
//!
//! Batch assembly (epoch shuffle, per-sample procedural generation via
//! `Dataset::fill`, augmentation) is pure CPU work that the serial training
//! loop used to pay *between* graph executions. The prefetcher moves it to
//! a worker thread with a fixed ring of reusable batch buffers (default
//! depth 2 — classic double buffering): the worker blocks until the
//! consumer recycles a buffer, so memory stays bounded at
//! `depth × batch × sample_len` floats and the steady-state loop allocates
//! nothing.
//!
//! **Reproducibility contract:** the worker drives the exact same
//! [`BatchIter`] the serial loop used, re-created per epoch with the same
//! `seed.wrapping_add(epoch)` stream the trainer used before this existed.
//! A training run with the prefetcher is therefore batch-for-batch —
//! and hence loss-for-loss — identical to the serial iterator (pinned by
//! `prefetch_matches_serial_iterator`).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::{Scope, ScopedJoinHandle};

use crate::data::augment::AugmentCfg;
use crate::data::loader::BatchIter;
use crate::data::Dataset;

/// One reusable batch buffer (recycled through the ring).
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    /// Epoch this batch belongs to (train mode; 0 in eval mode).
    pub epoch: u64,
    /// Leading valid samples. Always the full batch in train mode; the
    /// final eval batch of a dataset whose length is not a multiple of the
    /// batch size carries `len % batch` valid rows, with the tail padded
    /// by repeating the last valid sample (consumers must ignore padded
    /// rows — `evaluate_engine` does).
    pub valid: usize,
}

/// What the consumer receives: a filled batch or an epoch boundary.
pub enum Item {
    Batch(Batch),
    /// Epoch `epoch` just finished (train mode only; the worker is already
    /// assembling epoch `epoch + 1` while the consumer evaluates).
    EpochEnd { epoch: u64 },
}

/// Handle to the prefetch worker. Dropping it shuts the worker down; the
/// owning [`std::thread::scope`] joins it.
pub struct Prefetcher<'scope> {
    rx: Receiver<Item>,
    tx_back: Sender<Batch>,
    _handle: ScopedJoinHandle<'scope, ()>,
}

impl<'scope> Prefetcher<'scope> {
    /// Shuffled, augmented epochs — the training path. Emits
    /// `Item::EpochEnd` after each epoch's last full batch and shuts down
    /// after `epochs` epochs. The final partial batch of each epoch is
    /// dropped, as in the reference implementation (the lowered graphs
    /// have a fixed batch dimension and no masking).
    pub fn spawn_train<'env>(
        scope: &'scope Scope<'scope, 'env>,
        ds: &'env dyn Dataset,
        batch: usize,
        seed: u64,
        aug: AugmentCfg,
        epochs: usize,
        depth: usize,
    ) -> Prefetcher<'scope> {
        Self::spawn_train_inner(scope, ds, batch, seed, aug, 0, epochs, depth, false)
    }

    /// [`Prefetcher::spawn_train`] starting at `start_epoch` instead of 0
    /// — the resume path. Because each epoch's stream is derived from
    /// `seed.wrapping_add(epoch)` alone, epochs `start..total` here are
    /// byte-identical to the tail of an uninterrupted run.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_train_from<'env>(
        scope: &'scope Scope<'scope, 'env>,
        ds: &'env dyn Dataset,
        batch: usize,
        seed: u64,
        aug: AugmentCfg,
        start_epoch: u64,
        epochs: usize,
        depth: usize,
    ) -> Prefetcher<'scope> {
        Self::spawn_train_inner(scope, ds, batch, seed, aug, start_epoch, epochs, depth, false)
    }

    /// [`Prefetcher::spawn_train`] with the epoch's final partial batch
    /// **padded, not dropped** (`Batch::valid` marks the real rows, the
    /// tail repeats the last valid sample). The native trainer rides
    /// this: it masks rows ≥ `valid` out of the loss, the gradients and
    /// the BN statistics, so every training sample contributes exactly
    /// once per epoch. The full batches are byte-identical to the
    /// drop-last stream (pinned by `padded_train_extends_drop_last`).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_train_padded<'env>(
        scope: &'scope Scope<'scope, 'env>,
        ds: &'env dyn Dataset,
        batch: usize,
        seed: u64,
        aug: AugmentCfg,
        epochs: usize,
        depth: usize,
    ) -> Prefetcher<'scope> {
        Self::spawn_train_inner(scope, ds, batch, seed, aug, 0, epochs, depth, true)
    }

    /// [`Prefetcher::spawn_train_padded`] starting at `start_epoch` — see
    /// [`Prefetcher::spawn_train_from`].
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_train_padded_from<'env>(
        scope: &'scope Scope<'scope, 'env>,
        ds: &'env dyn Dataset,
        batch: usize,
        seed: u64,
        aug: AugmentCfg,
        start_epoch: u64,
        epochs: usize,
        depth: usize,
    ) -> Prefetcher<'scope> {
        Self::spawn_train_inner(scope, ds, batch, seed, aug, start_epoch, epochs, depth, true)
    }

    #[allow(clippy::too_many_arguments)]
    fn spawn_train_inner<'env>(
        scope: &'scope Scope<'scope, 'env>,
        ds: &'env dyn Dataset,
        batch: usize,
        seed: u64,
        aug: AugmentCfg,
        start_epoch: u64,
        epochs: usize,
        depth: usize,
        pad_final: bool,
    ) -> Prefetcher<'scope> {
        if depth < 2 {
            // Same degradation as spawn_eval: prime() clamps to one buffer,
            // so the worker can only assemble batch k+1 after the consumer
            // recycles batch k — the step loop loses all assembly overlap.
            // Degrade loudly, not silently.
            eprintln!(
                "prefetch(train): ring depth {depth} < 2 — batch assembly degrades to \
                 synchronous (no overlap with the step loop)"
            );
        }
        let (tx, rx) = channel::<Item>();
        let (tx_back, rx_back) = channel::<Batch>();
        prime(&tx_back, ds, batch, depth);
        let handle = scope.spawn(move || {
            let mut spare: Option<Batch> = None;
            for epoch in start_epoch..epochs as u64 {
                // identical stream to the serial loop's per-epoch iterator
                let mut it = BatchIter::new(ds, batch, seed.wrapping_add(epoch), aug);
                loop {
                    let mut buf = match spare.take() {
                        Some(b) => b,
                        None => match rx_back.recv() {
                            Ok(b) => b,
                            Err(_) => return, // consumer gone
                        },
                    };
                    let filled = if pad_final {
                        it.next_batch_padded(&mut buf.x, &mut buf.y)
                    } else if it.next_batch(&mut buf.x, &mut buf.y) {
                        Some(batch)
                    } else {
                        None
                    };
                    match filled {
                        Some(valid) => {
                            buf.epoch = epoch;
                            buf.valid = valid;
                            if tx.send(Item::Batch(buf)).is_err() {
                                return;
                            }
                        }
                        None => {
                            spare = Some(buf); // untouched: first buffer of next epoch
                            break;
                        }
                    }
                }
                if tx.send(Item::EpochEnd { epoch }).is_err() {
                    return;
                }
            }
        });
        Prefetcher { rx, tx_back, _handle: handle }
    }

    /// In-order single pass, no shuffle, no augmentation — the evaluation
    /// path (mirrors `BatchIter::for_eval`). No `EpochEnd` is emitted; the
    /// stream simply ends. Unlike the train path, the final batch is
    /// **padded, not dropped**: every sample of the dataset appears exactly
    /// once among the `valid` rows, so accuracy denominators can use the
    /// true dataset length.
    pub fn spawn_eval<'env>(
        scope: &'scope Scope<'scope, 'env>,
        ds: &'env dyn Dataset,
        batch: usize,
        depth: usize,
    ) -> Prefetcher<'scope> {
        if depth < 2 {
            // prime() silently clamps to 1 buffer, which serializes the
            // pipeline: the worker can only assemble batch k+1 after the
            // consumer recycles batch k. Degrade loudly, not silently.
            eprintln!(
                "prefetch(eval): ring depth {depth} < 2 — batch assembly degrades to \
                 synchronous (no overlap with inference)"
            );
        }
        let (tx, rx) = channel::<Item>();
        let (tx_back, rx_back) = channel::<Batch>();
        prime(&tx_back, ds, batch, depth);
        let handle = scope.spawn(move || {
            let sample_len = ds.sample_len();
            let n = ds.len();
            let n_batches = n.div_ceil(batch);
            for nb in 0..n_batches {
                let mut buf = match rx_back.recv() {
                    Ok(b) => b,
                    Err(_) => return,
                };
                let start = nb * batch;
                let valid = batch.min(n - start);
                for b in 0..valid {
                    buf.y[b] =
                        ds.fill(start + b, &mut buf.x[b * sample_len..(b + 1) * sample_len])
                            as i32;
                }
                // pad the tail by copying the last valid sample (the graph
                // needs a full batch; consumers skip rows >= valid) — a
                // memcpy, not a re-render of the procedural sample
                for b in valid..batch {
                    buf.x
                        .copy_within((valid - 1) * sample_len..valid * sample_len, b * sample_len);
                    buf.y[b] = buf.y[valid - 1];
                }
                buf.epoch = 0;
                buf.valid = valid;
                if tx.send(Item::Batch(buf)).is_err() {
                    return;
                }
            }
        });
        Prefetcher { rx, tx_back, _handle: handle }
    }

    /// Next item, or `None` when the worker has produced everything.
    pub fn next(&mut self) -> Option<Item> {
        self.rx.recv().ok()
    }

    /// Hand a consumed batch buffer back to the worker. Forgetting to
    /// recycle stalls the pipeline once the ring drains (it never
    /// deadlocks the consumer — only the worker waits on this channel).
    pub fn recycle(&mut self, b: Batch) {
        let _ = self.tx_back.send(b);
    }
}

/// Seed the recycle channel with `depth` zeroed buffers.
fn prime(tx_back: &Sender<Batch>, ds: &dyn Dataset, batch: usize, depth: usize) {
    let sample_len = ds.sample_len();
    for _ in 0..depth.max(1) {
        let _ = tx_back.send(Batch {
            x: vec![0.0f32; batch * sample_len],
            y: vec![0i32; batch],
            epoch: 0,
            valid: 0,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{self, SynthDigits};

    /// The reproducibility contract: batch-for-batch equality with the
    /// serial iterator across multiple epochs, augmentation on (so the
    /// per-epoch RNG streams are exercised end to end).
    #[test]
    fn prefetch_matches_serial_iterator() {
        let ds = SynthDigits::new(1, 80);
        let batch = 16;
        let seed = 42u64;
        let epochs = 3usize;
        let aug = AugmentCfg::paper();

        // serial reference: exactly what Trainer::run used to do
        let mut serial: Vec<(u64, Vec<f32>, Vec<i32>)> = Vec::new();
        for epoch in 0..epochs as u64 {
            let mut it = BatchIter::new(&ds, batch, seed.wrapping_add(epoch), aug);
            let mut x = vec![0.0f32; batch * ds.sample_len()];
            let mut y = vec![0i32; batch];
            while it.next_batch(&mut x, &mut y) {
                serial.push((epoch, x.clone(), y.clone()));
            }
        }

        let mut got: Vec<(u64, Vec<f32>, Vec<i32>)> = Vec::new();
        let mut epoch_ends = Vec::new();
        std::thread::scope(|scope| {
            let mut pf = Prefetcher::spawn_train(scope, &ds, batch, seed, aug, epochs, 2);
            while let Some(item) = pf.next() {
                match item {
                    Item::Batch(b) => {
                        got.push((b.epoch, b.x.clone(), b.y.clone()));
                        pf.recycle(b);
                    }
                    Item::EpochEnd { epoch } => epoch_ends.push(epoch),
                }
            }
        });

        assert_eq!(epoch_ends, vec![0, 1, 2]);
        assert_eq!(got.len(), serial.len());
        for (i, (a, b)) in serial.iter().zip(&got).enumerate() {
            assert_eq!(a.0, b.0, "batch {i}: epoch mismatch");
            assert_eq!(a.2, b.2, "batch {i}: labels diverge");
            assert_eq!(a.1, b.1, "batch {i}: pixels diverge");
        }
    }

    /// The padded train stream must replay the drop-last stream's full
    /// batches exactly and append one partial batch per epoch with
    /// `valid` marking the real rows.
    #[test]
    fn padded_train_extends_drop_last() {
        let ds = SynthDigits::new(1, 43); // 2 full batches of 16 + 11 left
        let batch = 16;
        let seed = 5u64;
        let epochs = 2usize;
        let aug = AugmentCfg::paper();
        let collect = |padded: bool| {
            let mut got: Vec<(u64, usize, Vec<f32>, Vec<i32>)> = Vec::new();
            std::thread::scope(|scope| {
                let mut pf = if padded {
                    Prefetcher::spawn_train_padded(scope, &ds, batch, seed, aug, epochs, 2)
                } else {
                    Prefetcher::spawn_train(scope, &ds, batch, seed, aug, epochs, 2)
                };
                while let Some(item) = pf.next() {
                    if let Item::Batch(b) = item {
                        got.push((b.epoch, b.valid, b.x.clone(), b.y.clone()));
                        pf.recycle(b);
                    }
                }
            });
            got
        };
        let plain = collect(false);
        let padded = collect(true);
        assert_eq!(plain.len(), 4); // 2 epochs × 2 full batches
        assert_eq!(padded.len(), 6); // + 1 partial per epoch
        let mut pi = 0usize;
        for p in &padded {
            if p.1 == batch {
                let q = &plain[pi];
                assert_eq!((p.0, p.1), (q.0, q.3.len()), "batch {pi}");
                assert_eq!(p.3, q.3, "labels diverge at full batch {pi}");
                assert_eq!(p.2, q.2, "pixels diverge at full batch {pi}");
                pi += 1;
            } else {
                assert_eq!(p.1, 11, "partial batch valid count");
                // pad rows repeat the last valid sample
                for r in 11..batch {
                    assert_eq!(p.3[r], p.3[10]);
                }
            }
        }
        assert_eq!(pi, plain.len());
    }

    /// The resume contract: a stream started at epoch `k` is byte-identical
    /// to the tail of the full stream — per-epoch seeding means no batch
    /// depends on history before its own epoch.
    #[test]
    fn spawn_train_from_matches_tail_of_full_run() {
        let ds = SynthDigits::new(3, 50);
        let batch = 16;
        let seed = 11u64;
        let aug = AugmentCfg::paper();
        let collect = |start: u64| {
            let mut got: Vec<(u64, usize, Vec<f32>, Vec<i32>)> = Vec::new();
            std::thread::scope(|scope| {
                let mut pf = Prefetcher::spawn_train_padded_from(
                    scope, &ds, batch, seed, aug, start, 3, 2,
                );
                while let Some(item) = pf.next() {
                    if let Item::Batch(b) = item {
                        got.push((b.epoch, b.valid, b.x.clone(), b.y.clone()));
                        pf.recycle(b);
                    }
                }
            });
            got
        };
        let full = collect(0);
        let tail = collect(1);
        let full_tail: Vec<_> = full.iter().filter(|b| b.0 >= 1).cloned().collect();
        assert!(!tail.is_empty());
        assert_eq!(tail, full_tail);
    }

    #[test]
    fn eval_mode_covers_dataset_in_order() {
        let ds = data::open("synth_cifar", false, 40).unwrap();
        let mut labels = Vec::new();
        std::thread::scope(|scope| {
            let mut pf = Prefetcher::spawn_eval(scope, ds.as_ref(), 10, 2);
            while let Some(item) = pf.next() {
                if let Item::Batch(b) = item {
                    assert_eq!(b.valid, 10, "exact split: every batch full");
                    labels.extend_from_slice(&b.y[..b.valid]);
                    pf.recycle(b);
                }
            }
        });
        assert_eq!(labels.len(), 40);
        let mut buf = vec![0.0; ds.sample_len()];
        for (i, &l) in labels.iter().enumerate() {
            assert_eq!(l, ds.fill(i, &mut buf) as i32, "sample {i}");
        }
    }

    /// `len % batch != 0`: the final batch is padded, not dropped — every
    /// sample appears exactly once among the valid rows (the bug this
    /// pins: eval used to silently skip the last `len % batch` samples).
    #[test]
    fn eval_mode_pads_final_partial_batch() {
        let ds = SynthDigits::new(2, 43);
        let batch = 16;
        let mut labels = Vec::new();
        let mut valids = Vec::new();
        std::thread::scope(|scope| {
            let mut pf = Prefetcher::spawn_eval(scope, &ds, batch, 2);
            while let Some(item) = pf.next() {
                if let Item::Batch(b) = item {
                    assert_eq!(b.y.len(), batch, "padded to the full batch");
                    valids.push(b.valid);
                    labels.extend_from_slice(&b.y[..b.valid]);
                    pf.recycle(b);
                }
            }
        });
        assert_eq!(valids, vec![16, 16, 11]);
        assert_eq!(labels.len(), 43);
        let mut buf = vec![0.0; ds.sample_len()];
        for (i, &l) in labels.iter().enumerate() {
            assert_eq!(l, ds.fill(i, &mut buf) as i32, "sample {i}");
        }
    }

    /// A dataset smaller than one batch still yields one padded batch.
    #[test]
    fn eval_mode_handles_tiny_dataset() {
        let ds = SynthDigits::new(2, 5);
        let mut total = 0usize;
        std::thread::scope(|scope| {
            let mut pf = Prefetcher::spawn_eval(scope, &ds, 16, 2);
            while let Some(item) = pf.next() {
                if let Item::Batch(b) = item {
                    assert_eq!(b.valid, 5);
                    total += b.valid;
                    pf.recycle(b);
                }
            }
        });
        assert_eq!(total, 5);
    }

    #[test]
    fn early_drop_shuts_worker_down() {
        let ds = SynthDigits::new(1, 200);
        std::thread::scope(|scope| {
            let mut pf = Prefetcher::spawn_train(
                scope,
                &ds,
                16,
                0,
                AugmentCfg::none(),
                50, // far more epochs than we consume
                2,
            );
            // consume two batches, then drop the handle mid-epoch
            for _ in 0..2 {
                match pf.next() {
                    Some(Item::Batch(b)) => pf.recycle(b),
                    _ => panic!("expected a batch"),
                }
            }
            drop(pf);
            // scope join must not hang: worker observes the closed channels
        });
    }

    #[test]
    fn depth_one_still_makes_progress() {
        let ds = SynthDigits::new(1, 48);
        let mut n = 0;
        std::thread::scope(|scope| {
            let mut pf =
                Prefetcher::spawn_train(scope, &ds, 16, 7, AugmentCfg::none(), 1, 1);
            while let Some(item) = pf.next() {
                if let Item::Batch(b) = item {
                    n += 1;
                    pf.recycle(b);
                }
            }
        });
        assert_eq!(n, 3);
    }
}
