//! Dataset substrate.
//!
//! The paper evaluates on MNIST, CIFAR10 and SVHN. Offline, this module
//! provides (a) an IDX loader for the real MNIST files when they are
//! present under `data/mnist/`, and (b) *procedural* stand-ins —
//! stroke-rasterized digits and textured color classes — that exercise the
//! identical training/eval code paths with controllable difficulty
//! (DESIGN.md §6). Every sample is generated deterministically from
//! (dataset seed, index), so datasets need no storage and train/test
//! splits are disjoint by construction.

pub mod augment;
pub mod idx;
pub mod loader;
pub mod prefetch;
pub mod synth;
pub mod textures;

pub use augment::AugmentCfg;
pub use loader::BatchIter;
pub use prefetch::{Batch, Item, Prefetcher};
pub use synth::SynthDigits;
pub use textures::{SynthCifar, SynthSvhn};

/// A supervised vision dataset with deterministic per-index generation.
pub trait Dataset: Sync {
    /// Number of samples.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Per-sample shape (H, W, C); MLP consumers flatten.
    fn shape(&self) -> (usize, usize, usize);
    fn n_classes(&self) -> usize;
    /// Write sample `idx` (values in [-1, 1], NHWC order) into `out`
    /// (length H*W*C) and return its label.
    fn fill(&self, idx: usize, out: &mut [f32]) -> u32;
    fn name(&self) -> &str;

    fn sample_len(&self) -> usize {
        let (h, w, c) = self.shape();
        h * w * c
    }
}

/// Instantiate a dataset by name: `synth_mnist`, `synth_cifar`,
/// `synth_svhn`, or `mnist` (real IDX files under `data/mnist/`).
/// `train` selects the split (disjoint seeds / file pairs).
pub fn open(name: &str, train: bool, len: usize) -> Result<Box<dyn Dataset>, String> {
    match name {
        "synth_mnist" => Ok(Box::new(SynthDigits::new(if train { 1 } else { 2 }, len))),
        "synth_cifar" => Ok(Box::new(SynthCifar::new(if train { 3 } else { 4 }, len))),
        "synth_svhn" => Ok(Box::new(SynthSvhn::new(if train { 5 } else { 6 }, len))),
        "mnist" => idx::Mnist::open("data/mnist", train)
            .map(|d| Box::new(d) as Box<dyn Dataset>),
        other => Err(format!(
            "unknown dataset {other:?} (expected synth_mnist|synth_cifar|synth_svhn|mnist)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_by_name() {
        for name in ["synth_mnist", "synth_cifar", "synth_svhn"] {
            let ds = open(name, true, 100).unwrap();
            assert_eq!(ds.len(), 100);
            assert_eq!(ds.n_classes(), 10);
        }
        assert!(open("nope", true, 1).is_err());
    }

    #[test]
    fn train_test_splits_differ() {
        let tr = open("synth_mnist", true, 10).unwrap();
        let te = open("synth_mnist", false, 10).unwrap();
        let mut a = vec![0.0; tr.sample_len()];
        let mut b = vec![0.0; te.sample_len()];
        tr.fill(0, &mut a);
        te.fill(0, &mut b);
        assert_ne!(a, b);
    }
}
