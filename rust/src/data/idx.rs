//! IDX (LeCun MNIST format) loader: used when the real MNIST files are
//! present under `data/mnist/` (`train-images-idx3-ubyte` etc., unzipped).
//! The training flow falls back to the procedural datasets otherwise
//! (DESIGN.md §6).

use crate::data::Dataset;
use std::path::Path;

pub struct Mnist {
    images: Vec<u8>,
    labels: Vec<u8>,
    rows: usize,
    cols: usize,
    train: bool,
}

fn read_u32(b: &[u8], pos: usize) -> Result<u32, String> {
    b.get(pos..pos + 4)
        .map(|s| u32::from_be_bytes(s.try_into().unwrap()))
        .ok_or_else(|| "truncated IDX header".to_string())
}

/// Parse an IDX image file: magic 0x00000803, dims [n, rows, cols], u8 pixels.
pub fn parse_idx_images(bytes: &[u8]) -> Result<(Vec<u8>, usize, usize, usize), String> {
    if read_u32(bytes, 0)? != 0x0803 {
        return Err("bad IDX image magic".into());
    }
    let n = read_u32(bytes, 4)? as usize;
    let rows = read_u32(bytes, 8)? as usize;
    let cols = read_u32(bytes, 12)? as usize;
    let want = 16 + n * rows * cols;
    if bytes.len() < want {
        return Err(format!("IDX image payload short: {} < {want}", bytes.len()));
    }
    Ok((bytes[16..want].to_vec(), n, rows, cols))
}

/// Parse an IDX label file: magic 0x00000801, dim [n], u8 labels.
pub fn parse_idx_labels(bytes: &[u8]) -> Result<Vec<u8>, String> {
    if read_u32(bytes, 0)? != 0x0801 {
        return Err("bad IDX label magic".into());
    }
    let n = read_u32(bytes, 4)? as usize;
    let want = 8 + n;
    if bytes.len() < want {
        return Err(format!("IDX label payload short: {} < {want}", bytes.len()));
    }
    Ok(bytes[8..want].to_vec())
}

impl Mnist {
    pub fn open(dir: &str, train: bool) -> Result<Mnist, String> {
        let (img_name, lbl_name) = if train {
            ("train-images-idx3-ubyte", "train-labels-idx1-ubyte")
        } else {
            ("t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte")
        };
        let img_path = Path::new(dir).join(img_name);
        let lbl_path = Path::new(dir).join(lbl_name);
        let img_bytes =
            std::fs::read(&img_path).map_err(|e| format!("{}: {e}", img_path.display()))?;
        let lbl_bytes =
            std::fs::read(&lbl_path).map_err(|e| format!("{}: {e}", lbl_path.display()))?;
        let (images, n, rows, cols) = parse_idx_images(&img_bytes)?;
        let labels = parse_idx_labels(&lbl_bytes)?;
        if labels.len() != n {
            return Err(format!("image/label count mismatch: {n} vs {}", labels.len()));
        }
        Ok(Mnist { images, labels, rows, cols, train })
    }
}

impl Dataset for Mnist {
    fn len(&self) -> usize {
        self.labels.len()
    }

    fn shape(&self) -> (usize, usize, usize) {
        (self.rows, self.cols, 1)
    }

    fn n_classes(&self) -> usize {
        10
    }

    fn fill(&self, idx: usize, out: &mut [f32]) -> u32 {
        let px = self.rows * self.cols;
        let src = &self.images[idx * px..(idx + 1) * px];
        for (o, &b) in out.iter_mut().zip(src) {
            *o = b as f32 / 127.5 - 1.0; // [0,255] -> [-1,1]
        }
        self.labels[idx] as u32
    }

    fn name(&self) -> &str {
        if self.train {
            "mnist-train"
        } else {
            "mnist-test"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_idx(n: usize, rows: usize, cols: usize) -> (Vec<u8>, Vec<u8>) {
        let mut img = vec![];
        img.extend_from_slice(&0x0803u32.to_be_bytes());
        img.extend_from_slice(&(n as u32).to_be_bytes());
        img.extend_from_slice(&(rows as u32).to_be_bytes());
        img.extend_from_slice(&(cols as u32).to_be_bytes());
        for i in 0..n * rows * cols {
            img.push((i % 256) as u8);
        }
        let mut lbl = vec![];
        lbl.extend_from_slice(&0x0801u32.to_be_bytes());
        lbl.extend_from_slice(&(n as u32).to_be_bytes());
        for i in 0..n {
            lbl.push((i % 10) as u8);
        }
        (img, lbl)
    }

    #[test]
    fn parses_wellformed() {
        let (img, lbl) = fake_idx(3, 4, 5);
        let (data, n, r, c) = parse_idx_images(&img).unwrap();
        assert_eq!((n, r, c), (3, 4, 5));
        assert_eq!(data.len(), 60);
        assert_eq!(parse_idx_labels(&lbl).unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let (mut img, lbl) = fake_idx(2, 2, 2);
        img[3] = 0x01;
        assert!(parse_idx_images(&img).is_err());
        let (img2, _) = fake_idx(2, 2, 2);
        assert!(parse_idx_images(&img2[..17]).is_err());
        assert!(parse_idx_labels(&lbl[..8]).is_err());
    }

    #[test]
    fn end_to_end_via_tempdir() {
        let dir = std::env::temp_dir().join(format!("gxnor_idx_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (img, lbl) = fake_idx(7, 28, 28);
        std::fs::write(dir.join("train-images-idx3-ubyte"), &img).unwrap();
        std::fs::write(dir.join("train-labels-idx1-ubyte"), &lbl).unwrap();
        let ds = Mnist::open(dir.to_str().unwrap(), true).unwrap();
        assert_eq!(ds.len(), 7);
        let mut x = vec![0.0; 784];
        let l = ds.fill(2, &mut x);
        assert_eq!(l, 2);
        assert!(x.iter().all(|&v| (-1.0..=1.0).contains(&v)));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_files_error() {
        assert!(Mnist::open("/nonexistent/dir", true).is_err());
    }
}
