//! Rust-side architecture descriptions, mirroring
//! `python/compile/model.py::build_arch`.
//!
//! The runtime itself never needs these (shapes come from the manifest);
//! they exist for the *hardware simulator*, which must know each layer's
//! spatial geometry (neuron count × fan-in) to turn a trained model into
//! the per-layer operation tables of Section 3.C.

/// One layer of a network.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layer {
    /// k×k convolution, cin -> cout, with "SAME" (true) or "VALID" padding.
    Conv { cin: usize, cout: usize, k: usize, same: bool },
    /// Max-pool size×size, stride = size.
    Pool { size: usize },
    Flatten,
    Dense { din: usize, dout: usize },
}

#[derive(Clone, Debug)]
pub struct Arch {
    pub name: &'static str,
    /// (H, W, C) per-sample input
    pub input: (usize, usize, usize),
    pub layers: Vec<Layer>,
}

/// Mirror of the python catalogue (width 1.0).
pub fn build_arch(name: &str) -> Result<Arch, String> {
    match name {
        "mlp" => Ok(Arch {
            name: "mlp",
            input: (1, 1, 784),
            layers: vec![
                Layer::Flatten,
                Layer::Dense { din: 784, dout: 512 },
                Layer::Dense { din: 512, dout: 512 },
                Layer::Dense { din: 512, dout: 10 },
            ],
        }),
        "cnn_mnist" => Ok(Arch {
            name: "cnn_mnist",
            input: (28, 28, 1),
            layers: vec![
                Layer::Conv { cin: 1, cout: 32, k: 5, same: false },
                Layer::Pool { size: 2 },
                Layer::Conv { cin: 32, cout: 64, k: 5, same: false },
                Layer::Pool { size: 2 },
                Layer::Flatten,
                Layer::Dense { din: 1024, dout: 512 },
                Layer::Dense { din: 512, dout: 10 },
            ],
        }),
        "cnn_cifar" => Ok(Arch {
            name: "cnn_cifar",
            input: (32, 32, 3),
            layers: vec![
                Layer::Conv { cin: 3, cout: 128, k: 3, same: true },
                Layer::Conv { cin: 128, cout: 128, k: 3, same: true },
                Layer::Pool { size: 2 },
                Layer::Conv { cin: 128, cout: 256, k: 3, same: true },
                Layer::Conv { cin: 256, cout: 256, k: 3, same: true },
                Layer::Pool { size: 2 },
                Layer::Conv { cin: 256, cout: 512, k: 3, same: true },
                Layer::Conv { cin: 512, cout: 512, k: 3, same: true },
                Layer::Pool { size: 2 },
                Layer::Flatten,
                Layer::Dense { din: 8192, dout: 1024 },
                Layer::Dense { din: 1024, dout: 10 },
            ],
        }),
        other => Err(format!("unknown arch {other:?}")),
    }
}

/// Rebuild a named architecture with its weighted-layer dimensions
/// overridden by actual weight shapes — Dense `[din, dout]`, Conv
/// `[k, k, cin, cout]` (HWIO) — so width-scaled artifacts drive the same
/// topology. This is how the native engine recovers the exact network a
/// manifest/checkpoint was lowered with.
pub fn arch_from_weights(name: &str, shapes: &[Vec<usize>]) -> Result<Arch, String> {
    let mut arch = build_arch(name)?;
    let mut wi = 0usize;
    for l in arch.layers.iter_mut() {
        match l {
            Layer::Conv { cin, cout, k, .. } => {
                let s = shapes
                    .get(wi)
                    .ok_or_else(|| format!("arch {name}: missing weight shape for conv {wi}"))?;
                if s.len() != 4 || s[0] != s[1] {
                    return Err(format!("conv {wi}: bad HWIO weight shape {s:?}"));
                }
                *k = s[0];
                *cin = s[2];
                *cout = s[3];
                wi += 1;
            }
            Layer::Dense { din, dout } => {
                let s = shapes
                    .get(wi)
                    .ok_or_else(|| format!("arch {name}: missing weight shape for dense {wi}"))?;
                if s.len() != 2 {
                    return Err(format!("dense {wi}: bad weight shape {s:?}"));
                }
                *din = s[0];
                *dout = s[1];
                wi += 1;
            }
            Layer::Pool { .. } | Layer::Flatten => {}
        }
    }
    if wi != shapes.len() {
        return Err(format!(
            "arch {name} has {wi} weighted layers, got {} weight shapes",
            shapes.len()
        ));
    }
    Ok(arch)
}

/// Trainable parameter descriptors for an architecture, mirroring
/// `python/compile/model.py::param_descs`: per weighted layer the weight
/// `W{i}` (HWIO for conv, `[din, dout]` for dense); hidden layers add
/// BatchNorm affine `gamma{i}`/`beta{i}` plus running state
/// `rmean{i}`/`rvar{i}`. Returns `(params, bn_names, bn_lens)`. This is
/// how the native training engine bootstraps **without a manifest** —
/// the same order the lowered graphs use, so checkpoints interoperate.
pub fn param_descs(
    arch: &Arch,
) -> (Vec<crate::nn::params::ParamDesc>, Vec<String>, Vec<usize>) {
    use crate::nn::params::{ParamDesc, ParamKind};
    let weighted: Vec<&Layer> = arch
        .layers
        .iter()
        .filter(|l| matches!(l, Layer::Conv { .. } | Layer::Dense { .. }))
        .collect();
    let n_w = weighted.len();
    let mut params = Vec::new();
    let mut bn_names = Vec::new();
    let mut bn_lens = Vec::new();
    for (i, l) in weighted.iter().enumerate() {
        let (shape, ch) = match **l {
            Layer::Conv { cin, cout, k, .. } => (vec![k, k, cin, cout], cout),
            Layer::Dense { din, dout } => (vec![din, dout], dout),
            _ => unreachable!(),
        };
        params.push(ParamDesc { name: format!("W{i}"), shape, kind: ParamKind::Weight, layer: i });
        if i + 1 < n_w {
            params.push(ParamDesc {
                name: format!("gamma{i}"),
                shape: vec![ch],
                kind: ParamKind::Gamma,
                layer: i,
            });
            params.push(ParamDesc {
                name: format!("beta{i}"),
                shape: vec![ch],
                kind: ParamKind::Beta,
                layer: i,
            });
            bn_names.push(format!("rmean{i}"));
            bn_names.push(format!("rvar{i}"));
            bn_lens.push(ch);
            bn_lens.push(ch);
        }
    }
    (params, bn_names, bn_lens)
}

/// One weighted layer's compute geometry after shape propagation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerGeometry {
    pub name: String,
    /// fan-in per neuron evaluation (M in Table 2)
    pub fan_in: usize,
    /// neuron evaluations per sample (out positions × out channels)
    pub neuron_evals: usize,
    /// trainable weights in the layer
    pub weights: usize,
}

impl LayerGeometry {
    /// Nominal multiply-accumulate (or XNOR) ops per sample.
    pub fn nominal_ops(&self) -> u64 {
        self.fan_in as u64 * self.neuron_evals as u64
    }
}

/// Propagate shapes through the network, yielding geometry per weighted
/// layer (the hwsim's input).
pub fn geometry(arch: &Arch) -> Vec<LayerGeometry> {
    let (mut h, mut w, mut c) = arch.input;
    let mut out = Vec::new();
    let mut li = 0usize;
    for layer in &arch.layers {
        match *layer {
            Layer::Conv { cin, cout, k, same } => {
                assert_eq!(c, cin, "channel mismatch at layer {li}");
                let (oh, ow) = if same { (h, w) } else { (h - k + 1, w - k + 1) };
                out.push(LayerGeometry {
                    name: format!("conv{li} {k}x{k}x{cin}->{cout}"),
                    fan_in: k * k * cin,
                    neuron_evals: oh * ow * cout,
                    weights: k * k * cin * cout,
                });
                h = oh;
                w = ow;
                c = cout;
                li += 1;
            }
            Layer::Pool { size } => {
                h /= size;
                w /= size;
            }
            Layer::Flatten => {
                c = h * w * c;
                h = 1;
                w = 1;
            }
            Layer::Dense { din, dout } => {
                assert_eq!(h * w * c, din, "dense fan-in mismatch at layer {li}");
                out.push(LayerGeometry {
                    name: format!("fc{li} {din}->{dout}"),
                    fan_in: din,
                    neuron_evals: dout,
                    weights: din * dout,
                });
                c = dout;
                h = 1;
                w = 1;
                li += 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_cnn_geometry_matches_paper() {
        // 32C5-MP2-64C5-MP2-512FC-SVM over 28x28: 24^2, 8^2 feature maps
        let arch = build_arch("cnn_mnist").unwrap();
        let g = geometry(&arch);
        assert_eq!(g.len(), 4);
        assert_eq!(g[0].fan_in, 25);
        assert_eq!(g[0].neuron_evals, 24 * 24 * 32);
        assert_eq!(g[1].fan_in, 5 * 5 * 32);
        assert_eq!(g[1].neuron_evals, 8 * 8 * 64);
        assert_eq!(g[2].fan_in, 1024);
        assert_eq!(g[2].neuron_evals, 512);
        assert_eq!(g[3].weights, 5120);
    }

    #[test]
    fn cifar_geometry_matches_paper() {
        let arch = build_arch("cnn_cifar").unwrap();
        let g = geometry(&arch);
        assert_eq!(g.len(), 8);
        // last conv block: 8x8 maps at 512 channels
        assert_eq!(g[5].neuron_evals, 8 * 8 * 512);
        // FC: 512 * 4 * 4 = 8192 -> 1024
        assert_eq!(g[6].fan_in, 8192);
        // total weights ~ 13M (the paper-scale net)
        let total: usize = g.iter().map(|l| l.weights).sum();
        assert!(total > 12_000_000 && total < 16_000_000, "{total}");
    }

    #[test]
    fn mlp_geometry() {
        let g = geometry(&build_arch("mlp").unwrap());
        assert_eq!(g.len(), 3);
        assert_eq!(g[0].nominal_ops(), 784 * 512);
    }

    #[test]
    fn unknown_arch_rejected() {
        assert!(build_arch("vgg").is_err());
    }

    #[test]
    fn arch_from_weights_overrides_width() {
        // a width-scaled mlp: 784-32-32-10 instead of 784-512-512-10
        let shapes = vec![vec![784, 32], vec![32, 32], vec![32, 10]];
        let a = arch_from_weights("mlp", &shapes).unwrap();
        assert_eq!(a.layers[1], Layer::Dense { din: 784, dout: 32 });
        assert_eq!(a.layers[3], Layer::Dense { din: 32, dout: 10 });
        let g = geometry(&a);
        assert_eq!(g[0].neuron_evals, 32);
    }

    #[test]
    fn param_descs_mirror_python_ordering() {
        use crate::nn::params::ParamKind;
        let arch = build_arch("cnn_mnist").unwrap();
        let (params, bn_names, bn_lens) = param_descs(&arch);
        // 4 weighted layers, 3 hidden with BN: 4 W + 3×(gamma, beta)
        assert_eq!(params.len(), 4 + 6);
        let names: Vec<&str> = params.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(
            names,
            ["W0", "gamma0", "beta0", "W1", "gamma1", "beta1", "W2", "gamma2", "beta2", "W3"]
        );
        assert_eq!(params[0].shape, vec![5, 5, 1, 32]);
        assert_eq!(params[0].kind, ParamKind::Weight);
        assert_eq!(params[1].shape, vec![32]);
        assert_eq!(params[6].shape, vec![1024, 512]);
        assert_eq!(bn_names, ["rmean0", "rvar0", "rmean1", "rvar1", "rmean2", "rvar2"]);
        assert_eq!(bn_lens, [32, 32, 64, 64, 512, 512]);
        // mlp: last layer has no BN
        let (p2, n2, _) = param_descs(&build_arch("mlp").unwrap());
        assert_eq!(p2.len(), 3 + 4);
        assert_eq!(n2.len(), 4);
    }

    #[test]
    fn arch_from_weights_rejects_mismatches() {
        // wrong count
        assert!(arch_from_weights("mlp", &[vec![784, 32]]).is_err());
        // wrong rank for a conv layer
        let bad = vec![vec![25, 32], vec![5, 5, 32, 64], vec![1024, 512], vec![512, 10]];
        assert!(arch_from_weights("cnn_mnist", &bad).is_err());
        // non-square conv kernel
        let bad2 = vec![
            vec![5, 3, 1, 32],
            vec![5, 5, 32, 64],
            vec![1024, 512],
            vec![512, 10],
        ];
        assert!(arch_from_weights("cnn_mnist", &bad2).is_err());
    }
}
