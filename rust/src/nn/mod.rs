//! Model-side bookkeeping: parameter descriptors (parsed from the artifact
//! manifest), discrete/dense initialization, the in-memory model state the
//! coordinator trains, and rust-side architecture geometry for the
//! hardware simulator.

pub mod arch;
pub mod init;
pub mod params;

pub use arch::{arch_from_weights, build_arch, geometry, Arch, Layer, LayerGeometry};
pub use params::{ModelState, ParamDesc, ParamKind};
