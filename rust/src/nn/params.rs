//! Parameter descriptors and trainable model state.
//!
//! The Rust side hard-codes nothing about network shapes: descriptors are
//! parsed from `artifacts/manifest.json` (written by `python/compile/aot.py`).
//! Weights are held *packed* on the Z_N grid (`PackedTensor`) — the paper's
//! no-hidden-weights property — and expanded to f32 only to cross the PJRT
//! boundary. BatchNorm affine parameters and running stats are small dense
//! f32 vectors (activation-side, O(#channels); see DESIGN.md §6).

use crate::ternary::{DiscreteSpace, PackedTensor};
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    Weight,
    Gamma,
    Beta,
}

impl ParamKind {
    pub fn parse(s: &str) -> Result<ParamKind, String> {
        match s {
            "weight" => Ok(ParamKind::Weight),
            "gamma" => Ok(ParamKind::Gamma),
            "beta" => Ok(ParamKind::Beta),
            other => Err(format!("unknown param kind {other:?}")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct ParamDesc {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: ParamKind,
    pub layer: usize,
}

impl ParamDesc {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn from_manifest(j: &Json) -> Result<ParamDesc, String> {
        Ok(ParamDesc {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or("param missing name")?
                .to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_usize_vec)
                .ok_or("param missing shape")?,
            kind: ParamKind::parse(
                j.get("kind").and_then(Json::as_str).ok_or("param missing kind")?,
            )?,
            layer: j.get("layer").and_then(Json::as_usize).ok_or("param missing layer")?,
        })
    }
}

/// One trainable parameter: packed weight or dense BN affine.
#[derive(Clone, Debug)]
pub enum ParamValue {
    /// Weights on the Z_N grid, bit-packed.
    Discrete(PackedTensor),
    /// BN gamma/beta, plain f32.
    Dense(Vec<f32>),
}

impl ParamValue {
    pub fn len(&self) -> usize {
        match self {
            ParamValue::Discrete(p) => p.len(),
            ParamValue::Dense(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand to f32 (PJRT boundary format).
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            ParamValue::Discrete(p) => p.unpack(),
            ParamValue::Dense(v) => v.clone(),
        }
    }
}

/// Full trainable state of one network: params + BN running stats.
#[derive(Clone, Debug)]
pub struct ModelState {
    pub descs: Vec<ParamDesc>,
    pub values: Vec<ParamValue>,
    pub bn_names: Vec<String>,
    pub bn_state: Vec<Vec<f32>>,
    pub space: DiscreteSpace,
}

impl ModelState {
    /// Total weight count (the paper's memory accounting unit).
    pub fn n_weights(&self) -> usize {
        self.descs
            .iter()
            .zip(&self.values)
            .filter(|(d, _)| d.kind == ParamKind::Weight)
            .map(|(d, _)| d.numel())
            .sum()
    }

    /// Bytes held by weights in packed form vs the f32 hidden-weight copy
    /// the paper's baselines would need. Returns (packed, fp32).
    pub fn weight_memory_bytes(&self) -> (usize, usize) {
        let mut packed = 0usize;
        let mut fp32 = 0usize;
        for (d, v) in self.descs.iter().zip(&self.values) {
            if d.kind == ParamKind::Weight {
                if let ParamValue::Discrete(p) = v {
                    packed += p.payload_bytes();
                }
                fp32 += d.numel() * 4;
            }
        }
        (packed, fp32)
    }

    /// Mean zero-state fraction over all weight tensors (Table 2 input).
    pub fn weight_zero_fraction(&self) -> f64 {
        let (mut zeros, mut total) = (0.0f64, 0.0f64);
        for (d, v) in self.descs.iter().zip(&self.values) {
            if d.kind == ParamKind::Weight {
                if let ParamValue::Discrete(p) = v {
                    zeros += p.zero_fraction() * p.len() as f64;
                    total += p.len() as f64;
                }
            }
        }
        if total == 0.0 {
            0.0
        } else {
            zeros / total
        }
    }

    /// Exact byte snapshot of the full trainable + BN state: packed
    /// tensors via their serialized form, dense tensors and running
    /// stats as raw little-endian f32 bits. Two models are bit-identical
    /// iff their fingerprints are equal — the determinism tests and the
    /// bench's thread-scaling trajectory check compare these.
    pub fn fingerprint(&self) -> Vec<u8> {
        let mut bytes = Vec::new();
        for v in &self.values {
            match v {
                ParamValue::Discrete(p) => p.serialize(&mut bytes),
                ParamValue::Dense(d) => {
                    for x in d {
                        bytes.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        for s in &self.bn_state {
            for x in s {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
        }
        bytes
    }

    /// Histogram over weight states (aggregated across tensors).
    pub fn weight_histogram(&self) -> Vec<u64> {
        let mut h = vec![0u64; self.space.n_states()];
        for (d, v) in self.descs.iter().zip(&self.values) {
            if d.kind == ParamKind::Weight {
                if let ParamValue::Discrete(p) = v {
                    for (i, c) in p.histogram().into_iter().enumerate() {
                        h[i] += c;
                    }
                }
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_param_desc() {
        let j = Json::parse(r#"{"name":"W0","shape":[784,512],"kind":"weight","layer":0}"#)
            .unwrap();
        let d = ParamDesc::from_manifest(&j).unwrap();
        assert_eq!(d.name, "W0");
        assert_eq!(d.numel(), 784 * 512);
        assert_eq!(d.kind, ParamKind::Weight);
    }

    #[test]
    fn parse_rejects_malformed() {
        let j = Json::parse(r#"{"name":"W0"}"#).unwrap();
        assert!(ParamDesc::from_manifest(&j).is_err());
        let j = Json::parse(r#"{"name":"x","shape":[1],"kind":"mystery","layer":0}"#).unwrap();
        assert!(ParamDesc::from_manifest(&j).is_err());
    }

    #[test]
    fn memory_accounting() {
        let space = DiscreteSpace::TERNARY;
        let w = PackedTensor::zeros(&[1000], space);
        let state = ModelState {
            descs: vec![
                ParamDesc { name: "W0".into(), shape: vec![1000], kind: ParamKind::Weight, layer: 0 },
                ParamDesc { name: "gamma0".into(), shape: vec![10], kind: ParamKind::Gamma, layer: 0 },
            ],
            values: vec![ParamValue::Discrete(w), ParamValue::Dense(vec![1.0; 10])],
            bn_names: vec![],
            bn_state: vec![],
            space,
        };
        assert_eq!(state.n_weights(), 1000);
        let (packed, fp) = state.weight_memory_bytes();
        assert_eq!(fp, 4000);
        assert!(packed <= 256 + 8, "2-bit packing: {packed}");
        assert_eq!(state.weight_zero_fraction(), 1.0);
        assert_eq!(state.weight_histogram()[1], 1000);
    }
}
