//! Parameter initialization.
//!
//! Weights start uniformly distributed over the states of Z_N (matching
//! `python/compile/model.py::init_params` — a nearest-grid projection of a
//! Glorot init collapses to all-zeros for coarse grids); BN gamma = 1,
//! beta = 0, running mean = 0, running var = 1.

use crate::nn::params::{ModelState, ParamDesc, ParamKind, ParamValue};
use crate::ternary::{DiscreteSpace, PackedTensor};
use crate::util::prng::Prng;

/// Build a fresh model state from manifest descriptors.
pub fn init_model(
    descs: Vec<ParamDesc>,
    bn_names: Vec<String>,
    bn_shapes: &[usize],
    space: DiscreteSpace,
    seed: u64,
) -> ModelState {
    let mut rng = Prng::new(seed);
    let mut values = Vec::with_capacity(descs.len());
    for d in &descs {
        match d.kind {
            ParamKind::Weight => {
                let mut tensor_rng = rng.fork(d.layer as u64 + 1);
                let vals: Vec<f32> = (0..d.numel())
                    .map(|_| space.state(tensor_rng.below(space.n_states())))
                    .collect();
                values.push(ParamValue::Discrete(PackedTensor::pack(
                    &vals, &d.shape, space,
                )));
            }
            ParamKind::Gamma => values.push(ParamValue::Dense(vec![1.0; d.numel()])),
            ParamKind::Beta => values.push(ParamValue::Dense(vec![0.0; d.numel()])),
        }
    }
    assert_eq!(bn_names.len(), bn_shapes.len());
    let bn_state = bn_names
        .iter()
        .zip(bn_shapes)
        .map(|(name, &len)| {
            if name.starts_with("rvar") {
                vec![1.0f32; len]
            } else {
                vec![0.0f32; len]
            }
        })
        .collect();
    ModelState { descs, values, bn_names, bn_state, space }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn descs() -> Vec<ParamDesc> {
        vec![
            ParamDesc { name: "W0".into(), shape: vec![20, 30], kind: ParamKind::Weight, layer: 0 },
            ParamDesc { name: "gamma0".into(), shape: vec![30], kind: ParamKind::Gamma, layer: 0 },
            ParamDesc { name: "beta0".into(), shape: vec![30], kind: ParamKind::Beta, layer: 0 },
            ParamDesc { name: "W1".into(), shape: vec![30, 10], kind: ParamKind::Weight, layer: 1 },
        ]
    }

    #[test]
    fn init_shapes_and_kinds() {
        let m = init_model(
            descs(),
            vec!["rmean0".into(), "rvar0".into()],
            &[30, 30],
            DiscreteSpace::TERNARY,
            42,
        );
        assert_eq!(m.values.len(), 4);
        assert_eq!(m.values[0].len(), 600);
        assert_eq!(m.values[1].to_f32(), vec![1.0; 30]);
        assert_eq!(m.values[2].to_f32(), vec![0.0; 30]);
        assert_eq!(m.bn_state[0], vec![0.0; 30]);
        assert_eq!(m.bn_state[1], vec![1.0; 30]);
        assert_eq!(m.n_weights(), 600 + 300);
    }

    #[test]
    fn weights_on_grid_and_not_degenerate() {
        for n in [0u32, 1, 3] {
            let space = DiscreteSpace::new(n);
            let m = init_model(descs(), vec![], &[], space, 7);
            if let ParamValue::Discrete(p) = &m.values[0] {
                let h = p.histogram();
                assert_eq!(h.iter().sum::<u64>(), 600);
                // roughly uniform: every state present for small spaces
                assert!(h.iter().all(|&c| c > 0), "N={n}: {h:?}");
            } else {
                panic!("W0 should be discrete");
            }
        }
    }

    #[test]
    fn different_layers_different_streams() {
        let m = init_model(descs(), vec![], &[], DiscreteSpace::TERNARY, 1);
        let w0 = m.values[0].to_f32();
        let w1 = m.values[3].to_f32();
        assert_ne!(&w0[..10], &w1[..10]);
    }

    #[test]
    fn deterministic_from_seed() {
        let a = init_model(descs(), vec![], &[], DiscreteSpace::TERNARY, 5);
        let b = init_model(descs(), vec![], &[], DiscreteSpace::TERNARY, 5);
        assert_eq!(a.values[0].to_f32(), b.values[0].to_f32());
        let c = init_model(descs(), vec![], &[], DiscreteSpace::TERNARY, 6);
        assert_ne!(a.values[0].to_f32(), c.values[0].to_f32());
    }
}
