//! `gxnor` — the GXNOR-Net training coordinator CLI.
//!
//! Subcommands:
//!   train    train a network with any Table-1 method (gxnor/bnn/bwn/twn/fp
//!            or multi:N1,N2) on a real or procedural dataset
//!   eval     evaluate a checkpoint (--engine xla|native)
//!   sweep    reproduce the ablation figures (m / a / r / levels)
//!   serve    async inference service: dynamic batching over native-engine
//!            replicas (server, client probes, loadgen, --bench)
//!   hwsim    print Table 2 + the Fig. 12 gating example
//!   info     list artifacts and their shapes
//!   inspect  describe a checkpoint (tensors, spaces, histograms)
//!
//! Run `gxnor <cmd> --help` for options.

use anyhow::{anyhow, Result};

use gxnor::cli::Command;
use gxnor::coordinator::checkpoint;
use gxnor::coordinator::method::Method;
use gxnor::coordinator::optimizer::OptKind;
use gxnor::coordinator::trainer::{
    evaluate_engine, NativeTrainer, TrainBackend, TrainConfig, Trainer,
};
use gxnor::hwsim::report as hwreport;
use gxnor::runtime::client::Runtime;
use gxnor::runtime::exec::{EngineKind, ExecEngine};
use gxnor::runtime::manifest::Manifest;
use gxnor::sweep;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print_usage();
        return;
    }
    let (cmd, rest) = (argv[0].as_str(), &argv[1..]);
    let result = match cmd {
        "train" => cmd_train(rest),
        "eval" => cmd_eval(rest),
        "sweep" => cmd_sweep(rest),
        "serve" => cmd_serve(rest),
        "hwsim" => cmd_hwsim(rest),
        "info" => cmd_info(rest),
        "inspect" => cmd_inspect(rest),
        other => Err(anyhow!("unknown command {other:?}; run `gxnor help`")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "gxnor — ternary weights & activations without full-precision memory\n\
         (Deng et al., Neural Networks 2018 — unified discretization framework)\n\n\
         usage: gxnor <train|eval|sweep|serve|hwsim|info|inspect> [options]\n"
    );
    let cmds = [
        train_cmd(),
        eval_cmd(),
        sweep_cmd(),
        serve_cmd(),
        hwsim_cmd(),
        info_cmd(),
        inspect_cmd(),
    ];
    for c in cmds {
        println!("{}", c.help());
    }
}

fn train_cmd() -> Command {
    Command::new("train", "train a network with the DST framework")
        .opt("config", "", "TOML config (configs/*.toml); CLI options override")
        .opt("set", "", "config override, e.g. train.epochs=20")
        .opt("arch", "mlp", "mlp | cnn_mnist | cnn_cifar")
        .opt("method", "gxnor", "fp|bwn|twn|bnn|gxnor|multi:N1,N2")
        .opt("dataset", "synth_mnist", "synth_mnist|synth_cifar|synth_svhn|mnist")
        .opt("epochs", "5", "training epochs")
        .opt("train-len", "4000", "train split size (procedural datasets)")
        .opt("test-len", "1000", "test split size")
        .opt("r", "0.5", "zero-window half width (sparsity knob)")
        .opt("a", "0.5", "derivative pulse half-width")
        .opt("m", "3.0", "DST transition nonlinearity")
        .opt("lr-start", "0.02", "initial learning rate")
        .opt("lr-fin", "0.001", "final learning rate")
        .opt("opt", "adam", "adam | sgd")
        .opt("update", "dst", "dst (paper) | hidden (Fig. 4a baseline: fp masters)")
        .opt("seed", "42", "RNG seed")
        .opt("engine", "xla", "training+eval engine: xla (PJRT graphs) | native (device-free DST)")
        .opt("threads", "0", "native-engine worker threads (0 = auto)")
        .opt("batch", "0", "native-engine batch size (0 = manifest batch, else 100)")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("save", "", "checkpoint path to write after training")
        .opt("checkpoint-every", "0", "save a resumable run checkpoint to --save every N epochs")
        .opt("resume", "", "resume a run checkpoint written by --checkpoint-every (native engine)")
        .opt("faults", "", "fault-injection spec, e.g. train_crash=2 (or GXNOR_FAULTS env)")
        .flag("augment", "pad-4 + random crop + hflip (paper CIFAR recipe)")
        .flag("quiet", "suppress per-epoch lines")
}

fn parse_train_cfg(a: &gxnor::cli::Args) -> Result<TrainConfig> {
    // layering: built-in defaults < TOML config < --set overrides < CLI opts
    let mut file_cfg = gxnor::config::Config::default();
    let cfg_path = a.opt_or("config", "");
    if !cfg_path.is_empty() {
        file_cfg = gxnor::config::Config::from_file(&cfg_path).map_err(|e| anyhow!(e))?;
    }
    if let Some(ov) = a.opt("set").filter(|s| !s.is_empty()) {
        let (k, v) = ov
            .split_once('=')
            .ok_or_else(|| anyhow!("--set expects key=value, got {ov:?}"))?;
        file_cfg.set(k, v).map_err(|e| anyhow!(e))?;
    }
    // CLI value if explicitly usable, else config value, else default
    let s = |cli: &str, key: &str, def: &str| -> String {
        match a.opt(cli) {
            Some(v) if v != def => v.to_string(), // explicit CLI override
            _ => file_cfg.str(key, &a.opt_or(cli, def)),
        }
    };
    // a malformed numeric value is an error naming the flag, never a
    // silent fall-back to the default (`--epochs abc` used to train 3)
    let f = |cli: &str, key: &str, def: f64| -> Result<f64> {
        let cli_v = a.opt_f64(cli, def).map_err(|e| anyhow!(e))?;
        Ok(if (cli_v - def).abs() > 1e-12 {
            cli_v
        } else {
            file_cfg.f64(key, cli_v)
        })
    };
    Ok(TrainConfig {
        arch: s("arch", "train.arch", "mlp"),
        method: Method::parse(&s("method", "train.method", "gxnor")).map_err(|e| anyhow!(e))?,
        dataset: s("dataset", "train.dataset", "synth_mnist"),
        train_len: f("train-len", "train.train_len", 4000.0)? as usize,
        test_len: f("test-len", "train.test_len", 1000.0)? as usize,
        epochs: f("epochs", "train.epochs", 5.0)? as usize,
        seed: f("seed", "train.seed", 42.0)? as u64,
        r: f("r", "train.r", 0.5)? as f32,
        a: f("a", "train.a", 0.5)? as f32,
        m: f("m", "train.m", 3.0)? as f32,
        lr_start: f("lr-start", "train.lr_start", 0.02)?,
        lr_fin: f("lr-fin", "train.lr_fin", 0.001)?,
        opt: OptKind::parse(&s("opt", "train.opt", "adam")).map_err(|e| anyhow!(e))?,
        update_rule: gxnor::coordinator::UpdateRule::parse(&s("update", "train.update", "dst"))
            .map_err(|e| anyhow!(e))?,
        augment: a.flag("augment") || file_cfg.bool("train.augment", false),
        dense_lr_scale: file_cfg.f64("train.dense_lr_scale", 0.5),
        engine: EngineKind::parse(&s("engine", "train.engine", "xla")).map_err(|e| anyhow!(e))?,
        threads: f("threads", "train.threads", 0.0)? as usize,
        batch: f("batch", "train.batch", 0.0)? as usize,
        verbose: !a.flag("quiet"),
        checkpoint_every: f("checkpoint-every", "train.checkpoint_every", 0.0)? as usize,
        checkpoint_path: String::new(), // filled from --save in cmd_train
        faults: None,                   // resolved from --faults in cmd_train
    })
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let a = train_cmd().parse(argv).map_err(|e| anyhow!(e))?;
    let mut cfg = parse_train_cfg(&a)?;
    let save = a.opt_or("save", "");
    let art = a.opt_or("artifacts", "artifacts");
    let resume = a.opt_or("resume", "");
    cfg.faults =
        gxnor::util::fault::FaultPlan::resolve(&a.opt_or("faults", "")).map_err(|e| anyhow!(e))?;
    if let Some(p) = cfg.faults.as_deref() {
        println!("fault plan    : {p}");
    }
    if cfg.checkpoint_every > 0 {
        if save.is_empty() {
            return Err(anyhow!("--checkpoint-every requires --save <path> (the checkpoint file)"));
        }
        cfg.checkpoint_path = save.clone();
    }
    if !resume.is_empty() && cfg.engine != EngineKind::Native {
        return Err(anyhow!(
            "--resume requires --engine native (run checkpoints capture the native DST loop)"
        ));
    }
    let train = gxnor::data::open(&cfg.dataset, true, cfg.train_len).map_err(|e| anyhow!(e))?;
    let test = gxnor::data::open(&cfg.dataset, false, cfg.test_len).map_err(|e| anyhow!(e))?;

    if cfg.engine == EngineKind::Native {
        // fully device-free: no PJRT client, no lowered graphs; the
        // manifest (when present) only contributes shapes and batch size
        let manifest = Manifest::load(&art).ok();
        println!(
            "engine=native arch={} method={} dataset={}{}",
            cfg.arch,
            cfg.method.name(),
            cfg.dataset,
            if manifest.is_some() { "" } else { " (no artifacts: catalogue shapes)" }
        );
        let mut trainer = NativeTrainer::new(manifest.as_ref(), cfg)?;
        println!("native batch {} ({} threads)", trainer.batch_size(), trainer.config().threads);
        if !resume.is_empty() {
            let next = trainer.resume_from(&resume)?;
            println!("resumed       : {resume} (continuing at epoch {next})");
        }
        let report = trainer.run(train.as_ref(), test.as_ref())?;
        print_train_report(&report);
        println!(
            "step-loop mem : {} B f32 weight mirrors + {} B fp32 masters (DST runs in the \
             packed domain); {} B derived weight bitplanes",
            report.weight_f32_mirror_bytes,
            report.hidden_fp32_bytes,
            trainer.engine_bitplane_bytes()
        );
        println!(
            "repack-skip   : {} bitplane rebuilds over {} DST updates ({} moved a state)",
            trainer.repack_count(),
            trainer.dst_update_count(),
            trainer.transitioned_update_count()
        );
        if !save.is_empty() {
            checkpoint::save(&trainer.model, &save).map_err(|e| anyhow!(e))?;
            println!("checkpoint    : {save}");
        }
        return Ok(());
    }

    let manifest = Manifest::load(&art).map_err(|e| anyhow!(e))?;
    let mut rt = Runtime::new()?;
    println!(
        "platform={} arch={} method={} dataset={}",
        rt.platform(),
        cfg.arch,
        cfg.method.name(),
        cfg.dataset
    );
    let mut trainer = Trainer::new(&mut rt, &manifest, cfg)?;
    println!("graph: {} (batch {})", trainer.graph_name(), trainer.batch_size());
    let report = trainer.run(train.as_ref(), test.as_ref())?;
    print_train_report(&report);
    println!(
        "step-loop mem : {} B f32 weight mirrors (PJRT boundary expansions)",
        report.weight_f32_mirror_bytes
    );
    if !save.is_empty() {
        checkpoint::save(&trainer.model, &save).map_err(|e| anyhow!(e))?;
        println!("checkpoint    : {save}");
    }
    Ok(())
}

/// Summary block shared by the XLA and native train paths.
fn print_train_report(report: &gxnor::coordinator::TrainReport) {
    println!("\ntest accuracy : {:.2}%", 100.0 * report.test_acc);
    println!("act sparsity  : {:.3}", report.mean_act_sparsity);
    println!("w zero frac   : {:.3}", report.weight_zero_fraction);
    println!(
        "weight memory : {} B packed vs {} B f32 ({:.1}x smaller)",
        report.packed_bytes,
        report.fp32_bytes,
        report.fp32_bytes as f64 / report.packed_bytes.max(1) as f64
    );
    println!(
        "per-step      : {:.1} ms total ({:.1} ms exec, {:.2} ms DST+update, {:.3} ms marshal)",
        report.step_time_ms, report.exec_time_ms, report.dst_time_ms, report.marshal_time_ms
    );
    println!(
        "step latency  : p50 {:.1} ms  p99 {:.1} ms  ({:.1} steps/s)",
        report.step_p50_ms, report.step_p99_ms, report.steps_per_sec
    );
    println!("loss curve    : {}", report.recorder.sparkline("loss", 60));
}

fn eval_cmd() -> Command {
    Command::new("eval", "evaluate a checkpoint on a dataset")
        .req("ckpt", "checkpoint path")
        .opt("arch", "mlp", "architecture of the checkpoint")
        .opt("method", "gxnor", "method used at training time")
        .opt("dataset", "synth_mnist", "dataset")
        .opt("test-len", "1000", "test split size")
        .opt("r", "0.5", "zero-window half width")
        .opt("engine", "xla", "inference engine: xla (PJRT graph) | native (gated XNOR)")
        .opt("threads", "0", "native-engine worker threads (0 = auto)")
        .opt("artifacts", "artifacts", "artifact directory")
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let a = eval_cmd().parse(argv).map_err(|e| anyhow!(e))?;
    let manifest = Manifest::load(&a.opt_or("artifacts", "artifacts")).map_err(|e| anyhow!(e))?;
    let engine = EngineKind::parse(&a.opt_or("engine", "xla")).map_err(|e| anyhow!(e))?;
    let arch = a.opt_or("arch", "mlp");
    let method = Method::parse(&a.opt_or("method", "gxnor")).map_err(|e| anyhow!(e))?;
    let dataset = a.opt_or("dataset", "synth_mnist");
    let test_len = a.opt_usize("test-len", 1000).map_err(|e| anyhow!(e))?;
    let r = a.opt_f32("r", 0.5).map_err(|e| anyhow!(e))?;
    let threads = a.opt_usize("threads", 0).map_err(|e| anyhow!(e))?;
    let ckpt = a.opt("ckpt").unwrap();
    let test = gxnor::data::open(&dataset, false, test_len).map_err(|e| anyhow!(e))?;
    println!("engine       : {}", engine.name());
    match engine {
        EngineKind::Native => {
            // fully device-free: metadata from the manifest, weights from
            // the checkpoint — no PJRT client is ever created, and the
            // gate report reflects exactly the evaluation just performed
            let mut eng = gxnor::engine::native_engine_from_checkpoint(
                &manifest, &arch, method, r, ckpt, threads,
            )?;
            println!("threads      : {}", eng.threads());
            let acc = evaluate_engine(&mut eng, test.as_ref())?;
            println!("test accuracy: {:.2}%", 100.0 * acc);
            for rep in eng.gate_report() {
                println!(
                    "gate {:<24} fired {:>6.1}% of {} nominal XNOR (w0 {:.3}, x0 {:.3})",
                    rep.name,
                    100.0 * (1.0 - rep.stats.resting_rate()),
                    rep.stats.total,
                    rep.w_zero_fraction,
                    rep.stats.x_zero_fraction(),
                );
            }
        }
        EngineKind::Xla => {
            let mut rt = Runtime::new()?;
            let cfg = TrainConfig {
                arch,
                method,
                dataset,
                test_len,
                r,
                engine,
                threads,
                verbose: false,
                ..Default::default()
            };
            let mut trainer = Trainer::new(&mut rt, &manifest, cfg)?;
            checkpoint::load(&mut trainer.model, ckpt).map_err(|e| anyhow!(e))?;
            let acc = trainer.evaluate(test.as_ref())?;
            println!("test accuracy: {:.2}%", 100.0 * acc);
        }
    }
    Ok(())
}

fn sweep_cmd() -> Command {
    Command::new("sweep", "reproduce the ablation figures (8/9/10/13)")
        .opt("param", "m", "m | a | r | levels")
        .opt("values", "", "comma list, e.g. 0.5,1,3,10 (scalar sweeps)")
        .opt("grid", "", "N1xN2 list for levels, e.g. 0,0;1,1;2,2;6,4")
        .opt("epochs", "3", "epochs per point")
        .opt("train-len", "3000", "train split size")
        .opt("test-len", "800", "test split size")
        .opt("dataset", "synth_mnist", "dataset")
        .opt("seed", "42", "RNG seed")
        .opt("engine", "xla", "sweep engine: xla (PJRT graphs) | native (device-free, all grids)")
        .opt("threads", "0", "native-engine worker threads (0 = auto)")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("csv", "", "write results CSV to this path")
}

fn cmd_sweep(argv: &[String]) -> Result<()> {
    let a = sweep_cmd().parse(argv).map_err(|e| anyhow!(e))?;
    let engine = EngineKind::parse(&a.opt_or("engine", "xla")).map_err(|e| anyhow!(e))?;
    let art = a.opt_or("artifacts", "artifacts");
    let base = TrainConfig {
        epochs: a.opt_usize("epochs", 3).map_err(|e| anyhow!(e))?,
        train_len: a.opt_usize("train-len", 3000).map_err(|e| anyhow!(e))?,
        test_len: a.opt_usize("test-len", 800).map_err(|e| anyhow!(e))?,
        dataset: a.opt_or("dataset", "synth_mnist"),
        seed: a.opt_u64("seed", 42).map_err(|e| anyhow!(e))?,
        engine,
        threads: a.opt_usize("threads", 0).map_err(|e| anyhow!(e))?,
        verbose: false,
        ..Default::default()
    };
    // the `--engine` dispatch: the native branch is fully device-free —
    // no PJRT client is ever constructed, and the manifest (when present)
    // only contributes shapes and batch size
    let manifest_opt: Option<Manifest>;
    let mut rt_slot: Option<Runtime> = None;
    let mut backend = match engine {
        EngineKind::Native => {
            manifest_opt = Manifest::load(&art).ok();
            println!(
                "engine=native{}",
                if manifest_opt.is_some() { "" } else { " (no artifacts: catalogue shapes)" }
            );
            TrainBackend::Native { manifest: manifest_opt.as_ref() }
        }
        EngineKind::Xla => {
            manifest_opt = Some(Manifest::load(&art).map_err(|e| anyhow!(e))?);
            rt_slot = Some(Runtime::new()?);
            let rt = rt_slot.as_mut().unwrap();
            println!("engine=xla platform={}", rt.platform());
            TrainBackend::Xla { rt, manifest: manifest_opt.as_ref().unwrap() }
        }
    };
    let param = a.opt_or("param", "m");
    // --grid/--values declare "" as their CLI default, and declared
    // defaults are seeded into the parsed options — so "present but
    // empty" means "use the built-in default", not "parse the empty
    // string" (which used to abort `gxnor sweep --param levels`)
    let or_default = |name: &str, def: &str| -> String {
        match a.opt(name) {
            Some(v) if !v.is_empty() => v.to_string(),
            _ => def.to_string(),
        }
    };
    let points = if param == "levels" {
        let grid_s = or_default("grid", "0,0;1,1;2,2;3,3;6,4");
        let grid: Vec<(u32, u32)> = grid_s
            .split(';')
            .map(|p| {
                let (x, y) = p.split_once(',').ok_or_else(|| anyhow!("bad grid point {p:?}"))?;
                let (n1, n2): (u32, u32) = (x.trim().parse()?, y.trim().parse()?);
                if n1 > 15 || n2 > 15 {
                    // DiscreteSpace::new asserts N <= 15: fail the whole
                    // sweep up front instead of panicking mid-grid
                    return Err(anyhow!("grid point {p:?}: N1/N2 must be <= 15"));
                }
                Ok((n1, n2))
            })
            .collect::<Result<_>>()?;
        sweep::sweep_levels(&mut backend, &base, &grid)?
    } else {
        let default_vals = match param.as_str() {
            "m" => "0.5,1,2,3,5,10",
            "a" => "0.1,0.25,0.5,1.0,2.0",
            _ => "0.05,0.2,0.5,0.8,0.95",
        };
        let vals: Vec<f64> = or_default("values", default_vals)
            .split(',')
            .map(|s| s.trim().parse::<f64>())
            .collect::<Result<_, _>>()?;
        sweep::sweep_scalar(&mut backend, &base, &param, &vals)?
    };
    print!("{}", sweep::render_table(&format!("sweep {param}"), &points));
    if let Some(bp) = sweep::best(&points) {
        println!("best: {} ({:.2}%)", bp.label, 100.0 * bp.test_acc);
    }
    let csv = a.opt_or("csv", "");
    if !csv.is_empty() {
        std::fs::write(&csv, sweep::render_csv(&points))?;
        println!("wrote {csv}");
    }
    Ok(())
}

fn serve_cmd() -> Command {
    Command::new("serve", "async inference service: dynamic batching over native replicas")
        .opt("ckpt", "", "checkpoint to serve (empty = seeded fresh init, bench only)")
        .opt("arch", "mlp", "mlp | cnn_mnist | cnn_cifar")
        .opt("method", "gxnor", "fp|bwn|twn|bnn|gxnor|multi:N1,N2")
        .opt("r", "0.5", "zero-window half width")
        .opt("seed", "42", "init + loadgen RNG seed")
        .opt("artifacts", "artifacts", "artifact dir (manifest supplies shapes when present)")
        .opt("addr", "127.0.0.1:7433", "listen address (server) / target (client modes)")
        .opt("replicas", "0", "engine replicas (0 = one per core)")
        .opt("engine-threads", "1", "worker threads inside each replica engine")
        .opt("max-batch", "64", "batch-cut size (SLO throughput knob)")
        .opt("max-wait-ms", "2", "batch-cut max wait (SLO latency knob)")
        .opt("queue-bound", "256", "queued-request bound; arrivals beyond it are shed")
        .opt("deadline-ms", "0", "per-request deadline from enqueue (0 = none)")
        .opt("rps", "500", "loadgen/bench offered load (Poisson arrivals/s)")
        .opt("duration-s", "5", "loadgen/bench measured window")
        .opt("warmup-s", "1", "loadgen/bench warmup discard")
        .opt("conns", "32", "loadgen/bench connections (= max in-flight)")
        .opt("retries", "0", "loadgen per-request retry budget (RETRY replies, dropped conns)")
        .opt("faults", "", "fault-injection spec, e.g. replica_panic=3 (or GXNOR_FAULTS env)")
        .opt("out", "BENCH_serve.json", "bench report path")
        .opt("probe", "", "client mode: health | ready | stats against --addr")
        .flag("loadgen", "client mode: open-loop load against --addr (errors on 0 completions)")
        .flag("shutdown", "client mode: ask the server at --addr to drain and exit")
        .flag("bench", "in-process open-loop benchmark; writes --out")
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let a = serve_cmd().parse(argv).map_err(|e| anyhow!(e))?;
    let addr = a.opt_socket_addr("addr", "127.0.0.1:7433").map_err(|e| anyhow!(e))?;
    let arch = a.opt_or("arch", "mlp");
    let method = Method::parse(&a.opt_or("method", "gxnor")).map_err(|e| anyhow!(e))?;
    let seed = a.opt_u64("seed", 42).map_err(|e| anyhow!(e))?;
    let spec = gxnor::serve::EngineSpec {
        arch: arch.clone(),
        method,
        r: a.opt_f32("r", 0.5).map_err(|e| anyhow!(e))?,
        ckpt: Some(a.opt_or("ckpt", "")).filter(|s| !s.is_empty()),
        artifacts: a.opt_or("artifacts", "artifacts"),
        seed,
    };
    let serve_cfg = gxnor::serve::ServeConfig {
        replicas: a.opt_usize("replicas", 0).map_err(|e| anyhow!(e))?,
        max_batch: a.opt_usize("max-batch", 64).map_err(|e| anyhow!(e))?,
        max_wait_ms: a.opt_f64("max-wait-ms", 2.0).map_err(|e| anyhow!(e))?,
        queue_bound: a.opt_usize("queue-bound", 256).map_err(|e| anyhow!(e))?,
        deadline_ms: a.opt_f64("deadline-ms", 0.0).map_err(|e| anyhow!(e))?,
    };
    let load_cfg = gxnor::serve::LoadgenCfg {
        rps: a.opt_f64("rps", 500.0).map_err(|e| anyhow!(e))?,
        duration_s: a.opt_f64("duration-s", 5.0).map_err(|e| anyhow!(e))?,
        warmup_s: a.opt_f64("warmup-s", 1.0).map_err(|e| anyhow!(e))?,
        conns: a.opt_usize("conns", 32).map_err(|e| anyhow!(e))?,
        seed,
        sample_len: 0, // filled per mode below
        deadline_ms: 0,
        retries: a.opt_usize("retries", 0).map_err(|e| anyhow!(e))? as u32,
    };
    let engine_threads = a.opt_usize("engine-threads", 1).map_err(|e| anyhow!(e))?;
    let faults =
        gxnor::util::fault::FaultPlan::resolve(&a.opt_or("faults", "")).map_err(|e| anyhow!(e))?;

    // ---- client modes -----------------------------------------------------
    let probe = a.opt_or("probe", "");
    if !probe.is_empty() {
        let mut c = gxnor::serve::Client::connect(addr)?;
        return match probe.as_str() {
            "health" => {
                let ok = c.health()?;
                println!("health: {ok}");
                if ok {
                    Ok(())
                } else {
                    Err(anyhow!("server at {addr} is unhealthy"))
                }
            }
            "ready" => {
                let info = c.ready_info()?;
                if info.total > 0 {
                    println!(
                        "ready: {} (replicas {}/{}{})",
                        info.ready,
                        info.live,
                        info.total,
                        if info.degraded { ", degraded" } else { "" }
                    );
                } else {
                    println!("ready: {}", info.ready);
                }
                if info.ready {
                    Ok(())
                } else {
                    Err(anyhow!("server at {addr} is not ready"))
                }
            }
            "stats" => {
                println!("{}", c.stats()?);
                Ok(())
            }
            other => Err(anyhow!("--probe: invalid value {other:?} (health|ready|stats)")),
        };
    }
    if a.flag("shutdown") {
        let mut c = gxnor::serve::Client::connect(addr)?;
        c.shutdown_server()?;
        println!("shutdown acknowledged by {addr}");
        return Ok(());
    }
    if a.flag("loadgen") {
        let load = gxnor::serve::LoadgenCfg {
            sample_len: gxnor::serve::arch_sample_len(&arch)?,
            // in client mode --deadline-ms rides each request (INFER_DL)
            deadline_ms: serve_cfg.deadline_ms.max(0.0) as u32,
            ..load_cfg
        };
        let report = gxnor::serve::loadgen::run(addr, &load).map_err(|e| anyhow!(e))?;
        print_load_report(&report);
        if report.errors > 0 {
            return Err(anyhow!("loadgen: {} protocol/transport errors", report.errors));
        }
        if report.completed == 0 {
            return Err(anyhow!("loadgen: no requests completed in the measured window"));
        }
        return Ok(());
    }

    // ---- bench mode -------------------------------------------------------
    if a.flag("bench") {
        let doc = gxnor::serve::run_bench(&spec, &serve_cfg, &load_cfg, engine_threads)?;
        let out = a.opt_or("out", "BENCH_serve.json");
        std::fs::write(&out, doc.to_string())?;
        let load = doc.get("load");
        let lat = load.and_then(|l| l.get("latency_ms"));
        let g = |j: Option<&gxnor::util::json::Json>, k: &str| {
            j.and_then(|v| v.get(k)).and_then(|v| v.as_f64()).unwrap_or(0.0)
        };
        println!(
            "serve bench: {:.0} rps offered -> {:.0} rps served | p50 {:.2} ms p99 {:.2} ms | \
             batch fill {:.1} | shed {:.1}%",
            g(load, "offered_rps"),
            g(load, "throughput_rps"),
            g(lat, "p50_ms"),
            g(lat, "p99_ms"),
            g(doc.get("server"), "mean_batch_fill"),
            100.0 * g(load, "shed_rate"),
        );
        println!("wrote {out}");
        return Ok(());
    }

    // ---- server mode ------------------------------------------------------
    let (engines, sample_len, factory) = gxnor::serve::build_engines(
        &spec,
        serve_cfg.replicas,
        serve_cfg.max_batch,
        engine_threads,
    )?;
    let n_replicas = engines.len();
    if let Some(p) = faults.as_deref() {
        println!("fault plan: {p}");
    }
    let svc = gxnor::serve::Service::start_supervised(
        addr,
        serve_cfg.clone(),
        engines,
        Some(factory),
        faults,
        sample_len,
    )
    .map_err(|e| anyhow!(e))?;
    let init_note = if spec.ckpt.is_none() {
        " (fresh-init weights: latency bench only)"
    } else {
        ""
    };
    println!(
        "serving arch={} method={} on {} | replicas={} max_batch={} max_wait={}ms \
         queue_bound={} deadline={}ms{}",
        arch,
        method.name(),
        svc.addr,
        n_replicas,
        serve_cfg.max_batch,
        serve_cfg.max_wait_ms,
        serve_cfg.queue_bound,
        serve_cfg.deadline_ms,
        init_note,
    );
    println!("ready — send SHUTDOWN (gxnor serve --shutdown --addr {}) to stop", svc.addr);
    let stats = svc.stats_handle();
    svc.join(); // blocks until a SHUTDOWN frame drains the service
    println!("drained; final stats: {}", gxnor::util::lock_recover(&stats).to_json());
    Ok(())
}

fn print_load_report(r: &gxnor::serve::LoadReport) {
    println!(
        "loadgen: sent={} completed={} shed={} deadline_missed={} errors={} retried={} \
         (+{} warmup discarded)",
        r.sent, r.completed, r.shed, r.deadline_missed, r.errors, r.retried, r.warmup_discarded
    );
    println!(
        "  offered {:.1} rps -> served {:.1} rps | latency p50 {:.2} ms p99 {:.2} ms \
         mean {:.2} ms max {:.2} ms | shed rate {:.2}%",
        r.offered_rps,
        r.throughput_rps,
        r.latency.p50_ms,
        r.latency.p99_ms,
        r.latency.mean_ms,
        r.latency.max_ms,
        100.0 * r.shed_rate()
    );
}

fn hwsim_cmd() -> Command {
    Command::new("hwsim", "event-driven architecture analysis (Table 2, Fig. 12)")
        .opt("m", "100", "neuron fan-in M")
        .opt("pw0", "0.3333333", "weight zero-state probability")
        .opt("px0", "0.3333333", "activation zero-state probability")
        .opt("trials", "10000", "Fig. 12 sampling trials")
}

fn cmd_hwsim(argv: &[String]) -> Result<()> {
    let a = hwsim_cmd().parse(argv).map_err(|e| anyhow!(e))?;
    println!(
        "{}",
        hwreport::table2(
            a.opt_u64("m", 100).map_err(|e| anyhow!(e))?,
            a.opt_f64("pw0", 1.0 / 3.0).map_err(|e| anyhow!(e))?,
            a.opt_f64("px0", 1.0 / 3.0).map_err(|e| anyhow!(e))?,
        )
    );
    let (nominal, mean) =
        hwreport::fig12_example(a.opt_usize("trials", 10000).map_err(|e| anyhow!(e))?, 7);
    println!(
        "Fig. 12 example: {nominal} nominal XNOR ops -> {mean:.2} active on average \
         (paper: 21 -> 9)"
    );
    Ok(())
}

fn inspect_cmd() -> Command {
    Command::new("inspect", "describe a checkpoint (tensors, spaces, histograms)")
}

fn cmd_inspect(argv: &[String]) -> Result<()> {
    let a = inspect_cmd().parse(argv).map_err(|e| anyhow!(e))?;
    let path = a
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: gxnor inspect <ckpt>"))?;
    let bytes = std::fs::read(path)?;
    print!("{}", checkpoint::inspect(&bytes).map_err(|e| anyhow!(e))?);
    Ok(())
}

fn info_cmd() -> Command {
    Command::new("info", "list lowered artifacts")
        .opt("artifacts", "artifacts", "artifact directory")
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let a = info_cmd().parse(argv).map_err(|e| anyhow!(e))?;
    let manifest = Manifest::load(&a.opt_or("artifacts", "artifacts")).map_err(|e| anyhow!(e))?;
    println!(
        "{:<28} {:>6} {:>8} {:>8} {:>9}",
        "graph", "batch", "params", "inputs", "outputs"
    );
    for g in &manifest.graphs {
        println!(
            "{:<28} {:>6} {:>8} {:>8} {:>9}",
            g.name,
            g.batch,
            g.params.len(),
            g.inputs.len(),
            g.outputs.len()
        );
    }
    Ok(())
}
