//! The replica pool: N engines, each owned by its own worker thread,
//! consuming batch jobs from one shared channel.
//!
//! Work distribution is the simplest thing that is correct: the single
//! `Receiver<BatchJob>` sits behind a mutex and exactly one *idle*
//! replica blocks in `recv` holding it at a time. When a job arrives that
//! replica takes it, releases the lock (another idle replica immediately
//! parks in `recv`), and runs inference outside the lock — so the lock is
//! only ever held by a thread with nothing to do, and busy replicas never
//! serialize each other. Batch affinity is whoever-is-free, which is also
//! the right policy: replicas are interchangeable by construction
//! (identical `ModelState`, and the engine's logits are bit-identical
//! regardless of thread count or batch packing — pinned by the parity
//! tests), so served results cannot depend on which replica ran them.
//!
//! Shutdown is by channel closure: the dispatcher drops the job sender
//! once the queue is drained, every replica's `recv` errors out, and
//! [`ReplicaPool::join`] reaps the threads — in-flight batches always
//! finish and reply first.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::runtime::exec::ExecEngine;

use super::queue::Ticket;
use super::service::{Reply, ReqPayload, ServeStats};

/// One cut batch, FIFO tickets included.
pub struct BatchJob {
    pub tickets: Vec<Ticket<ReqPayload>>,
}

pub struct ReplicaPool {
    tx: Option<Sender<BatchJob>>,
    handles: Vec<JoinHandle<()>>,
}

impl ReplicaPool {
    /// Spawn one worker thread per engine. Every engine must accept
    /// partial batches — SLO cuts fill to at most `max_batch`, and padding
    /// a short batch would burn replica time on ghost samples.
    pub fn spawn(
        engines: Vec<Box<dyn ExecEngine + Send>>,
        stats: Arc<Mutex<ServeStats>>,
        t0: Instant,
    ) -> Result<ReplicaPool, String> {
        if engines.is_empty() {
            return Err("serve: replica pool needs at least one engine".into());
        }
        for (i, e) in engines.iter().enumerate() {
            if !e.supports_partial_batch() {
                return Err(format!(
                    "serve: replica {i} (engine {:?}) does not support partial batches",
                    e.name()
                ));
            }
        }
        let (tx, rx) = channel::<BatchJob>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = engines
            .into_iter()
            .map(|eng| {
                let rx = Arc::clone(&rx);
                let stats = Arc::clone(&stats);
                std::thread::spawn(move || replica_loop(eng, rx, stats, t0))
            })
            .collect();
        Ok(ReplicaPool { tx: Some(tx), handles })
    }

    /// A fresh job-submission handle (the dispatcher holds one; when every
    /// clone is dropped the replicas drain and exit).
    pub fn sender(&self) -> Sender<BatchJob> {
        self.tx.as_ref().expect("pool not joined").clone()
    }

    /// Drop the pool's own sender and wait for every replica to exit.
    /// Callers must drop their cloned senders first or this blocks.
    pub fn join(mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn replica_loop(
    mut eng: Box<dyn ExecEngine + Send>,
    rx: Arc<Mutex<Receiver<BatchJob>>>,
    stats: Arc<Mutex<ServeStats>>,
    t0: Instant,
) {
    let nc = eng.n_classes();
    let mut xbuf: Vec<f32> = Vec::new();
    loop {
        // hold the lock only while idle in recv — release before inference
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let job = match job {
            Ok(j) => j,
            Err(_) => break, // channel closed: orderly shutdown
        };
        if job.tickets.is_empty() {
            continue;
        }
        xbuf.clear();
        for t in &job.tickets {
            xbuf.extend_from_slice(&t.payload.input);
        }
        let fill = job.tickets.len();
        match eng.infer_batch(&xbuf) {
            Ok(logits) => {
                let now_ns = t0.elapsed().as_nanos() as u64;
                // reply first, account second — the requester should not
                // wait on the stats mutex
                let mut lats = Vec::with_capacity(fill);
                for (i, t) in job.tickets.iter().enumerate() {
                    let row = logits[i * nc..(i + 1) * nc].to_vec();
                    let _ = t.payload.reply.send(Reply::Logits(row));
                    lats.push(now_ns.saturating_sub(t.enqueued_ns) as f64 / 1e6);
                }
                let mut st = stats.lock().unwrap();
                st.batches += 1;
                st.batch_fill_sum += fill as f64;
                st.completed += fill as u64;
                for l in lats {
                    st.record_latency(l);
                }
            }
            Err(e) => {
                let msg = format!("replica inference failed: {e}");
                for t in &job.tickets {
                    let _ = t.payload.reply.send(Reply::Error(msg.clone()));
                }
                stats.lock().unwrap().internal_errors += 1;
            }
        }
    }
}
