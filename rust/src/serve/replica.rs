//! The replica pool: N engines, each owned by its own worker thread,
//! consuming batch jobs from one shared channel — supervised, so a
//! replica crash degrades capacity instead of killing the service.
//!
//! Work distribution is the simplest thing that is correct: the single
//! `Receiver<BatchJob>` sits behind a mutex and exactly one *idle*
//! replica blocks in `recv` holding it at a time. When a job arrives that
//! replica takes it, releases the lock (another idle replica immediately
//! parks in `recv`), and runs inference outside the lock — so the lock is
//! only ever held by a thread with nothing to do, and busy replicas never
//! serialize each other. Batch affinity is whoever-is-free, which is also
//! the right policy: replicas are interchangeable by construction
//! (identical `ModelState`, and the engine's logits are bit-identical
//! regardless of thread count or batch packing — pinned by the parity
//! tests), so served results cannot depend on which replica ran them.
//!
//! ## Failure model
//!
//! `infer_batch` runs under `catch_unwind`. A panic retires the worker
//! (its engine may hold arbitrarily corrupt state), answers the batch's
//! tickets with [`Reply::Retry`] — the request was *not* served, and the
//! client may idempotently resubmit — and hands the slot to the
//! supervisor thread, which rebuilds a fresh engine via the
//! [`EngineFactory`] under capped exponential backoff. While a slot is
//! down the pool serves on the survivors; the live-replica count is
//! exported for READY's degraded report. Without a factory (the legacy
//! [`ReplicaPool::spawn`]), a crashed slot simply stays down.
//!
//! Shutdown is by channel closure: the dispatcher drops the job sender
//! once the queue is drained, every replica's `recv` errors out, and
//! [`ReplicaPool::join`] reaps the supervisor — in-flight batches always
//! finish and reply first.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::runtime::exec::ExecEngine;
use crate::util::fault::FaultPlan;
use crate::util::lock::lock_recover;
use crate::util::pool;

use super::queue::Ticket;
use super::service::{Reply, ReqPayload, ServeStats};

/// Builds a fresh replica engine (used by the supervisor to replace a
/// crashed one). Must produce engines interchangeable with the originals:
/// same model, same batch capacity.
pub type EngineFactory = Arc<dyn Fn() -> Result<Box<dyn ExecEngine + Send>, String> + Send + Sync>;

/// First respawn delay after a crash.
const RESPAWN_BACKOFF_BASE: Duration = Duration::from_millis(50);
/// Backoff ceiling — a persistently crashing replica retries at this rate.
const RESPAWN_BACKOFF_CAP: Duration = Duration::from_secs(5);
/// A worker that survived this long resets its slot's backoff ladder.
const RESPAWN_STABLE_UPTIME: Duration = Duration::from_secs(5);
/// Supervisor poll cadence (reap exits, fire due respawns).
const SUPERVISE_TICK: Duration = Duration::from_millis(25);

/// One cut batch, FIFO tickets included.
pub struct BatchJob {
    pub tickets: Vec<Ticket<ReqPayload>>,
}

/// How a worker thread ended.
enum WorkerExit {
    /// Job channel closed — orderly shutdown, never respawned.
    Drained,
    /// Panic during inference — respawn if a factory is available.
    Crashed,
}

/// Decrements the live-replica gauge when the worker exits, however it
/// exits.
struct LiveGuard(Arc<AtomicUsize>);

impl Drop for LiveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One supervised worker slot.
struct WorkerSlot {
    handle: Option<JoinHandle<WorkerExit>>,
    spawned: Instant,
    backoff: Duration,
    respawn_at: Option<Instant>,
}

pub struct ReplicaPool {
    tx: Sender<BatchJob>,
    supervisor: JoinHandle<()>,
    shutdown: Arc<AtomicBool>,
    live: Arc<AtomicUsize>,
    total: usize,
}

impl ReplicaPool {
    /// Spawn one worker thread per engine, unsupervised (a crashed slot
    /// stays down). Every engine must accept partial batches — SLO cuts
    /// fill to at most `max_batch`, and padding a short batch would burn
    /// replica time on ghost samples.
    pub fn spawn(
        engines: Vec<Box<dyn ExecEngine + Send>>,
        stats: Arc<Mutex<ServeStats>>,
        t0: Instant,
    ) -> Result<ReplicaPool, String> {
        Self::spawn_supervised(engines, None, stats, t0, None)
    }

    /// [`ReplicaPool::spawn`] plus crash supervision: with a `factory`,
    /// a panicked worker's slot is rebuilt with a fresh engine under
    /// capped exponential backoff (base 50 ms, cap 5 s, ladder reset
    /// after 5 s of stable uptime).
    pub fn spawn_supervised(
        engines: Vec<Box<dyn ExecEngine + Send>>,
        factory: Option<EngineFactory>,
        stats: Arc<Mutex<ServeStats>>,
        t0: Instant,
        faults: Option<Arc<FaultPlan>>,
    ) -> Result<ReplicaPool, String> {
        if engines.is_empty() {
            return Err("serve: replica pool needs at least one engine".into());
        }
        for (i, e) in engines.iter().enumerate() {
            if !e.supports_partial_batch() {
                return Err(format!(
                    "serve: replica {i} (engine {:?}) does not support partial batches",
                    e.name()
                ));
            }
        }
        let total = engines.len();
        let (tx, rx) = channel::<BatchJob>();
        let rx = Arc::new(Mutex::new(rx));
        let shutdown = Arc::new(AtomicBool::new(false));
        let live = Arc::new(AtomicUsize::new(0));

        let mut slots: Vec<WorkerSlot> = engines
            .into_iter()
            .map(|eng| WorkerSlot {
                handle: Some(spawn_worker(
                    eng,
                    Arc::clone(&rx),
                    Arc::clone(&stats),
                    t0,
                    Arc::clone(&live),
                    faults.clone(),
                )),
                spawned: Instant::now(),
                backoff: RESPAWN_BACKOFF_BASE,
                respawn_at: None,
            })
            .collect();

        let supervisor = {
            let shutdown = Arc::clone(&shutdown);
            let live = Arc::clone(&live);
            pool::spawn_service("replica-supervisor", move || loop {
                for slot in slots.iter_mut() {
                    if slot.handle.as_ref().is_some_and(|h| h.is_finished()) {
                        if let Some(h) = slot.handle.take() {
                            let exit = h.join().unwrap_or(WorkerExit::Crashed);
                            if matches!(exit, WorkerExit::Crashed)
                                && factory.is_some()
                                && !shutdown.load(Ordering::Acquire)
                            {
                                if slot.spawned.elapsed() >= RESPAWN_STABLE_UPTIME {
                                    slot.backoff = RESPAWN_BACKOFF_BASE;
                                }
                                slot.respawn_at = Some(Instant::now() + slot.backoff);
                                slot.backoff = (slot.backoff * 2).min(RESPAWN_BACKOFF_CAP);
                            }
                        }
                    }
                    if let Some(at) = slot.respawn_at {
                        if shutdown.load(Ordering::Acquire) {
                            slot.respawn_at = None;
                        } else if Instant::now() >= at {
                            // a respawn is only scheduled when a factory
                            // exists; without one the slot stays down
                            match factory.as_ref().map(|build| build()) {
                                Some(Ok(eng)) => {
                                    slot.respawn_at = None;
                                    slot.spawned = Instant::now();
                                    slot.handle = Some(spawn_worker(
                                        eng,
                                        Arc::clone(&rx),
                                        Arc::clone(&stats),
                                        t0,
                                        Arc::clone(&live),
                                        faults.clone(),
                                    ));
                                    lock_recover(&stats).replica_restarts += 1;
                                }
                                Some(Err(e)) => {
                                    eprintln!("serve: replica respawn failed: {e}");
                                    slot.respawn_at = Some(Instant::now() + slot.backoff);
                                    slot.backoff = (slot.backoff * 2).min(RESPAWN_BACKOFF_CAP);
                                }
                                None => slot.respawn_at = None,
                            }
                        }
                    }
                }
                let quiet = slots
                    .iter()
                    .all(|s| s.handle.is_none() && s.respawn_at.is_none());
                if quiet {
                    return;
                }
                std::thread::sleep(SUPERVISE_TICK);
            })
        };

        Ok(ReplicaPool {
            tx,
            supervisor,
            shutdown,
            live,
            total,
        })
    }

    /// A fresh job-submission handle (the dispatcher holds one; when every
    /// clone is dropped the replicas drain and exit).
    pub fn sender(&self) -> Sender<BatchJob> {
        self.tx.clone()
    }

    /// Live-replica gauge (READY's degraded report reads this).
    pub fn live_handle(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.live)
    }

    /// Configured replica count (the denominator of the degraded report).
    pub fn total(&self) -> usize {
        self.total
    }

    /// Stop supervision, drop the pool's own sender, and wait for every
    /// worker (via the supervisor) to exit. Callers must drop their
    /// cloned senders first or this blocks.
    pub fn join(self) {
        let ReplicaPool { tx, supervisor, shutdown, .. } = self;
        shutdown.store(true, Ordering::Release);
        drop(tx);
        let _ = supervisor.join();
    }
}

fn spawn_worker(
    eng: Box<dyn ExecEngine + Send>,
    rx: Arc<Mutex<Receiver<BatchJob>>>,
    stats: Arc<Mutex<ServeStats>>,
    t0: Instant,
    live: Arc<AtomicUsize>,
    faults: Option<Arc<FaultPlan>>,
) -> JoinHandle<WorkerExit> {
    // gauge up before the thread exists so READY can never observe a
    // spawned-but-uncounted replica
    live.fetch_add(1, Ordering::SeqCst);
    pool::spawn_service("replica", move || {
        let _guard = LiveGuard(live);
        replica_loop(eng, rx, stats, t0, faults)
    })
}

fn replica_loop(
    mut eng: Box<dyn ExecEngine + Send>,
    rx: Arc<Mutex<Receiver<BatchJob>>>,
    stats: Arc<Mutex<ServeStats>>,
    t0: Instant,
    faults: Option<Arc<FaultPlan>>,
) -> WorkerExit {
    let nc = eng.n_classes();
    let mut xbuf: Vec<f32> = Vec::new();
    loop {
        // hold the lock only while idle in recv — release before inference
        let job = {
            let guard = lock_recover(&rx);
            guard.recv()
        };
        let job = match job {
            Ok(j) => j,
            Err(_) => return WorkerExit::Drained, // channel closed: orderly shutdown
        };
        if job.tickets.is_empty() {
            continue;
        }
        xbuf.clear();
        for t in &job.tickets {
            xbuf.extend_from_slice(&t.payload.input);
        }
        let fill = job.tickets.len();
        let inject = faults.as_deref().is_some_and(|f| f.fire_replica_panic());
        // AssertUnwindSafe: on panic the engine is discarded, never reused,
        // so torn internal state cannot leak into a later inference.
        let result = catch_unwind(AssertUnwindSafe(|| {
            if inject {
                panic!("injected replica panic (FaultPlan replica_panic)");
            }
            eng.infer_batch(&xbuf).map(|l| l[..fill * nc].to_vec())
        }));
        match result {
            Ok(Ok(logits)) => {
                let now_ns = t0.elapsed().as_nanos() as u64;
                // reply first, account second — the requester should not
                // wait on the stats mutex
                let mut lats = Vec::with_capacity(fill);
                for (i, t) in job.tickets.iter().enumerate() {
                    let row = logits[i * nc..(i + 1) * nc].to_vec();
                    let _ = t.payload.reply.send(Reply::Logits(row));
                    lats.push(now_ns.saturating_sub(t.enqueued_ns) as f64 / 1e6);
                }
                let mut st = lock_recover(&stats);
                st.batches += 1;
                st.batch_fill_sum += fill as f64;
                st.completed += fill as u64;
                for l in lats {
                    st.record_latency(l);
                }
            }
            Ok(Err(e)) => {
                let msg = format!("replica inference failed: {e}");
                for t in &job.tickets {
                    let _ = t.payload.reply.send(Reply::Error(msg.clone()));
                }
                let mut st = lock_recover(&stats);
                st.internal_errors += 1;
                st.errored += fill as u64;
            }
            Err(_) => {
                // Panic mid-inference: every ticket of this batch gets
                // Retry (none were served — safe to resubmit), and the
                // worker retires so the supervisor can rebuild a clean
                // engine. Tickets are accounted so completed + shed +
                // errored still explains every accepted request.
                for t in &job.tickets {
                    let _ = t.payload.reply.send(Reply::Retry);
                }
                let mut st = lock_recover(&stats);
                st.replica_panics += 1;
                st.errored += fill as u64;
                return WorkerExit::Crashed;
            }
        }
    }
}
