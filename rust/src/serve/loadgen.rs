//! Open-loop synthetic load generator.
//!
//! Closed-loop drivers (send, wait, send again) hide overload: when the
//! server slows down the driver slows down with it and latency looks
//! flat. This generator is **open-loop**: arrival times follow a Poisson
//! process at the configured RPS, pre-scheduled against a fixed origin,
//! and every request's latency is measured from its *scheduled* arrival —
//! not from when the connection got around to writing it — so time spent
//! queued behind a slow response counts against the server (the standard
//! coordinated-omission correction).
//!
//! Mechanics: arrivals are drawn once up front (exponential inter-arrival
//! gaps, `-ln(1-u)/rps`, via the repo's deterministic [`Prng`]) and
//! striped round-robin across a pool of connection workers. Each worker
//! holds one TCP connection, sleeps until an arrival's scheduled instant,
//! fires, and blocks for the reply — so `conns` bounds the generator's
//! in-flight requests; size it above `rps × expected latency` or the
//! generator itself becomes the bottleneck (the report can't tell you,
//! but a mean latency far above p50 is the tell). Requests scheduled in
//! the first `warmup_s` seconds are sent but discarded from the report,
//! per the BENCH_kernels warmup methodology.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use crate::metrics::LatencySummary;
use crate::util::json::Json;
use crate::util::pool;
use crate::util::prng::Prng;

use super::service::{ClientReply, RetryCfg, RetryClient};

#[derive(Clone, Debug)]
pub struct LoadgenCfg {
    /// Offered load: Poisson arrival rate, requests/second.
    pub rps: f64,
    /// Measured window, after warmup.
    pub duration_s: f64,
    /// Requests scheduled before this offset are sent but not reported.
    pub warmup_s: f64,
    /// Connection workers = max in-flight requests.
    pub conns: usize,
    /// Arrival-process and sample-content seed (deterministic schedule).
    pub seed: u64,
    /// f32 values per request (must match the served model's input).
    pub sample_len: usize,
    /// Optional per-request deadline to send (0 = plain INFER frames).
    pub deadline_ms: u32,
    /// Retry budget per request (0 = no retries): RETRY replies and
    /// dropped connections are resubmitted under jittered backoff.
    pub retries: u32,
}

impl Default for LoadgenCfg {
    fn default() -> Self {
        LoadgenCfg {
            rps: 500.0,
            duration_s: 5.0,
            warmup_s: 1.0,
            conns: 32,
            seed: 42,
            sample_len: 784,
            deadline_ms: 0,
            retries: 0,
        }
    }
}

/// Aggregated client-side view of one run (measured window only, except
/// `warmup_discarded`).
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub sent: u64,
    pub completed: u64,
    pub shed: u64,
    pub deadline_missed: u64,
    /// Transport/protocol failures (io errors, ERROR frames, bad replies)
    /// that survived the retry budget.
    pub errors: u64,
    /// Retry attempts spent across measured requests (0 when retries are
    /// off; a crash-free run keeps it 0 even with a budget).
    pub retried: u64,
    pub warmup_discarded: u64,
    /// Arrivals scheduled in the measured window / duration.
    pub offered_rps: f64,
    /// Completions in the measured window / duration.
    pub throughput_rps: f64,
    /// Scheduled-arrival → reply latency of completed requests.
    pub latency: LatencySummary,
}

impl LoadReport {
    /// Shed fraction of measured sends (queue sheds + deadline misses).
    pub fn shed_rate(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            (self.shed + self.deadline_missed) as f64 / self.sent as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sent", Json::num(self.sent as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("deadline_missed", Json::num(self.deadline_missed as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("retried", Json::num(self.retried as f64)),
            ("warmup_discarded", Json::num(self.warmup_discarded as f64)),
            ("offered_rps", Json::num(self.offered_rps)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("shed_rate", Json::num(self.shed_rate())),
            ("latency_ms", self.latency.to_json()),
        ])
    }
}

/// Cap on the pre-drawn arrival schedule (memory guard; ~16 MB of f64).
const MAX_ARRIVALS: usize = 2_000_000;

/// Draw the Poisson arrival schedule: offsets in seconds from the run
/// origin, strictly increasing, covering warmup + measured window.
fn draw_arrivals(cfg: &LoadgenCfg) -> Result<Vec<f64>, String> {
    if cfg.rps <= 0.0 {
        return Err(format!("loadgen: rps must be > 0, got {}", cfg.rps));
    }
    if cfg.duration_s <= 0.0 {
        return Err(format!("loadgen: duration must be > 0, got {}", cfg.duration_s));
    }
    let total_s = cfg.warmup_s.max(0.0) + cfg.duration_s;
    let mut rng = Prng::new(cfg.seed);
    let mut arrivals = Vec::new();
    let mut t = 0.0f64;
    loop {
        let u = rng.uniform_f64(); // [0, 1) → 1-u in (0, 1], ln well-defined
        t += -(1.0 - u).ln() / cfg.rps;
        if t >= total_s {
            break;
        }
        arrivals.push(t);
        if arrivals.len() > MAX_ARRIVALS {
            return Err(format!(
                "loadgen: rps {} × {}s exceeds the {MAX_ARRIVALS}-request schedule cap",
                cfg.rps, total_s
            ));
        }
    }
    Ok(arrivals)
}

#[derive(Default)]
struct WorkerOut {
    sent: u64,
    completed: u64,
    shed: u64,
    deadline_missed: u64,
    errors: u64,
    retried: u64,
    warmup_discarded: u64,
    latencies_ms: Vec<f64>,
}

/// Run one open-loop session against a serving endpoint.
pub fn run(addr: SocketAddr, cfg: &LoadgenCfg) -> Result<LoadReport, String> {
    if cfg.sample_len == 0 {
        return Err("loadgen: sample_len must be > 0".into());
    }
    let arrivals = draw_arrivals(cfg)?;
    let conns = cfg.conns.max(1);
    let warmup_s = cfg.warmup_s.max(0.0);
    // Connect everything before taking the origin so connection setup
    // doesn't eat into the schedule (it would read as server latency).
    let mut clients: Vec<RetryClient> = Vec::with_capacity(conns);
    for w in 0..conns {
        let rcfg = RetryCfg {
            retries: cfg.retries,
            seed: cfg.seed ^ w as u64,
            ..RetryCfg::default()
        };
        let mut rc = RetryClient::new(addr, rcfg);
        rc.preconnect()
            .map_err(|e| format!("loadgen: connect {addr}: {e}"))?;
        clients.push(rc);
    }
    let t0 = Instant::now();
    let mut seed_rng = Prng::new(cfg.seed ^ 0x5eed_10ad);
    let tasks: Vec<_> = clients
        .into_iter()
        .enumerate()
        .map(|(w, mut client)| {
            let arrivals = &arrivals;
            let mut rng = seed_rng.fork(w as u64);
            let (sample_len, deadline_ms) = (cfg.sample_len, cfg.deadline_ms);
            move || {
                let mut out = WorkerOut::default();
                let mut sample = vec![0.0f32; sample_len];
                for sched_s in arrivals.iter().skip(w).step_by(conns) {
                    let sched = t0 + Duration::from_secs_f64(*sched_s);
                    if let Some(d) = sched.checked_duration_since(Instant::now()) {
                        std::thread::sleep(d);
                    } // else: behind schedule — fire immediately (open loop)
                    for v in sample.iter_mut() {
                        *v = rng.range_f32(-1.0, 1.0);
                    }
                    let measured = *sched_s >= warmup_s;
                    if measured {
                        out.sent += 1;
                    } else {
                        out.warmup_discarded += 1;
                    }
                    let reply = client.infer_retry(&sample, deadline_ms);
                    let lat_ms =
                        Instant::now().saturating_duration_since(sched).as_secs_f64() * 1e3;
                    if !measured {
                        continue;
                    }
                    match reply {
                        Ok((r, attempts)) => {
                            out.retried += u64::from(attempts);
                            match r {
                                ClientReply::Logits(_) => {
                                    out.completed += 1;
                                    out.latencies_ms.push(lat_ms);
                                }
                                ClientReply::Shed { .. } => out.shed += 1,
                                ClientReply::Deadline => out.deadline_missed += 1,
                                // a Retry that survived the whole budget is
                                // a failed request
                                ClientReply::Error(_) | ClientReply::Retry => out.errors += 1,
                            }
                        }
                        Err(_) => out.errors += 1,
                    }
                }
                out
            }
        })
        .collect();
    let outs = pool::scope_map(tasks);

    let mut report = LoadReport::default();
    let mut lats: Vec<f64> = Vec::new();
    for o in outs {
        report.sent += o.sent;
        report.completed += o.completed;
        report.shed += o.shed;
        report.deadline_missed += o.deadline_missed;
        report.errors += o.errors;
        report.retried += o.retried;
        report.warmup_discarded += o.warmup_discarded;
        lats.extend(o.latencies_ms);
    }
    report.offered_rps = report.sent as f64 / cfg.duration_s;
    report.throughput_rps = report.completed as f64 / cfg.duration_s;
    report.latency = LatencySummary::from_unsorted(&lats);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_schedule_is_poisson_like() {
        let cfg = LoadgenCfg {
            rps: 1000.0,
            duration_s: 4.0,
            warmup_s: 1.0,
            seed: 7,
            ..LoadgenCfg::default()
        };
        let a = draw_arrivals(&cfg).unwrap();
        // mean count = rps × total = 5000; Poisson σ ≈ 71 — ±6σ bounds
        assert!((4500..=5500).contains(&a.len()), "{}", a.len());
        // strictly increasing, inside [0, total)
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        assert!(a.first().copied().unwrap_or(0.0) >= 0.0);
        assert!(a.last().copied().unwrap_or(0.0) < 5.0);
        // deterministic in the seed
        assert_eq!(a, draw_arrivals(&cfg).unwrap());
        let b = draw_arrivals(&LoadgenCfg { seed: 8, ..cfg }).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn arrival_schedule_rejects_bad_config() {
        assert!(draw_arrivals(&LoadgenCfg { rps: 0.0, ..LoadgenCfg::default() }).is_err());
        assert!(draw_arrivals(&LoadgenCfg { duration_s: 0.0, ..LoadgenCfg::default() }).is_err());
        // schedule cap names the limit instead of OOMing
        let huge = LoadgenCfg { rps: 1e9, duration_s: 1.0, ..LoadgenCfg::default() };
        assert!(draw_arrivals(&huge).unwrap_err().contains("cap"));
    }

    #[test]
    fn shed_rate_math() {
        let r = LoadReport { sent: 100, shed: 5, deadline_missed: 5, ..LoadReport::default() };
        assert!((r.shed_rate() - 0.1).abs() < 1e-12);
        assert_eq!(LoadReport::default().shed_rate(), 0.0);
    }
}
