//! The TCP service: accept loop, connection handlers, and the dispatcher
//! thread that owns the [`BatchQueue`].
//!
//! Thread topology (one `Service`):
//!
//! ```text
//!            accept thread ── spawns ──► conn thread (per connection)
//!                                             │  decode frame, validate
//!                                             ▼
//!                  mpsc ──────────────► dispatcher thread
//!                                             │  BatchQueue: bound/deadline/cut
//!                                             ▼
//!                  mpsc (jobs) ───────► replica pool (serve::replica)
//!                                             │  NativeEngine::infer_batch
//!                                             ▼
//!                  per-request mpsc ──► conn thread ──► response frame
//! ```
//!
//! The accept loop never blocks on anything but `accept` itself (and that
//! is non-blocking + poll, so shutdown is prompt): connection handlers
//! hand requests to the dispatcher over an unbounded channel and the
//! *bound* lives in the queue, which sheds with a depth report instead of
//! applying backpressure to the socket.
//!
//! ## Frame protocol
//!
//! Every message is `u32le length | u8 type | payload`, where `length`
//! counts the type byte plus payload. Request types:
//!
//! | type | name        | payload                                  |
//! |------|-------------|------------------------------------------|
//! | 0x01 | INFER       | `sample_len` f32le values                |
//! | 0x02 | HEALTH      | empty                                    |
//! | 0x03 | READY       | empty                                    |
//! | 0x04 | STATS       | empty                                    |
//! | 0x05 | SHUTDOWN    | empty (SIGTERM-equivalent, acked)        |
//! | 0x06 | STATS_RESET | empty                                    |
//! | 0x07 | INFER_DL    | u32le deadline_ms, then f32le samples    |
//!
//! Response types:
//!
//! | type | name       | payload                                   |
//! |------|------------|-------------------------------------------|
//! | 0x81 | LOGITS     | `n_classes` f32le values                  |
//! | 0x82 | SHED       | u32le queue depth observed                |
//! | 0x83 | ERROR      | utf-8 message                             |
//! | 0x84 | HEALTH_OK  | u8 1                                      |
//! | 0x85 | READY      | u8 0/1, then optionally u8 degraded,      |
//! |      |            | u32le live replicas, u32le total replicas |
//! | 0x86 | STATS      | utf-8 JSON (see `ServeStats::to_json`)    |
//! | 0x87 | DEADLINE   | empty (request expired before dispatch)   |
//! | 0x88 | SHUTDOWN   | empty (ack; server is draining)           |
//! | 0x89 | RESET_OK   | empty                                     |
//! | 0x8A | RETRY      | empty (replica died mid-batch; the request|
//! |      |            | was not served and is safe to resubmit)   |

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::metrics::LatencySummary;
use crate::runtime::exec::ExecEngine;
use crate::util::fault::Faults;
use crate::util::json::Json;
use crate::util::lock::lock_recover;
use crate::util::pool;
use crate::util::prng::Prng;

use super::queue::{BatchQueue, CutReason, Offer, QueueConfig, NO_DEADLINE};
use super::replica::{BatchJob, EngineFactory, ReplicaPool};

/// Frame type constants (see module docs for the table).
pub mod frame {
    pub const INFER: u8 = 0x01;
    pub const HEALTH: u8 = 0x02;
    pub const READY: u8 = 0x03;
    pub const STATS: u8 = 0x04;
    pub const SHUTDOWN: u8 = 0x05;
    pub const STATS_RESET: u8 = 0x06;
    pub const INFER_DL: u8 = 0x07;

    pub const R_LOGITS: u8 = 0x81;
    pub const R_SHED: u8 = 0x82;
    pub const R_ERROR: u8 = 0x83;
    pub const R_HEALTH: u8 = 0x84;
    pub const R_READY: u8 = 0x85;
    pub const R_STATS: u8 = 0x86;
    pub const R_DEADLINE: u8 = 0x87;
    pub const R_SHUTDOWN: u8 = 0x88;
    pub const R_RESET: u8 = 0x89;
    pub const R_RETRY: u8 = 0x8A;

    /// Hard cap on `length`; anything larger is a protocol error (a
    /// sample is a few KB — 16 MiB means a corrupt or hostile header).
    pub const MAX_FRAME: usize = 1 << 24;
}

/// Serving knobs, resolved (no zeros-meaning-auto left) by the CLI layer.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Engine replicas (each its own `NativeEngine` + worker thread).
    pub replicas: usize,
    /// Batch-cut size; every replica engine must be built with at least
    /// this batch capacity.
    pub max_batch: usize,
    /// Batch-cut max wait — the queueing half of the latency SLO.
    pub max_wait_ms: f64,
    /// Queued-request bound; arrivals beyond it are shed with the depth.
    pub queue_bound: usize,
    /// Default per-request deadline from enqueue (0 = none).
    pub deadline_ms: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            replicas: 1,
            max_batch: 64,
            max_wait_ms: 2.0,
            queue_bound: 256,
            deadline_ms: 0.0,
        }
    }
}

/// What a request ultimately resolves to (sent over the per-request
/// reply channel from dispatcher or replica to the connection thread).
#[derive(Debug)]
pub enum Reply {
    Logits(Vec<f32>),
    Shed { depth: u32 },
    Deadline,
    Error(String),
    /// The replica serving this request's batch panicked before producing
    /// logits; the request was not served and is safe to resubmit.
    Retry,
}

/// Queue payload: the decoded sample plus the reply path. `deadline_ns`
/// is absolute on the service clock ([`NO_DEADLINE`] when none applies).
pub struct ReqPayload {
    pub input: Vec<f32>,
    pub deadline_ns: u64,
    pub reply: Sender<Reply>,
}

/// Service-side counters, guarded by one mutex (touched per batch and per
/// shed — far coarser than per-sample work, so contention is negligible).
#[derive(Debug, Default)]
pub struct ServeStats {
    pub completed: u64,
    pub shed_queue: u64,
    pub shed_deadline: u64,
    pub protocol_errors: u64,
    pub internal_errors: u64,
    pub batches: u64,
    pub batch_fill_sum: f64,
    pub cut_max_batch: u64,
    pub cut_max_wait: u64,
    /// Replica worker panics caught mid-batch (each retires one worker).
    pub replica_panics: u64,
    /// Crashed replicas rebuilt by the supervisor.
    pub replica_restarts: u64,
    /// Requests that reached a replica but got Error/Retry instead of
    /// logits. Together with `completed`, `shed_*` this accounts for
    /// every request accepted into the queue.
    pub errored: u64,
    /// Enqueue→reply latency per completed request. Capped so a long-lived
    /// server cannot grow without bound; the digest then covers the first
    /// `LAT_CAP` completions since the last reset (counters keep counting).
    pub service_latency_ms: Vec<f64>,
}

impl ServeStats {
    /// Latency-sample cap (~8 MiB of f64 worst case).
    pub const LAT_CAP: usize = 1 << 20;

    pub fn record_latency(&mut self, ms: f64) {
        if self.service_latency_ms.len() < Self::LAT_CAP {
            self.service_latency_ms.push(ms);
        }
    }

    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_fill_sum / self.batches as f64
        }
    }

    pub fn reset(&mut self) {
        *self = ServeStats::default();
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("completed", Json::num(self.completed as f64)),
            ("shed_queue", Json::num(self.shed_queue as f64)),
            ("shed_deadline", Json::num(self.shed_deadline as f64)),
            ("protocol_errors", Json::num(self.protocol_errors as f64)),
            ("internal_errors", Json::num(self.internal_errors as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("mean_batch_fill", Json::num(self.mean_batch_fill())),
            ("cut_max_batch", Json::num(self.cut_max_batch as f64)),
            ("cut_max_wait", Json::num(self.cut_max_wait as f64)),
            ("replica_panics", Json::num(self.replica_panics as f64)),
            ("replica_restarts", Json::num(self.replica_restarts as f64)),
            ("errored", Json::num(self.errored as f64)),
            (
                "service_latency_ms",
                LatencySummary::from_unsorted(&self.service_latency_ms).to_json(),
            ),
        ])
    }
}

// ---- framing helpers --------------------------------------------------------

pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut v = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        v.extend_from_slice(&x.to_le_bytes());
    }
    v
}

pub fn bytes_to_f32s(b: &[u8]) -> Option<Vec<f32>> {
    if b.len() % 4 != 0 {
        return None;
    }
    Some(
        b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
    )
}

pub fn write_frame(w: &mut impl Write, ty: u8, body: &[u8]) -> io::Result<()> {
    let len = (body.len() + 1) as u32;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&[ty])?;
    w.write_all(body)?;
    w.flush()
}

/// Blocking frame read (client side / tests — the server side uses the
/// incremental [`FrameBuf`] so read timeouts can't split a frame).
pub fn read_frame_blocking(r: &mut impl Read) -> io::Result<(u8, Vec<u8>)> {
    let mut hdr = [0u8; 4];
    r.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr) as usize;
    if len == 0 || len > frame::MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("bad frame length {len}"),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    let payload = body.split_off(1);
    Ok((body[0], payload))
}

/// Incremental frame parser: bytes go in as they arrive (including after
/// read timeouts mid-frame), complete frames come out. This is what lets
/// connection threads use short read timeouts to notice shutdown without
/// ever corrupting the stream.
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
}

impl FrameBuf {
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// `Ok(None)` = need more bytes; `Err` = unrecoverable framing error
    /// (caller should drop the connection).
    pub fn next_frame(&mut self) -> Result<Option<(u8, Vec<u8>)>, String> {
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]]) as usize;
        if len == 0 || len > frame::MAX_FRAME {
            return Err(format!("bad frame length {len}"));
        }
        if self.buf.len() < 4 + len {
            return Ok(None);
        }
        let rest = self.buf.split_off(4 + len);
        let head = std::mem::replace(&mut self.buf, rest);
        Ok(Some((head[4], head[5..].to_vec())))
    }
}

// ---- the service ------------------------------------------------------------

/// How long the dispatcher sleeps when idle (also bounds how fast every
/// thread notices the shutdown flag).
const IDLE_TICK: Duration = Duration::from_millis(25);
/// Connection-thread read timeout (shutdown responsiveness).
const CONN_READ_TIMEOUT: Duration = Duration::from_millis(100);
/// How long a connection thread waits for the engine reply before giving
/// up on a request (far beyond any sane SLO — a backstop, not a policy).
const REPLY_TIMEOUT: Duration = Duration::from_secs(30);

fn now_ns(t0: Instant) -> u64 {
    t0.elapsed().as_nanos() as u64
}

/// A running inference service. Dropping it does **not** stop the
/// threads; call [`Service::shutdown_and_join`] (or send a SHUTDOWN frame
/// and call [`Service::join`]).
pub struct Service {
    /// Actual bound address (resolves port 0 to the ephemeral port).
    pub addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    stats: Arc<Mutex<ServeStats>>,
    accept: Option<JoinHandle<()>>,
    dispatcher: Option<JoinHandle<()>>,
    pool: Option<ReplicaPool>,
}

impl Service {
    /// Bind, spawn the replica pool + dispatcher + accept loop, and
    /// return once the service is ready (readiness probes answer `true`
    /// from that point on). `sample_len` is the per-request input length
    /// every INFER frame must match exactly. Unsupervised: a crashed
    /// replica stays down (see [`Service::start_supervised`]).
    pub fn start(
        addr: SocketAddr,
        cfg: ServeConfig,
        engines: Vec<Box<dyn ExecEngine + Send>>,
        sample_len: usize,
    ) -> Result<Service, String> {
        Self::start_supervised(addr, cfg, engines, None, None, sample_len)
    }

    /// [`Service::start`] plus fault tolerance: with a `factory`, crashed
    /// replica workers are rebuilt under capped exponential backoff while
    /// the pool keeps serving on the survivors, and READY reports the
    /// degraded live/total replica counts. `faults` is the deterministic
    /// fault-injection plan (`None` in production — zero cost).
    pub fn start_supervised(
        addr: SocketAddr,
        cfg: ServeConfig,
        engines: Vec<Box<dyn ExecEngine + Send>>,
        factory: Option<EngineFactory>,
        faults: Faults,
        sample_len: usize,
    ) -> Result<Service, String> {
        if sample_len == 0 {
            return Err("serve: sample_len must be > 0".into());
        }
        for (i, e) in engines.iter().enumerate() {
            if e.batch() < cfg.max_batch {
                return Err(format!(
                    "serve: replica {i} batch capacity {} < max_batch {}",
                    e.batch(),
                    cfg.max_batch
                ));
            }
        }
        let qcfg = QueueConfig {
            max_batch: cfg.max_batch,
            max_wait_ns: (cfg.max_wait_ms.max(0.0) * 1e6) as u64,
            bound: cfg.queue_bound,
            deadline_ns: (cfg.deadline_ms.max(0.0) * 1e6) as u64,
        };
        qcfg.validate()?;

        let t0 = Instant::now();
        let shutdown = Arc::new(AtomicBool::new(false));
        let ready = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(Mutex::new(ServeStats::default()));

        let pool =
            ReplicaPool::spawn_supervised(engines, factory, Arc::clone(&stats), t0, faults.clone())?;
        let live = pool.live_handle();
        let total = pool.total() as u32;
        let job_tx = pool.sender();

        let (req_tx, req_rx) = channel::<ReqPayload>();
        let dispatcher = {
            let stats = Arc::clone(&stats);
            let shutdown = Arc::clone(&shutdown);
            let faults = faults.clone();
            pool::spawn_service("dispatcher", move || {
                dispatcher_loop(qcfg, req_rx, job_tx, stats, shutdown, t0, faults);
            })
        };

        let listener = TcpListener::bind(addr).map_err(|e| format!("serve: bind {addr}: {e}"))?;
        let bound = listener
            .local_addr()
            .map_err(|e| format!("serve: local_addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("serve: set_nonblocking: {e}"))?;
        ready.store(true, Ordering::Release);

        let accept = {
            let ctx = ConnCtx {
                req_tx,
                stats: Arc::clone(&stats),
                shutdown: Arc::clone(&shutdown),
                ready,
                live,
                total,
                t0,
                sample_len,
                dl_default_ns: qcfg.deadline_ns,
                conn_drop_frames: faults
                    .as_deref()
                    .and_then(|f| f.conn_drop_frames())
                    .unwrap_or(0),
            };
            pool::spawn_service("accept", move || {
                accept_loop(listener, ctx);
            })
        };

        Ok(Service {
            addr: bound,
            shutdown,
            stats,
            accept: Some(accept),
            dispatcher: Some(dispatcher),
            pool: Some(pool),
        })
    }

    /// Signal shutdown (idempotent; the SHUTDOWN frame does the same).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
    }

    /// Render the current stats (same JSON the STATS frame returns).
    pub fn stats_json(&self) -> Json {
        lock_recover(&self.stats).to_json()
    }

    /// Shared handle to the live counters — lets a caller read final
    /// stats *after* [`Service::join`] consumed the service.
    pub fn stats_handle(&self) -> Arc<Mutex<ServeStats>> {
        Arc::clone(&self.stats)
    }

    /// Block until the service exits: the accept loop ends (shutdown flag),
    /// connection threads drain, the dispatcher flushes the queue, and the
    /// replica pool finishes in-flight batches — in that order, so every
    /// accepted request gets *some* reply before the threads go away.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        if let Some(p) = self.pool.take() {
            p.join();
        }
    }

    pub fn shutdown_and_join(self) {
        self.shutdown();
        self.join();
    }
}

fn dispatcher_loop(
    qcfg: QueueConfig,
    req_rx: Receiver<ReqPayload>,
    job_tx: Sender<BatchJob>,
    stats: Arc<Mutex<ServeStats>>,
    shutdown: Arc<AtomicBool>,
    t0: Instant,
    faults: Faults,
) {
    let mut queue: BatchQueue<ReqPayload> = BatchQueue::new(qcfg);
    'outer: loop {
        // 1) act on everything already due: expire, then cut until quiet
        let next_event;
        loop {
            let p = queue.poll(now_ns(t0));
            if !p.expired.is_empty() {
                lock_recover(&stats).shed_deadline += p.expired.len() as u64;
                for t in p.expired {
                    let _ = t.payload.reply.send(Reply::Deadline);
                }
            }
            match p.batch {
                Some(cut) => {
                    {
                        let mut st = lock_recover(&stats);
                        match cut.reason {
                            CutReason::MaxBatch => st.cut_max_batch += 1,
                            CutReason::MaxWait => st.cut_max_wait += 1,
                        }
                    }
                    if let Some(d) = faults.as_deref().and_then(|f| f.dispatch_delay()) {
                        std::thread::sleep(d);
                    }
                    if job_tx.send(BatchJob { tickets: cut.tickets }).is_err() {
                        // replica pool is gone; nothing can be served
                        break 'outer;
                    }
                }
                None => {
                    next_event = p.next_event_ns;
                    break;
                }
            }
        }
        // 2) sleep until the next arrival or the next timer, whichever
        //    comes first (capped so the shutdown flag is honored promptly)
        let wait = match next_event {
            Some(t) => Duration::from_nanos(t.saturating_sub(now_ns(t0))).min(IDLE_TICK),
            None => IDLE_TICK,
        };
        match req_rx.recv_timeout(wait) {
            Ok(req) => {
                offer_one(&mut queue, &stats, req, t0);
                // drain the burst that may have accumulated behind it
                while let Ok(req) = req_rx.try_recv() {
                    offer_one(&mut queue, &stats, req, t0);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::Acquire) && queue.is_empty() {
                    break;
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                if queue.is_empty() {
                    break;
                }
                // all senders gone; let remaining tickets age into a
                // max-wait cut instead of spinning on the dead channel
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
    // job_tx drops here; replicas exit after finishing in-flight batches
}

fn offer_one(
    queue: &mut BatchQueue<ReqPayload>,
    stats: &Mutex<ServeStats>,
    req: ReqPayload,
    t0: Instant,
) {
    let dl = req.deadline_ns;
    match queue.offer_deadline(req, now_ns(t0), dl) {
        Offer::Accepted { .. } => {}
        Offer::Shed { payload, depth } => {
            lock_recover(stats).shed_queue += 1;
            let _ = payload.reply.send(Reply::Shed { depth: depth as u32 });
        }
    }
}

/// Everything a connection handler needs, bundled so the accept → conn →
/// frame plumbing is one clone instead of eight loose arguments.
#[derive(Clone)]
struct ConnCtx {
    req_tx: Sender<ReqPayload>,
    stats: Arc<Mutex<ServeStats>>,
    shutdown: Arc<AtomicBool>,
    ready: Arc<AtomicBool>,
    /// Live-replica gauge (owned by the pool supervisor).
    live: Arc<AtomicUsize>,
    /// Configured replica count.
    total: u32,
    t0: Instant,
    sample_len: usize,
    dl_default_ns: u64,
    /// Fault injection: drop each connection after this many handled
    /// frames (0 = disabled).
    conn_drop_frames: u64,
}

fn accept_loop(listener: TcpListener, ctx: ConnCtx) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !ctx.shutdown.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nodelay(true);
                let _ = stream.set_read_timeout(Some(CONN_READ_TIMEOUT));
                let ctx = ctx.clone();
                conns.push(pool::spawn_service("conn", move || {
                    conn_loop(stream, ctx);
                }));
                // opportunistically reap finished handlers so a long-lived
                // server doesn't accumulate one JoinHandle per past conn
                conns.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
    // master req_tx (and all conn clones, once they exit) must drop for
    // the dispatcher to see Disconnected and drain out
    drop(ctx);
    for h in conns {
        let _ = h.join();
    }
}

fn conn_loop(mut stream: TcpStream, ctx: ConnCtx) {
    let mut fb = FrameBuf::default();
    let mut tmp = [0u8; 64 * 1024];
    let mut handled: u64 = 0;
    loop {
        // parse everything already buffered before touching the socket
        loop {
            match fb.next_frame() {
                Ok(Some((ty, body))) => {
                    let keep = handle_frame(&mut stream, ty, &body, &ctx);
                    handled += 1;
                    if ctx.conn_drop_frames > 0 && handled >= ctx.conn_drop_frames {
                        // injected fault: sever the connection mid-session
                        return;
                    }
                    if !keep {
                        return;
                    }
                }
                Ok(None) => break,
                Err(_) => {
                    lock_recover(&ctx.stats).protocol_errors += 1;
                    let _ = write_frame(&mut stream, frame::R_ERROR, b"bad frame length");
                    return;
                }
            }
        }
        if ctx.shutdown.load(Ordering::Acquire) {
            return;
        }
        match stream.read(&mut tmp) {
            Ok(0) => return, // clean EOF
            Ok(n) => fb.push(&tmp[..n]),
            // read timeout: loop around (re-checks the shutdown flag)
            Err(e) => match e.kind() {
                io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {}
                _ => return,
            },
        }
    }
}

/// Handle one decoded frame; returns `false` when the connection should
/// close (fatal protocol error).
fn handle_frame(stream: &mut TcpStream, ty: u8, body: &[u8], ctx: &ConnCtx) -> bool {
    let ConnCtx { stats, sample_len, dl_default_ns, t0, .. } = ctx;
    let (sample_len, dl_default_ns, t0) = (*sample_len, *dl_default_ns, *t0);
    match ty {
        frame::INFER | frame::INFER_DL => {
            let (dl_req_ns, sample_bytes) = if ty == frame::INFER_DL {
                if body.len() < 4 {
                    lock_recover(stats).protocol_errors += 1;
                    let _ = write_frame(stream, frame::R_ERROR, b"INFER_DL: missing deadline");
                    return true;
                }
                let ms = u32::from_le_bytes([body[0], body[1], body[2], body[3]]);
                (u64::from(ms) * 1_000_000, &body[4..])
            } else {
                (0, body)
            };
            let input = match bytes_to_f32s(sample_bytes) {
                Some(v) if v.len() == sample_len => v,
                _ => {
                    lock_recover(stats).protocol_errors += 1;
                    let msg = format!(
                        "INFER: expected {} f32 values ({} bytes), got {} bytes",
                        sample_len,
                        sample_len * 4,
                        sample_bytes.len()
                    );
                    let _ = write_frame(stream, frame::R_ERROR, msg.as_bytes());
                    return true;
                }
            };
            // effective deadline: the tighter of the request's and the
            // configured default (0 on either side = unconstrained)
            let now = now_ns(t0);
            let dl_abs = match (dl_default_ns, dl_req_ns) {
                (0, 0) => NO_DEADLINE,
                (0, r) => now.saturating_add(r),
                (d, 0) => now.saturating_add(d),
                (d, r) => now.saturating_add(d.min(r)),
            };
            let (reply_tx, reply_rx) = channel::<Reply>();
            let req = ReqPayload { input, deadline_ns: dl_abs, reply: reply_tx };
            if ctx.req_tx.send(req).is_err() {
                let _ = write_frame(stream, frame::R_ERROR, b"service is shutting down");
                return true;
            }
            match reply_rx.recv_timeout(REPLY_TIMEOUT) {
                Ok(Reply::Logits(l)) => {
                    let _ = write_frame(stream, frame::R_LOGITS, &f32s_to_bytes(&l));
                }
                Ok(Reply::Shed { depth }) => {
                    let _ = write_frame(stream, frame::R_SHED, &depth.to_le_bytes());
                }
                Ok(Reply::Deadline) => {
                    let _ = write_frame(stream, frame::R_DEADLINE, &[]);
                }
                Ok(Reply::Error(msg)) => {
                    let _ = write_frame(stream, frame::R_ERROR, msg.as_bytes());
                }
                Ok(Reply::Retry) => {
                    let _ = write_frame(stream, frame::R_RETRY, &[]);
                }
                Err(_) => {
                    let _ = write_frame(stream, frame::R_ERROR, b"timed out waiting for reply");
                }
            }
            true
        }
        frame::HEALTH => {
            let _ = write_frame(stream, frame::R_HEALTH, &[1]);
            true
        }
        frame::READY => {
            let live = ctx.live.load(Ordering::Acquire) as u32;
            let up = ctx.ready.load(Ordering::Acquire)
                && !ctx.shutdown.load(Ordering::Acquire)
                && live > 0;
            // byte 0 keeps the legacy 0/1 meaning; the degraded flag and
            // live/total counts ride behind it for newer probes
            let mut out = vec![u8::from(up), u8::from(live < ctx.total)];
            out.extend_from_slice(&live.to_le_bytes());
            out.extend_from_slice(&ctx.total.to_le_bytes());
            let _ = write_frame(stream, frame::R_READY, &out);
            true
        }
        frame::STATS => {
            let json = lock_recover(stats).to_json().to_string();
            let _ = write_frame(stream, frame::R_STATS, json.as_bytes());
            true
        }
        frame::STATS_RESET => {
            lock_recover(stats).reset();
            let _ = write_frame(stream, frame::R_RESET, &[]);
            true
        }
        frame::SHUTDOWN => {
            ctx.shutdown.store(true, Ordering::Release);
            let _ = write_frame(stream, frame::R_SHUTDOWN, &[]);
            true
        }
        other => {
            lock_recover(stats).protocol_errors += 1;
            let msg = format!("unknown frame type 0x{other:02x}");
            let _ = write_frame(stream, frame::R_ERROR, msg.as_bytes());
            true
        }
    }
}

// ---- client -----------------------------------------------------------------

/// What the server answered an INFER with (client-side mirror of [`Reply`]).
#[derive(Debug, Clone, PartialEq)]
pub enum ClientReply {
    Logits(Vec<f32>),
    Shed { depth: u32 },
    Deadline,
    Error(String),
    /// The serving replica died mid-batch; the request was not served and
    /// an idempotent resubmit is safe ([`RetryClient`] does this).
    Retry,
}

/// Decoded READY reply: liveness plus the degradation report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReadyInfo {
    /// Accepting traffic (false once shutdown begins or no replica lives).
    pub ready: bool,
    /// Some configured replicas are currently down.
    pub degraded: bool,
    /// Live replica count (0 when the server predates the extended reply).
    pub live: u32,
    /// Configured replica count (0 when unknown).
    pub total: u32,
}

/// Minimal blocking client over the frame protocol — used by the load
/// generator, the probe/shutdown CLI modes, and the loopback tests.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        Ok(Client { stream })
    }

    fn roundtrip(&mut self, ty: u8, body: &[u8]) -> io::Result<(u8, Vec<u8>)> {
        write_frame(&mut self.stream, ty, body)?;
        read_frame_blocking(&mut self.stream)
    }

    pub fn infer(&mut self, sample: &[f32]) -> io::Result<ClientReply> {
        let (ty, body) = self.roundtrip(frame::INFER, &f32s_to_bytes(sample))?;
        Ok(decode_reply(ty, body))
    }

    /// INFER with a per-request deadline in milliseconds.
    pub fn infer_deadline(&mut self, sample: &[f32], deadline_ms: u32) -> io::Result<ClientReply> {
        let mut body = deadline_ms.to_le_bytes().to_vec();
        body.extend_from_slice(&f32s_to_bytes(sample));
        let (ty, body) = self.roundtrip(frame::INFER_DL, &body)?;
        Ok(decode_reply(ty, body))
    }

    pub fn health(&mut self) -> io::Result<bool> {
        let (ty, body) = self.roundtrip(frame::HEALTH, &[])?;
        Ok(ty == frame::R_HEALTH && body.first() == Some(&1))
    }

    pub fn ready(&mut self) -> io::Result<bool> {
        let (ty, body) = self.roundtrip(frame::READY, &[])?;
        Ok(ty == frame::R_READY && body.first() == Some(&1))
    }

    /// READY with the degradation report (live/total replica counts). A
    /// legacy 1-byte reply decodes with `degraded = false`, counts 0.
    pub fn ready_info(&mut self) -> io::Result<ReadyInfo> {
        let (ty, body) = self.roundtrip(frame::READY, &[])?;
        if ty != frame::R_READY {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected READY reply, got 0x{ty:02x}"),
            ));
        }
        let ready = body.first() == Some(&1);
        if body.len() >= 10 {
            Ok(ReadyInfo {
                ready,
                degraded: body[1] == 1,
                live: u32::from_le_bytes([body[2], body[3], body[4], body[5]]),
                total: u32::from_le_bytes([body[6], body[7], body[8], body[9]]),
            })
        } else {
            Ok(ReadyInfo { ready, degraded: false, live: 0, total: 0 })
        }
    }

    /// Raw stats JSON string.
    pub fn stats(&mut self) -> io::Result<String> {
        let (ty, body) = self.roundtrip(frame::STATS, &[])?;
        if ty != frame::R_STATS {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected STATS reply, got 0x{ty:02x}"),
            ));
        }
        String::from_utf8(body)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "stats not utf-8"))
    }

    pub fn stats_reset(&mut self) -> io::Result<()> {
        let (ty, _) = self.roundtrip(frame::STATS_RESET, &[])?;
        if ty != frame::R_RESET {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected RESET ack, got 0x{ty:02x}"),
            ));
        }
        Ok(())
    }

    /// Ask the server to exit (acked before the server drains).
    pub fn shutdown_server(&mut self) -> io::Result<()> {
        let (ty, _) = self.roundtrip(frame::SHUTDOWN, &[])?;
        if ty != frame::R_SHUTDOWN {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected SHUTDOWN ack, got 0x{ty:02x}"),
            ));
        }
        Ok(())
    }
}

fn decode_reply(ty: u8, body: Vec<u8>) -> ClientReply {
    match ty {
        frame::R_LOGITS => match bytes_to_f32s(&body) {
            Some(l) => ClientReply::Logits(l),
            None => ClientReply::Error("logits reply not a multiple of 4 bytes".into()),
        },
        frame::R_SHED => {
            let depth = if body.len() >= 4 {
                u32::from_le_bytes([body[0], body[1], body[2], body[3]])
            } else {
                0
            };
            ClientReply::Shed { depth }
        }
        frame::R_DEADLINE => ClientReply::Deadline,
        frame::R_RETRY => ClientReply::Retry,
        frame::R_ERROR => ClientReply::Error(String::from_utf8_lossy(&body).into_owned()),
        other => ClientReply::Error(format!("unexpected reply type 0x{other:02x}")),
    }
}

// ---- retrying client --------------------------------------------------------

/// Retry policy for [`RetryClient`].
#[derive(Clone, Copy, Debug)]
pub struct RetryCfg {
    /// Extra attempts after the first (0 = no retries).
    pub retries: u32,
    /// First backoff (before jitter), milliseconds.
    pub backoff_base_ms: f64,
    /// Backoff ceiling, milliseconds.
    pub backoff_cap_ms: f64,
    /// Jitter seed — same seed, same backoff sequence (determinism for
    /// tests and reproducible load generation).
    pub seed: u64,
}

impl Default for RetryCfg {
    fn default() -> Self {
        RetryCfg { retries: 0, backoff_base_ms: 10.0, backoff_cap_ms: 1_000.0, seed: 0 }
    }
}

/// Equal-jitter exponential backoff: attempt `k` sleeps uniformly in
/// `[cap_k/2, cap_k)` where `cap_k = min(base · 2^k, cap)`. The random
/// half de-synchronizes clients that failed together (no retry stampede);
/// the deterministic half keeps the mean predictable.
pub fn backoff_ms(attempt: u32, base_ms: f64, cap_ms: f64, rng: &mut Prng) -> f64 {
    let exp = base_ms * 2f64.powi(attempt.min(62) as i32);
    let capped = exp.min(cap_ms);
    capped / 2.0 + rng.uniform_f64() * (capped / 2.0)
}

/// A [`Client`] wrapper that survives the failures the service can now
/// produce: RETRY replies (replica died mid-batch) and dropped
/// connections both trigger an idempotent resubmit under equal-jitter
/// exponential backoff — INFER is read-only, so resubmitting can never
/// double-apply anything. A request deadline always wins over the retry
/// budget: no attempt (or sleep) is started that the deadline can't fit.
pub struct RetryClient {
    addr: SocketAddr,
    cfg: RetryCfg,
    rng: Prng,
    conn: Option<Client>,
}

impl RetryClient {
    pub fn new(addr: SocketAddr, cfg: RetryCfg) -> RetryClient {
        RetryClient { addr, cfg, rng: Prng::new(cfg.seed), conn: None }
    }

    /// Establish the connection eagerly (load generators call this before
    /// the measurement window so connect cost isn't billed to a request).
    pub fn preconnect(&mut self) -> io::Result<()> {
        self.conn().map(|_| ())
    }

    fn conn(&mut self) -> io::Result<&mut Client> {
        if self.conn.is_none() {
            self.conn = Some(Client::connect(self.addr)?);
        }
        self.conn
            .as_mut()
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotConnected, "retry client: no connection"))
    }

    /// INFER with retries; returns the final reply plus the number of
    /// retry attempts used (0 = first try succeeded). `deadline_ms == 0`
    /// means no deadline; otherwise the deadline spans *all* attempts and
    /// each resubmit carries only the remaining budget.
    pub fn infer_retry(
        &mut self,
        sample: &[f32],
        deadline_ms: u32,
    ) -> io::Result<(ClientReply, u32)> {
        let start = Instant::now();
        let mut attempt: u32 = 0;
        loop {
            let rem_ms = if deadline_ms == 0 {
                0
            } else {
                let left = f64::from(deadline_ms) - start.elapsed().as_secs_f64() * 1e3;
                if left <= 0.0 {
                    return Ok((ClientReply::Deadline, attempt));
                }
                (left.ceil() as u32).max(1)
            };
            let res = match self.conn() {
                Ok(c) => {
                    if deadline_ms == 0 {
                        c.infer(sample)
                    } else {
                        c.infer_deadline(sample, rem_ms)
                    }
                }
                Err(e) => Err(e),
            };
            let retryable = match &res {
                // transport failure: the connection is suspect — drop it
                // so the next attempt reconnects from scratch
                Err(_) => {
                    self.conn = None;
                    true
                }
                Ok(ClientReply::Retry) => true,
                Ok(_) => false,
            };
            if !retryable || attempt >= self.cfg.retries {
                return res.map(|r| (r, attempt));
            }
            let sleep_ms =
                backoff_ms(attempt, self.cfg.backoff_base_ms, self.cfg.backoff_cap_ms, &mut self.rng);
            if deadline_ms > 0 {
                let after_ms = start.elapsed().as_secs_f64() * 1e3 + sleep_ms;
                if after_ms >= f64::from(deadline_ms) {
                    // the deadline would expire mid-backoff: report it now
                    // rather than sleeping past it
                    return Ok((ClientReply::Deadline, attempt));
                }
            }
            std::thread::sleep(Duration::from_secs_f64(sleep_ms / 1e3));
            attempt += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_byte_roundtrip() {
        let xs = [0.0f32, -1.5, 3.25, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&xs)).unwrap(), xs);
        assert!(bytes_to_f32s(&[0, 1, 2]).is_none());
    }

    #[test]
    fn frame_buf_reassembles_split_frames() {
        // one frame delivered in three fragments, then two frames at once
        let mut out = Vec::new();
        write_frame(&mut out, frame::INFER, &[9, 9, 9, 9]).unwrap();
        let mut fb = FrameBuf::default();
        fb.push(&out[..2]);
        assert!(fb.next_frame().unwrap().is_none());
        fb.push(&out[2..6]);
        assert!(fb.next_frame().unwrap().is_none());
        fb.push(&out[6..]);
        let (ty, body) = fb.next_frame().unwrap().unwrap();
        assert_eq!((ty, body.as_slice()), (frame::INFER, &[9u8, 9, 9, 9][..]));

        let mut two = Vec::new();
        write_frame(&mut two, frame::HEALTH, &[]).unwrap();
        write_frame(&mut two, frame::STATS, &[]).unwrap();
        fb.push(&two);
        assert_eq!(fb.next_frame().unwrap().unwrap().0, frame::HEALTH);
        assert_eq!(fb.next_frame().unwrap().unwrap().0, frame::STATS);
        assert!(fb.next_frame().unwrap().is_none());
    }

    #[test]
    fn frame_buf_rejects_bad_lengths() {
        let mut fb = FrameBuf::default();
        fb.push(&0u32.to_le_bytes()); // length 0
        assert!(fb.next_frame().is_err());
        let mut fb = FrameBuf::default();
        fb.push(&(frame::MAX_FRAME as u32 + 1).to_le_bytes());
        assert!(fb.next_frame().is_err());
    }

    #[test]
    fn stats_json_has_schema_fields() {
        let mut st = ServeStats {
            completed: 3,
            batches: 2,
            batch_fill_sum: 3.0,
            ..ServeStats::default()
        };
        st.record_latency(1.0);
        let j = st.to_json();
        assert_eq!(j.get("completed").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(j.get("mean_batch_fill").and_then(|v| v.as_f64()), Some(1.5));
        assert!(j.get("service_latency_ms").is_some());
        st.reset();
        assert_eq!(st.completed, 0);
        assert!(st.service_latency_ms.is_empty());
    }
}
