//! The async inference service: dynamic batching over native-engine
//! replicas, plus the open-loop benchmark that measures it.
//!
//! This is the production shape the packed ternary kernels exist for
//! (paper §4: the efficiency argument assumes the kernels are *fed*):
//! many concurrent single-sample requests, coalesced into engine batches
//! under a latency SLO, sharded across one `NativeEngine` replica per
//! core. The pieces, bottom-up:
//!
//! * [`queue`] — the pure batching/shedding/deadline logic on a virtual
//!   clock (deterministically tested, no sockets).
//! * [`replica`] — N engines on N worker threads behind one job channel.
//! * [`service`] — the TCP accept loop, frame protocol, connection
//!   handlers, and the dispatcher thread that owns the queue.
//! * [`loadgen`] — the Poisson open-loop load generator and its report.
//!
//! Correctness anchor: serving must be a *scheduling* layer only. The
//! native engine's per-sample independence means logits for a request are
//! bit-identical no matter which replica ran it, how full its batch was,
//! or how many threads the engine used — `tests/serve.rs` pins exactly
//! that against direct `infer_batch` calls.

pub mod loadgen;
pub mod queue;
pub mod replica;
pub mod service;

pub use loadgen::{LoadReport, LoadgenCfg};
pub use queue::{BatchQueue, CutReason, Offer, QueueConfig, Ticket};
pub use replica::{EngineFactory, ReplicaPool};
pub use service::{Client, ClientReply, ReadyInfo, RetryCfg, RetryClient, ServeConfig, Service};

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::method::Method;
use crate::engine::{bitplane, model_from_checkpoint_or_init, NativeEngine};
use crate::nn::arch::build_arch;
use crate::runtime::exec::ExecEngine;
use crate::runtime::manifest::Manifest;
use crate::util::json::{provenance, Json};
use crate::util::pool;

/// Everything needed to materialize identical engine replicas.
#[derive(Clone, Debug)]
pub struct EngineSpec {
    pub arch: String,
    pub method: Method,
    /// Zero-window half width (the paper's `r`).
    pub r: f32,
    /// Checkpoint to serve; `None` = seeded fresh init (latency benching
    /// only — logits are exercised, never accuracy-checked).
    pub ckpt: Option<String>,
    /// Artifact dir whose manifest, when present, supplies param shapes;
    /// the catalogue arch is the device-free fallback.
    pub artifacts: String,
    pub seed: u64,
}

/// Per-request input length for an arch (flattened h×w×c), without
/// building an engine — the client-side loadgen mode needs this.
pub fn arch_sample_len(arch: &str) -> Result<usize> {
    let a = build_arch(arch).map_err(|e| anyhow!(e))?;
    let (h, w, c) = a.input;
    Ok(h * w * c)
}

/// Build `replicas` identical native engines (shared `ModelState`, one
/// engine each) with `max_batch` capacity and `engine_threads` intra-
/// engine workers. Returns the engines, the model's sample length, and a
/// factory that rebuilds an identical replica from the same model — the
/// supervisor uses it to replace a crashed worker without re-reading the
/// checkpoint. `replicas = 0` resolves to one per available core.
pub fn build_engines(
    spec: &EngineSpec,
    replicas: usize,
    max_batch: usize,
    engine_threads: usize,
) -> Result<(Vec<Box<dyn ExecEngine + Send>>, usize, EngineFactory)> {
    let n = if replicas == 0 {
        pool::resolve_threads(0)
    } else {
        replicas
    };
    let manifest = Manifest::load(&spec.artifacts).ok();
    let (model, n_classes) = model_from_checkpoint_or_init(
        manifest.as_ref(),
        &spec.arch,
        spec.method,
        spec.ckpt.as_deref(),
        spec.seed,
    )?;
    let model = Arc::new(model);
    let mut engines: Vec<Box<dyn ExecEngine + Send>> = Vec::with_capacity(n);
    let mut sample_len = 0;
    for _ in 0..n {
        let eng = NativeEngine::from_model(
            &spec.arch,
            spec.method,
            &model,
            spec.r,
            max_batch,
            n_classes,
            engine_threads,
        )?;
        sample_len = eng.sample_len();
        engines.push(Box::new(eng));
    }
    let factory: EngineFactory = {
        let arch = spec.arch.clone();
        let method = spec.method;
        let r = spec.r;
        Arc::new(move || {
            NativeEngine::from_model(&arch, method, &model, r, max_batch, n_classes, engine_threads)
                .map(|e| Box::new(e) as Box<dyn ExecEngine + Send>)
                .map_err(|e| e.to_string())
        })
    };
    Ok((engines, sample_len, factory))
}

/// `serve --bench`: start an in-process service on an ephemeral loopback
/// port, drive it with the open-loop generator, and assemble the
/// `bench_serve.v1` document (client-side latency/throughput/shed-rate
/// plus the server's own batch-fill and cut counters, stats-reset at the
/// warmup boundary so both sides describe the measured window).
pub fn run_bench(
    spec: &EngineSpec,
    serve_cfg: &ServeConfig,
    load_cfg: &LoadgenCfg,
    engine_threads: usize,
) -> Result<Json> {
    let (engines, sample_len, factory) =
        build_engines(spec, serve_cfg.replicas, serve_cfg.max_batch, engine_threads)?;
    let n_replicas = engines.len();
    let addr = std::net::SocketAddr::from(([127, 0, 0, 1], 0));
    let svc =
        Service::start_supervised(addr, serve_cfg.clone(), engines, Some(factory), None, sample_len)
            .map_err(|e| anyhow!(e))?;
    let bound = svc.addr;

    let mut probe = Client::connect(bound).map_err(|e| anyhow!("bench: connect: {e}"))?;
    if !probe.ready().map_err(|e| anyhow!("bench: ready probe: {e}"))? {
        svc.shutdown_and_join();
        return Err(anyhow!("bench: service reported not ready"));
    }

    // Reset server-side counters at the warmup boundary from a side
    // thread, so the STATS we read afterwards cover (approximately) the
    // measured window — same discard discipline as the client report.
    let warmup = std::time::Duration::from_secs_f64(load_cfg.warmup_s.max(0.0));
    let resetter = pool::spawn_service("bench-reset", move || {
        std::thread::sleep(warmup);
        let _ = probe.stats_reset();
    });

    let load = LoadgenCfg { sample_len, ..load_cfg.clone() };
    let report = loadgen::run(bound, &load).map_err(|e| anyhow!(e));
    let _ = resetter.join();
    let server_stats = svc.stats_json();
    svc.shutdown_and_join();
    let report = report?;

    Ok(Json::obj(vec![
        ("schema", Json::str("bench_serve.v1")),
        ("provenance", provenance(bitplane::LANE_WORDS)),
        (
            "config",
            Json::obj(vec![
                ("arch", Json::str(&spec.arch)),
                ("method", Json::str(&spec.method.name())),
                ("r", Json::num(spec.r as f64)),
                (
                    "ckpt",
                    match &spec.ckpt {
                        Some(p) => Json::str(p),
                        None => Json::Null,
                    },
                ),
                ("replicas", Json::num(n_replicas as f64)),
                ("engine_threads", Json::num(engine_threads as f64)),
                ("max_batch", Json::num(serve_cfg.max_batch as f64)),
                ("max_wait_ms", Json::num(serve_cfg.max_wait_ms)),
                ("queue_bound", Json::num(serve_cfg.queue_bound as f64)),
                ("deadline_ms", Json::num(serve_cfg.deadline_ms)),
                ("rps", Json::num(load.rps)),
                ("duration_s", Json::num(load.duration_s)),
                ("warmup_s", Json::num(load.warmup_s)),
                ("conns", Json::num(load.conns as f64)),
                ("sample_len", Json::num(sample_len as f64)),
                ("seed", Json::num(load.seed as f64)),
            ]),
        ),
        ("load", report.to_json()),
        ("server", server_stats),
    ]))
}
