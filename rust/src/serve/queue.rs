//! The SLO batching queue, as pure logic.
//!
//! `BatchQueue` is the heart of the serving layer: a bounded FIFO of
//! pending requests that coalesces arrivals into engine batches under a
//! latency SLO. It knows nothing about sockets, threads, or wall clocks —
//! time is a `u64` nanosecond counter the caller advances — so every cut
//! decision (max-wait vs max-batch races, deadline expiry, bound
//! rejection) is pinned by deterministic virtual-clock tests in
//! `tests/serve.rs` rather than by sleeping in CI.
//!
//! Policy, in order:
//!
//! 1. **Bound** — `offer` rejects when the queue already holds `bound`
//!    tickets, returning the payload *and the observed depth* so the
//!    caller can shed with backpressure information instead of blocking.
//! 2. **Deadline** — `poll` first expires tickets whose absolute deadline
//!    has passed. An expired request never reaches a replica: spending
//!    engine time on an answer nobody is waiting for only delays the
//!    requests still inside their deadline.
//! 3. **Cut** — a batch dispatches when `max_batch` tickets are waiting
//!    (cut reason [`CutReason::MaxBatch`]) or when the *oldest* ticket has
//!    waited `max_wait`, which flushes everything queued (reason
//!    [`CutReason::MaxWait`]). When both hold at the same instant,
//!    max-batch wins: the reason names the condition that bounded the
//!    batch size.
//!
//! FIFO order is preserved within and across batches (`seq` is a
//! monotonic arrival counter and the queue only ever drains from the
//! front, deadline removals aside).

use std::collections::VecDeque;

/// Absolute-deadline sentinel for "no deadline".
pub const NO_DEADLINE: u64 = u64::MAX;

/// SLO knobs, all in the queue's virtual nanoseconds.
#[derive(Clone, Copy, Debug)]
pub struct QueueConfig {
    /// Cut a batch as soon as this many tickets are waiting (≥ 1).
    pub max_batch: usize,
    /// Cut whatever is queued once the oldest ticket has waited this long.
    pub max_wait_ns: u64,
    /// Shed arrivals once this many tickets are already queued.
    pub bound: usize,
    /// Default per-request deadline from enqueue (0 = none). `offer_deadline`
    /// can tighten it per ticket.
    pub deadline_ns: u64,
}

impl QueueConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.max_batch == 0 {
            return Err("serve queue: max_batch must be >= 1".into());
        }
        if self.bound < self.max_batch {
            return Err(format!(
                "serve queue: bound {} < max_batch {} — a full batch could never assemble",
                self.bound, self.max_batch
            ));
        }
        Ok(())
    }
}

/// One queued request: arrival bookkeeping plus the caller's payload.
#[derive(Debug)]
pub struct Ticket<T> {
    /// Monotonic arrival number (FIFO witness).
    pub seq: u64,
    pub enqueued_ns: u64,
    /// Absolute expiry ([`NO_DEADLINE`] when none applies).
    pub deadline_ns: u64,
    pub payload: T,
}

/// Outcome of an [`BatchQueue::offer`].
#[derive(Debug)]
pub enum Offer<T> {
    /// Enqueued; `depth` is the queue length *after* insertion.
    Accepted { depth: usize },
    /// Bound hit: the payload comes back untouched together with the
    /// depth observed, so the caller can reply "shed, N ahead of you".
    Shed { payload: T, depth: usize },
}

/// Why a batch was cut.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CutReason {
    /// `max_batch` tickets were waiting.
    MaxBatch,
    /// The oldest ticket hit `max_wait_ns`.
    MaxWait,
}

/// A dispatched batch: tickets in FIFO order plus the cut reason.
#[derive(Debug)]
pub struct Cut<T> {
    pub tickets: Vec<Ticket<T>>,
    pub reason: CutReason,
}

/// Result of advancing the queue to a point in time.
#[derive(Debug)]
pub struct Poll<T> {
    /// Tickets whose deadline passed — shed *before* any dispatch.
    pub expired: Vec<Ticket<T>>,
    /// At most one batch per call; callers loop until `None`.
    pub batch: Option<Cut<T>>,
    /// Earliest future instant at which `poll` could act again (the next
    /// max-wait cut or deadline expiry), `None` when the queue is empty.
    pub next_event_ns: Option<u64>,
}

pub struct BatchQueue<T> {
    cfg: QueueConfig,
    q: VecDeque<Ticket<T>>,
    seq: u64,
}

impl<T> BatchQueue<T> {
    /// Panics on an invalid config — validate at the CLI boundary first.
    pub fn new(cfg: QueueConfig) -> Self {
        if let Err(e) = cfg.validate() {
            panic!("{e}");
        }
        BatchQueue { cfg, q: VecDeque::new(), seq: 0 }
    }

    pub fn depth(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Enqueue under the configured default deadline.
    pub fn offer(&mut self, payload: T, now_ns: u64) -> Offer<T> {
        let dl = match self.cfg.deadline_ns {
            0 => NO_DEADLINE,
            d => now_ns.saturating_add(d),
        };
        self.offer_deadline(payload, now_ns, dl)
    }

    /// Enqueue with an explicit absolute deadline (the per-request path;
    /// the service clamps it to the configured default when one is set).
    pub fn offer_deadline(&mut self, payload: T, now_ns: u64, deadline_ns: u64) -> Offer<T> {
        if self.q.len() >= self.cfg.bound {
            return Offer::Shed { payload, depth: self.q.len() };
        }
        let seq = self.seq;
        self.seq += 1;
        self.q.push_back(Ticket { seq, enqueued_ns: now_ns, deadline_ns, payload });
        Offer::Accepted { depth: self.q.len() }
    }

    /// Advance to `now_ns`: expire dead tickets, then cut at most one
    /// batch. Callers loop while `batch` is `Some` (a burst can leave
    /// several full batches queued), then sleep until `next_event_ns` or
    /// the next arrival.
    pub fn poll(&mut self, now_ns: u64) -> Poll<T> {
        // deadline expiry first — an expired ticket must never be counted
        // toward a cut or handed to a replica. Per-ticket deadlines need
        // not be monotone in arrival order, hence the position scan.
        let mut expired = Vec::new();
        while let Some(i) = self.q.iter().position(|t| t.deadline_ns <= now_ns) {
            if let Some(t) = self.q.remove(i) {
                expired.push(t);
            }
        }

        let batch = if self.q.len() >= self.cfg.max_batch {
            let tickets: Vec<Ticket<T>> = self.q.drain(..self.cfg.max_batch).collect();
            Some(Cut { tickets, reason: CutReason::MaxBatch })
        } else if self
            .q
            .front()
            .is_some_and(|t| now_ns >= t.enqueued_ns.saturating_add(self.cfg.max_wait_ns))
        {
            let tickets: Vec<Ticket<T>> = self.q.drain(..).collect();
            Some(Cut { tickets, reason: CutReason::MaxWait })
        } else {
            None
        };

        let next_wait = self
            .q
            .front()
            .map(|t| t.enqueued_ns.saturating_add(self.cfg.max_wait_ns));
        let next_deadline = self
            .q
            .iter()
            .map(|t| t.deadline_ns)
            .filter(|&d| d != NO_DEADLINE)
            .min();
        let next_event_ns = match (next_wait, next_deadline) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        Poll { expired, batch, next_event_ns }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, max_wait_ns: u64, bound: usize, deadline_ns: u64) -> QueueConfig {
        QueueConfig { max_batch, max_wait_ns, bound, deadline_ns }
    }

    #[test]
    fn config_validation() {
        assert!(cfg(0, 1, 1, 0).validate().is_err());
        assert!(cfg(8, 1, 4, 0).validate().is_err()); // bound < max_batch
        assert!(cfg(8, 1, 8, 0).validate().is_ok());
    }

    #[test]
    fn empty_queue_is_quiet() {
        let mut q: BatchQueue<u32> = BatchQueue::new(cfg(4, 100, 16, 0));
        let p = q.poll(1_000);
        assert!(p.expired.is_empty());
        assert!(p.batch.is_none());
        assert_eq!(p.next_event_ns, None);
    }

    #[test]
    fn accept_reports_depth_after_insert() {
        let mut q: BatchQueue<u32> = BatchQueue::new(cfg(4, 100, 16, 0));
        match q.offer(7, 0) {
            Offer::Accepted { depth } => assert_eq!(depth, 1),
            Offer::Shed { .. } => panic!("shed below bound"),
        }
        assert_eq!(q.depth(), 1);
    }
}
